"""AOT exporter smoke tests: HLO text well-formedness + manifest contract.

Runs against a freshly exported *tiny* variant (small batch) so the test
doesn't depend on `make artifacts` having run, plus validates the real
manifest when artifacts/ already exists.
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as graphs
from compile.aot import PRESETS, export_fn, to_hlo_text
from compile.models import get_model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrippable():
    fn = graphs.build_ragek_select(8, 3)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((100,), jnp.float32),
        jax.ShapeDtypeStruct((100,), jnp.int32),
    )
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True: the root computation yields a tuple
    assert "tuple" in text


def test_export_fn_writes_file_and_iface():
    mdl = get_model("mnist")
    fn = graphs.build_eval_batch(mdl)
    with tempfile.TemporaryDirectory() as td:
        meta = export_fn(
            fn,
            (
                jax.ShapeDtypeStruct((mdl.d,), jnp.float32),
                jax.ShapeDtypeStruct((16, 784), jnp.float32),
                jax.ShapeDtypeStruct((16,), jnp.int32),
            ),
            "tiny_eval",
            td,
        )
        assert os.path.exists(os.path.join(td, meta["file"]))
        assert meta["inputs"] == [["f32", [39760]], ["f32", [16, 784]], ["i32", [16]]]
        assert meta["outputs"] == [["f32", []], ["f32", []]]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_complete_and_files_exist():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == 1
    expected_arts = {
        "train_step", "local_round", "local_round_fast", "local_round_grad",
        "grad_topr", "grad", "eval_batch", "apply_sparse", "apply_dense",
        "ragek_select",
    }
    for name, preset in PRESETS.items():
        m = manifest["models"][name]
        assert set(m["artifacts"]) == expected_arts
        assert m["r"] == preset["r"] and m["k"] == preset["k"]
        assert m["k_total"] == preset["n_clients"] * preset["k"]
        for art in m["artifacts"].values():
            path = os.path.join(ART, art["file"])
            assert os.path.exists(path), path
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head
        init = np.fromfile(os.path.join(ART, m["init_params"]), np.float32)
        assert init.shape[0] == m["d"]
        assert np.isfinite(init).all()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_d_matches_table1():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["models"]["mnist"]["d"] == 39760
    assert manifest["models"]["cifar"]["d"] == 2515338
