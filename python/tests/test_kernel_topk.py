"""Top-r kernels vs oracles: exact path, candidate stage, approx path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels.topk import approx_topr_abs, block_topm, topr_abs
from compile.kernels import ref


@given(
    d=st.integers(10, 5000),
    r=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_topr_abs_exact(d, r, seed):
    r = min(r, d)
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    v, i = topr_abs(g, r=r)
    rv, ri = ref.topr_abs_ref(g, r)
    np.testing.assert_array_equal(i, ri)
    np.testing.assert_allclose(v, rv)


def test_topr_abs_paper_dims():
    """The two (d, r) pairs the paper actually runs."""
    rng = np.random.default_rng(0)
    for d, r in [(39760, 75), (2515338, 2500)]:
        g = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        v, i = topr_abs(g, r=r)
        rv, ri = ref.topr_abs_ref(g, r)
        np.testing.assert_array_equal(i, ri)
        np.testing.assert_allclose(v, rv)


def test_topr_abs_ties_prefer_lower_index():
    g = jnp.zeros(100, jnp.float32).at[jnp.array([7, 3, 50])].set(2.0)
    _, i = topr_abs(g, r=3)
    np.testing.assert_array_equal(np.sort(np.asarray(i)), [3, 7, 50])
    # remaining (all-zero ties) would fill from index 0 upward
    _, i5 = topr_abs(g, r=5)
    assert set(np.asarray(i5[:3])) == {3, 7, 50}
    np.testing.assert_array_equal(np.asarray(i5[3:]), [0, 1])


@given(
    d=st.integers(1, 3000),
    m=st.integers(1, 8),
    block=st.sampled_from([64, 256, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_topm_matches_ref(d, m, block, seed):
    m = min(m, block)
    rng = np.random.default_rng(seed)
    # distinct magnitudes so ordering is unambiguous
    g = jnp.asarray(rng.permutation(np.arange(1, d + 1, dtype=np.float32)))
    sign = jnp.asarray(rng.choice([-1.0, 1.0], size=d).astype(np.float32))
    g = g * sign
    v, i = block_topm(g, m=m, block=block)
    rv, ri = ref.block_topm_ref(g, m, block)
    np.testing.assert_array_equal(i, ri)
    np.testing.assert_allclose(v, rv)


def test_approx_topr_exact_when_spread():
    """When each block holds <= m of the top-r, approx == exact."""
    d, block, m, r = 4096, 512, 8, 16
    g = np.zeros(d, np.float32)
    # two hits per block for the first 8 blocks
    for b in range(8):
        g[b * block + 1] = 100.0 + b
        g[b * block + 99] = 50.0 + b
    g = jnp.asarray(g)
    av, ai = approx_topr_abs(g, r=r, m=m, block=block)
    rv, ri = ref.topr_abs_ref(g, r)
    np.testing.assert_array_equal(ai, ri)
    np.testing.assert_allclose(av, rv)


def test_approx_topr_misses_when_concentrated():
    """Documents the known failure mode: > m of the top-r in one block."""
    d, block, m = 2048, 512, 4
    g = np.zeros(d, np.float32)
    g[:8] = np.arange(8, 0, -1)  # 8 biggest all in block 0, m = 4
    av, ai = approx_topr_abs(jnp.asarray(g), r=8, m=m, block=block)
    hit = len(set(np.asarray(ai).tolist()) & set(range(8)))
    assert hit == 4  # only the block's top-m survive


@given(seed=st.integers(0, 2**31 - 1))
def test_approx_topr_recall_random_gradients(seed):
    """On i.i.d. gradients (the realistic case) recall should be high."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(8192,)), jnp.float32)
    r = 32
    _, ai = approx_topr_abs(g, r=r, m=8, block=512)
    _, ri = ref.topr_abs_ref(g, r)
    recall = len(set(np.asarray(ai).tolist()) & set(np.asarray(ri).tolist())) / r
    assert recall >= 0.9
