"""Model-zoo tests: Table I parameter counts, shapes, learning sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import get_model
from compile.models.common import adam_step, eval_stats, xent_mean


def test_param_counts_match_table1():
    """Table I: 39,760 (MNIST MLP) and 2,515,338 (CIFAR10 CNN), exactly."""
    assert get_model("mnist").d == 39760
    assert get_model("cifar").d == 2515338


def test_unknown_model_raises():
    with pytest.raises(KeyError):
        get_model("imagenet")


@pytest.mark.parametrize("name,idim", [("mnist", 784), ("cifar", 3072)])
def test_fwd_shapes(name, idim):
    mdl = get_model(name)
    p = jnp.asarray(mdl.init(0))
    x = jnp.zeros((5, idim), jnp.float32)
    logits = mdl.fwd(p, x)
    assert logits.shape == (5, 10)
    assert jnp.all(jnp.isfinite(logits))


def test_init_deterministic():
    m = get_model("mnist")
    np.testing.assert_array_equal(m.init(42), m.init(42))
    assert not np.array_equal(m.init(42), m.init(43))


def test_mlp_gradient_matches_finite_difference():
    mdl = get_model("mnist")
    rng = np.random.default_rng(0)
    p = jnp.asarray(mdl.init(1))
    x = jnp.asarray(rng.normal(size=(4, 784)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, size=4), jnp.int32)
    g = jax.grad(mdl.loss)(p, x, y)
    eps = 1e-2
    for j in [0, 100, 39000, 39759]:
        e = jnp.zeros_like(p).at[j].set(eps)
        fd = (mdl.loss(p + e, x, y) - mdl.loss(p - e, x, y)) / (2 * eps)
        np.testing.assert_allclose(g[j], fd, rtol=0.05, atol=1e-3)


def test_mlp_learns_toy_problem():
    """A few hundred Adam steps must fit a 2-class toy problem."""
    mdl = get_model("mnist")
    rng = np.random.default_rng(0)
    p = jnp.asarray(mdl.init(0))
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    t = jnp.asarray(0.0)
    x = np.zeros((64, 784), np.float32)
    y = rng.integers(0, 2, size=64).astype(np.int32)
    x[np.arange(64), y * 300] = 5.0  # class signal at two pixels
    x = jnp.asarray(x + rng.normal(size=x.shape) * 0.05)
    y = jnp.asarray(y)

    step = jax.jit(
        lambda p, m, v, t: adam_step(
            p, m, v, t, jax.grad(mdl.loss)(p, x, y), 1e-3
        )
    )
    loss0 = float(mdl.loss(p, x, y))
    for _ in range(300):
        p, m, v, t = step(p, m, v, t)
    loss1 = float(mdl.loss(p, x, y))
    assert loss1 < loss0 * 0.2, (loss0, loss1)
    _, correct = eval_stats(mdl.fwd(p, x), y)
    assert float(correct) >= 60


def test_xent_mean_uniform_logits():
    logits = jnp.zeros((8, 10))
    y = jnp.arange(8, dtype=jnp.int32) % 10
    np.testing.assert_allclose(xent_mean(logits, y), np.log(10.0), rtol=1e-6)


def test_adam_step_closed_form_first_step():
    """After one step from zero state, update = -lr * g/(|g| + eps*corr)."""
    p = jnp.asarray([1.0, -2.0, 0.5])
    g = jnp.asarray([0.3, -0.7, 0.0])
    m = jnp.zeros(3)
    v = jnp.zeros(3)
    lr = 1e-2
    p1, m1, v1, t1 = adam_step(p, m, v, jnp.asarray(0.0), g, lr)
    # bias-corrected first step moves by exactly lr * sign(g) (eps-small)
    expect = p - lr * np.sign(np.asarray(g))
    np.testing.assert_allclose(p1[:2], expect[:2], atol=1e-5)
    np.testing.assert_allclose(p1[2], p[2])
    assert float(t1) == 1.0


def test_cnn_gradient_nonzero_everywhere():
    """Every layer of the CNN must receive gradient signal."""
    mdl = get_model("cifar")
    rng = np.random.default_rng(0)
    p = jnp.asarray(mdl.init(0))
    x = jnp.asarray(rng.normal(size=(2, 3072)), jnp.float32)
    y = jnp.asarray([1, 7], jnp.int32)
    g = np.asarray(jax.grad(mdl.loss)(p, x, y))
    off = 0
    for name, shape in mdl.param_specs:
        n = int(np.prod(shape))
        seg = g[off : off + n]
        assert np.isfinite(seg).all(), name
        assert np.abs(seg).max() > 0, f"dead gradient in {name}"
        off += n
