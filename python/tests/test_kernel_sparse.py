"""Sparse/age kernels vs oracles (eq. 2 semantics live here)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile.kernels.sparse import age_update, masked_reset, scatter_add
from compile.kernels import ref


@given(
    d=st.integers(1, 40000),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_reset_matches_ref(d, seed):
    rng = np.random.default_rng(seed)
    age = jnp.asarray(rng.integers(0, 100, size=d), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=d), jnp.int32)
    got = masked_reset(age, mask)
    want = ref.masked_reset_ref(age, mask)
    np.testing.assert_array_equal(got, want)


@given(
    d=st.integers(4, 10000),
    k=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_age_update_matches_ref(d, k, seed):
    k = min(k, d)
    rng = np.random.default_rng(seed)
    age = jnp.asarray(rng.integers(0, 50, size=d), jnp.int32)
    idx = jnp.asarray(rng.choice(d, size=k, replace=False), jnp.int32)
    got = age_update(age, idx)
    want = ref.age_update_ref(age, idx)
    np.testing.assert_array_equal(got, want)


def test_age_update_invariant_partition():
    """eq. (2): every coordinate is either 0 (selected) or old+1."""
    rng = np.random.default_rng(7)
    age = jnp.asarray(rng.integers(0, 9, size=1000), jnp.int32)
    idx = jnp.asarray([0, 13, 999], jnp.int32)
    new = np.asarray(age_update(age, idx))
    old = np.asarray(age)
    sel = set([0, 13, 999])
    for j in range(1000):
        if j in sel:
            assert new[j] == 0
        else:
            assert new[j] == old[j] + 1


@given(
    d=st.integers(4, 10000),
    k=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_scatter_add_matches_ref(d, k, seed):
    rng = np.random.default_rng(seed)
    dst = jnp.asarray(rng.normal(size=d), jnp.float32)
    idx = jnp.asarray(rng.integers(0, d, size=k), jnp.int32)  # dups allowed
    vals = jnp.asarray(rng.normal(size=k), jnp.float32)
    got = scatter_add(dst, idx, vals)
    want = ref.scatter_add_ref(dst, idx, vals)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_scatter_add_duplicates_accumulate():
    dst = jnp.zeros(4, jnp.float32)
    idx = jnp.asarray([1, 1, 1], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    np.testing.assert_allclose(scatter_add(dst, idx, vals), [0, 6, 0, 0])


def test_scatter_add_zero_padding_is_noop():
    """The aggregation path pads with (idx=0, val=0) entries."""
    dst = jnp.asarray([5.0, 6.0], jnp.float32)
    idx = jnp.zeros(8, jnp.int32)
    vals = jnp.zeros(8, jnp.float32)
    np.testing.assert_array_equal(scatter_add(dst, idx, vals), dst)
