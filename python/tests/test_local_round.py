"""local_round graph semantics: scan of H train steps == H sequential
train_step calls, and the top-r report refers to the LAST step's gradient
(Algorithm 1 lines 4-8)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as graphs
from compile.models import get_model
from compile.kernels.ref import topr_abs_ref

LR = 1e-4


def _data(h, b, seed=0):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(h, b, 784)), jnp.float32)
    ys = jnp.asarray(rng.integers(0, 10, size=(h, b)), jnp.int32)
    return xs, ys


def test_local_round_equals_sequential_steps():
    mdl = get_model("mnist")
    h, b, r = 3, 8, 20
    xs, ys = _data(h, b)
    p = jnp.asarray(mdl.init(0))
    z = jnp.zeros_like(p)
    t = jnp.asarray(0.0)

    round_fn = jax.jit(graphs.build_local_round(mdl, LR, h, r))
    rp, rm, rv, rt, mean_loss, tv, ti = round_fn(p, z, z, t, xs, ys)

    step_fn = jax.jit(graphs.build_train_step(mdl, LR))
    sp, sm, sv, st = p, z, z, t
    losses = []
    for i in range(h):
        sp, sm, sv, st, loss = step_fn(sp, sm, sv, st, xs[i], ys[i])
        losses.append(float(loss))

    np.testing.assert_allclose(rp, sp, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(rm, sm, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(rv, sv, rtol=1e-5, atol=1e-7)
    assert float(rt) == float(st) == h
    np.testing.assert_allclose(float(mean_loss), np.mean(losses), rtol=1e-5)


def test_local_round_topr_is_last_step_gradient():
    mdl = get_model("mnist")
    h, b, r = 2, 8, 25
    xs, ys = _data(h, b, seed=3)
    p = jnp.asarray(mdl.init(1))
    z = jnp.zeros_like(p)
    t = jnp.asarray(0.0)

    round_fn = jax.jit(graphs.build_local_round(mdl, LR, h, r))
    _, _, _, _, _, tv, ti = round_fn(p, z, z, t, xs, ys)

    # replay: params right before the last step
    step_fn = jax.jit(graphs.build_train_step(mdl, LR))
    sp, sm, sv, st = p, z, z, t
    for i in range(h - 1):
        sp, sm, sv, st, _ = step_fn(sp, sm, sv, st, xs[i], ys[i])
    g = jax.grad(mdl.loss)(sp, xs[h - 1], ys[h - 1])
    _, want_i = topr_abs_ref(g, r)
    np.testing.assert_array_equal(ti, want_i)
    # values are the SIGNED gradient entries at the reported indices
    np.testing.assert_allclose(tv, g[want_i], rtol=1e-5, atol=1e-7)


def test_apply_sparse_equals_apply_dense_on_scatter():
    mdl = get_model("mnist")
    d = mdl.d
    rng = np.random.default_rng(0)
    p = jnp.asarray(mdl.init(2))
    z = jnp.zeros_like(p)
    t = jnp.asarray(0.0)
    idx = jnp.asarray(rng.choice(d, size=40, replace=False), jnp.int32)
    vals = jnp.asarray(rng.normal(size=40), jnp.float32)

    sparse_fn = jax.jit(graphs.build_apply_sparse(LR))
    dense_fn = jax.jit(graphs.build_apply_dense(LR))
    update = jnp.zeros((d,), jnp.float32).at[idx].add(vals)

    sp = sparse_fn(p, z, z, t, idx, vals)
    dp = dense_fn(p, z, z, t, update)
    for a, b in zip(sp, dp):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)
