"""Algorithm 2 (rAge-k) semantics: fused graph vs numpy reference.

This is the contract the Rust coordinator mirrors; the tie-breaking rules
asserted here ("value desc, index asc" at both top-r and age-rank stages)
are what make the cross-layer integration tests exact.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile.model import build_ragek_select
from compile.kernels.ref import ragek_select_ref


def numpy_ragek(g, age, r, k):
    """Straight-from-the-paper numpy implementation of Algorithm 2."""
    order = np.lexsort((np.arange(len(g)), -np.abs(g)))  # |g| desc, idx asc
    top_ind = order[:r]
    arank = np.lexsort((np.arange(r), -age[top_ind].astype(np.float64)))
    sel = top_ind[arank[:k]]
    new_age = (age + 1).copy()
    new_age[sel] = 0
    return sel.astype(np.int32), g[sel], new_age


@given(
    d=st.integers(20, 5000),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_graph_matches_numpy(d, seed):
    rng = np.random.default_rng(seed)
    r = min(16, d)
    k = max(1, r // 3)
    # distinct |g| so top-r is unambiguous across implementations
    mags = rng.permutation(np.arange(1, d + 1, dtype=np.float32))
    g = mags * rng.choice([-1.0, 1.0], size=d).astype(np.float32)
    age = rng.integers(0, 30, size=d).astype(np.int32)

    fn = build_ragek_select(r, k)
    sel, vals, new_age = fn(jnp.asarray(g), jnp.asarray(age))
    nsel, nvals, nage = numpy_ragek(g, age, r, k)

    assert sorted(np.asarray(sel).tolist()) == sorted(nsel.tolist())
    np.testing.assert_array_equal(np.asarray(new_age), nage)
    np.testing.assert_allclose(np.sort(np.asarray(vals)), np.sort(nvals))


def test_ref_and_graph_agree():
    rng = np.random.default_rng(1)
    d, r, k = 500, 40, 7
    g = jnp.asarray(rng.normal(size=d), jnp.float32)
    age = jnp.asarray(rng.integers(0, 20, size=d), jnp.int32)
    fn = build_ragek_select(r, k)
    s1, v1, a1 = fn(g, age)
    s2, v2, a2 = ragek_select_ref(g, age, r, k)
    np.testing.assert_array_equal(s1, s2)
    np.testing.assert_allclose(v1, v2)
    np.testing.assert_array_equal(a1, a2)


def test_selected_are_oldest_of_topr():
    """Property: selected indices maximize age among the top-r set."""
    rng = np.random.default_rng(5)
    d, r, k = 1000, 50, 10
    g = rng.normal(size=d).astype(np.float32)
    age = rng.integers(0, 100, size=d).astype(np.int32)
    sel, _, _ = numpy_ragek(g, age, r, k)
    order = np.lexsort((np.arange(d), -np.abs(g)))
    top = order[:r]
    unsel = [j for j in top if j not in set(sel.tolist())]
    assert min(age[sel]) >= max(age[j] for j in unsel) - 0  # allow ties
    # strictly: every unselected top-r index has age <= every selected one
    assert max(age[unsel]) <= max(age[sel])


def test_equal_ages_reduce_to_topk():
    """With a uniform age vector, rAge-k degenerates to top-k (the paper's
    r = k note in §II-A)."""
    rng = np.random.default_rng(2)
    d, r, k = 300, 30, 8
    mags = rng.permutation(np.arange(1, d + 1, dtype=np.float32))
    g = mags * rng.choice([-1.0, 1.0], size=d)
    g = g.astype(np.float32)
    age = np.zeros(d, np.int32)
    sel, _, _ = numpy_ragek(g, age, r, k)
    order = np.lexsort((np.arange(d), -np.abs(g)))
    np.testing.assert_array_equal(np.sort(sel), np.sort(order[:k].astype(np.int32)))


def test_rotation_under_repeated_selection():
    """Ages force exploration: with a static gradient, repeated rAge-k
    rounds rotate through the whole top-r set instead of hammering the
    top-k (the bias the paper attributes to plain top-k)."""
    rng = np.random.default_rng(3)
    d, r, k = 200, 20, 5
    mags = rng.permutation(np.arange(1, d + 1, dtype=np.float32))
    g = (mags * rng.choice([-1.0, 1.0], size=d)).astype(np.float32)
    age = np.zeros(d, np.int32)
    seen = set()
    for _ in range(4):  # r/k = 4 rounds covers the top-r exactly once
        sel, _, age = numpy_ragek(g, age, r, k)
        assert seen.isdisjoint(sel.tolist())
        seen.update(sel.tolist())
    order = np.lexsort((np.arange(d), -np.abs(g)))
    assert seen == set(order[:r].tolist())
