import os
import sys

# Run from python/ (`cd python && pytest tests/`) or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
