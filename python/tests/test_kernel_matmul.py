"""Pallas matmul / dense kernel vs jnp oracle (hypothesis shape sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels.matmul import dense, matmul
from compile.kernels import ref

dims = st.integers(min_value=1, max_value=300)


@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    got = matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize(
    "shape",
    [(1, 1, 1), (256, 784, 50), (256, 50, 10), (64, 2048, 128), (7, 129, 257)],
)
def test_matmul_model_shapes(shape):
    m, k, n = shape
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    np.testing.assert_allclose(
        matmul(x, w), ref.matmul_ref(x, w), rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("tiles", [(8, 8, 8), (16, 32, 64), (128, 128, 128)])
def test_matmul_explicit_small_tiles(tiles):
    """Multi-step grids (the real-TPU tiling shape) stay correct even
    though the exported graphs default to one-step grids."""
    bm, bn, bk = tiles
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(50, 130)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(130, 70)), jnp.float32)
    got = matmul(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=2e-4, atol=2e-4)


def test_matmul_exact_zero_and_identity():
    x = jnp.zeros((16, 16), jnp.float32)
    w = jnp.eye(16, dtype=jnp.float32)
    np.testing.assert_array_equal(matmul(x, w), x)
    x2 = jnp.arange(256, dtype=jnp.float32).reshape(16, 16)
    np.testing.assert_allclose(matmul(x2, w), x2, rtol=1e-6)


def test_dense_forward_and_grad_match_ref():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(32, 100)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(100, 20)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(20,)), jnp.float32)
    np.testing.assert_allclose(
        dense(x, w, b), ref.dense_ref(x, w, b), rtol=2e-4, atol=2e-4
    )

    def f_kernel(x, w, b):
        return jnp.sum(jnp.sin(dense(x, w, b)))

    def f_ref(x, w, b):
        return jnp.sum(jnp.sin(ref.dense_ref(x, w, b)))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=2e-4, atol=2e-4)
