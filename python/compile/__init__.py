"""Build-time Python for the rAge-k stack (never imported at runtime).

``compile.kernels`` — Layer-1 Pallas kernels (+ jnp oracles in
``kernels.ref``); ``compile.models`` — Layer-2 model zoo (Table I);
``compile.model`` — exported-graph builders; ``compile.aot`` — the HLO-text
exporter driven by ``make artifacts``.
"""
