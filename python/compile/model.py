"""Layer-2 graph builders: the functions that become PJRT artifacts.

Each builder returns a pure, pytree-free function (flat tensors in, tuple
out) so the HLO interface is trivially consumable from Rust. All state is
explicit: ``(params[d], m[d], v[d], t[])`` is the client/server Adam
state, ages are i32[d], labels i32[batch].

Exported graphs (per model; shapes baked at lowering time from the
experiment config — see ``compile.aot``):

=================  =============================================================
``train_step``     one local Adam step: (p, m, v, t, x, y) -> (p', m', v', t', loss)
``local_round``    ``lax.scan`` of H train steps; also returns the last step's
                   gradient top-r report — one PJRT call per global round
``grad_topr``      gradient + top-r report at the current params
``grad``           dense gradient (dense baseline + cross-layer tests)
``eval_batch``     (loss_sum, correct_count) over a batch
``apply_sparse``   server Adam on an aggregated sparse update
                   (idx[K], val[K]) scattered into f32[d]
``apply_dense``    server Adam on a dense update vector
``ragek_select``   fused Algorithm 2: (grad, age) -> (sel_idx[k], sel_val[k], age')
=================  =============================================================
"""

import jax
import jax.numpy as jnp

from compile.kernels.sparse import age_update, scatter_add
from compile.kernels.topk import topr_abs
from compile.models.common import ModelDef, adam_step, eval_stats


def build_train_step(model: ModelDef, lr: float):
    def train_step(params, m, v, t, x, y):
        loss, grad = jax.value_and_grad(model.loss)(params, x, y)
        params, m, v, t = adam_step(params, m, v, t, grad, lr)
        return params, m, v, t, loss

    return train_step


def build_local_round(model: ModelDef, lr: float, h: int, r: int):
    """H local Adam steps + the top-r index report of the last gradient.

    Matches Algorithm 1 lines 4-8: the gradient sparsified at a global
    iteration is the one computed in the last local step (t % H == 0).

    The step loop is **unrolled at trace time** rather than `lax.scan`:
    the pinned XLA 0.5.1 CPU backend executes while-loop bodies without
    cross-op fusion (measured 25x slower per step on the CNN —
    EXPERIMENTS.md §Perf); unrolling keeps the whole round one fused
    computation and one PJRT dispatch.
    """

    def local_round(params, m, v, t, xs, ys):
        losses = []
        grad = jnp.zeros_like(params)
        for i in range(h):
            loss, grad = jax.value_and_grad(model.loss)(params, xs[i], ys[i])
            params, m, v, t = adam_step(params, m, v, t, grad, lr)
            losses.append(loss)
        _, top_idx = topr_abs(grad, r=r)
        # report the SIGNED gradient values: the k-subset the PS requests
        # is uploaded straight from this report (Algorithm 1 line 8)
        mean_loss = jnp.mean(jnp.stack(losses))
        return params, m, v, t, mean_loss, grad[top_idx], top_idx

    return local_round


def build_local_round_grad(model: ModelDef, lr: float, h: int):
    """H local Adam steps returning the last *dense* gradient instead of
    its in-graph top-r. Transferring the d-vector (10 MB at CIFAR scale)
    and selecting on the Rust side (heap top-r, ~14 ms at d=2.5M) is ~200x
    cheaper than the in-graph d log d argsort on the pinned XLA CPU
    backend (~2.9 s) — EXPERIMENTS.md §Perf. Unrolled like
    :func:`build_local_round`."""

    def local_round_grad(params, m, v, t, xs, ys):
        losses = []
        grad = jnp.zeros_like(params)
        for i in range(h):
            loss, grad = jax.value_and_grad(model.loss)(params, xs[i], ys[i])
            params, m, v, t = adam_step(params, m, v, t, grad, lr)
            losses.append(loss)
        return params, m, v, t, jnp.mean(jnp.stack(losses)), grad

    return local_round_grad


def build_local_round_fast(model: ModelDef, lr: float, h: int):
    """H local Adam steps without the top-r report — the Delta-payload
    hot path (the report is recomputed from the error-feedback memory on
    the Rust side, so the d log d sort here would be wasted work).
    Unrolled like :func:`build_local_round`."""

    def local_round_fast(params, m, v, t, xs, ys):
        losses = []
        for i in range(h):
            loss, grad = jax.value_and_grad(model.loss)(params, xs[i], ys[i])
            params, m, v, t = adam_step(params, m, v, t, grad, lr)
            losses.append(loss)
        return params, m, v, t, jnp.mean(jnp.stack(losses))

    return local_round_fast


def build_grad_topr(model: ModelDef, r: int):
    def grad_topr(params, x, y):
        loss, grad = jax.value_and_grad(model.loss)(params, x, y)
        _, top_idx = topr_abs(grad, r=r)
        return loss, grad[top_idx], top_idx

    return grad_topr


def build_grad(model: ModelDef):
    def grad_fn(params, x, y):
        loss, grad = jax.value_and_grad(model.loss)(params, x, y)
        return grad, loss

    return grad_fn


def build_eval_batch(model: ModelDef):
    def eval_batch(params, x, y):
        logits = model.fwd(params, x)
        return eval_stats(logits, y)

    return eval_batch


def build_apply_sparse(lr: float):
    """Server optimizer: scatter the aggregated (idx, val) pairs into a
    dense update and take an Adam step on it. Padding entries are
    (idx=0, val=0) no-ops."""

    def apply_sparse(params, m, v, t, idx, vals):
        update = scatter_add(jnp.zeros_like(params), idx, vals)
        return adam_step(params, m, v, t, update, lr)

    return apply_sparse


def build_apply_dense(lr: float):
    def apply_dense(params, m, v, t, update):
        return adam_step(params, m, v, t, update, lr)

    return apply_dense


def build_ragek_select(r: int, k: int):
    """Fused Algorithm 2 (client-side mode + cross-layer oracle):

    top-r by |g|, then the k oldest of those, then the eq. (2) age sweep.
    """

    def ragek_select(grad, age):
        _, top_idx = topr_abs(grad, r=r)
        # stable argsort == lax.top_k tie contract; avoids the TopK HLO op
        # the pinned xla_extension text parser cannot read (see topr_abs)
        rank = jnp.argsort(-age[top_idx].astype(jnp.float32), stable=True)[:k]
        sel = top_idx[rank]
        new_age = age_update(age, sel)
        return sel, grad[sel], new_age

    return ragek_select
