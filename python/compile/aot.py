"""AOT exporter: lower every Layer-2 graph to HLO **text** + manifest.

Run once by ``make artifacts``::

    cd python && python -m compile.aot --out ../artifacts

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

The exporter also dumps deterministic initial parameters
(``<model>_init.bin``, raw little-endian f32) and ``manifest.json``
describing every artifact's interface so the Rust runtime can type-check
calls at load time.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as graphs
from compile.models import get_model

INIT_SEED = 42

# Experiment presets (paper §III-B). CIFAR batch/H are scan-chunked: the
# Rust client loops `local_round` (h_scan steps per PJRT call) to reach the
# paper's H; batch is reduced for the CPU testbed (documented in
# EXPERIMENTS.md).
PRESETS = {
    "mnist": dict(batch=256, h_scan=4, r=75, k=10, n_clients=10, lr=1e-4),
    "cifar": dict(batch=64, h_scan=4, r=2500, k=100, n_clients=6, lr=1e-4),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _iface(entry):
    """JSON-able [dtype, shape] descriptor."""
    dt = {"float32": "f32", "int32": "i32"}[str(entry.dtype)]
    return [dt, list(entry.shape)]


def export_fn(fn, example_args, name, outdir):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(outdir, fname), "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *example_args)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    return {
        "file": fname,
        "inputs": [_iface(a) for a in example_args],
        "outputs": [_iface(o) for o in outs],
    }


def export_model(name: str, outdir: str, cfg: dict) -> dict:
    mdl = get_model(name)
    d = mdl.d
    b, hs, r, k = cfg["batch"], cfg["h_scan"], cfg["r"], cfg["k"]
    n, lr = cfg["n_clients"], cfg["lr"]
    ktot = n * k
    idim = int(np.prod(mdl.input_shape))

    pd = _spec((d,))
    sc = _spec(())
    x1 = _spec((b, idim))
    y1 = _spec((b,), jnp.int32)
    xh = _spec((hs, b, idim))
    yh = _spec((hs, b), jnp.int32)
    age = _spec((d,), jnp.int32)

    arts = {}
    arts["train_step"] = export_fn(
        graphs.build_train_step(mdl, lr), (pd, pd, pd, sc, x1, y1),
        f"{name}_train_step", outdir)
    arts["local_round"] = export_fn(
        graphs.build_local_round(mdl, lr, hs, r), (pd, pd, pd, sc, xh, yh),
        f"{name}_local_round", outdir)
    arts["local_round_fast"] = export_fn(
        graphs.build_local_round_fast(mdl, lr, hs), (pd, pd, pd, sc, xh, yh),
        f"{name}_local_round_fast", outdir)
    arts["local_round_grad"] = export_fn(
        graphs.build_local_round_grad(mdl, lr, hs), (pd, pd, pd, sc, xh, yh),
        f"{name}_local_round_grad", outdir)
    arts["grad_topr"] = export_fn(
        graphs.build_grad_topr(mdl, r), (pd, x1, y1),
        f"{name}_grad_topr", outdir)
    arts["grad"] = export_fn(
        graphs.build_grad(mdl), (pd, x1, y1), f"{name}_grad", outdir)
    arts["eval_batch"] = export_fn(
        graphs.build_eval_batch(mdl), (pd, x1, y1), f"{name}_eval_batch",
        outdir)
    arts["apply_sparse"] = export_fn(
        graphs.build_apply_sparse(lr),
        (pd, pd, pd, sc, _spec((ktot,), jnp.int32), _spec((ktot,))),
        f"{name}_apply_sparse", outdir)
    arts["apply_dense"] = export_fn(
        graphs.build_apply_dense(lr), (pd, pd, pd, sc, pd),
        f"{name}_apply_dense", outdir)
    arts["ragek_select"] = export_fn(
        graphs.build_ragek_select(r, k), (pd, age),
        f"{name}_ragek_select", outdir)

    init = mdl.init(INIT_SEED)
    init_file = f"{name}_init.bin"
    init.tofile(os.path.join(outdir, init_file))

    return {
        "d": d,
        "batch": b,
        "h_scan": hs,
        "r": r,
        "k": k,
        "n_clients": n,
        "k_total": ktot,
        "input_dim": idim,
        "num_classes": mdl.num_classes,
        "lr": lr,
        "init_seed": INIT_SEED,
        "init_params": init_file,
        "artifacts": arts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="mnist,cifar")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": 1, "models": {}}
    for name in args.models.split(","):
        name = name.strip()
        print(f"[aot] exporting {name} ...", flush=True)
        manifest["models"][name] = export_model(name, args.out, PRESETS[name])

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    nfiles = sum(len(m["artifacts"]) for m in manifest["models"].values())
    print(f"[aot] wrote {nfiles} HLO artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
