"""Top-r-by-magnitude selection — the per-client hot spot of rAge-k.

Two paths, both exercised by the test suite:

* :func:`topr_abs` (exact, the default): a streaming Pallas ``|.|`` stage
  (blocked HBM->VMEM elementwise kernel) feeding ``jax.lax.top_k``. On a
  real TPU the Pallas stage fuses ahead of XLA's native TopK; exactness is
  what the convergence result in the paper's §II-A assumes.

* :func:`approx_topr_abs`: the two-stage candidate scheme used by
  large-scale gradient-compression systems (per-block top-m candidates in
  Pallas via an unrolled iterated-max — no data-dependent control flow, so
  it vectorizes on the VPU — then one small ``lax.top_k`` merge over the
  ``nblocks * m`` survivors). Exact whenever every block holds at most m of
  the global top-r; the ablation bench quantifies the recall/latency
  trade-off.

Tie-breaking everywhere is "value desc, index asc" (the ``lax.top_k``
contract); the Rust selection code mirrors it so cross-layer tests can
require exact index equality.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# --------------------------------------------------------------------- abs

def _abs_kernel(g_ref, o_ref):
    o_ref[...] = jnp.abs(g_ref[...])


@functools.partial(jax.jit, static_argnames=("block",))
def abs_blocked(g, *, block: int = 16384):
    """|g| as a blocked streaming Pallas kernel (pads with -1 sentinels,
    slices back). The (8, 128)-aligned default block is 64 KiB of VMEM."""
    d = g.shape[0]
    nblocks = -(-d // block)
    gp = jnp.pad(g, (0, nblocks * block - d), constant_values=-1.0)
    out = pl.pallas_call(
        _abs_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nblocks * block,), jnp.float32),
        interpret=True,
    )(gp)
    return out[:d]


@functools.partial(jax.jit, static_argnames=("r",))
def topr_abs(g, *, r: int):
    """Exact top-r of |g| -> (vals[r], idx[r] i32), descending.

    Lowered as a stable argsort + slice rather than ``lax.top_k``: recent
    jax emits the dedicated ``TopK`` HLO op with a ``largest`` attribute
    that the pinned xla_extension 0.5.1 text parser rejects. A stable
    ascending sort of ``-|g|`` has the identical contract (value desc,
    index asc on ties) and lowers to the classic variadic ``sort`` op.
    """
    a = abs_blocked(g)
    idx = jnp.argsort(-a, stable=True)[:r].astype(jnp.int32)
    return a[idx], idx


# ------------------------------------------------------- blockwise top-m

def _topm_kernel(g_ref, vals_ref, idx_ref, *, m: int, block: int, d: int):
    """Per-block top-m via m unrolled (max, argmax, mask) rounds.

    The loop bound is static, the body is pure vector ops over the VMEM
    block — the TPU-friendly replacement for a CUDA warp-shuffle top-k.
    Padding lanes (global index >= d) are forced to the -1 sentinel so
    they can never outrank real data (|g| >= 0 everywhere).
    """
    base = pl.program_id(0) * block
    lanes = jnp.arange(block, dtype=jnp.int32)
    a = jnp.where(base + lanes < d, jnp.abs(g_ref[...]), -1.0)
    for i in range(m):
        v = jnp.max(a)
        j = jnp.argmax(a).astype(jnp.int32)
        vals_ref[i] = v
        idx_ref[i] = base + j
        a = jnp.where(lanes == j, -jnp.inf, a)


@functools.partial(jax.jit, static_argnames=("m", "block"))
def block_topm(g, *, m: int, block: int = 4096):
    """Per-block top-m of |g| -> (vals[nblocks, m], idx[nblocks, m]).

    Padding lanes carry -1 sentinels so they can never enter a top-m that
    also contains real data (|g| >= 0 everywhere).
    """
    d = g.shape[0]
    nblocks = -(-d // block)
    gp = jnp.pad(g, (0, nblocks * block - d), constant_values=-1.0)
    vals, idx = pl.pallas_call(
        functools.partial(_topm_kernel, m=m, block=block, d=d),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((m,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks * m,), jnp.float32),
            jax.ShapeDtypeStruct((nblocks * m,), jnp.int32),
        ],
        interpret=True,
    )(gp)
    return vals.reshape(nblocks, m), idx.reshape(nblocks, m)


@functools.partial(jax.jit, static_argnames=("r", "m", "block"))
def approx_topr_abs(g, *, r: int, m: int = 8, block: int = 4096):
    """Two-stage approximate top-r: per-block top-m candidates + merge.

    Returns (vals[r], idx[r]); exact iff no block contributes more than m
    of the true top-r. Candidate merge keys on (value, -index) so the
    tie-break contract matches :func:`topr_abs`.
    """
    cand_v, cand_i = block_topm(g, m=m, block=block)
    cand_v = cand_v.reshape(-1)
    cand_i = cand_i.reshape(-1)
    if cand_v.shape[0] < r:
        raise ValueError(
            f"nblocks*m = {cand_v.shape[0]} < r = {r}; increase m or shrink block"
        )
    vals, pos = jax.lax.top_k(cand_v, r)
    return vals, cand_i[pos]
