"""Layer-1 Pallas kernels for the rAge-k stack.

Every kernel here runs with ``interpret=True`` (the CPU PJRT plugin cannot
execute Mosaic custom-calls); on a real TPU the same ``pallas_call``s lower
to Mosaic. Correctness is pinned against the pure-``jnp`` oracles in
:mod:`compile.kernels.ref` by the pytest + hypothesis suite.

Kernels:

* :func:`~compile.kernels.matmul.matmul` — tiled matmul shaped for the
  128x128 MXU; used by the dense layers of both models via
  :func:`~compile.kernels.matmul.dense` (custom VJP, so fwd *and* bwd run
  through the kernel).
* :func:`~compile.kernels.topk.topr_abs` — top-r selection by |g| (the
  per-client hot spot of the rAge-k protocol): a streaming Pallas |.|
  stage feeding ``lax.top_k``; plus the blockwise candidate kernel
  :func:`~compile.kernels.topk.block_topm` powering the approximate mode.
* :func:`~compile.kernels.sparse.masked_reset` — the eq. (2) age update
  ``a' = (a + 1) * (1 - mask)`` as a streaming elementwise kernel.
* :func:`~compile.kernels.sparse.scatter_add` — sparse (idx, val) apply.
"""

from compile.kernels.matmul import matmul, dense
from compile.kernels.topk import topr_abs, block_topm, approx_topr_abs
from compile.kernels.sparse import masked_reset, scatter_add, age_update

__all__ = [
    "matmul",
    "dense",
    "topr_abs",
    "block_topm",
    "approx_topr_abs",
    "masked_reset",
    "scatter_add",
    "age_update",
]
