"""Pure-jnp oracles for the Pallas kernels.

These are the single source of truth for kernel semantics; the pytest +
hypothesis suite asserts ``assert_allclose(kernel(x), ref(x))`` over swept
shapes/values, and the Rust side mirrors the same tie-breaking contract
("value desc, index asc" — what ``jax.lax.top_k`` implements) so Rust, jnp
and Pallas agree bit-for-bit on selection.
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain f32 matmul oracle."""
    return jnp.matmul(x, w)


def dense_ref(x, w, b):
    """Dense layer oracle: x @ w + b."""
    return jnp.matmul(x, w) + b


def topr_abs_ref(g, r):
    """Exact top-r of |g|.

    Returns ``(vals, idx)`` where ``vals = |g|[idx]`` sorted descending and
    ties broken towards the smaller index (the ``lax.top_k`` contract).
    """
    vals, idx = jax.lax.top_k(jnp.abs(g), r)
    return vals, idx.astype(jnp.int32)


def block_topm_ref(g, m, block):
    """Per-block top-m of |g| (candidate stage oracle).

    ``g`` is padded with -1 sentinels to a multiple of ``block``; for each
    block the m largest |value|s and their *global* indices are returned,
    shapes ``(nblocks, m)``.
    """
    d = g.shape[0]
    nblocks = -(-d // block)
    gp = jnp.pad(jnp.abs(g), (0, nblocks * block - d), constant_values=-1.0)
    gb = gp.reshape(nblocks, block)
    vals, idx = jax.lax.top_k(gb, m)
    gidx = idx + (jnp.arange(nblocks) * block)[:, None]
    return vals, gidx.astype(jnp.int32)


def masked_reset_ref(age, mask):
    """eq. (2) oracle: requested indices (mask==1) reset to 0, rest age +1."""
    return (age + 1) * (1 - mask)


def age_update_ref(age, idx):
    """eq. (2) with an index list instead of a dense mask."""
    mask = jnp.zeros_like(age).at[idx].set(1)
    return masked_reset_ref(age, mask)


def scatter_add_ref(dst, idx, vals, scale=1.0):
    """dst + scale * scatter(idx, vals). Duplicate indices accumulate."""
    return dst.at[idx].add(scale * vals)


def ragek_select_ref(g, age, r, k):
    """Algorithm 2 oracle (fused client-side rAge-k).

    1. top-r indices of |g|;
    2. among them, the k with the highest age (ties: smaller *rank* in the
       top-r list, i.e. larger magnitude, wins — the ``lax.top_k``
       contract applied to ``age[top_ind]``);
    3. ages +1 everywhere, then 0 at the selected indices.

    Returns (sel_idx[k], sel_val[k] = g[sel_idx], new_age[d]).
    """
    _, top_ind = jax.lax.top_k(jnp.abs(g), r)
    _, age_rank = jax.lax.top_k(age[top_ind].astype(jnp.float32), k)
    sel = top_ind[age_rank].astype(jnp.int32)
    new_age = age_update_ref(age, sel)
    return sel, g[sel], new_age
