"""Tiled Pallas matmul + dense layer with a kernel-backed custom VJP.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper trains on
GPUs where cuBLAS handles the dense math; on TPU the analogous hot spot is
an MXU-tiled matmul. Blocks default to (128, 128) output tiles with the
contraction dimension streamed through VMEM in ``bk`` slabs — the BlockSpec
grid expresses the HBM->VMEM schedule a CUDA kernel would write with
threadblocks + shared memory. The output tile doubles as the f32
accumulator (revisited across the innermost K grid axis), which is the
MXU accumulate path on real hardware.

Inputs whose dimensions are not tile multiples are zero-padded in the
wrapper and the result sliced back; zero padding is exact for matmul.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, K/bk), K innermost."""
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _fit_tile(requested: int, dim: int, floor: int = 8) -> int:
    """Shrink a tile to the next pow2 >= dim so tiny layers don't pay
    128x zero padding (e.g. the 50-wide MLP hidden layer)."""
    pow2 = 1 << max(0, dim - 1).bit_length()
    return min(requested, max(floor, pow2))


# Default tiles: sized so every dense layer in the model zoo compiles to a
# single-iteration grid. Under interpret=True each grid step lowers to a
# dynamic-slice loop iteration that the pinned XLA 0.5.1 CPU backend
# executes without cross-iteration fusion (~7x slowdown measured on the
# CNN FC stack — EXPERIMENTS.md §Perf); one-step grids run at native dot
# speed. On a real TPU these caps would instead be chosen to fit VMEM
# (~(128, 128) tiles with a 128-slab contraction; see DESIGN.md
# §Hardware-Adaptation) — pass bm/bn/bk explicitly to study that shape.
_BM, _BN, _BK = 256, 1024, 2048


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, w, *, bm: int = _BM, bn: int = _BN, bk: int = _BK):
    """``x[M, K] @ w[K, N] -> [M, N]`` through the Pallas tile kernel."""
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, f"contraction mismatch: {x.shape} @ {w.shape}"
    bm = _fit_tile(bm, m)
    bn = _fit_tile(bn, n)
    bk = _fit_tile(bk, kdim)
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def dense(x, w, b):
    """Dense layer ``x @ w + b`` whose fwd *and* bwd use the Pallas matmul."""
    return matmul(x, w) + b


def _dense_fwd(x, w, b):
    return dense(x, w, b), (x, w)


def _dense_bwd(res, gy):
    x, w = res
    gx = matmul(gy, w.T)
    gw = matmul(x.T, gy)
    gb = jnp.sum(gy, axis=0)
    return gx, gw, gb


dense.defvjp(_dense_fwd, _dense_bwd)
