"""Sparse-update + age-protocol kernels.

* :func:`masked_reset` — eq. (2) of the paper, ``a' = (a + 1) * (1 - m)``,
  as a blocked streaming elementwise Pallas kernel (the d-dimensional age
  sweep the PS performs every global round; d = 2.5M for the CIFAR model).
* :func:`age_update` — eq. (2) taking the selected index list: builds the
  dense mask with an XLA scatter, then streams through ``masked_reset``.
* :func:`scatter_add` — applies a sparse (idx, val) gradient to a dense
  vector. The scatter itself is XLA's native op (data-dependent cross-block
  writes don't map onto a fixed BlockSpec schedule); it is wrapped here so
  the artifact graphs and the oracle tests share one entry point.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masked_reset_kernel(a_ref, m_ref, o_ref):
    o_ref[...] = (a_ref[...] + 1) * (1 - m_ref[...])


@functools.partial(jax.jit, static_argnames=("block",))
def masked_reset(age, mask, *, block: int = 16384):
    """eq. (2): ages +1 everywhere, reset to 0 where mask == 1.

    ``age`` and ``mask`` are i32 vectors of equal length; padding lanes are
    discarded on the way out.
    """
    d = age.shape[0]
    nblocks = -(-d // block)
    pad = nblocks * block - d
    ap = jnp.pad(age, (0, pad))
    mp = jnp.pad(mask, (0, pad))
    out = pl.pallas_call(
        _masked_reset_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nblocks * block,), age.dtype),
        interpret=True,
    )(ap, mp)
    return out[:d]


@jax.jit
def age_update(age, idx):
    """eq. (2) from an index list: mask = onehot(idx); masked_reset."""
    mask = jnp.zeros_like(age).at[idx].set(1)
    return masked_reset(age, mask)


@jax.jit
def scatter_add(dst, idx, vals, scale=1.0):
    """dst + scale * scatter(idx, vals); duplicate indices accumulate."""
    return dst.at[idx].add(scale * vals)
