"""Network 1 of Table I: FC(784,50) -> ReLU -> FC(50,10) -> softmax.

39,760 parameters exactly (784*50 + 50 + 50*10 + 10); the test suite
asserts the count. Dense layers run through the Pallas matmul kernel
(fwd and bwd, via the custom VJP in ``kernels.matmul``).
"""

import jax.numpy as jnp

from compile.kernels.matmul import dense
from compile.models.common import ModelDef

_SPECS = (
    ("fc1.w", (784, 50)),
    ("fc1.b", (50,)),
    ("fc2.w", (50, 10)),
    ("fc2.b", (10,)),
)


def _fwd(flat, x):
    from compile.models.common import unflatten_params

    w1, b1, w2, b2 = unflatten_params(flat, _SPECS)
    h = jnp.maximum(dense(x, w1, b1), 0.0)
    return dense(h, w2, b2)


def mnist_mlp() -> ModelDef:
    return ModelDef(
        name="mnist",
        param_specs=_SPECS,
        input_shape=(784,),
        num_classes=10,
        fwd=_fwd,
    )
