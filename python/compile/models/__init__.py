"""Layer-2 model zoo (Table I of the paper).

Models are defined over a **flat f32[d] parameter vector** — the Rust
coordinator treats every model as an opaque (d, batch, input_shape) triple
and the graphs unflatten internally. ``get_model`` is the registry used by
``compile.aot`` and the tests.
"""

from compile.models.common import ModelDef, flatten_params, unflatten_params
from compile.models.mlp import mnist_mlp
from compile.models.cnn import cifar_cnn

_REGISTRY = {
    "mnist": mnist_mlp,
    "cifar": cifar_cnn,
}


def get_model(name: str) -> ModelDef:
    """Look up a model by registry name ('mnist' | 'cifar')."""
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown model {name!r}; have {sorted(_REGISTRY)}")


__all__ = [
    "ModelDef",
    "get_model",
    "flatten_params",
    "unflatten_params",
    "mnist_mlp",
    "cifar_cnn",
]
