"""Network 2 of Table I: the 2,515,338-parameter CIFAR10 CNN.

Reconstruction notes (DESIGN.md §5): Table I lists
``conv(3,64,3)+BN, maxpool(2,2), conv(64,128,3)+BN, conv(128,256,3)+BN,
conv(256,512,3)+BN, FC(2048,128), FC(128,256), FC(256,512), FC(512,1024),
FC(1024,10)``. The stated total (2,515,338) matches this layer list with a
bias on every conv/FC and 2 learned parameters per BN channel — the test
suite asserts the exact count. FC(2048, .) requires the conv stack to end
at 2x2x512 spatially, which pins the reconstruction to SAME-padded convs
with a 2x2 maxpool after *each* of the four conv+BN groups
(32 -> 16 -> 8 -> 4 -> 2).

BN uses batch statistics (the learned scale/shift are the only BN
parameters in the Table I count, so running stats are not part of the
model state). FC layers run through the Pallas ``dense`` kernel; the convs
stay on XLA's native conv (already MXU-mapped on TPU — see DESIGN.md
§Hardware-Adaptation).
"""

import jax
import jax.numpy as jnp

from compile.kernels.matmul import dense
from compile.models.common import ModelDef, unflatten_params

_SPECS = (
    ("conv1.w", (3, 3, 3, 64)),
    ("conv1.b", (64,)),
    ("bn1.scale", (64,)),
    ("bn1.shift", (64,)),
    ("conv2.w", (3, 3, 64, 128)),
    ("conv2.b", (128,)),
    ("bn2.scale", (128,)),
    ("bn2.shift", (128,)),
    ("conv3.w", (3, 3, 128, 256)),
    ("conv3.b", (256,)),
    ("bn3.scale", (256,)),
    ("bn3.shift", (256,)),
    ("conv4.w", (3, 3, 256, 512)),
    ("conv4.b", (512,)),
    ("bn4.scale", (512,)),
    ("bn4.shift", (512,)),
    ("fc1.w", (2048, 128)),
    ("fc1.b", (128,)),
    ("fc2.w", (128, 256)),
    ("fc2.b", (256,)),
    ("fc3.w", (256, 512)),
    ("fc3.b", (512,)),
    ("fc4.w", (512, 1024)),
    ("fc4.b", (1024,)),
    ("fc5.w", (1024, 10)),
    ("fc5.b", (10,)),
)

_BN_EPS = 1e-5


def _conv(x, w, b):
    """SAME-padded 3x3 conv, NHWC / HWIO."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _bn(x, scale, shift):
    """Batch-norm over (N, H, W) with batch statistics."""
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return scale * (x - mean) * jax.lax.rsqrt(var + _BN_EPS) + shift


def _pool(x):
    """2x2 max pool, stride 2."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


def _fwd(flat, x):
    p = unflatten_params(flat, _SPECS)
    (c1w, c1b, s1, h1, c2w, c2b, s2, h2, c3w, c3b, s3, h3,
     c4w, c4b, s4, h4, f1w, f1b, f2w, f2b, f3w, f3b, f4w, f4b, f5w, f5b) = p
    x = x.reshape(x.shape[0], 32, 32, 3)
    x = _pool(jnp.maximum(_bn(_conv(x, c1w, c1b), s1, h1), 0.0))
    x = _pool(jnp.maximum(_bn(_conv(x, c2w, c2b), s2, h2), 0.0))
    x = _pool(jnp.maximum(_bn(_conv(x, c3w, c3b), s3, h3), 0.0))
    x = _pool(jnp.maximum(_bn(_conv(x, c4w, c4b), s4, h4), 0.0))
    x = x.reshape(x.shape[0], 2 * 2 * 512)
    x = jnp.maximum(dense(x, f1w, f1b), 0.0)
    x = jnp.maximum(dense(x, f2w, f2b), 0.0)
    x = jnp.maximum(dense(x, f3w, f3b), 0.0)
    x = jnp.maximum(dense(x, f4w, f4b), 0.0)
    return dense(x, f5w, f5b)


def cifar_cnn() -> ModelDef:
    return ModelDef(
        name="cifar",
        param_specs=_SPECS,
        input_shape=(3072,),  # flat 32*32*3; reshaped inside fwd
        num_classes=10,
        fwd=_fwd,
    )
