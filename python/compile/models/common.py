"""Shared model machinery: flat-parameter handling, losses, Adam.

The Adam constants here (b1=0.9, b2=0.999, eps=1e-8, lr=1e-4 per the
paper's §III-B) are mirrored exactly by ``rust/src/optimizer/adam.rs``;
the integration suite cross-checks a train step between the two stacks.
"""

import dataclasses
import math
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """Everything the AOT exporter needs to know about a model."""

    name: str
    param_specs: Tuple[Tuple[str, Tuple[int, ...]], ...]
    input_shape: Tuple[int, ...]  # per-sample, e.g. (784,) or (32, 32, 3)
    num_classes: int
    # fwd(flat_params, x[batch, *input_shape]) -> logits[batch, num_classes]
    fwd: Callable

    @property
    def d(self) -> int:
        return sum(int(np.prod(s)) for _, s in self.param_specs)

    def unflatten(self, flat):
        return unflatten_params(flat, self.param_specs)

    def loss(self, flat, x, y):
        """Mean softmax cross-entropy."""
        logits = self.fwd(flat, x)
        return xent_mean(logits, y)

    def init(self, seed: int) -> np.ndarray:
        """He-style init, deterministic in ``seed``; returns flat f32[d]."""
        rng = np.random.default_rng(seed)
        parts = []
        for name, shape in self.param_specs:
            parts.append(_init_one(rng, name, shape))
        return np.concatenate([p.reshape(-1) for p in parts]).astype(np.float32)


def _init_one(rng, name: str, shape: Sequence[int]) -> np.ndarray:
    if name.endswith(".scale"):  # batch-norm scale
        return np.ones(shape, np.float32)
    if name.endswith((".b", ".shift")):  # biases / batch-norm shift
        return np.zeros(shape, np.float32)
    # He-normal over fan-in: conv HWIO -> prod(shape[:-1]); fc (in, out).
    fan_in = int(np.prod(shape[:-1]))
    std = math.sqrt(2.0 / max(1, fan_in))
    return (rng.standard_normal(shape) * std).astype(np.float32)


def flatten_params(parts: Sequence[jnp.ndarray]) -> jnp.ndarray:
    return jnp.concatenate([p.reshape(-1) for p in parts])


def unflatten_params(flat, specs) -> List[jnp.ndarray]:
    out, off = [], 0
    for _, shape in specs:
        n = int(np.prod(shape))
        out.append(flat[off : off + n].reshape(shape))
        off += n
    assert off == flat.shape[0], f"flat vector length {flat.shape[0]} != {off}"
    return out


def xent_mean(logits, y):
    """Mean softmax cross-entropy; y is i32[batch]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    return -jnp.mean(picked)


def eval_stats(logits, y):
    """(summed loss, correct count) over a batch — Rust divides."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    loss_sum = -jnp.sum(picked)
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1).astype(jnp.int32) == y.astype(jnp.int32))
        .astype(jnp.float32)
    )
    return loss_sum, correct


def adam_step(params, m, v, t, grad, lr):
    """One bias-corrected Adam step; t is an f32 scalar step counter."""
    t1 = t + 1.0
    m1 = ADAM_B1 * m + (1.0 - ADAM_B1) * grad
    v1 = ADAM_B2 * v + (1.0 - ADAM_B2) * grad * grad
    mhat = m1 / (1.0 - ADAM_B1**t1)
    vhat = v1 / (1.0 - ADAM_B2**t1)
    new = params - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return new, m1, v1, t1
