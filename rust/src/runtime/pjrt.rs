//! PJRT execution of the AOT HLO-text artifacts via the `xla` crate —
//! compiled only under the `xla-runtime` cargo feature (the bindings are
//! an optional dependency; everything else in the crate, including the
//! pure-Rust backend and the whole coordinator, builds without them).

// Timing external XLA compile/execute calls is inherently wall-clock;
// the clippy.toml clock ban (DESIGN.md §13) targets the deterministic
// simulation layers, not runtime profiling.
#![allow(clippy::disallowed_methods)]

use super::read_f32_file;
use super::{Manifest, ModelManifest};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// A loaded PJRT runtime for one model's artifact set. Artifacts compile
/// **lazily on first call** — the CNN graphs take seconds each to compile
/// single-core, and most drivers touch only 3-4 of the 9 artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    model: ModelManifest,
    executables: std::sync::Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// cumulative (calls, seconds) per artifact — perf-pass instrumentation
    pub stats: crate::util::timer::Profile,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("model", &self.model.name)
            .field(
                "loaded",
                &self.executables.lock().unwrap().keys().cloned().collect::<Vec<_>>(),
            )
            .finish()
    }
}

impl Runtime {
    /// Create a CPU PJRT client over `model`'s artifact set (lazy
    /// compilation — see struct docs).
    pub fn load(artifacts_dir: &str, model_name: &str) -> Result<Self> {
        let dir = PathBuf::from(artifacts_dir);
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let model = manifest
            .models
            .get(model_name)
            .ok_or_else(|| anyhow!("model {model_name:?} not in manifest"))?
            .clone();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Runtime {
            client,
            dir,
            model,
            executables: std::sync::Mutex::new(HashMap::new()),
            stats: crate::util::timer::Profile::new(),
        })
    }

    /// Back-compat alias: load + eagerly compile one artifact.
    pub fn load_one(artifacts_dir: &str, model_name: &str, artifact: &str) -> Result<Self> {
        let rt = Self::load(artifacts_dir, model_name)?;
        rt.ensure_compiled(artifact)?;
        Ok(rt)
    }

    /// Compile `name` if it is not resident yet.
    pub fn ensure_compiled(&self, name: &str) -> Result<()> {
        {
            if self.executables.lock().unwrap().contains_key(name) {
                return Ok(());
            }
        }
        let meta = self
            .model
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
        let path = self.dir.join(&meta.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path utf-8")?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        let secs = t0.elapsed().as_secs_f64();
        self.stats.add(&format!("compile.{name}"), secs);
        crate::debug!("runtime: compiled {name} in {secs:.2}s");
        self.executables.lock().unwrap().insert(name.to_string(), exe);
        Ok(())
    }

    pub fn model(&self) -> &ModelManifest {
        &self.model
    }

    /// Initial parameters dumped by the exporter (raw LE f32).
    pub fn init_params(&self) -> Result<Vec<f32>> {
        let path = self.dir.join(&self.model.init_params);
        read_f32_file(&path, self.model.d)
    }

    /// Execute `name` with the given inputs; shapes are checked against
    /// the manifest; the 1-tuple result is decomposed into output
    /// literals.
    pub fn call(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let meta = self
            .model
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
        if inputs.len() != meta.inputs.len() {
            bail!(
                "{name}: got {} inputs, manifest says {}",
                inputs.len(),
                meta.inputs.len()
            );
        }
        for (i, (lit, want)) in inputs.iter().zip(&meta.inputs).enumerate() {
            let n = lit.element_count();
            let expect: usize = want.shape.iter().product();
            if n != expect {
                bail!("{name}: input {i} has {n} elements, manifest says {expect}");
            }
        }
        self.ensure_compiled(name)?;
        let guard = self.executables.lock().unwrap();
        let exe = guard
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not compiled"))?;
        let t0 = std::time::Instant::now();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: fetching result: {e}"))?;
        let outs = tuple.to_tuple().map_err(|e| anyhow!("{name}: untuple: {e}"))?;
        self.stats.add(name, t0.elapsed().as_secs_f64());
        if outs.len() != meta.outputs.len() {
            bail!(
                "{name}: got {} outputs, manifest says {}",
                outs.len(),
                meta.outputs.len()
            );
        }
        Ok(outs)
    }
}

// ---------------------------------------------------------------- literal helpers

/// f32 slice -> literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = shape.iter().product();
    if data.len() as i64 != expect {
        bail!("lit_f32: {} values for shape {shape:?}", data.len());
    }
    if shape.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    xla::Literal::vec1(data)
        .reshape(shape)
        .map_err(|e| anyhow!("reshape {shape:?}: {e}"))
}

/// i32 slice -> literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = shape.iter().product();
    if data.len() as i64 != expect {
        bail!("lit_i32: {} values for shape {shape:?}", data.len());
    }
    if shape.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    xla::Literal::vec1(data)
        .reshape(shape)
        .map_err(|e| anyhow!("reshape {shape:?}: {e}"))
}

/// f32 scalar literal.
pub fn lit_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// literal -> Vec<f32>.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))
}

/// literal -> Vec<i32>.
pub fn to_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))
}

/// literal -> f32 scalar.
pub fn to_scalar(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("scalar: {e}"))
}
