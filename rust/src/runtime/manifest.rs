//! `artifacts/manifest.json` schema: what the AOT exporter promises about
//! every HLO artifact (interface shapes/dtypes, model hyper-parameters,
//! initial-parameter dump). The runtime type-checks calls against this.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorIface {
    /// "f32" | "i32"
    pub dtype: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<TensorIface>,
    pub outputs: Vec<TensorIface>,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub d: usize,
    pub batch: usize,
    pub h_scan: usize,
    pub r: usize,
    pub k: usize,
    pub n_clients: usize,
    pub k_total: usize,
    pub input_dim: usize,
    pub num_classes: usize,
    pub lr: f64,
    pub init_params: String,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelManifest>,
}

fn iface(j: &Json) -> Result<TensorIface> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("iface not an array"))?;
    if arr.len() != 2 {
        bail!("iface must be [dtype, shape]");
    }
    let dtype = arr[0].as_str().ok_or_else(|| anyhow!("dtype"))?.to_string();
    let shape = arr[1]
        .as_arr()
        .ok_or_else(|| anyhow!("shape"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("dim")))
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorIface { dtype, shape })
}

impl Manifest {
    pub fn parse(j: &Json) -> Result<Manifest> {
        let fmt = j.get("format").and_then(Json::as_usize).unwrap_or(0);
        if fmt != 1 {
            bail!("unsupported manifest format {fmt}");
        }
        let mut models = BTreeMap::new();
        let mobj = j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing models"))?;
        for (name, mj) in mobj {
            let need = |key: &str| -> Result<usize> {
                mj.get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("model {name}: missing {key}"))
            };
            let mut artifacts = BTreeMap::new();
            let aobj = mj
                .get("artifacts")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow!("model {name}: missing artifacts"))?;
            for (aname, aj) in aobj {
                let inputs = aj
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{aname}: inputs"))?
                    .iter()
                    .map(iface)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = aj
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{aname}: outputs"))?
                    .iter()
                    .map(iface)
                    .collect::<Result<Vec<_>>>()?;
                artifacts.insert(
                    aname.clone(),
                    ArtifactMeta {
                        file: aj
                            .get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("{aname}: file"))?
                            .to_string(),
                        inputs,
                        outputs,
                    },
                );
            }
            models.insert(
                name.clone(),
                ModelManifest {
                    name: name.clone(),
                    d: need("d")?,
                    batch: need("batch")?,
                    h_scan: need("h_scan")?,
                    r: need("r")?,
                    k: need("k")?,
                    n_clients: need("n_clients")?,
                    k_total: need("k_total")?,
                    input_dim: need("input_dim")?,
                    num_classes: need("num_classes")?,
                    lr: mj.get("lr").and_then(Json::as_f64).unwrap_or(1e-4),
                    init_params: mj
                        .get("init_params")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("model {name}: init_params"))?
                        .to_string(),
                    artifacts,
                },
            );
        }
        Ok(Manifest { models })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        Self::parse(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "models": {
        "mnist": {
          "d": 39760, "batch": 256, "h_scan": 4, "r": 75, "k": 10,
          "n_clients": 10, "k_total": 100, "input_dim": 784,
          "num_classes": 10, "lr": 0.0001, "init_seed": 42,
          "init_params": "mnist_init.bin",
          "artifacts": {
            "eval_batch": {
              "file": "mnist_eval_batch.hlo.txt",
              "inputs": [["f32", [39760]], ["f32", [256, 784]], ["i32", [256]]],
              "outputs": [["f32", []], ["f32", []]]
            }
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(&Json::parse(SAMPLE).unwrap()).unwrap();
        let mm = &m.models["mnist"];
        assert_eq!(mm.d, 39760);
        assert_eq!(mm.k_total, 100);
        let a = &mm.artifacts["eval_batch"];
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[1].shape, vec![256, 784]);
        assert_eq!(a.inputs[2].dtype, "i32");
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
    }

    #[test]
    fn rejects_wrong_format() {
        let j = Json::parse(r#"{"format": 9, "models": {}}"#).unwrap();
        assert!(Manifest::parse(&j).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let j = Json::parse(r#"{"format": 1, "models": {"m": {"d": 5}}}"#).unwrap();
        assert!(Manifest::parse(&j).is_err());
    }
}
