//! Runtime layer: the artifact manifest schema (always available — the
//! CLI's `info` command and the build tooling only need metadata) and the
//! PJRT executor for the AOT HLO-text artifacts produced by
//! `make artifacts` (behind the `xla-runtime` cargo feature, since the
//! `xla` crate is an optional dependency). This is the only place Python
//! output crosses into the Rust request path — as compiled executables,
//! never as a process.

pub mod manifest;

pub use manifest::{ArtifactMeta, Manifest, ModelManifest};

#[cfg(feature = "xla-runtime")]
mod pjrt;

#[cfg(feature = "xla-runtime")]
pub use pjrt::{lit_f32, lit_i32, lit_scalar, to_f32, to_i32, to_scalar, Runtime};

use anyhow::{bail, Context, Result};
use std::path::Path;

/// Read a raw little-endian f32 file of exactly `n` values.
pub fn read_f32_file(path: &Path, n: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() != n * 4 {
        bail!("{path:?}: {} bytes, expected {}", bytes.len(), n * 4);
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}
