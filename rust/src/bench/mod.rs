//! Micro-benchmark harness (criterion is not in the offline registry):
//! warmup + timed iterations, mean/median/p99 + throughput reporting,
//! and a tabular printer shared by every `rust/benches/*.rs` target.

// Measuring wall time is this module's whole purpose; the clippy.toml
// clock ban (DESIGN.md §13) protects the deterministic layers, not this.
#![allow(clippy::disallowed_methods)]

use crate::util::{mean, percentile, stddev};
use std::time::Instant;

/// One benchmark's collected timings.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub secs: Vec<f64>,
    /// optional work units per iteration (elements, bytes, ...) for
    /// throughput reporting
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        mean(&self.secs)
    }

    pub fn median(&self) -> f64 {
        percentile(&self.secs, 50.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.secs, 99.0)
    }

    pub fn stddev(&self) -> f64 {
        stddev(&self.secs)
    }

    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / self.mean())
    }
}

/// Format seconds human-readably.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:8.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:8.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:8.3}ms", s * 1e3)
    } else {
        format!("{s:8.3}s ")
    }
}

/// The harness: `Bench::new("suite").run("case", || work())`.
pub struct Bench {
    suite: String,
    /// minimum wall time to spend measuring each case
    pub min_secs: f64,
    pub warmup_iters: usize,
    pub results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        println!("\n== bench suite: {suite} ==");
        println!(
            "{:<42} {:>10} {:>10} {:>10} {:>8}",
            "case", "mean", "median", "p99", "iters"
        );
        Bench {
            suite: suite.to_string(),
            min_secs: std::env::var("BENCH_MIN_SECS")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(0.5),
            warmup_iters: 2,
            results: Vec::new(),
        }
    }

    /// Time `f` until `min_secs` of samples accumulate (at least 3 iters).
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.run_units(name, None, move || {
            std::hint::black_box(f());
        })
    }

    /// Time `f` exactly once, no warmup — for end-to-end harnesses whose
    /// body is itself a full (expensive, stateful) experiment run.
    pub fn run_once(&mut self, name: &str, f: impl FnOnce()) -> &BenchResult {
        let t0 = Instant::now();
        f();
        let res = BenchResult {
            name: name.to_string(),
            iters: 1,
            secs: vec![t0.elapsed().as_secs_f64()],
            units_per_iter: None,
        };
        println!(
            "{:<42} {} {} {} {:>8}",
            res.name,
            fmt_time(res.mean()),
            fmt_time(res.median()),
            fmt_time(res.p99()),
            res.iters
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Like `run`, with a throughput denominator (units per iteration).
    pub fn run_units(
        &mut self,
        name: &str,
        units_per_iter: Option<f64>,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut secs = Vec::new();
        let t_start = Instant::now();
        loop {
            let t0 = Instant::now();
            f();
            secs.push(t0.elapsed().as_secs_f64());
            if secs.len() >= 3 && t_start.elapsed().as_secs_f64() > self.min_secs {
                break;
            }
            if secs.len() >= 10_000 {
                break;
            }
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: secs.len(),
            secs,
            units_per_iter,
        };
        let tput = res
            .throughput()
            .map(|t| format!("  {:>12.1} unit/s", t))
            .unwrap_or_default();
        println!(
            "{:<42} {} {} {} {:>8}{tput}",
            res.name,
            fmt_time(res.mean()),
            fmt_time(res.median()),
            fmt_time(res.p99()),
            res.iters
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Dump all results as JSON (consumed by EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("suite", Json::Str(self.suite.clone())),
            (
                "results",
                Json::Arr(
                    self.results
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("name", Json::Str(r.name.clone())),
                                ("mean_s", Json::Num(r.mean())),
                                ("median_s", Json::Num(r.median())),
                                ("p99_s", Json::Num(r.p99())),
                                ("iters", Json::Num(r.iters as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write results JSON under results/bench/.
    pub fn save(&self) {
        let dir = std::path::Path::new("results/bench");
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(format!("{}.json", self.suite.replace('/', "_")));
            let _ = std::fs::write(&path, self.to_json().to_pretty());
            println!("  -> {}", path.display());
        }
    }
}

/// The shared sharded-topology bench scenario (used by `bench_end2end`
/// and `bench_sharding`, so the config and the parallelism threshold
/// cannot drift apart).
pub mod sharding {
    use super::Bench;
    use crate::clustering::MergeRule;
    use crate::config::ExperimentConfig;
    use crate::coordinator::strategies::StrategyKind;
    use crate::coordinator::topology::Topology;
    use crate::fl::metrics::CommStats;
    use crate::fl::trainer::build_sharded_inprocess;
    use anyhow::Result;

    /// The standard multi-core scenario: 8 MNIST clients, **one serial
    /// client lane per shard** so the shard level is the only
    /// parallelism left. `shards = 0` = flat.
    pub fn scenario(shards: usize, rounds: usize) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::mnist_scaled();
        cfg.strategy = StrategyKind::RageK;
        cfg.n_clients = 8;
        cfg.parallel = 1;
        cfg.rounds = rounds;
        cfg.train_n = 2000;
        cfg.test_n = 256;
        cfg.eval_every = 0;
        if shards > 0 {
            cfg.topology = Topology::Sharded { shards, root_merge: MergeRule::Min };
        }
        cfg
    }

    /// Time the serial-vs-parallel shard drive at 4 shards and — on any
    /// host with >= 2 cores — assert the scoped-thread driver beats the
    /// serial sum of the shard collects by at least 10%. Returns
    /// `(serial_secs, parallel_secs, parallel_run_comm)` so callers can
    /// also pin the zero-extra-bytes roll-up.
    pub fn drive_comparison(b: &mut Bench, rounds: usize) -> Result<(f64, f64, CommStats)> {
        let cfg4 = scenario(4, rounds);
        let (mut e_ser, mut p_ser) = build_sharded_inprocess(&cfg4)?;
        let serial = b
            .run_once(&format!("{rounds} rounds n=8 sharded x4, serial shard drive"), || {
                for _ in 0..rounds {
                    e_ser.run_round_serial(&mut p_ser).unwrap();
                }
            })
            .mean();
        let (mut e_par, mut p_par) = build_sharded_inprocess(&cfg4)?;
        let parallel = b
            .run_once(&format!("{rounds} rounds n=8 sharded x4, parallel shard drive"), || {
                for _ in 0..rounds {
                    e_par.run_round(&mut p_par).unwrap();
                }
            })
            .mean();
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        println!(
            "shard drive: serial sum {serial:.3}s vs parallel {parallel:.3}s \
             ({:.2}x on {cores} cores)",
            serial / parallel
        );
        // hard gate only where one shard thread per core leaves ample
        // margin (4 shards; expected ~0.3x there) — a loaded 2-core
        // runner's single sample is too noisy to fail the build on
        if cores >= 4 {
            assert!(
                parallel < serial * 0.9,
                "shard rounds must execute in parallel: parallel {parallel:.3}s vs \
                 serial sum {serial:.3}s on {cores} cores"
            );
        }
        Ok((serial, parallel, e_par.comm()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples() {
        let mut b = Bench::new("selftest");
        b.min_secs = 0.01;
        let r = b.run("noop", || 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.mean() >= 0.0);
        let j = b.to_json();
        assert_eq!(j.at(&["results"]).as_arr().unwrap().len(), 1);
    }

    #[test]
    fn formats_scales() {
        assert!(fmt_time(2e-9).contains("ns"));
        assert!(fmt_time(2e-5).contains("µs"));
        assert!(fmt_time(2e-2).contains("ms"));
        assert!(fmt_time(2.0).contains("s"));
    }
}
