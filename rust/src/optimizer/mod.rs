//! Server-side optimizer applied to the aggregated update g~ (Algorithm 1
//! line 11 — "update global model theta^t based on g~"; the paper does
//! not pin the server rule, so it is pluggable: Adam matches the client
//! optimizer and is the default, SGD is the ablation).

use crate::nn::adam::AdamState;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServerOptKind {
    Sgd { lr: f32 },
    Adam { lr: f32 },
}

/// Stateful server optimizer over the flat global parameter vector.
#[derive(Debug)]
pub enum ServerOpt {
    Sgd { lr: f32 },
    Adam { lr: f32, state: AdamState },
}

impl ServerOpt {
    pub fn new(kind: ServerOptKind, d: usize) -> Self {
        match kind {
            ServerOptKind::Sgd { lr } => ServerOpt::Sgd { lr },
            ServerOptKind::Adam { lr } => ServerOpt::Adam { lr, state: AdamState::new(d) },
        }
    }

    /// Apply a dense aggregated update as the "gradient".
    pub fn apply_dense(&mut self, params: &mut [f32], update: &[f32]) {
        match self {
            ServerOpt::Sgd { lr } => {
                for (p, &u) in params.iter_mut().zip(update) {
                    *p -= *lr * u;
                }
            }
            ServerOpt::Adam { lr, state } => state.step(params, update, *lr),
        }
    }

    /// Adam state access for the XLA-backed path (`apply_*` artifacts own
    /// the state tensors; the trainer keeps them in sync through here).
    pub fn adam_state_mut(&mut self) -> Option<&mut AdamState> {
        match self {
            ServerOpt::Adam { state, .. } => Some(state),
            ServerOpt::Sgd { .. } => None,
        }
    }

    pub fn kind(&self) -> ServerOptKind {
        match self {
            ServerOpt::Sgd { lr } => ServerOptKind::Sgd { lr: *lr },
            ServerOpt::Adam { lr, .. } => ServerOptKind::Adam { lr: *lr },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_step() {
        let mut opt = ServerOpt::new(ServerOptKind::Sgd { lr: 0.1 }, 3);
        let mut p = vec![1.0f32, 2.0, 3.0];
        opt.apply_dense(&mut p, &[1.0, 0.0, -1.0]);
        assert_eq!(p, vec![0.9, 2.0, 3.1]);
    }

    #[test]
    fn adam_matches_raw_state() {
        let mut opt = ServerOpt::new(ServerOptKind::Adam { lr: 0.01 }, 2);
        let mut p1 = vec![1.0f32, -1.0];
        let mut p2 = p1.clone();
        let g = vec![0.5f32, 0.25];
        opt.apply_dense(&mut p1, &g);
        let mut st = AdamState::new(2);
        st.step(&mut p2, &g, 0.01);
        assert_eq!(p1, p2);
    }

    #[test]
    fn kind_roundtrip() {
        let opt = ServerOpt::new(ServerOptKind::Adam { lr: 0.5 }, 1);
        assert_eq!(opt.kind(), ServerOptKind::Adam { lr: 0.5 });
    }
}
