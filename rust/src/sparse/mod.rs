//! Sparse vectors and top-k selection — the L3 hot-path primitives.
//!
//! Tie-breaking contract everywhere: **value descending, index ascending**
//! (what `jax.lax.top_k` implements), so the Rust coordinator, the jnp
//! oracles and the HLO artifacts agree exactly (cross-checked in
//! `rust/tests/integration_runtime.rs`).

/// A sparse gradient: parallel (indices, values), indices unique unless
/// produced by aggregation with `merge = false`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SparseVec {
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
}

impl SparseVec {
    pub fn new(idx: Vec<u32>, val: Vec<f32>) -> Self {
        assert_eq!(idx.len(), val.len());
        SparseVec { idx, val }
    }

    pub fn len(&self) -> usize {
        self.idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    /// Materialize into a dense vector of length `d`, accumulating
    /// duplicate indices.
    pub fn to_dense(&self, d: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; d];
        self.add_into(&mut out, 1.0);
        out
    }

    /// `dense += scale * self`.
    pub fn add_into(&self, dense: &mut [f32], scale: f32) {
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            dense[i as usize] += scale * v;
        }
    }

    /// Wire size in bytes under the **raw** v1 codec (4 B index + 4 B
    /// value per entry) — the protocol-semantic uplink cost model of
    /// DESIGN.md §6. The packed v2 codec ships the same entries as
    /// delta+varint index blocks (~1–2 B per index; see
    /// `fl::codec::index_block_bytes`) plus f32 or f16 values; exact
    /// per-frame sizes live in `fl::transport::update_frame_bytes`.
    pub fn wire_bytes(&self) -> usize {
        self.len() * 8
    }

    /// L2 norm.
    pub fn norm(&self) -> f32 {
        self.val.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

/// Exact top-k indices of `score` (value desc, index asc), k <= len.
/// O(n log k) via a bounded min-heap; the k = n case short-circuits to a
/// sort. Returns indices ordered by descending score.
pub fn topk_indices(score: &[f32], k: usize) -> Vec<u32> {
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    assert!(k <= score.len(), "topk: k={k} > n={}", score.len());
    if k == 0 {
        return Vec::new();
    }

    // Heap entry ordered so the heap root is the *worst* kept element:
    // smallest value, then largest index.
    #[derive(PartialEq)]
    struct Entry(f32, u32);
    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, o: &Self) -> Ordering {
            // reversed: BinaryHeap is a max-heap, we want min-by-(val, -idx)
            o.0.partial_cmp(&self.0)
                .unwrap_or(Ordering::Equal)
                .then_with(|| self.1.cmp(&o.1))
        }
    }

    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(k + 1);
    for (i, &v) in score.iter().enumerate() {
        let e = Entry(v, i as u32);
        if heap.len() < k {
            heap.push(e);
        } else if let Some(worst) = heap.peek() {
            // keep e if it beats the worst kept: higher value, or equal
            // value with lower index
            let beats = v > worst.0 || (v == worst.0 && (i as u32) < worst.1);
            if beats {
                heap.pop();
                heap.push(e);
            }
        }
    }
    let mut kept: Vec<Entry> = heap.into_vec();
    kept.sort_by(|a, b| {
        b.0.partial_cmp(&a.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.1.cmp(&b.1))
    });
    kept.into_iter().map(|e| e.1).collect()
}

/// Top-k by |value| of a dense gradient -> SparseVec carrying the *signed*
/// values (the client-side top-k / top-r primitive).
pub fn topk_abs_sparse(g: &[f32], k: usize) -> SparseVec {
    let abs: Vec<f32> = g.iter().map(|v| v.abs()).collect();
    let idx = topk_indices(&abs, k);
    let val = idx.iter().map(|&i| g[i as usize]).collect();
    SparseVec { idx, val }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn oracle_topk(score: &[f32], k: usize) -> Vec<u32> {
        let mut order: Vec<u32> = (0..score.len() as u32).collect();
        order.sort_by(|&a, &b| {
            score[b as usize]
                .partial_cmp(&score[a as usize])
                .unwrap()
                .then_with(|| a.cmp(&b))
        });
        order.truncate(k);
        order
    }

    #[test]
    fn matches_sort_oracle() {
        let mut rng = crate::util::rng::Rng::new(0);
        for _ in 0..50 {
            let n = 1 + rng.below(200);
            let k = rng.below(n + 1);
            let mut score = vec![0.0f32; n];
            for v in score.iter_mut() {
                // coarse quantization to force ties
                *v = (rng.gaussian() * 3.0).round() as f32;
            }
            assert_eq!(topk_indices(&score, k), oracle_topk(&score, k));
        }
    }

    #[test]
    fn tie_break_low_index_wins() {
        let score = [1.0f32, 5.0, 5.0, 5.0, 0.0];
        assert_eq!(topk_indices(&score, 2), vec![1, 2]);
    }

    #[test]
    fn topk_abs_keeps_signed_values() {
        let g = [0.1f32, -9.0, 3.0, -0.5];
        let s = topk_abs_sparse(&g, 2);
        assert_eq!(s.idx, vec![1, 2]);
        assert_eq!(s.val, vec![-9.0, 3.0]);
    }

    #[test]
    fn dense_roundtrip_and_duplicates() {
        let s = SparseVec::new(vec![1, 3, 1], vec![2.0, -1.0, 0.5]);
        let d = s.to_dense(5);
        assert_eq!(d, vec![0.0, 2.5, 0.0, -1.0, 0.0]);
        assert_eq!(s.wire_bytes(), 24);
    }

    #[test]
    fn add_into_scales() {
        let s = SparseVec::new(vec![0, 2], vec![1.0, 1.0]);
        let mut dense = vec![1.0f32; 3];
        s.add_into(&mut dense, 0.5);
        assert_eq!(dense, vec![1.5, 1.0, 1.5]);
    }

    #[test]
    fn empty_and_full_k() {
        assert!(topk_indices(&[1.0, 2.0], 0).is_empty());
        assert_eq!(topk_indices(&[1.0, 2.0], 2), vec![1, 0]);
    }
}
