//! Pure-Rust neural-net substrate: the MNIST MLP (Network 1 of Table I)
//! with hand-written backprop, the Adam optimizer, and the softmax
//! cross-entropy loss.
//!
//! This is the artifact-free compute backend (`backend::RustBackend`) —
//! it trains the paper's MNIST experiments with no Python anywhere, keeps
//! the test suite independent of `make artifacts`, and doubles as the
//! numerics oracle the PJRT runtime is validated against (constants here
//! mirror `python/compile/models/common.py` exactly).

pub mod adam;
pub mod loss;
pub mod mlp;

/// Basic row-major matmul helpers shared by the MLP fwd/bwd passes.
/// (ikj loop order for cache-friendliness; hot enough to matter in the
/// simulator but not worth SIMD intrinsics — see EXPERIMENTS.md §Perf.)
pub(crate) fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            // no zero-skip branch here: it defeats autovectorization of
            // the inner FMA loop, a net loss even on relu-sparse inputs
            // (EXPERIMENTS.md §Perf)
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// out = a^T @ b where a is [m, k] (so out is [k, n]).
pub(crate) fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), k * n);
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// out = a @ b^T where b is [n, k], a is [m, k] (out [m, n]).
pub(crate) fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (av, bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            out[i * n + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_2x2() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [1.0, 1.0, 1.0, 1.0];
        let mut out = [0.0f32; 4];
        matmul(&a, &b, 2, 2, 2, &mut out);
        assert_eq!(out, [3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transposed_variants_agree() {
        let mut rng = crate::util::rng::Rng::new(0);
        let (m, k, n) = (5, 7, 3);
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        rng.fill_gaussian(&mut a, 1.0);
        rng.fill_gaussian(&mut b, 1.0);
        let mut want = vec![0.0f32; m * n];
        matmul(&a, &b, m, k, n, &mut want);

        // a^T path: at is [k, m]; (a^T)^T @ b  via matmul_tn(at ...)
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let mut got = vec![0.0f32; m * n];
        matmul_tn(&at, &b, k, m, n, &mut got);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }

        // b^T path
        let mut bt = vec![0.0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        let mut got2 = vec![0.0f32; m * n];
        matmul_nt(&a, &bt, m, k, n, &mut got2);
        for (x, y) in got2.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }
}
