//! Adam optimizer over flat parameter vectors; constants mirror
//! `python/compile/models/common.py` (b1=0.9, b2=0.999, eps=1e-8) so the
//! Rust backend and the HLO artifacts take bit-comparable steps.

pub const B1: f32 = 0.9;
pub const B2: f32 = 0.999;
pub const EPS: f32 = 1e-8;

/// Flat Adam state (m, v, step count t).
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: f32,
}

impl AdamState {
    pub fn new(d: usize) -> Self {
        AdamState { m: vec![0.0; d], v: vec![0.0; d], t: 0.0 }
    }

    /// One bias-corrected step: `params -= lr * mhat / (sqrt(vhat) + eps)`.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1.0;
        let bc1 = 1.0 - B1.powf(self.t);
        let bc2 = 1.0 - B2.powf(self.t);
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * g;
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + EPS);
        }
    }

    /// Sparse step: only the coordinates in `idx` carry gradient; all
    /// other coordinates still receive the moment decay (exactly what the
    /// dense step does with g = 0 there). Used by the server optimizer on
    /// aggregated sparse updates when `sparse_moment_decay` is enabled;
    /// the default server path materializes dense (matching the
    /// `apply_sparse` artifact) — see `optimizer::ServerOpt`.
    pub fn step_sparse_exact(
        &mut self,
        params: &mut [f32],
        idx: &[u32],
        val: &[f32],
        lr: f32,
    ) {
        let mut grad = vec![0.0f32; params.len()];
        for (&i, &v) in idx.iter().zip(val) {
            grad[i as usize] += v;
        }
        self.step(params, &grad, lr);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_moves_by_lr_sign() {
        // bias-corrected first step ~= lr * sign(g)
        let mut p = vec![1.0f32, -2.0, 0.5];
        let g = vec![0.3f32, -0.7, 0.0];
        let mut st = AdamState::new(3);
        st.step(&mut p, &g, 0.01);
        assert!((p[0] - (1.0 - 0.01)).abs() < 1e-5);
        assert!((p[1] - (-2.0 + 0.01)).abs() < 1e-5);
        assert_eq!(p[2], 0.5);
        assert_eq!(st.t, 1.0);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize (x - 3)^2 -> x = 3
        let mut p = vec![0.0f32];
        let mut st = AdamState::new(1);
        for _ in 0..4000 {
            let g = vec![2.0 * (p[0] - 3.0)];
            st.step(&mut p, &g, 0.01);
        }
        assert!((p[0] - 3.0).abs() < 0.05, "got {}", p[0]);
    }

    #[test]
    fn sparse_exact_matches_dense() {
        let mut p1 = vec![1.0f32; 6];
        let mut p2 = p1.clone();
        let mut s1 = AdamState::new(6);
        let mut s2 = AdamState::new(6);
        let mut dense = vec![0.0f32; 6];
        dense[2] = 0.5;
        dense[4] = -1.0;
        for _ in 0..3 {
            s1.step(&mut p1, &dense, 0.01);
            s2.step_sparse_exact(&mut p2, &[2, 4], &[0.5, -1.0], 0.01);
        }
        assert_eq!(p1, p2);
        assert_eq!(s1.m, s2.m);
    }
}
