//! Softmax cross-entropy (mean over batch) + eval statistics, numerically
//! stable (log-sum-exp), mirroring `python/compile/models/common.py`.

/// logits: [b, c] row-major. Returns (mean loss, dlogits [b, c]) where
/// dlogits is the gradient of the *mean* loss.
pub fn xent_mean_with_grad(logits: &[f32], y: &[i32], c: usize) -> (f32, Vec<f32>) {
    let b = y.len();
    assert_eq!(logits.len(), b * c);
    let mut dlogits = vec![0.0f32; b * c];
    let mut loss = 0.0f64;
    for i in 0..b {
        let row = &logits[i * c..(i + 1) * c];
        let maxv = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut sum = 0.0f32;
        for &v in row {
            sum += (v - maxv).exp();
        }
        let lse = maxv + sum.ln();
        let yi = y[i] as usize;
        assert!(yi < c, "label {yi} out of range");
        loss += (lse - row[yi]) as f64;
        let drow = &mut dlogits[i * c..(i + 1) * c];
        for (j, &v) in row.iter().enumerate() {
            let p = (v - lse).exp();
            drow[j] = (p - if j == yi { 1.0 } else { 0.0 }) / b as f32;
        }
    }
    ((loss / b as f64) as f32, dlogits)
}

/// (summed loss, correct count) over a batch — same contract as the
/// `eval_batch` artifact.
pub fn eval_stats(logits: &[f32], y: &[i32], c: usize) -> (f32, usize) {
    let b = y.len();
    let mut loss_sum = 0.0f64;
    let mut correct = 0usize;
    for i in 0..b {
        let row = &logits[i * c..(i + 1) * c];
        let maxv = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut sum = 0.0f32;
        let mut argmax = 0usize;
        let mut best = f32::MIN;
        for (j, &v) in row.iter().enumerate() {
            sum += (v - maxv).exp();
            if v > best {
                best = v;
                argmax = j;
            }
        }
        let lse = maxv + sum.ln();
        loss_sum += (lse - row[y[i] as usize]) as f64;
        if argmax == y[i] as usize {
            correct += 1;
        }
    }
    (loss_sum as f32, correct)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_loss_is_log_c() {
        let logits = vec![0.0f32; 3 * 10];
        let y = vec![0, 5, 9];
        let (loss, dl) = xent_mean_with_grad(&logits, &y, 10);
        assert!((loss - (10.0f32).ln()).abs() < 1e-6);
        // gradient rows sum to zero
        for i in 0..3 {
            let s: f32 = dl[i * 10..(i + 1) * 10].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut rng = crate::util::rng::Rng::new(0);
        let (b, c) = (4, 6);
        let mut logits = vec![0.0f32; b * c];
        rng.fill_gaussian(&mut logits, 2.0);
        let y: Vec<i32> = (0..b as i32).collect();
        let (_, grad) = xent_mean_with_grad(&logits, &y, c);
        let eps = 1e-2f32;
        for j in [0, 7, 13, 23] {
            let mut lp = logits.clone();
            lp[j] += eps;
            let mut lm = logits.clone();
            lm[j] -= eps;
            let (fp, _) = xent_mean_with_grad(&lp, &y, c);
            let (fm, _) = xent_mean_with_grad(&lm, &y, c);
            let fd = (fp - fm) / (2.0 * eps);
            assert!((grad[j] - fd).abs() < 1e-3, "j={j}: {} vs {fd}", grad[j]);
        }
    }

    #[test]
    fn stable_for_huge_logits() {
        let logits = vec![1e4f32, -1e4, 0.0, 0.0];
        let (loss, _) = xent_mean_with_grad(&logits, &[0, 1], 2);
        assert!(loss.is_finite());
        let (ls, correct) = eval_stats(&logits, &[0, 1], 2);
        assert!(ls.is_finite());
        assert_eq!(correct, 1); // row 2 predicts class 0, label 1
    }

    #[test]
    fn eval_counts_correct() {
        let logits = vec![2.0f32, 1.0, 0.0, 5.0];
        let (_, correct) = eval_stats(&logits, &[0, 1], 2);
        assert_eq!(correct, 2);
    }
}
