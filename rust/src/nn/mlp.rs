//! Network 1 of Table I in pure Rust: FC(784,50) -> ReLU -> FC(50,10),
//! flat 39,760-parameter vector with hand-written backprop.
//!
//! Parameter layout matches `python/compile/models/mlp.py` exactly
//! (w1 | b1 | w2 | b2, row-major), so parameters, gradients and Adam
//! states are interchangeable with the HLO artifacts.

use super::loss::{eval_stats, xent_mean_with_grad};
use super::{matmul, matmul_nt, matmul_tn};
use crate::util::rng::Rng;

pub const IN: usize = 784;
pub const HID: usize = 50;
pub const OUT: usize = 10;
pub const D: usize = IN * HID + HID + HID * OUT + OUT; // 39,760

const W1: usize = 0;
const B1O: usize = IN * HID;
const W2: usize = B1O + HID;
const B2O: usize = W2 + HID * OUT;

/// He-normal init (fan-in) over a flat vector. (Statistically equivalent
/// to the python init; for *identical* params across stacks use the
/// `mnist_init.bin` dump from `make artifacts`.)
pub fn init(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut p = vec![0.0f32; D];
    rng.fill_gaussian(&mut p[W1..B1O], (2.0f32 / IN as f32).sqrt());
    // b1 zeros
    rng.fill_gaussian(&mut p[W2..B2O], (2.0f32 / HID as f32).sqrt());
    // b2 zeros
    p
}

/// Forward pass: logits [b, 10]. `x` is [b, 784] row-major.
pub fn forward(params: &[f32], x: &[f32], b: usize) -> Vec<f32> {
    let (logits, _) = forward_cached(params, x, b);
    logits
}

/// Forward keeping the post-ReLU hidden activations for backprop.
fn forward_cached(params: &[f32], x: &[f32], b: usize) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(params.len(), D);
    assert_eq!(x.len(), b * IN);
    let mut h = vec![0.0f32; b * HID];
    matmul(x, &params[W1..B1O], b, IN, HID, &mut h);
    for i in 0..b {
        for j in 0..HID {
            let v = h[i * HID + j] + params[B1O + j];
            h[i * HID + j] = if v > 0.0 { v } else { 0.0 };
        }
    }
    let mut logits = vec![0.0f32; b * OUT];
    matmul(&h, &params[W2..B2O], b, HID, OUT, &mut logits);
    for i in 0..b {
        for j in 0..OUT {
            logits[i * OUT + j] += params[B2O + j];
        }
    }
    (logits, h)
}

/// Loss + flat gradient of the mean cross-entropy.
pub fn loss_and_grad(params: &[f32], x: &[f32], y: &[i32]) -> (f32, Vec<f32>) {
    let b = y.len();
    let (logits, h) = forward_cached(params, x, b);
    let (loss, dlogits) = xent_mean_with_grad(&logits, y, OUT);

    let mut grad = vec![0.0f32; D];
    // dw2 = h^T @ dlogits ; db2 = col-sums of dlogits
    matmul_tn(&h, &dlogits, b, HID, OUT, &mut grad[W2..B2O]);
    for i in 0..b {
        for j in 0..OUT {
            grad[B2O + j] += dlogits[i * OUT + j];
        }
    }
    // dh = dlogits @ w2^T, masked by relu
    let mut dh = vec![0.0f32; b * HID];
    // w2 is [HID, OUT]; need dlogits [b, OUT] @ w2^T [OUT, HID]
    matmul_nt(&dlogits, &params[W2..B2O], b, OUT, HID, &mut dh);
    for (dhv, &hv) in dh.iter_mut().zip(&h) {
        if hv <= 0.0 {
            *dhv = 0.0;
        }
    }
    // dw1 = x^T @ dh ; db1 = col-sums of dh
    matmul_tn(x, &dh, b, IN, HID, &mut grad[W1..B1O]);
    for i in 0..b {
        for j in 0..HID {
            grad[B1O + j] += dh[i * HID + j];
        }
    }
    (loss, grad)
}

/// (summed loss, correct count) over a batch.
pub fn evaluate(params: &[f32], x: &[f32], y: &[i32]) -> (f32, usize) {
    let logits = forward(params, x, y.len());
    eval_stats(&logits, y, OUT)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn d_matches_table1() {
        assert_eq!(D, 39760);
    }

    #[test]
    fn forward_shape_and_finite() {
        let p = init(0);
        let x = vec![0.5f32; 3 * IN];
        let logits = forward(&p, &x, 3);
        assert_eq!(logits.len(), 30);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut rng = Rng::new(1);
        let p = init(1);
        let b = 3;
        let mut x = vec![0.0f32; b * IN];
        rng.fill_gaussian(&mut x, 0.5);
        let y = vec![1, 7, 3];
        let (_, grad) = loss_and_grad(&p, &x, &y);
        let eps = 1e-2f32;
        // spot-check coordinates in every parameter block
        for j in [5usize, 39_000, B1O + 3, W2 + 17, B2O + 9] {
            let mut pp = p.clone();
            pp[j] += eps;
            let (fp, _) = loss_and_grad(&pp, &x, &y);
            let mut pm = p.clone();
            pm[j] -= eps;
            let (fm, _) = loss_and_grad(&pm, &x, &y);
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (grad[j] - fd).abs() < 2e-3,
                "coord {j}: analytic {} vs fd {fd}",
                grad[j]
            );
        }
    }

    #[test]
    fn learns_a_toy_task() {
        let mut rng = Rng::new(2);
        let mut p = init(0);
        let b = 32;
        let mut x = vec![0.0f32; b * IN];
        let mut y = vec![0i32; b];
        for i in 0..b {
            let cls = (i % 2) as i32;
            y[i] = cls;
            x[i * IN + (cls as usize) * 400 + 10] = 4.0;
            for j in 0..IN {
                x[i * IN + j] += rng.gaussian() as f32 * 0.02;
            }
        }
        let mut adam = crate::nn::adam::AdamState::new(D);
        let (loss0, _) = loss_and_grad(&p, &x, &y);
        for _ in 0..200 {
            let (_, g) = loss_and_grad(&p, &x, &y);
            adam.step(&mut p, &g, 1e-3);
        }
        let (loss1, _) = loss_and_grad(&p, &x, &y);
        assert!(loss1 < loss0 * 0.2, "{loss0} -> {loss1}");
        let (_, correct) = evaluate(&p, &x, &y);
        assert_eq!(correct, b);
    }
}
