//! `ragek` CLI — train / evaluate / cluster / serve with the rAge-k stack.
//!
//! ```text
//! ragek train   --model mnist --strategy ragek --rounds 150
//! ragek compare --model mnist --rounds 100          # rAge-k vs rTop-k
//! ragek cluster --model mnist --rounds 60           # Fig. 2 heatmaps
//! ragek info                                        # artifact manifest
//! ```

use anyhow::{bail, Result};
use ragek::config::{BackendKind, ExperimentConfig};
use ragek::coordinator::scheduler::SchedulerKind;
use ragek::coordinator::strategies::StrategyKind;
use ragek::fl::trainer::Trainer;
use ragek::util::argparse::{ArgError, ArgSpec};
use ragek::util::{logging, plot};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn train_spec(cmd: &str, about: &str) -> ArgSpec {
    ArgSpec::new(cmd, about)
        .opt("model", "mnist", "model/dataset: mnist | cifar")
        .opt("strategy", "ragek", "ragek | ragek-indep | rtopk | topk | randk | dense")
        .opt("backend", "auto", "rust | xla | auto")
        .opt("rounds", "0", "global rounds (0 = preset default)")
        .opt("clients", "0", "number of clients (0 = preset)")
        .opt("participation", "", "fraction of clients polled per round (empty = preset)")
        .opt("scheduler", "", "cohort policy: round-robin | random | age-debt (empty = preset)")
        .opt("shards", "", "PS topology: 0 = flat (default), N >= 1 = N shard engines")
        .opt("root-merge", "", "root age-vector merge under sharding: min | max (empty = min)")
        .opt("io-timeout-ms", "", "PS-side per-phase connection deadline in ms (empty/0 = none)")
        .opt("overschedule", "", "extra cohort members scheduled per round; the round commits on the first m reports (empty/0 = off)")
        .opt("deadline-factor", "", "adaptive per-client deadline = clamp(rtt-ewma * factor, min, io-timeout) (empty/0 = flat io-timeout)")
        .opt("deadline-min-ms", "", "floor for the adaptive per-client deadline in ms")
        .opt("reshard", "", "re-partition shards at recluster boundaries: true | false")
        .opt("codec", "", "wire codec: raw | packed | packed-f16 (empty = preset)")
        .opt("downlink", "", "broadcast mode: dense | delta (empty = preset)")
        .opt("client-store", "", "per-client state storage: dense | compact (empty = preset)")
        .opt("parallel", "", "in-process client lanes (empty = preset, 0 = auto, 1 = serial)")
        .opt("seed", "42", "experiment seed")
        .opt("config", "", "JSON config file (overrides preset)")
        .opt("out", "results", "output directory")
        .flag("verbose", "debug logging")
}

fn build_config(a: &ragek::util::argparse::Args) -> Result<ExperimentConfig> {
    let mut cfg = if !a.get("config").is_empty() {
        ExperimentConfig::load(a.get("config"))?
    } else {
        match a.get("model") {
            "mnist" => ExperimentConfig::mnist_scaled(),
            "cifar" => ExperimentConfig::cifar_paper(),
            other => bail!("unknown model {other:?}"),
        }
    };
    if let Some(s) = StrategyKind::parse(a.get("strategy")) {
        cfg.strategy = s;
    } else {
        bail!("unknown strategy {:?}", a.get("strategy"));
    }
    match a.get("backend") {
        "rust" => cfg.backend = BackendKind::Rust,
        "xla" => cfg.backend = BackendKind::Xla,
        "auto" => {} // preset default
        other => bail!("unknown backend {other:?}"),
    }
    let rounds = a.get_usize("rounds")?;
    if rounds > 0 {
        cfg.rounds = rounds;
    }
    let clients = a.get_usize("clients")?;
    if clients > 0 {
        cfg.n_clients = clients;
    }
    if !a.get("parallel").is_empty() {
        cfg.parallel = a.get_usize("parallel")?;
    }
    if !a.get("participation").is_empty() {
        cfg.participation = a.get_f64("participation")?;
    }
    if !a.get("scheduler").is_empty() {
        cfg.scheduler = SchedulerKind::parse(a.get("scheduler"))
            .ok_or_else(|| anyhow::anyhow!("unknown scheduler {:?}", a.get("scheduler")))?;
    }
    let root_merge = match a.get("root-merge") {
        "" | "min" => ragek::clustering::MergeRule::Min,
        "max" => ragek::clustering::MergeRule::Max,
        other => bail!("unknown root-merge {other:?} (want min | max)"),
    };
    if !a.get("shards").is_empty() {
        cfg.topology =
            ragek::coordinator::topology::Topology::from_shards(a.get_usize("shards")?, root_merge);
    } else if !a.get("root-merge").is_empty() {
        cfg.topology = ragek::coordinator::topology::Topology::from_shards(
            cfg.topology.shards_knob(),
            root_merge,
        );
    }
    if !a.get("io-timeout-ms").is_empty() {
        cfg.io_timeout_ms = a.get_usize("io-timeout-ms")? as u64;
    }
    if !a.get("overschedule").is_empty() {
        cfg.overschedule = a.get_usize("overschedule")?;
    }
    if !a.get("deadline-factor").is_empty() {
        cfg.deadline_factor = a.get_f64("deadline-factor")?;
    }
    if !a.get("deadline-min-ms").is_empty() {
        cfg.deadline_min_ms = a.get_usize("deadline-min-ms")? as u64;
    }
    match a.get("reshard") {
        "" => {}
        "true" | "on" => cfg.reshard = true,
        "false" | "off" => cfg.reshard = false,
        other => bail!("unknown reshard {other:?} (want true | false)"),
    }
    if !a.get("codec").is_empty() {
        cfg.codec = ragek::fl::codec::Codec::parse(a.get("codec"))
            .ok_or_else(|| anyhow::anyhow!("unknown codec {:?}", a.get("codec")))?;
    }
    if !a.get("downlink").is_empty() {
        cfg.downlink = ragek::config::Downlink::parse(a.get("downlink"))
            .ok_or_else(|| anyhow::anyhow!("unknown downlink {:?}", a.get("downlink")))?;
    }
    if !a.get("client-store").is_empty() {
        cfg.client_store = ragek::config::ClientStore::parse(a.get("client-store"))
            .ok_or_else(|| anyhow::anyhow!("unknown client-store {:?}", a.get("client-store")))?;
    }
    cfg.seed = a.get_usize("seed")? as u64;
    cfg.validate()?;
    Ok(cfg)
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first().map(String::as_str) else {
        print_global_help();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd {
        "train" => cmd_train(rest),
        "compare" => cmd_compare(rest),
        "cluster" => cmd_cluster(rest),
        "serve" => cmd_serve(rest),
        "worker" => cmd_worker(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            print_global_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `ragek help`)"),
    }
}

fn print_global_help() {
    println!(
        "ragek — communication-efficient federated learning with the age factor\n\n\
         Commands:\n\
         \x20 train    run one FL training experiment\n\
         \x20 compare  run rAge-k vs rTop-k side by side (Fig. 3 / Fig. 5)\n\
         \x20 cluster  run and dump connectivity heatmaps (Fig. 2 / Fig. 4)\n\
         \x20 serve    run the PS for a multi-process deployment (TCP)\n\
         \x20 worker   run one client process against a serve PS\n\
         \x20 info     print the artifact manifest\n\n\
         `ragek <command> --help` for options."
    );
}

fn parse_or_help(spec: ArgSpec, rest: &[String]) -> Result<Option<ragek::util::argparse::Args>> {
    match spec.parse(rest) {
        Ok(a) => Ok(Some(a)),
        Err(ArgError::HelpRequested) => {
            println!("{}", spec.usage());
            Ok(None)
        }
        Err(e) => Err(e.into()),
    }
}

fn cmd_train(rest: &[String]) -> Result<()> {
    let Some(a) = parse_or_help(train_spec("ragek train", "run one FL experiment"), rest)?
    else {
        return Ok(());
    };
    if a.get_flag("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    let cfg = build_config(&a)?;
    ragek::info!(
        "training {} with {} (backend {:?}, {} clients, {} rounds)",
        cfg.model,
        cfg.strategy.name(),
        cfg.backend,
        cfg.n_clients,
        cfg.rounds
    );
    let mut trainer = Trainer::from_config(&cfg)?;
    let report = trainer.run()?;
    println!(
        "final accuracy {:.2}%  uplink {:.2} MiB  clusters {:?}",
        report.final_accuracy * 100.0,
        report.history.comm.uplink() as f64 / (1 << 20) as f64,
        report.cluster_labels
    );
    let outdir = std::path::Path::new(a.get("out"));
    std::fs::create_dir_all(outdir)?;
    let stem = format!("train_{}_{}", cfg.model, cfg.strategy.name().replace('/', "-"));
    std::fs::write(outdir.join(format!("{stem}.json")), report.history.to_json().to_pretty())?;
    std::fs::write(outdir.join(format!("{stem}.csv")), report.history.to_csv())?;
    println!("wrote {}/{stem}.{{json,csv}}", outdir.display());
    Ok(())
}

fn cmd_compare(rest: &[String]) -> Result<()> {
    let Some(a) = parse_or_help(
        train_spec("ragek compare", "rAge-k vs rTop-k at equal (r, k)"),
        rest,
    )?
    else {
        return Ok(());
    };
    if a.get_flag("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    let mut histories = Vec::new();
    for strategy in [StrategyKind::RageK, StrategyKind::RTopK] {
        let mut cfg = build_config(&a)?;
        cfg.strategy = strategy;
        let mut trainer = Trainer::from_config(&cfg)?;
        let report = trainer.run()?;
        histories.push(report.history);
    }
    let refs: Vec<&ragek::fl::metrics::History> = histories.iter().collect();
    println!("\naccuracy over rounds:");
    println!("{}", ragek::fl::metrics::History::chart_accuracy(&refs, 70, 16));
    for h in &histories {
        println!(
            "{:<12} final acc {:.2}%  uplink {:.2} MiB",
            h.name,
            h.final_accuracy() * 100.0,
            h.comm.uplink() as f64 / (1 << 20) as f64
        );
    }
    Ok(())
}

fn cmd_cluster(rest: &[String]) -> Result<()> {
    let Some(a) = parse_or_help(
        train_spec("ragek cluster", "dump connectivity heatmaps (Fig. 2 / Fig. 4)"),
        rest,
    )?
    else {
        return Ok(());
    };
    if a.get_flag("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    let cfg = build_config(&a)?;
    let mut trainer = Trainer::from_config(&cfg)?;
    // snapshot cadence mirroring Fig. 2 (1, 21, 41, 61) scaled to the run
    let quarter = (cfg.rounds / 4).max(1);
    trainer.heatmap_rounds = vec![1, quarter + 1, 2 * quarter + 1, 3 * quarter + 1]
        .into_iter()
        .filter(|&r| r <= cfg.rounds)
        .collect();
    let report = trainer.run()?;
    for (round, m) in &report.heatmaps {
        println!("\nconnectivity at round {round}:");
        println!("{}", plot::heatmap(m, true));
    }
    if let Some(truth) = &report.truth_labels {
        println!("ground-truth pairs: {truth:?}");
    }
    println!("final clusters:      {:?}", report.cluster_labels);
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let spec = train_spec("ragek serve", "parameter server for multi-process FL")
        .opt("port", "7700", "TCP port to listen on (shard s listens on port + s when sharded)");
    let Some(a) = parse_or_help(spec, rest)? else {
        return Ok(());
    };
    if a.get_flag("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    let mut cfg = build_config(&a)?;
    // deployment default: the Delta payload (must match the workers')
    cfg.payload = ragek::config::Payload::Delta;
    let report = ragek::fl::distributed::run_server(&cfg, a.get_usize("port")? as u16)?;
    println!(
        "serve: {} rounds done, final acc {:.2}%, clusters {:?}",
        report.rounds,
        report.final_accuracy * 100.0,
        report.cluster_labels
    );
    Ok(())
}

fn cmd_worker(rest: &[String]) -> Result<()> {
    let spec = train_spec("ragek worker", "one client process for multi-process FL")
        .opt("connect", "127.0.0.1:7700", "PS base address (the worker adds its shard offset)")
        .opt("id", "0", "client id (0..n_clients)")
        .opt("rejoin", "0", "re-admission generation after a crash (0 = fresh join)");
    let Some(a) = parse_or_help(spec, rest)? else {
        return Ok(());
    };
    if a.get_flag("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    let mut cfg = build_config(&a)?;
    cfg.payload = ragek::config::Payload::Delta; // must match cmd_serve
    let id = a.get_usize("id")?;
    // under a sharded topology the worker talks to its shard's PS at
    // base_port + shard (mirroring cmd_serve's bind layout); Rejoin
    // handshakes are routed by global id on the PS side (DESIGN.md §10),
    // so after a dynamic re-shard this statically-derived port still
    // lands the comeback on whichever shard owns the client now
    let shards = cfg.topology.n_shards();
    let addr = if shards > 1 {
        let (shard, _) = ragek::coordinator::topology::locate(cfg.n_clients, shards, id);
        let (host, port) = a
            .get("connect")
            .rsplit_once(':')
            .ok_or_else(|| anyhow::anyhow!("--connect must be host:port"))?;
        let port = port
            .parse::<u16>()?
            .checked_add(shard as u16)
            .ok_or_else(|| anyhow::anyhow!("shard {shard} port offset exceeds 65535"))?;
        format!("{host}:{port}")
    } else {
        a.get("connect").to_string()
    };
    let generation = a.get_usize("rejoin")? as u32;
    if generation > 0 {
        ragek::fl::distributed::run_worker_rejoin(&cfg, &addr, id, generation)
    } else {
        ragek::fl::distributed::run_worker(&cfg, &addr, id)
    }
}

fn cmd_info(rest: &[String]) -> Result<()> {
    let spec = ArgSpec::new("ragek info", "print the artifact manifest")
        .opt("artifacts", "artifacts", "artifacts directory");
    let Some(a) = parse_or_help(spec, rest)? else {
        return Ok(());
    };
    let path = std::path::Path::new(a.get("artifacts")).join("manifest.json");
    let manifest = ragek::runtime::Manifest::load(&path)?;
    for (name, m) in &manifest.models {
        println!(
            "model {name}: d={} batch={} r={} k={} h_scan={} (lr {})",
            m.d, m.batch, m.r, m.k, m.h_scan, m.lr
        );
        for (aname, art) in &m.artifacts {
            println!("  {aname:<14} {} ({} in, {} out)", art.file, art.inputs.len(), art.outputs.len());
        }
    }
    Ok(())
}
