//! # ragek — communication-efficient federated learning with the age factor
//!
//! A production-grade reproduction of *"rAge-k: Communication-Efficient
//! Federated Learning Using Age Factor"* (Mortaheb, Kaswan, Ulukus, 2024)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the parameter-server coordinator: per-cluster
//!   [`age::AgeVector`]s implementing the eq. (2) protocol lazily (O(k)
//!   updates instead of the d-dimensional sweep), per-client
//!   [`age::FrequencyVector`]s, the eq. (3) similarity matrix, a from-scratch
//!   [`clustering::dbscan`] implementation, the rAge-k index
//!   [`coordinator::selection`] (including disjoint assignment inside a
//!   cluster), sparse aggregation, server-side optimizers, baselines
//!   (rTop-k / top-k / rand-k / dense), and the round protocol implemented
//!   **once** in [`coordinator::engine::RoundEngine`] with byte-accurate
//!   communication accounting — driven identically by the parallel
//!   in-process pool ([`fl::pool::InProcessPool`], scoped-thread client
//!   training) and the TCP deployment ([`fl::distributed`]), whose wire
//!   format is versioned by [`fl::codec::Codec`] (raw v1 | packed v2
//!   delta-varint sparse frames, lossless | packed-f16) with per-stream
//!   reused frame buffers (no per-frame buffer allocations in steady
//!   state).
//! * **Layer 2** — JAX model graphs AOT-lowered to HLO text
//!   (`python/compile`), executed from [`runtime`] via the PJRT C API.
//! * **Layer 1** — Pallas kernels (top-r scan, age sweep, tiled matmul)
//!   lowered into the same artifacts.
//!
//! Python never runs on the request path: `make artifacts` is build-time
//! only, and `backend::RustBackend` even allows training the MNIST MLP with
//! no artifacts at all (it doubles as the numerics oracle for the runtime
//! integration tests).
//!
//! Quick start (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use ragek::config::ExperimentConfig;
//! use ragek::fl::trainer::Trainer;
//!
//! let cfg = ExperimentConfig::mnist_paper();
//! let mut trainer = Trainer::from_config(&cfg).unwrap();
//! let report = trainer.run().unwrap();
//! println!("final accuracy: {:.2}%", report.final_accuracy * 100.0);
//! ```

pub mod age;
pub mod backend;
pub mod bench;
pub mod clustering;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fl;
pub mod nn;
pub mod optimizer;
pub mod runtime;
pub mod sparse;
pub mod testing;
pub mod util;
