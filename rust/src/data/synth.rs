//! Synthetic MNIST/CIFAR counterparts (the documented no-network
//! substitution, DESIGN.md §3).
//!
//! Each class c gets a fixed smooth template: a sum of `BUMPS` 2-D
//! Gaussian bumps whose positions/widths/amplitudes are drawn from a
//! class-keyed RNG (same template across runs and across train/test).
//! A sample is `clip(intensity * template + noise)`, with a small random
//! translation — enough variation that a linear model cannot trivially
//! memorize, while a 2-layer MLP reaches high accuracy in a few hundred
//! steps (mirroring MNIST's difficulty scale).
//!
//! What matters for rAge-k: gradients of clients holding different label
//! subsets live on different coordinates (distinct templates + distinct
//! output-layer rows), which is exactly the signal the frequency-vector
//! clustering (eq. 3) keys on.

use super::Dataset;
use crate::util::rng::Rng;

const BUMPS: usize = 6;

struct Template {
    /// [h * w] grayscale template in [0, 1]
    img: Vec<f32>,
    h: usize,
    w: usize,
}

fn class_template(corpus_tag: u64, class: u8, h: usize, w: usize) -> Template {
    let mut rng = Rng::new(corpus_tag ^ (0xC1A55 + class as u64 * 7919));
    let mut img = vec![0.0f32; h * w];
    for _ in 0..BUMPS {
        let cy = rng.uniform_in(0.15, 0.85) * h as f32;
        let cx = rng.uniform_in(0.15, 0.85) * w as f32;
        let sy = rng.uniform_in(0.06, 0.18) * h as f32;
        let sx = rng.uniform_in(0.06, 0.18) * w as f32;
        let amp = rng.uniform_in(0.4, 1.0);
        for y in 0..h {
            for x in 0..w {
                let dy = (y as f32 - cy) / sy;
                let dx = (x as f32 - cx) / sx;
                img[y * w + x] += amp * (-(dy * dy + dx * dx) / 2.0).exp();
            }
        }
    }
    let max = img.iter().cloned().fold(f32::MIN, f32::max).max(1e-6);
    for v in img.iter_mut() {
        *v /= max;
    }
    Template { img, h, w }
}

fn render_sample(t: &Template, rng: &mut Rng, channels: usize, out: &mut Vec<f32>) {
    // random +-2 pixel translation, per-sample intensity, pixel noise
    let dy = rng.below(5) as isize - 2;
    let dx = rng.below(5) as isize - 2;
    let intensity = rng.uniform_in(0.7, 1.2);
    let (h, w) = (t.h, t.w);
    for c in 0..channels {
        // per-channel gain keeps RGB channels correlated but not identical
        let gain = if channels == 1 { 1.0 } else { 0.8 + 0.2 * c as f32 / 2.0 };
        for y in 0..h {
            for x in 0..w {
                let sy = y as isize + dy;
                let sx = x as isize + dx;
                let base = if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                    t.img[sy as usize * w + sx as usize]
                } else {
                    0.0
                };
                let noise = rng.gaussian() as f32 * 0.08;
                out.push((intensity * gain * base + noise).clamp(0.0, 1.0));
            }
        }
    }
}

fn synthesize(
    corpus_tag: u64,
    seed: u64,
    n: usize,
    h: usize,
    w: usize,
    channels: usize,
) -> Dataset {
    let num_classes = 10;
    let templates: Vec<Template> = (0..num_classes)
        .map(|c| class_template(corpus_tag, c as u8, h, w))
        .collect();
    let dim = h * w * channels;
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n * dim);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let class = (i % num_classes) as u8; // balanced classes
        render_sample(&templates[class as usize], &mut rng, channels, &mut x);
        y.push(class);
    }
    Dataset { x, y, dim, num_classes }
}

/// 28x28x1 synthetic-MNIST (dim 784).
pub fn synthetic_mnist(seed: u64, n: usize) -> Dataset {
    synthesize(0x31415, seed, n, 28, 28, 1)
}

/// 32x32x3 synthetic-CIFAR (dim 3072; HWC layout to match the CNN graph).
pub fn synthetic_cifar(seed: u64, n: usize) -> Dataset {
    // note: the CNN reshapes [B, 3072] -> [B, 32, 32, 3]; render channels
    // as the fastest-varying axis to match NHWC.
    let ds = synthesize_nhwc(0x27182, seed, n, 32, 32, 3);
    ds
}

fn synthesize_nhwc(
    corpus_tag: u64,
    seed: u64,
    n: usize,
    h: usize,
    w: usize,
    channels: usize,
) -> Dataset {
    let chw = synthesize(corpus_tag, seed, n, h, w, channels);
    if channels == 1 {
        return chw;
    }
    // transpose each sample CHW -> HWC
    let dim = h * w * channels;
    let mut x = vec![0.0f32; chw.x.len()];
    for s in 0..n {
        let src = &chw.x[s * dim..(s + 1) * dim];
        let dst = &mut x[s * dim..(s + 1) * dim];
        for c in 0..channels {
            for y in 0..h {
                for xx in 0..w {
                    dst[(y * w + xx) * channels + c] = src[c * h * w + y * w + xx];
                }
            }
        }
    }
    Dataset { x, y: chw.y, dim, num_classes: chw.num_classes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let d = synthetic_mnist(0, 50);
        assert_eq!(d.dim, 784);
        assert_eq!(d.len(), 50);
        assert!(d.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let c = synthetic_cifar(0, 20);
        assert_eq!(c.dim, 3072);
        assert_eq!(c.num_classes, 10);
    }

    #[test]
    fn balanced_classes() {
        let d = synthetic_mnist(1, 100);
        let mut counts = [0usize; 10];
        for &y in &d.y {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn deterministic_in_seed() {
        let a = synthetic_mnist(7, 10);
        let b = synthetic_mnist(7, 10);
        assert_eq!(a.x, b.x);
        let c = synthetic_mnist(8, 10);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn templates_are_class_distinct() {
        // distance between class means must dominate within-class spread
        let d = synthetic_mnist(3, 200);
        let mut means = vec![vec![0.0f64; 784]; 10];
        let mut counts = [0usize; 10];
        for i in 0..d.len() {
            let c = d.y[i] as usize;
            counts[c] += 1;
            for (m, &v) in means[c].iter_mut().zip(d.sample(i)) {
                *m += v as f64;
            }
        }
        for (c, m) in means.iter_mut().enumerate() {
            for v in m.iter_mut() {
                *v /= counts[c] as f64;
            }
        }
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
        };
        let d01 = dist(&means[0], &means[1]);
        assert!(d01 > 1.0, "class means too close: {d01}");
    }

    #[test]
    fn train_test_share_templates() {
        // different seeds, same class templates: per-class means correlate
        let tr = synthetic_mnist(1, 300);
        let te = synthetic_mnist(2, 300);
        let mean_of = |d: &Dataset, cls: u8| -> Vec<f64> {
            let idx = d.indices_with_labels(&[cls]);
            let mut m = vec![0.0f64; d.dim];
            for &i in &idx {
                for (mm, &v) in m.iter_mut().zip(d.sample(i)) {
                    *mm += v as f64;
                }
            }
            m.iter().map(|v| v / idx.len() as f64).collect()
        };
        let a = mean_of(&tr, 4);
        let b = mean_of(&te, 4);
        let dot: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
        let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(dot / (na * nb) > 0.95, "cosine {}", dot / (na * nb));
    }
}
