//! Datasets and non-iid partitioning.
//!
//! The testbed has no network access, so the paper's MNIST/CIFAR10
//! downloads are substituted with seeded **synthetic** counterparts
//! ([`synth`]) that keep the properties rAge-k actually depends on
//! (DESIGN.md §3): same tensor shapes, 10 classes, learnable to high
//! accuracy, and label-dependent gradient support so frequency vectors
//! cluster clients by label set. Real-format parsers ([`idx`],
//! [`cifar_bin`]) are provided — drop the canonical files under `data/`
//! and [`load_dataset`] picks them up instead.

pub mod cifar_bin;
pub mod idx;
pub mod partition;
pub mod synth;

use crate::util::rng::Rng;

/// An in-memory labelled image dataset with flat f32 samples.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// row-major [n, dim] samples, values roughly in [0, 1]
    pub x: Vec<f32>,
    /// labels in [0, num_classes)
    pub y: Vec<u8>,
    pub dim: usize,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Subset by sample indices (copies).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * self.dim);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.sample(i));
            y.push(self.y[i]);
        }
        Dataset { x, y, dim: self.dim, num_classes: self.num_classes }
    }

    /// Indices of all samples whose label is in `labels`.
    pub fn indices_with_labels(&self, labels: &[u8]) -> Vec<usize> {
        (0..self.len()).filter(|&i| labels.contains(&self.y[i])).collect()
    }
}

/// A client's view of a corpus: an `Arc`-shared [`Dataset`] plus an
/// optional row-index view. At fleet scale (PR 9) every client holds a
/// `Shard` over the **same** corpus allocation — per-client cost is the
/// index list (4 bytes/row), not a row copy — while small tests can wrap
/// an owned `Dataset` via [`Shard::from_owned`]. Row order follows the
/// index list exactly, matching what [`Dataset::subset`] would have
/// copied, so training numerics are identical to the old owned-shard
/// path.
#[derive(Debug, Clone)]
pub struct Shard {
    data: std::sync::Arc<Dataset>,
    /// `None` = the whole dataset is the shard
    idx: Option<Vec<u32>>,
}

impl Shard {
    /// The whole corpus as one shard (no index indirection).
    pub fn whole(data: std::sync::Arc<Dataset>) -> Self {
        Shard { data, idx: None }
    }

    /// A row-index view over a shared corpus.
    pub fn view(data: std::sync::Arc<Dataset>, idx: Vec<u32>) -> Self {
        debug_assert!(idx.iter().all(|&i| (i as usize) < data.len()));
        Shard { data, idx: Some(idx) }
    }

    /// Wrap an owned dataset (tests, TCP workers holding one shard).
    pub fn from_owned(ds: Dataset) -> Self {
        Shard::whole(std::sync::Arc::new(ds))
    }

    pub fn len(&self) -> usize {
        match &self.idx {
            Some(idx) => idx.len(),
            None => self.data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.data.dim
    }

    pub fn num_classes(&self) -> usize {
        self.data.num_classes
    }

    /// Map a shard-local row position to the corpus row index.
    fn corpus_row(&self, i: usize) -> usize {
        match &self.idx {
            Some(idx) => idx[i] as usize,
            None => i,
        }
    }

    pub fn row(&self, i: usize) -> &[f32] {
        self.data.sample(self.corpus_row(i))
    }

    pub fn label(&self, i: usize) -> u8 {
        self.data.y[self.corpus_row(i)]
    }

    /// Sorted distinct labels present in this shard.
    pub fn label_set(&self) -> Vec<u8> {
        let mut set: Vec<u8> = (0..self.len()).map(|i| self.label(i)).collect();
        set.sort_unstable();
        set.dedup();
        set
    }

    /// Gather shard-local row positions into contiguous (x, y) buffers
    /// for the backend call (the `Shard` face of [`gather_batch`]).
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(idx.len() * self.dim());
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.label(i) as i32);
        }
        (x, y)
    }
}

/// Partition a shared corpus into per-client [`Shard`] views — the
/// fleet-scale replacement for mapping [`partition::partition`] through
/// [`Dataset::subset`]: one corpus allocation, n index views over it.
pub fn partition_shards(
    data: &std::sync::Arc<Dataset>,
    n_clients: usize,
    scheme: &partition::Scheme,
    seed: u64,
) -> Vec<Shard> {
    partition::partition(data, n_clients, scheme, seed)
        .into_iter()
        .map(|idx| Shard::view(data.clone(), idx.into_iter().map(|i| i as u32).collect()))
        .collect()
}

/// Cycling mini-batch iterator with per-epoch reshuffling.
#[derive(Debug)]
pub struct BatchIter {
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl BatchIter {
    pub fn new(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        BatchIter { order, cursor: 0, rng }
    }

    /// Next batch of `b` indices (wraps + reshuffles at epoch end; with
    /// fewer than `b` samples, indices repeat within the batch).
    pub fn next_batch(&mut self, b: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(b);
        while out.len() < b {
            if self.cursor >= self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
            }
            out.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        out
    }
}

/// Gather a batch into contiguous (x, y) buffers for the backend call.
pub fn gather_batch(ds: &Dataset, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
    let mut x = Vec::with_capacity(idx.len() * ds.dim);
    let mut y = Vec::with_capacity(idx.len());
    for &i in idx {
        x.extend_from_slice(ds.sample(i));
        y.push(ds.y[i] as i32);
    }
    (x, y)
}

/// Which corpus an experiment uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Corpus {
    Mnist,
    Cifar10,
}

/// Load (train, test): real files under `data_dir` when present
/// (MNIST IDX / CIFAR-10 binary batches), otherwise the synthetic
/// counterpart (documented substitution — DESIGN.md §3).
pub fn load_dataset(
    corpus: Corpus,
    data_dir: &str,
    seed: u64,
    train_n: usize,
    test_n: usize,
) -> (Dataset, Dataset) {
    match corpus {
        Corpus::Mnist => {
            if let Ok(pair) = idx::load_mnist_dir(data_dir) {
                crate::info!("data: using real MNIST from {data_dir}");
                return pair;
            }
            crate::info!("data: real MNIST not found under {data_dir}; using synthetic-MNIST");
            (
                synth::synthetic_mnist(seed, train_n),
                synth::synthetic_mnist(seed ^ 0x5eed, test_n),
            )
        }
        Corpus::Cifar10 => {
            if let Ok(pair) = cifar_bin::load_cifar_dir(data_dir) {
                crate::info!("data: using real CIFAR-10 from {data_dir}");
                return pair;
            }
            crate::info!("data: real CIFAR-10 not found under {data_dir}; using synthetic-CIFAR");
            (
                synth::synthetic_cifar(seed, train_n),
                synth::synthetic_cifar(seed ^ 0x5eed, test_n),
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            x: (0..12).map(|i| i as f32).collect(),
            y: vec![0, 1, 2],
            dim: 4,
            num_classes: 3,
        }
    }

    #[test]
    fn subset_and_sample() {
        let d = tiny();
        assert_eq!(d.sample(1), &[4.0, 5.0, 6.0, 7.0]);
        let s = d.subset(&[2, 0]);
        assert_eq!(s.y, vec![2, 0]);
        assert_eq!(s.sample(0), &[8.0, 9.0, 10.0, 11.0]);
    }

    #[test]
    fn label_filter() {
        let d = tiny();
        assert_eq!(d.indices_with_labels(&[0, 2]), vec![0, 2]);
    }

    #[test]
    fn batch_iter_covers_epoch() {
        let mut it = BatchIter::new(10, 0);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..5 {
            for i in it.next_batch(2) {
                seen.insert(i);
            }
        }
        assert_eq!(seen.len(), 10);
    }

    #[test]
    fn batch_iter_small_dataset_repeats() {
        let mut it = BatchIter::new(3, 1);
        let b = it.next_batch(8);
        assert_eq!(b.len(), 8);
        assert!(b.iter().all(|&i| i < 3));
    }

    #[test]
    fn gather_batch_layout() {
        let d = tiny();
        let (x, y) = gather_batch(&d, &[1, 0]);
        assert_eq!(x, vec![4.0, 5.0, 6.0, 7.0, 0.0, 1.0, 2.0, 3.0]);
        assert_eq!(y, vec![1, 0]);
    }

    /// A `Shard` view must read bit-for-bit what an owned `subset` copy
    /// would have — same rows, same order, same gather layout.
    #[test]
    fn shard_view_matches_owned_subset() {
        let d = std::sync::Arc::new(tiny());
        let owned = d.subset(&[2, 0]);
        let view = Shard::view(d.clone(), vec![2, 0]);
        assert_eq!(view.len(), owned.len());
        assert_eq!(view.dim(), owned.dim);
        assert_eq!(view.num_classes(), owned.num_classes);
        for i in 0..owned.len() {
            assert_eq!(view.row(i), owned.sample(i));
            assert_eq!(view.label(i), owned.y[i]);
        }
        let (vx, vy) = view.gather(&[1, 0, 1]);
        let (ox, oy) = gather_batch(&owned, &[1, 0, 1]);
        assert_eq!(vx, ox);
        assert_eq!(vy, oy);
        assert_eq!(view.label_set(), vec![0, 2]);
    }

    #[test]
    fn whole_shard_passthrough() {
        let d = std::sync::Arc::new(tiny());
        let s = Shard::whole(d.clone());
        assert_eq!(s.len(), 3);
        for i in 0..3 {
            assert_eq!(s.row(i), d.sample(i));
        }
        assert_eq!(s.label_set(), vec![0, 1, 2]);
    }

    #[test]
    fn partition_shards_cover_like_subsets() {
        let data = std::sync::Arc::new(synth::synthetic_mnist(0, 120));
        let scheme = partition::Scheme::Iid;
        let shards = partition_shards(&data, 4, &scheme, 7);
        let parts = partition::partition(&data, 4, &scheme, 7);
        assert_eq!(shards.len(), 4);
        for (s, p) in shards.iter().zip(&parts) {
            assert_eq!(s.len(), p.len());
            for (i, &row) in p.iter().enumerate() {
                assert_eq!(s.row(i), data.sample(row));
            }
        }
    }
}
