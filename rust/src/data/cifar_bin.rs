//! CIFAR-10 binary-batch parser (`data_batch_1.bin` .. `data_batch_5.bin`,
//! `test_batch.bin`; 1 label byte + 3072 CHW pixel bytes per record).
//! Pixels are converted to NHWC f32 in [0, 1] to match the CNN graph.

use super::Dataset;
use anyhow::{bail, Context, Result};
use std::path::Path;

const REC: usize = 1 + 3072;
const H: usize = 32;
const W: usize = 32;
const C: usize = 3;

/// Parse one binary batch file's bytes.
pub fn parse_batch(bytes: &[u8]) -> Result<Dataset> {
    if bytes.is_empty() || bytes.len() % REC != 0 {
        bail!("cifar: file size {} not a multiple of {REC}", bytes.len());
    }
    let n = bytes.len() / REC;
    let mut x = Vec::with_capacity(n * 3072);
    let mut y = Vec::with_capacity(n);
    for rec in bytes.chunks_exact(REC) {
        let label = rec[0];
        if label > 9 {
            bail!("cifar: label {label} out of range");
        }
        y.push(label);
        let px = &rec[1..];
        // stored CHW planes (R, G, B); emit HWC
        for yy in 0..H {
            for xx in 0..W {
                for c in 0..C {
                    x.push(px[c * H * W + yy * W + xx] as f32 / 255.0);
                }
            }
        }
    }
    Ok(Dataset { x, y, dim: 3072, num_classes: 10 })
}

fn append(dst: &mut Dataset, src: Dataset) {
    dst.x.extend(src.x);
    dst.y.extend(src.y);
}

/// Load the canonical CIFAR-10 binary layout from a directory (accepts
/// files directly in `dir` or under `dir/cifar-10-batches-bin/`).
pub fn load_cifar_dir(dir: &str) -> Result<(Dataset, Dataset)> {
    let base = Path::new(dir);
    let root = if base.join("data_batch_1.bin").exists() {
        base.to_path_buf()
    } else {
        base.join("cifar-10-batches-bin")
    };
    let read = |name: &str| -> Result<Dataset> {
        let p = root.join(name);
        let bytes = std::fs::read(&p).with_context(|| format!("reading {p:?}"))?;
        parse_batch(&bytes)
    };
    let mut train = read("data_batch_1.bin")?;
    for i in 2..=5 {
        append(&mut train, read(&format!("data_batch_{i}.bin"))?);
    }
    let test = read("test_batch.bin")?;
    Ok((train, test))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(label: u8, fill: u8) -> Vec<u8> {
        let mut r = vec![label];
        r.extend(std::iter::repeat(fill).take(3072));
        r
    }

    #[test]
    fn parse_two_records() {
        let mut bytes = record(3, 255);
        bytes.extend(record(9, 0));
        let ds = parse_batch(&bytes).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.y, vec![3, 9]);
        assert_eq!(ds.dim, 3072);
        assert!((ds.x[0] - 1.0).abs() < 1e-6);
        assert_eq!(ds.x[3072], 0.0);
    }

    #[test]
    fn chw_to_hwc_transpose() {
        // R plane = 30, G = 60, B = 90: first HWC pixel must be [30,60,90]/255
        let mut r = vec![1u8];
        for (plane, v) in [30u8, 60, 90].iter().enumerate() {
            let _ = plane;
            r.extend(std::iter::repeat(*v).take(1024));
        }
        let ds = parse_batch(&r).unwrap();
        for (i, want) in [30.0, 60.0, 90.0].iter().enumerate() {
            assert!((ds.x[i] - want / 255.0).abs() < 1e-6);
        }
    }

    #[test]
    fn rejects_bad_sizes_and_labels() {
        assert!(parse_batch(&[0u8; 100]).is_err());
        assert!(parse_batch(&[]).is_err());
        let bad = record(11, 0);
        assert!(parse_batch(&bad).is_err());
    }
}
