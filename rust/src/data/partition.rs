//! Non-iid client partitioning.
//!
//! [`Scheme::PaperPairs`] is the paper's §III-C construction: clients are
//! paired, each pair owning a disjoint label subset (MNIST: 10 clients /
//! 5 pairs x 2 labels; CIFAR: 6 clients / 3 pairs x 3-4 labels). The pairs
//! are the ground-truth clusters DBSCAN must rediscover (Fig. 2/4).
//! Dirichlet and IID schemes are included for ablations.

use super::Dataset;
use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub enum Scheme {
    /// The paper's paired-label construction for `n_clients`.
    PaperPairs,
    /// Label-distribution skew: per-client class proportions drawn from
    /// Dirichlet(alpha) (alpha -> 0 extreme non-iid, alpha -> inf iid).
    Dirichlet { alpha: f64 },
    /// Uniform random split.
    Iid,
}

/// The labels assigned to each client under [`Scheme::PaperPairs`]:
/// clients 2p and 2p+1 share label block p. Label blocks split
/// `num_classes` as evenly as possible, remainder going to the last block
/// (the paper's CIFAR split is 3/3/4).
pub fn paper_pair_labels(n_clients: usize, num_classes: usize) -> Vec<Vec<u8>> {
    assert!(n_clients % 2 == 0, "PaperPairs needs an even client count");
    let n_pairs = n_clients / 2;
    let base = num_classes / n_pairs;
    let mut blocks: Vec<Vec<u8>> = Vec::with_capacity(n_pairs);
    let mut next = 0u8;
    for p in 0..n_pairs {
        let take = if p + 1 == n_pairs { num_classes as u8 - next } else { base as u8 };
        blocks.push((next..next + take).collect());
        next += take;
    }
    (0..n_clients).map(|i| blocks[i / 2].clone()).collect()
}

/// Ground-truth cluster id per client under [`Scheme::PaperPairs`]
/// (client i belongs to pair i/2) — what Fig. 2/4 should recover.
pub fn paper_pair_truth(n_clients: usize) -> Vec<usize> {
    (0..n_clients).map(|i| i / 2).collect()
}

/// Split `ds` into per-client sample-index lists. Every sample is assigned
/// to at most one client; PaperPairs splits each label's samples evenly
/// between the two clients of its pair.
pub fn partition(ds: &Dataset, n_clients: usize, scheme: &Scheme, seed: u64) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(seed ^ 0x9a97);
    match scheme {
        Scheme::PaperPairs => {
            let labels = paper_pair_labels(n_clients, ds.num_classes);
            let mut out = vec![Vec::new(); n_clients];
            for class in 0..ds.num_classes as u8 {
                let holders: Vec<usize> = (0..n_clients)
                    .filter(|&i| labels[i].contains(&class))
                    .collect();
                let mut samples = ds.indices_with_labels(&[class]);
                rng.shuffle(&mut samples);
                for (j, s) in samples.into_iter().enumerate() {
                    out[holders[j % holders.len()]].push(s);
                }
            }
            out
        }
        Scheme::Dirichlet { alpha } => {
            let mut out = vec![Vec::new(); n_clients];
            for class in 0..ds.num_classes as u8 {
                let mut samples = ds.indices_with_labels(&[class]);
                rng.shuffle(&mut samples);
                let props = dirichlet(&mut rng, n_clients, *alpha);
                // cumulative cut points over this class's samples
                let n = samples.len();
                let mut start = 0usize;
                let mut acc = 0.0f64;
                for (i, p) in props.iter().enumerate() {
                    acc += p;
                    let end = if i + 1 == n_clients { n } else { (acc * n as f64) as usize };
                    for &s in &samples[start..end.min(n)] {
                        out[i].push(s);
                    }
                    start = end.min(n);
                }
            }
            out
        }
        Scheme::Iid => {
            let mut all: Vec<usize> = (0..ds.len()).collect();
            rng.shuffle(&mut all);
            let mut out = vec![Vec::new(); n_clients];
            for (j, s) in all.into_iter().enumerate() {
                out[j % n_clients].push(s);
            }
            out
        }
    }
}

/// Sample from Dirichlet(alpha * 1) via normalized Gamma(alpha) draws
/// (Marsaglia–Tsang for alpha >= 1, boosted for alpha < 1).
fn dirichlet(rng: &mut Rng, n: usize, alpha: f64) -> Vec<f64> {
    let mut g: Vec<f64> = (0..n).map(|_| gamma(rng, alpha)).collect();
    let sum: f64 = g.iter().sum();
    if sum <= 0.0 {
        return vec![1.0 / n as f64; n];
    }
    for x in g.iter_mut() {
        *x /= sum;
    }
    g
}

fn gamma(rng: &mut Rng, alpha: f64) -> f64 {
    if alpha < 1.0 {
        // Gamma(a) = Gamma(a + 1) * U^(1/a)
        let u: f64 = rng.uniform().max(1e-300);
        return gamma(rng, alpha + 1.0) * u.powf(1.0 / alpha);
    }
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.gaussian();
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u: f64 = rng.uniform();
        if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
            return d * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::synthetic_mnist;

    #[test]
    fn paper_labels_mnist_layout() {
        let labels = paper_pair_labels(10, 10);
        assert_eq!(labels[0], vec![0, 1]);
        assert_eq!(labels[1], vec![0, 1]);
        assert_eq!(labels[8], vec![8, 9]);
        assert_eq!(labels[9], vec![8, 9]);
    }

    #[test]
    fn paper_labels_cifar_layout() {
        // 6 clients / 3 pairs over 10 classes -> 3/3/4 (paper §III-C)
        let labels = paper_pair_labels(6, 10);
        assert_eq!(labels[0], vec![0, 1, 2]);
        assert_eq!(labels[2], vec![3, 4, 5]);
        assert_eq!(labels[4], vec![6, 7, 8, 9]);
        assert_eq!(labels[4], labels[5]);
    }

    #[test]
    fn paper_partition_respects_labels_and_covers() {
        let ds = synthetic_mnist(0, 400);
        let parts = partition(&ds, 10, &Scheme::PaperPairs, 1);
        let labels = paper_pair_labels(10, 10);
        let mut seen = vec![false; ds.len()];
        for (i, part) in parts.iter().enumerate() {
            assert!(!part.is_empty());
            for &s in part {
                assert!(labels[i].contains(&ds.y[s]));
                assert!(!seen[s], "sample {s} assigned twice");
                seen[s] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "every sample must be assigned");
    }

    #[test]
    fn pair_members_get_balanced_shares() {
        let ds = synthetic_mnist(0, 400);
        let parts = partition(&ds, 10, &Scheme::PaperPairs, 1);
        for p in 0..5 {
            let a = parts[2 * p].len() as i64;
            let b = parts[2 * p + 1].len() as i64;
            assert!((a - b).abs() <= 2, "pair {p}: {a} vs {b}");
        }
    }

    #[test]
    fn iid_partition_covers_evenly() {
        let ds = synthetic_mnist(0, 100);
        let parts = partition(&ds, 4, &Scheme::Iid, 0);
        assert!(parts.iter().all(|p| p.len() == 25));
    }

    #[test]
    fn dirichlet_partition_covers_all() {
        let ds = synthetic_mnist(0, 300);
        let parts = partition(&ds, 5, &Scheme::Dirichlet { alpha: 0.3 }, 2);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 300);
    }

    #[test]
    fn dirichlet_low_alpha_is_skewed() {
        let mut rng = Rng::new(0);
        let p = dirichlet(&mut rng, 10, 0.05);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let maxp = p.iter().cloned().fold(0.0, f64::max);
        assert!(maxp > 0.5, "alpha=0.05 should concentrate: max {maxp}");
        let u = dirichlet(&mut rng, 10, 1000.0);
        let maxu = u.iter().cloned().fold(0.0, f64::max);
        assert!(maxu < 0.2, "alpha=1000 should be near-uniform: max {maxu}");
    }
}
