//! MNIST IDX format parser (the real-data path; used when the canonical
//! `train-images-idx3-ubyte` etc. files are dropped under `data/`).
//! Supports the raw and `.gz` forms (flate2 is in the offline registry).

use super::Dataset;
use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

/// Parse an IDX payload (magic, dims, u8 data).
pub fn parse_idx(bytes: &[u8]) -> Result<(Vec<usize>, Vec<u8>)> {
    if bytes.len() < 4 {
        bail!("idx: truncated header");
    }
    if bytes[0] != 0 || bytes[1] != 0 {
        bail!("idx: bad magic {:02x}{:02x}", bytes[0], bytes[1]);
    }
    if bytes[2] != 0x08 {
        bail!("idx: only u8 payloads supported (type 0x{:02x})", bytes[2]);
    }
    let ndim = bytes[3] as usize;
    let mut off = 4;
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        if off + 4 > bytes.len() {
            bail!("idx: truncated dims");
        }
        dims.push(u32::from_be_bytes(bytes[off..off + 4].try_into().unwrap()) as usize);
        off += 4;
    }
    let total: usize = dims.iter().product();
    if bytes.len() - off < total {
        bail!("idx: payload shorter than dims imply ({} < {total})", bytes.len() - off);
    }
    Ok((dims, bytes[off..off + total].to_vec()))
}

fn read_maybe_gz(path: &Path) -> Result<Vec<u8>> {
    let raw = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if raw.len() >= 2 && raw[0] == 0x1f && raw[1] == 0x8b {
        let mut out = Vec::new();
        flate2::read::GzDecoder::new(&raw[..])
            .read_to_end(&mut out)
            .context("gunzip")?;
        Ok(out)
    } else {
        Ok(raw)
    }
}

fn find_file(dir: &Path, stem: &str) -> Result<Vec<u8>> {
    for cand in [stem.to_string(), format!("{stem}.gz")] {
        let p = dir.join(&cand);
        if p.exists() {
            return read_maybe_gz(&p);
        }
    }
    bail!("{stem}[.gz] not found in {dir:?}")
}

/// Build a `Dataset` from IDX image + label payloads.
pub fn dataset_from_idx(images: &[u8], labels: &[u8]) -> Result<Dataset> {
    let (idim, ibytes) = parse_idx(images)?;
    let (ldim, lbytes) = parse_idx(labels)?;
    if idim.len() != 3 || ldim.len() != 1 || idim[0] != ldim[0] {
        bail!("idx: unexpected shapes {idim:?} / {ldim:?}");
    }
    let dim = idim[1] * idim[2];
    let x: Vec<f32> = ibytes.iter().map(|&b| b as f32 / 255.0).collect();
    Ok(Dataset { x, y: lbytes, dim, num_classes: 10 })
}

/// Load the canonical MNIST 4-file layout from a directory.
pub fn load_mnist_dir(dir: &str) -> Result<(Dataset, Dataset)> {
    let dir = Path::new(dir);
    let train = dataset_from_idx(
        &find_file(dir, "train-images-idx3-ubyte")?,
        &find_file(dir, "train-labels-idx1-ubyte")?,
    )?;
    let test = dataset_from_idx(
        &find_file(dir, "t10k-images-idx3-ubyte")?,
        &find_file(dir, "t10k-labels-idx1-ubyte")?,
    )?;
    Ok((train, test))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_idx_images(n: usize, h: usize, w: usize) -> Vec<u8> {
        let mut b = vec![0, 0, 0x08, 3];
        for d in [n, h, w] {
            b.extend_from_slice(&(d as u32).to_be_bytes());
        }
        b.extend((0..n * h * w).map(|i| (i % 251) as u8));
        b
    }

    fn make_idx_labels(n: usize) -> Vec<u8> {
        let mut b = vec![0, 0, 0x08, 1];
        b.extend_from_slice(&(n as u32).to_be_bytes());
        b.extend((0..n).map(|i| (i % 10) as u8));
        b
    }

    #[test]
    fn parse_synthetic_idx() {
        let img = make_idx_images(3, 4, 5);
        let (dims, data) = parse_idx(&img).unwrap();
        assert_eq!(dims, vec![3, 4, 5]);
        assert_eq!(data.len(), 60);
        let ds = dataset_from_idx(&img, &make_idx_labels(3)).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim, 20);
        assert_eq!(ds.y, vec![0, 1, 2]);
        assert!((ds.x[1] - 1.0 / 255.0).abs() < 1e-7);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_idx(&[]).is_err());
        assert!(parse_idx(&[1, 2, 3, 4]).is_err()); // bad magic
        assert!(parse_idx(&[0, 0, 0x0d, 1, 0, 0, 0, 1, 9]).is_err()); // f32 type
        let mut img = make_idx_images(2, 2, 2);
        img.truncate(img.len() - 1); // short payload
        assert!(parse_idx(&img).is_err());
    }

    #[test]
    fn mismatched_counts_rejected() {
        let img = make_idx_images(3, 2, 2);
        let lab = make_idx_labels(4);
        assert!(dataset_from_idx(&img, &lab).is_err());
    }

    #[test]
    fn gz_roundtrip() {
        use std::io::Write;
        let img = make_idx_images(2, 3, 3);
        let tmp = std::env::temp_dir().join("ragek_idx_test.gz");
        let f = std::fs::File::create(&tmp).unwrap();
        let mut enc = flate2::write::GzEncoder::new(f, flate2::Compression::fast());
        enc.write_all(&img).unwrap();
        enc.finish().unwrap();
        let back = read_maybe_gz(&tmp).unwrap();
        assert_eq!(back, img);
        std::fs::remove_file(&tmp).ok();
    }
}
