//! Age and frequency vectors — the bookkeeping at the heart of rAge-k.
//!
//! [`AgeVector`] implements the eq. (2) protocol: after each global round
//! the requested indices reset to age 0 and every other index ages by +1.
//! One age vector exists **per cluster** (every client starts as a
//! singleton cluster); on cluster formation member vectors are merged and
//! on reassignment a client adopts its new cluster's vector (DESIGN.md §5).
//!
//! The representation is **lazy**: instead of materializing the d ages and
//! sweeping all of them every round (O(d) per cluster per round — 2.5M
//! adds at CIFAR scale), the vector stores the epoch `round` and, per
//! index, the round of its last reset, so
//!
//! ```text
//! age[j] = round - last_reset[j]
//! ```
//!
//! and the eq. (2) update is one counter bump plus k writes — O(k). The
//! rare O(d) operations (merge on cluster formation, reset on splits)
//! rebase both operands onto a common epoch, so the partition invariant
//! "every age is 0 (just selected) or old+1" holds bit-for-bit against the
//! dense sweep; [`DenseAgeVector`] keeps that sweep around as the oracle
//! (`rust/tests/properties.rs` pins lazy ≡ dense, `benches/bench_age.rs`
//! measures the gap at d = 2.5M).
//!
//! [`FrequencyVector`] counts how often each index was requested from a
//! client (the f^t[i] of eq. (3)); its pairwise dot products drive the
//! DBSCAN clustering.

/// Per-cluster age vector (eq. 2), lazy epoch-offset representation.
#[derive(Debug, Clone)]
pub struct AgeVector {
    /// round at which index j last reset to age 0 (invariant: <= round)
    last_reset: Vec<u32>,
    /// rounds elapsed in this vector's epoch
    round: u32,
}

/// Equality is on the *ages*, not the internal epoch: two vectors that
/// went through different merge/rebase histories but agree on every
/// `age[j]` compare equal.
impl PartialEq for AgeVector {
    fn eq(&self, other: &Self) -> bool {
        self.d() == other.d() && (0..self.d()).all(|j| self.get(j) == other.get(j))
    }
}

impl AgeVector {
    pub fn new(d: usize) -> Self {
        AgeVector { last_reset: vec![0; d], round: 0 }
    }

    pub fn d(&self) -> usize {
        self.last_reset.len()
    }

    /// Rounds elapsed in this vector's epoch (diagnostics).
    pub fn round(&self) -> u32 {
        self.round
    }

    pub fn get(&self, j: usize) -> u32 {
        self.round - self.last_reset[j]
    }

    /// Dense materialization (oracle comparisons, artifact interop).
    pub fn to_vec(&self) -> Vec<u32> {
        self.last_reset.iter().map(|&lr| self.round - lr).collect()
    }

    /// eq. (2): every index ages by one, except the just-requested
    /// `selected` indices which reset to 0. Lazily this is one epoch bump
    /// plus |selected| writes — O(k), not the d-dimensional sweep (see
    /// `benches/bench_age.rs` for the gap at d = 2.5M).
    pub fn update(&mut self, selected: &[u32]) {
        self.round += 1;
        for &j in selected {
            self.last_reset[j as usize] = self.round;
        }
    }

    /// Merge another cluster's vector into this one. Elementwise **min**:
    /// age = time since *any* member updated the index, which is the
    /// coordination-relevant notion (an index one member just refreshed
    /// is not stale for the cluster). `MergeRule` ablations live in
    /// `clustering::manager`.
    pub fn merge_min(&mut self, other: &AgeVector) {
        self.merge_with(other, u32::min);
    }

    /// Elementwise max merge (pessimistic alternative, for the ablation).
    pub fn merge_max(&mut self, other: &AgeVector) {
        self.merge_with(other, u32::max);
    }

    /// Merges happen only on (M-periodic) cluster formation, so O(d) is
    /// fine here; both operands are rebased onto a common epoch that can
    /// represent every merged age.
    fn merge_with(&mut self, other: &AgeVector, pick: fn(u32, u32) -> u32) {
        assert_eq!(self.d(), other.d());
        let my_round = self.round;
        let round = my_round.max(other.round);
        for (j, lr) in self.last_reset.iter_mut().enumerate() {
            let age = pick(my_round - *lr, other.round - other.last_reset[j]);
            *lr = round - age;
        }
        self.round = round;
    }

    /// All ages back to 0 (cluster split carry-over rule).
    pub fn reset(&mut self) {
        self.last_reset.fill(self.round);
    }

    /// Ages gathered at `idx` as f32 scores (selection input).
    pub fn gather(&self, idx: &[u32]) -> Vec<f32> {
        idx.iter().map(|&j| (self.round - self.last_reset[j as usize]) as f32).collect()
    }

    pub fn max_age(&self) -> u32 {
        self.last_reset.iter().map(|&lr| self.round - lr).max().unwrap_or(0)
    }

    pub fn mean_age(&self) -> f64 {
        if self.last_reset.is_empty() {
            return 0.0;
        }
        let sum: f64 = self.last_reset.iter().map(|&lr| (self.round - lr) as f64).sum();
        sum / self.last_reset.len() as f64
    }
}

/// The dense eq. (2) sweep the lazy representation replaced: +1 over all
/// d entries, then reset of the selected indices. Kept as the numerics
/// oracle for the lazy/dense equivalence property test and as the O(d)
/// baseline in `benches/bench_age.rs`. Not used on any hot path.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseAgeVector {
    ages: Vec<u32>,
}

impl DenseAgeVector {
    pub fn new(d: usize) -> Self {
        DenseAgeVector { ages: vec![0; d] }
    }

    pub fn d(&self) -> usize {
        self.ages.len()
    }

    pub fn get(&self, j: usize) -> u32 {
        self.ages[j]
    }

    pub fn as_slice(&self) -> &[u32] {
        &self.ages
    }

    pub fn update(&mut self, selected: &[u32]) {
        for a in self.ages.iter_mut() {
            *a += 1;
        }
        for &j in selected {
            self.ages[j as usize] = 0;
        }
    }

    pub fn merge_min(&mut self, other: &DenseAgeVector) {
        assert_eq!(self.d(), other.d());
        for (a, &b) in self.ages.iter_mut().zip(&other.ages) {
            *a = (*a).min(b);
        }
    }

    pub fn merge_max(&mut self, other: &DenseAgeVector) {
        assert_eq!(self.d(), other.d());
        for (a, &b) in self.ages.iter_mut().zip(&other.ages) {
            *a = (*a).max(b);
        }
    }

    pub fn reset(&mut self) {
        self.ages.fill(0);
    }

    pub fn max_age(&self) -> u32 {
        self.ages.iter().copied().max().unwrap_or(0)
    }
}

/// Per-client request-frequency vector (the f^t[i] of eq. (3)).
///
/// Stored sparsely (only requested indices ever become non-zero and only
/// k per round do) — the dot products in eq. (3) then cost O(nnz), not
/// O(d), which is what makes the M-periodic clustering cheap at d = 2.5M.
#[derive(Debug, Clone, Default)]
pub struct FrequencyVector {
    counts: std::collections::HashMap<u32, u32>,
    total: u64,
}

impl FrequencyVector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one round's requested indices.
    pub fn record(&mut self, idx: &[u32]) {
        for &j in idx {
            *self.counts.entry(j).or_insert(0) += 1;
            self.total += 1;
        }
    }

    pub fn nnz(&self) -> usize {
        self.counts.len()
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn get(&self, j: u32) -> u32 {
        self.counts.get(&j).copied().unwrap_or(0)
    }

    /// <self, other> (sparse dot product over the smaller support).
    pub fn dot(&self, other: &FrequencyVector) -> f64 {
        let (small, big) = if self.counts.len() <= other.counts.len() {
            (self, other)
        } else {
            (other, self)
        };
        small
            .counts
            .iter()
            .map(|(&j, &c)| c as f64 * big.get(j) as f64)
            .sum()
    }

    /// <self, self>.
    pub fn self_dot(&self) -> f64 {
        self.counts.values().map(|&c| (c as f64) * (c as f64)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_partition_invariant() {
        let mut a = AgeVector::new(10);
        a.update(&[2, 5]);
        a.update(&[5, 7]);
        // after round 2: 5,7 are 0; 2 aged once since reset; others 2
        assert_eq!(a.get(5), 0);
        assert_eq!(a.get(7), 0);
        assert_eq!(a.get(2), 1);
        assert_eq!(a.get(0), 2);
        // invariant: every age is either 0 (just selected) or old+1
        let before = a.clone();
        a.update(&[0]);
        for j in 0..10 {
            if j == 0 {
                assert_eq!(a.get(j), 0);
            } else {
                assert_eq!(a.get(j), before.get(j) + 1);
            }
        }
    }

    #[test]
    fn merge_min_takes_freshest() {
        let mut a = AgeVector::new(4);
        let mut b = AgeVector::new(4);
        a.update(&[0]); // a = [0,1,1,1]
        b.update(&[3]);
        b.update(&[3]); // b = [2,2,2,0]
        a.merge_min(&b);
        assert_eq!(a.to_vec(), vec![0, 1, 1, 0]);
        let mut c = AgeVector::new(4);
        c.update(&[1]);
        let mut d = AgeVector::new(4);
        d.update(&[2]);
        d.merge_max(&c);
        assert_eq!(d.to_vec(), vec![1, 1, 1, 1]);
    }

    #[test]
    fn merge_rebases_across_epochs() {
        // operands with very different epochs must still merge exactly
        let mut a = AgeVector::new(3);
        for _ in 0..20 {
            a.update(&[0]); // a = [0, 20, 20]
        }
        let mut b = AgeVector::new(3);
        b.update(&[1]); // b = [1, 0, 1]
        let mut min = a.clone();
        min.merge_min(&b);
        assert_eq!(min.to_vec(), vec![0, 0, 1]);
        let mut max = b; // merge into the *younger* epoch: needs rebasing
        max.merge_max(&a);
        assert_eq!(max.to_vec(), vec![1, 20, 20]);
        // merged vectors keep obeying eq. (2)
        max.update(&[2]);
        assert_eq!(max.to_vec(), vec![2, 21, 0]);
    }

    #[test]
    fn equality_ignores_epoch() {
        let mut a = AgeVector::new(3);
        a.update(&[0, 1, 2]);
        a.update(&[1]); // ages [1, 0, 1]
        let mut b = AgeVector::new(3);
        b.update(&[0, 2]);
        b.update(&[1]); // ages [1, 0, 1] via a different history
        assert_eq!(a, b);
        b.update(&[2]);
        assert_ne!(a, b);
    }

    #[test]
    fn reset_zeroes_all_ages() {
        let mut a = AgeVector::new(5);
        a.update(&[1]);
        a.update(&[2]);
        assert_eq!(a.max_age(), 2);
        a.reset();
        assert_eq!(a.max_age(), 0);
        assert_eq!(a.to_vec(), vec![0; 5]);
        // and eq. (2) continues from the zeroed state
        a.update(&[4]);
        assert_eq!(a.to_vec(), vec![1, 1, 1, 1, 0]);
    }

    #[test]
    fn gather_scores() {
        let mut a = AgeVector::new(5);
        a.update(&[1]);
        a.update(&[4]);
        assert_eq!(a.gather(&[0, 1, 4]), vec![2.0, 1.0, 0.0]);
        assert_eq!(a.max_age(), 2);
        assert!((a.mean_age() - (2.0 + 1.0 + 2.0 + 2.0 + 0.0) / 5.0).abs() < 1e-12);
    }

    #[test]
    fn frequency_dot_products() {
        let mut f1 = FrequencyVector::new();
        let mut f2 = FrequencyVector::new();
        f1.record(&[1, 2, 3]);
        f1.record(&[1, 2]);
        f2.record(&[2, 3, 9]);
        // f1 = {1:2, 2:2, 3:1}, f2 = {2:1, 3:1, 9:1}
        assert_eq!(f1.dot(&f2), 3.0);
        assert_eq!(f1.self_dot(), 9.0);
        assert_eq!(f2.self_dot(), 3.0);
        assert_eq!(f1.dot(&f2), f2.dot(&f1));
        assert_eq!(f1.total(), 5);
        assert_eq!(f1.nnz(), 3);
    }

    #[test]
    fn empty_frequency_is_zero() {
        let f = FrequencyVector::new();
        assert_eq!(f.self_dot(), 0.0);
        assert_eq!(f.dot(&FrequencyVector::new()), 0.0);
    }
}
