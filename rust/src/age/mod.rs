//! Age and frequency vectors — the bookkeeping at the heart of rAge-k.
//!
//! [`AgeVector`] implements the eq. (2) protocol: after each global round
//! the requested indices reset to age 0 and every other index ages by +1.
//! One age vector exists **per cluster** (every client starts as a
//! singleton cluster); on cluster formation member vectors are merged and
//! on reassignment a client adopts its new cluster's vector (DESIGN.md §5).
//!
//! The representation is **lazy**: instead of materializing the d ages and
//! sweeping all of them every round (O(d) per cluster per round — 2.5M
//! adds at CIFAR scale), the vector stores the epoch `round` and, per
//! index, the round of its last reset, so
//!
//! ```text
//! age[j] = round - last_reset[j]
//! ```
//!
//! and the eq. (2) update is one counter bump plus k writes — O(k). The
//! rare O(d) operations (merge on cluster formation, reset on splits)
//! rebase both operands onto a common epoch, so the partition invariant
//! "every age is 0 (just selected) or old+1" holds bit-for-bit against the
//! dense sweep; [`DenseAgeVector`] keeps that sweep around as the oracle
//! (`rust/tests/properties.rs` pins lazy ≡ dense, `benches/bench_age.rs`
//! measures the gap at d = 2.5M).
//!
//! [`FrequencyVector`] counts how often each index was requested from a
//! client (the f^t[i] of eq. (3)); its pairwise dot products drive the
//! DBSCAN clustering.

/// Per-cluster age vector (eq. 2), lazy epoch-offset representation with
/// a **hybrid sparse/dense backing** (fleet-scale refit, DESIGN.md §12).
///
/// A fresh vector is all-zero and a typical cluster only ever resets a
/// small, stable subset of the d coordinates (k per round, heavily
/// repeated), so materializing `last_reset` as a `Vec<u32>` of length d
/// *per cluster* is the O(n·d) assumption that killed fleet-scale runs:
/// 10⁵ singleton clusters at the MNIST d = 39760 is ~16 GB before the
/// first round. The hybrid starts [`Repr::Sparse`] — a map of the touched
/// coordinates over an implicit `base` reset-round for everything else —
/// and only densifies when the touched support grows past d/4 (at which
/// point the map would cost more than the vector). All observable
/// semantics (`get`, eq. (2) `update`, merges, `reset`, equality) are
/// bit-for-bit those of the dense epoch-offset form, pinned against
/// [`DenseAgeVector`] in `rust/tests/properties.rs` and the
/// representation-transition tests below.
///
/// The running `sum_last` makes `mean_age` O(1) exact integer arithmetic,
/// and in the sparse regime `max_age` is O(1) too (some coordinate always
/// sits at `base`) — both were O(d) sweeps the age-debt scheduler paid
/// per cluster per round.
#[derive(Debug, Clone)]
pub struct AgeVector {
    d: usize,
    /// rounds elapsed in this vector's epoch
    round: u32,
    /// conceptual `last_reset[j]` (round at which j last reset to age 0,
    /// invariant: <= round), in one of two physical forms
    repr: Repr,
    /// running sum of the conceptual `last_reset` over all d coordinates
    sum_last: u64,
}

#[derive(Debug, Clone)]
enum Repr {
    /// `map[j]` overrides; every other coordinate has `last_reset = base`.
    /// Invariants: every map value >= `base`, and `map.len() * 4 < d` —
    /// so at least one coordinate always sits at `base`, making it the
    /// exact minimum of the conceptual vector.
    Sparse { map: std::collections::HashMap<u32, u32>, base: u32 },
    /// the classical materialized `last_reset` vector
    Dense(Vec<u32>),
}

/// Equality is on the *ages*, not the internal epoch: two vectors that
/// went through different merge/rebase histories but agree on every
/// `age[j]` compare equal.
impl PartialEq for AgeVector {
    fn eq(&self, other: &Self) -> bool {
        self.d() == other.d() && (0..self.d()).all(|j| self.get(j) == other.get(j))
    }
}

impl AgeVector {
    /// O(1) in d — a fresh vector materializes nothing (the fleet-scale
    /// property `ClusterManager::new` relies on for 10⁵+ singletons).
    pub fn new(d: usize) -> Self {
        AgeVector {
            d,
            round: 0,
            repr: Repr::Sparse { map: std::collections::HashMap::new(), base: 0 },
            sum_last: 0,
        }
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Rounds elapsed in this vector's epoch (diagnostics).
    pub fn round(&self) -> u32 {
        self.round
    }

    /// Conceptual `last_reset[j]`; panics on j >= d like the dense form.
    #[inline]
    fn last(&self, j: usize) -> u32 {
        assert!(j < self.d, "age index {j} out of bounds (d = {})", self.d);
        match &self.repr {
            Repr::Sparse { map, base } => map.get(&(j as u32)).copied().unwrap_or(*base),
            Repr::Dense(last) => last[j],
        }
    }

    pub fn get(&self, j: usize) -> u32 {
        self.round - self.last(j)
    }

    /// Coordinates explicitly tracked by the backing store: the touched
    /// support in the sparse regime, d once densified (diagnostics — the
    /// memory-model number `bench_fleetscale` reports).
    pub fn backing_len(&self) -> usize {
        match &self.repr {
            Repr::Sparse { map, .. } => map.len(),
            Repr::Dense(last) => last.len(),
        }
    }

    /// Dense materialization (oracle comparisons, artifact interop).
    pub fn to_vec(&self) -> Vec<u32> {
        (0..self.d).map(|j| self.get(j)).collect()
    }

    /// Sparse support outgrew d/4: switch to the materialized vector
    /// (cheaper than the map from here on). One-way per epoch — `reset`
    /// re-sparsifies on cluster splits.
    fn maybe_densify(&mut self) {
        if let Repr::Sparse { map, base } = &self.repr {
            if self.d > 0 && map.len() * 4 >= self.d {
                let mut last = vec![*base; self.d];
                for (&j, &lr) in map {
                    last[j as usize] = lr;
                }
                self.repr = Repr::Dense(last);
            }
        }
    }

    /// eq. (2): every index ages by one, except the just-requested
    /// `selected` indices which reset to 0. Lazily this is one epoch bump
    /// plus |selected| writes — O(k), not the d-dimensional sweep (see
    /// `benches/bench_age.rs` for the gap at d = 2.5M).
    pub fn update(&mut self, selected: &[u32]) {
        self.round += 1;
        let round = self.round;
        match &mut self.repr {
            Repr::Sparse { map, base } => {
                for &j in selected {
                    assert!((j as usize) < self.d, "age index {j} out of bounds");
                    let lr = map.entry(j).or_insert(*base);
                    self.sum_last += (round - *lr) as u64;
                    *lr = round;
                }
            }
            Repr::Dense(last) => {
                for &j in selected {
                    let lr = &mut last[j as usize];
                    self.sum_last += (round - *lr) as u64;
                    *lr = round;
                }
            }
        }
        self.maybe_densify();
    }

    /// Merge another cluster's vector into this one. Elementwise **min**:
    /// age = time since *any* member updated the index, which is the
    /// coordination-relevant notion (an index one member just refreshed
    /// is not stale for the cluster). `MergeRule` ablations live in
    /// `clustering::manager`.
    pub fn merge_min(&mut self, other: &AgeVector) {
        self.merge_with(other, u32::min);
    }

    /// Elementwise max merge (pessimistic alternative, for the ablation).
    pub fn merge_max(&mut self, other: &AgeVector) {
        self.merge_with(other, u32::max);
    }

    /// Merges happen only on (M-periodic) cluster formation; both
    /// operands are rebased onto a common epoch that can represent every
    /// merged age. Two sparse operands merge in O(|support union|) — the
    /// merged default age is `pick` of the operand defaults, and because
    /// each operand's tracked ages never exceed its default age and
    /// `pick` is monotone, every merged override stays <= the merged
    /// default, i.e. lands at or above the new base (the sparse
    /// invariant). Either operand dense -> O(d) materialized merge, as
    /// before.
    fn merge_with(&mut self, other: &AgeVector, pick: fn(u32, u32) -> u32) {
        assert_eq!(self.d(), other.d());
        let (r1, r2) = (self.round, other.round);
        let round = r1.max(r2);
        if let (Repr::Sparse { map: m1, base: b1 }, Repr::Sparse { map: m2, base: b2 }) =
            (&self.repr, &other.repr)
        {
            let default = pick(r1 - b1, r2 - b2);
            let base = round - default;
            let mut map = std::collections::HashMap::with_capacity(m1.len() + m2.len());
            let mut overridden = |j: u32| {
                let a1 = r1 - m1.get(&j).copied().unwrap_or(*b1);
                let a2 = r2 - m2.get(&j).copied().unwrap_or(*b2);
                let age = pick(a1, a2);
                if age != default {
                    map.insert(j, round - age);
                }
            };
            for &j in m1.keys() {
                overridden(j);
            }
            for &j in m2.keys() {
                if !m1.contains_key(&j) {
                    overridden(j);
                }
            }
            self.sum_last = base as u64 * (self.d - map.len()) as u64
                + map.values().map(|&lr| lr as u64).sum::<u64>();
            self.repr = Repr::Sparse { map, base };
            self.round = round;
            self.maybe_densify();
            return;
        }
        let mut last = Vec::with_capacity(self.d);
        let mut sum = 0u64;
        for j in 0..self.d {
            let age = pick(r1 - self.last(j), r2 - other.last(j));
            let lr = round - age;
            sum += lr as u64;
            last.push(lr);
        }
        self.repr = Repr::Dense(last);
        self.sum_last = sum;
        self.round = round;
    }

    /// All ages back to 0 (cluster split carry-over rule). Re-enters the
    /// sparse regime: the zeroed vector is uniform, so nothing needs
    /// materializing.
    pub fn reset(&mut self) {
        self.repr = Repr::Sparse { map: std::collections::HashMap::new(), base: self.round };
        self.sum_last = self.round as u64 * self.d as u64;
    }

    /// Ages gathered at `idx` as f32 scores (selection input).
    pub fn gather(&self, idx: &[u32]) -> Vec<f32> {
        idx.iter().map(|&j| self.get(j as usize) as f32).collect()
    }

    /// O(1) in the sparse regime (some coordinate always sits at `base`,
    /// the exact minimum last-reset); the densified regime keeps the old
    /// O(d) sweep.
    pub fn max_age(&self) -> u32 {
        if self.d == 0 {
            return 0;
        }
        match &self.repr {
            Repr::Sparse { base, .. } => self.round - base,
            Repr::Dense(last) => {
                let round = self.round;
                last.iter().map(|&lr| round - lr).max().unwrap_or(0)
            }
        }
    }

    /// O(1): exact integer arithmetic over the running last-reset sum
    /// (`sum(age) = round * d - sum_last`), converted to f64 once.
    pub fn mean_age(&self) -> f64 {
        if self.d == 0 {
            return 0.0;
        }
        (self.round as u64 * self.d as u64 - self.sum_last) as f64 / self.d as f64
    }
}

/// The dense eq. (2) sweep the lazy representation replaced: +1 over all
/// d entries, then reset of the selected indices. Kept as the numerics
/// oracle for the lazy/dense equivalence property test and as the O(d)
/// baseline in `benches/bench_age.rs`. Not used on any hot path.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseAgeVector {
    ages: Vec<u32>,
}

impl DenseAgeVector {
    pub fn new(d: usize) -> Self {
        DenseAgeVector { ages: vec![0; d] }
    }

    pub fn d(&self) -> usize {
        self.ages.len()
    }

    pub fn get(&self, j: usize) -> u32 {
        self.ages[j]
    }

    pub fn as_slice(&self) -> &[u32] {
        &self.ages
    }

    pub fn update(&mut self, selected: &[u32]) {
        for a in self.ages.iter_mut() {
            *a += 1;
        }
        for &j in selected {
            self.ages[j as usize] = 0;
        }
    }

    pub fn merge_min(&mut self, other: &DenseAgeVector) {
        assert_eq!(self.d(), other.d());
        for (a, &b) in self.ages.iter_mut().zip(&other.ages) {
            *a = (*a).min(b);
        }
    }

    pub fn merge_max(&mut self, other: &DenseAgeVector) {
        assert_eq!(self.d(), other.d());
        for (a, &b) in self.ages.iter_mut().zip(&other.ages) {
            *a = (*a).max(b);
        }
    }

    pub fn reset(&mut self) {
        self.ages.fill(0);
    }

    pub fn max_age(&self) -> u32 {
        self.ages.iter().copied().max().unwrap_or(0)
    }
}

/// Per-client request-frequency vector (the f^t[i] of eq. (3)).
///
/// Stored sparsely (only requested indices ever become non-zero and only
/// k per round do) — the dot products in eq. (3) then cost O(nnz), not
/// O(d), which is what makes the M-periodic clustering cheap at d = 2.5M.
#[derive(Debug, Clone, Default)]
pub struct FrequencyVector {
    counts: std::collections::HashMap<u32, u32>,
    total: u64,
}

impl FrequencyVector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one round's requested indices.
    pub fn record(&mut self, idx: &[u32]) {
        for &j in idx {
            *self.counts.entry(j).or_insert(0) += 1;
            self.total += 1;
        }
    }

    pub fn nnz(&self) -> usize {
        self.counts.len()
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn get(&self, j: u32) -> u32 {
        self.counts.get(&j).copied().unwrap_or(0)
    }

    /// The support as (index, count) pairs, in arbitrary (hash) order —
    /// the material the clustering posting index is built from.
    pub fn iter(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.counts.iter().map(|(&j, &c)| (j, c))
    }

    /// <self, other> (sparse dot product over the smaller support).
    pub fn dot(&self, other: &FrequencyVector) -> f64 {
        let (small, big) = if self.counts.len() <= other.counts.len() {
            (self, other)
        } else {
            (other, self)
        };
        small
            .counts
            .iter()
            .map(|(&j, &c)| c as f64 * big.get(j) as f64)
            .sum()
    }

    /// <self, self>.
    pub fn self_dot(&self) -> f64 {
        self.counts.values().map(|&c| (c as f64) * (c as f64)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_partition_invariant() {
        let mut a = AgeVector::new(10);
        a.update(&[2, 5]);
        a.update(&[5, 7]);
        // after round 2: 5,7 are 0; 2 aged once since reset; others 2
        assert_eq!(a.get(5), 0);
        assert_eq!(a.get(7), 0);
        assert_eq!(a.get(2), 1);
        assert_eq!(a.get(0), 2);
        // invariant: every age is either 0 (just selected) or old+1
        let before = a.clone();
        a.update(&[0]);
        for j in 0..10 {
            if j == 0 {
                assert_eq!(a.get(j), 0);
            } else {
                assert_eq!(a.get(j), before.get(j) + 1);
            }
        }
    }

    #[test]
    fn merge_min_takes_freshest() {
        let mut a = AgeVector::new(4);
        let mut b = AgeVector::new(4);
        a.update(&[0]); // a = [0,1,1,1]
        b.update(&[3]);
        b.update(&[3]); // b = [2,2,2,0]
        a.merge_min(&b);
        assert_eq!(a.to_vec(), vec![0, 1, 1, 0]);
        let mut c = AgeVector::new(4);
        c.update(&[1]);
        let mut d = AgeVector::new(4);
        d.update(&[2]);
        d.merge_max(&c);
        assert_eq!(d.to_vec(), vec![1, 1, 1, 1]);
    }

    #[test]
    fn merge_rebases_across_epochs() {
        // operands with very different epochs must still merge exactly
        let mut a = AgeVector::new(3);
        for _ in 0..20 {
            a.update(&[0]); // a = [0, 20, 20]
        }
        let mut b = AgeVector::new(3);
        b.update(&[1]); // b = [1, 0, 1]
        let mut min = a.clone();
        min.merge_min(&b);
        assert_eq!(min.to_vec(), vec![0, 0, 1]);
        let mut max = b; // merge into the *younger* epoch: needs rebasing
        max.merge_max(&a);
        assert_eq!(max.to_vec(), vec![1, 20, 20]);
        // merged vectors keep obeying eq. (2)
        max.update(&[2]);
        assert_eq!(max.to_vec(), vec![2, 21, 0]);
    }

    #[test]
    fn equality_ignores_epoch() {
        let mut a = AgeVector::new(3);
        a.update(&[0, 1, 2]);
        a.update(&[1]); // ages [1, 0, 1]
        let mut b = AgeVector::new(3);
        b.update(&[0, 2]);
        b.update(&[1]); // ages [1, 0, 1] via a different history
        assert_eq!(a, b);
        b.update(&[2]);
        assert_ne!(a, b);
    }

    #[test]
    fn reset_zeroes_all_ages() {
        let mut a = AgeVector::new(5);
        a.update(&[1]);
        a.update(&[2]);
        assert_eq!(a.max_age(), 2);
        a.reset();
        assert_eq!(a.max_age(), 0);
        assert_eq!(a.to_vec(), vec![0; 5]);
        // and eq. (2) continues from the zeroed state
        a.update(&[4]);
        assert_eq!(a.to_vec(), vec![1, 1, 1, 1, 0]);
    }

    #[test]
    fn gather_scores() {
        let mut a = AgeVector::new(5);
        a.update(&[1]);
        a.update(&[4]);
        assert_eq!(a.gather(&[0, 1, 4]), vec![2.0, 1.0, 0.0]);
        assert_eq!(a.max_age(), 2);
        assert!((a.mean_age() - (2.0 + 1.0 + 2.0 + 2.0 + 0.0) / 5.0).abs() < 1e-12);
    }

    #[test]
    fn fresh_vector_materializes_nothing() {
        // the fleet-scale property: 10^5 singleton clusters at d = 2.5M
        // must cost O(1) each until coordinates are actually touched
        let a = AgeVector::new(2_515_338);
        assert_eq!(a.backing_len(), 0);
        assert_eq!(a.max_age(), 0);
        assert_eq!(a.mean_age(), 0.0);
        assert_eq!(a.d(), 2_515_338);
    }

    #[test]
    fn sparse_tracks_only_touched_support() {
        let mut a = AgeVector::new(1000);
        for _ in 0..50 {
            a.update(&[3, 7, 900]);
        }
        assert_eq!(a.backing_len(), 3, "repeated resets must not grow the backing");
        assert_eq!(a.get(3), 0);
        assert_eq!(a.get(0), 50);
        assert_eq!(a.max_age(), 50);
        let expect_mean = (997.0 * 50.0) / 1000.0;
        assert!((a.mean_age() - expect_mean).abs() < 1e-12);
    }

    #[test]
    fn densifies_past_quarter_support_with_identical_ages() {
        let d = 40;
        let mut a = AgeVector::new(d);
        let mut oracle = DenseAgeVector::new(d);
        // touch one new coordinate per round until the sparse->dense
        // transition triggers, checking exact agreement across it
        for j in 0..(d as u32 / 2) {
            a.update(&[j]);
            oracle.update(&[j]);
            assert_eq!(a.to_vec(), oracle.as_slice(), "diverged at round {j}");
            assert_eq!(a.max_age(), oracle.max_age());
        }
        assert_eq!(a.backing_len(), d, "support of d/2 must have densified");
        // and reset() re-enters the sparse regime
        a.reset();
        oracle.reset();
        assert_eq!(a.backing_len(), 0);
        assert_eq!(a.to_vec(), oracle.as_slice());
        a.update(&[0]);
        oracle.update(&[0]);
        assert_eq!(a.to_vec(), oracle.as_slice());
    }

    #[test]
    fn sparse_merge_stays_sparse_and_exact() {
        // two sparse operands with different epochs and overlapping
        // support merge in O(union) without materializing d entries
        let d = 10_000;
        let cases: [(fn(u32, u32) -> u32, fn(&mut AgeVector, &AgeVector)); 2] =
            [(u32::min, AgeVector::merge_min), (u32::max, AgeVector::merge_max)];
        for (pick, merge) in cases {
            let mut a = AgeVector::new(d);
            let mut b = AgeVector::new(d);
            for _ in 0..7 {
                a.update(&[1, 2, 3]);
            }
            for _ in 0..3 {
                b.update(&[3, 4]);
            }
            let mut merged = a.clone();
            merge(&mut merged, &b);
            assert!(merged.backing_len() <= 5, "merge must stay sparse");
            for j in 0..d {
                assert_eq!(merged.get(j), pick(a.get(j), b.get(j)), "index {j}");
            }
            let brute: f64 = (0..d).map(|j| merged.get(j) as f64).sum::<f64>() / d as f64;
            assert!((merged.mean_age() - brute).abs() < 1e-9);
            assert_eq!(merged.max_age(), (0..d).map(|j| merged.get(j)).max().unwrap());
        }
    }

    #[test]
    fn frequency_dot_products() {
        let mut f1 = FrequencyVector::new();
        let mut f2 = FrequencyVector::new();
        f1.record(&[1, 2, 3]);
        f1.record(&[1, 2]);
        f2.record(&[2, 3, 9]);
        // f1 = {1:2, 2:2, 3:1}, f2 = {2:1, 3:1, 9:1}
        assert_eq!(f1.dot(&f2), 3.0);
        assert_eq!(f1.self_dot(), 9.0);
        assert_eq!(f2.self_dot(), 3.0);
        assert_eq!(f1.dot(&f2), f2.dot(&f1));
        assert_eq!(f1.total(), 5);
        assert_eq!(f1.nnz(), 3);
    }

    #[test]
    fn empty_frequency_is_zero() {
        let f = FrequencyVector::new();
        assert_eq!(f.self_dot(), 0.0);
        assert_eq!(f.dot(&FrequencyVector::new()), 0.0);
    }
}
