//! Cluster lifecycle: every client starts as a singleton cluster; every M
//! rounds DBSCAN labels are folded into persistent cluster state.
//!
//! Age-vector carry-over rules (DESIGN.md §5, from the paper's §II):
//! * a new group inherits the **merged** (elementwise-min by default) age
//!   vectors of every old cluster whose member set survived intact into
//!   the group — "when a client is added to an existing cluster, its age
//!   vector is merged with that of the cluster";
//! * clients arriving from a *split* cluster contribute nothing — "if a
//!   client ... is reassigned to a different group, the age vector
//!   relevant for that client is automatically reset".

use super::dbscan::NOISE;
use crate::age::AgeVector;

/// How member age vectors combine on cluster formation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeRule {
    /// freshest-wins (default; an index any member just updated is not
    /// stale for the cluster)
    Min,
    /// stalest-wins (pessimistic ablation)
    Max,
}

/// Persistent cluster state across reclustering events.
#[derive(Debug)]
pub struct ClusterManager {
    d: usize,
    rule: MergeRule,
    /// client -> cluster id (dense, 0..n_clusters)
    assignment: Vec<usize>,
    /// cluster id -> members (sorted)
    members: Vec<Vec<usize>>,
    /// cluster id -> age vector
    ages: Vec<AgeVector>,
}

/// What a reclustering event did (for logs/metrics).
#[derive(Debug, Default, PartialEq, Eq)]
pub struct ReclusterEvents {
    pub merges: usize,
    pub resets: usize,
    pub n_clusters: usize,
}

impl ClusterManager {
    /// Every client starts as its own cluster (paper §II).
    pub fn new(n_clients: usize, d: usize, rule: MergeRule) -> Self {
        ClusterManager {
            d,
            rule,
            assignment: (0..n_clients).collect(),
            members: (0..n_clients).map(|i| vec![i]).collect(),
            ages: (0..n_clients).map(|_| AgeVector::new(d)).collect(),
        }
    }

    /// Reconstitute a manager from explicit cluster state — the dynamic
    /// re-sharding hand-off (DESIGN.md §8): a root aggregator gathers
    /// shard-local clusters into a fleet-wide manager (and splits the
    /// result back into per-shard managers) without disturbing the age
    /// vectors. `groups[c]` are the (sorted) members of cluster `c`;
    /// groups must disjointly cover `0..n_clients` and come ordered by
    /// smallest member, matching [`Self::recluster`]'s id convention.
    pub fn from_parts(
        n_clients: usize,
        d: usize,
        rule: MergeRule,
        groups: Vec<Vec<usize>>,
        ages: Vec<AgeVector>,
    ) -> Self {
        assert_eq!(groups.len(), ages.len(), "one age vector per cluster");
        let mut assignment = vec![usize::MAX; n_clients];
        for (cid, group) in groups.iter().enumerate() {
            assert!(!group.is_empty(), "empty cluster {cid}");
            assert!(group.windows(2).all(|w| w[0] < w[1]), "members must be sorted");
            for &m in group {
                assert!(m < n_clients && assignment[m] == usize::MAX, "member {m} misassigned");
                assignment[m] = cid;
            }
        }
        assert!(
            assignment.iter().all(|&c| c != usize::MAX),
            "groups must cover every client"
        );
        assert!(
            groups.windows(2).all(|w| w[0][0] < w[1][0]),
            "clusters must be ordered by smallest member"
        );
        for age in &ages {
            assert_eq!(age.d(), d, "age dimension mismatch");
        }
        ClusterManager { d, rule, assignment, members: groups, ages }
    }

    pub fn n_clients(&self) -> usize {
        self.assignment.len()
    }

    pub fn n_clusters(&self) -> usize {
        self.members.len()
    }

    pub fn cluster_of(&self, client: usize) -> usize {
        self.assignment[client]
    }

    pub fn members_of(&self, cluster: usize) -> &[usize] {
        &self.members[cluster]
    }

    pub fn age_of_cluster(&self, cluster: usize) -> &AgeVector {
        &self.ages[cluster]
    }

    pub fn age_of_client(&self, client: usize) -> &AgeVector {
        &self.ages[self.assignment[client]]
    }

    /// eq. (2) for one cluster after a global round: one +1 sweep, then
    /// reset of every index requested from any member this round.
    pub fn update_ages(&mut self, cluster: usize, requested_union: &[u32]) {
        self.ages[cluster].update(requested_union);
    }

    /// Current assignment as ground-truth-comparable labels.
    pub fn labels(&self) -> Vec<usize> {
        self.assignment.clone()
    }

    /// Partition the clients into `shards` balanced groups **without
    /// splitting any cluster** — the assignment a hierarchical topology
    /// uses so every cluster's disjoint-selection coordination stays
    /// inside one shard engine. Deterministic: clusters are taken in id
    /// order (ids are ordered by smallest member) and each shard is
    /// filled to its balanced target before the next opens, so with
    /// singleton clusters (the initial state) the result is exactly the
    /// contiguous balanced slices of `0..n`. Member lists within a shard
    /// come out sorted. Requires `1 <= shards <= n_clusters`.
    pub fn shard_slices(&self, shards: usize) -> Vec<Vec<usize>> {
        let n = self.n_clients();
        assert!(
            shards >= 1 && shards <= self.n_clusters(),
            "need 1 <= shards ({shards}) <= n_clusters ({})",
            self.n_clusters()
        );
        // balanced targets: the first n % shards shards take one extra
        let base = n / shards;
        let target = |s: usize| base + usize::from(s < n % shards);
        let mut out: Vec<Vec<usize>> = vec![Vec::new(); shards];
        let mut s = 0;
        for (ci, cluster) in self.members.iter().enumerate() {
            // advance when the current shard met its target — or when the
            // remaining clusters are exactly one per still-empty shard
            // (oversized clusters may have overfilled earlier shards), so
            // no shard is ever left without clients
            let clusters_left = self.members.len() - ci;
            let empty_after = shards - s - 1;
            if s + 1 < shards
                && !out[s].is_empty()
                && (out[s].len() >= target(s) || clusters_left == empty_after)
            {
                s += 1;
            }
            out[s].extend_from_slice(cluster);
        }
        for slice in &mut out {
            slice.sort_unstable();
        }
        out
    }

    /// Fold DBSCAN output into persistent clusters. `labels[i]` is the
    /// DBSCAN label of client i ([`NOISE`] allowed).
    pub fn recluster(&mut self, labels: &[isize]) -> ReclusterEvents {
        assert_eq!(labels.len(), self.n_clients());
        // group clients by new label; noise -> singleton groups
        let mut groups: Vec<Vec<usize>> = Vec::new();
        {
            let mut by_label: std::collections::BTreeMap<isize, Vec<usize>> =
                std::collections::BTreeMap::new();
            for (i, &l) in labels.iter().enumerate() {
                if l == NOISE {
                    groups.push(vec![i]);
                } else {
                    by_label.entry(l).or_default().push(i);
                }
            }
            groups.extend(by_label.into_values());
        }
        groups.sort(); // deterministic ids by smallest member

        let mut events = ReclusterEvents { n_clusters: groups.len(), ..Default::default() };
        let old_members = std::mem::take(&mut self.members);
        let old_ages = std::mem::take(&mut self.ages);
        let old_assignment = self.assignment.clone();

        let mut new_ages: Vec<AgeVector> = Vec::with_capacity(groups.len());
        for group in &groups {
            // old clusters fully contained in this group carry their vector
            let group_set: std::collections::HashSet<usize> = group.iter().cloned().collect();
            let mut carried: Vec<&AgeVector> = Vec::new();
            let mut seen_old: std::collections::HashSet<usize> = Default::default();
            for &client in group {
                let oc = old_assignment[client];
                if !seen_old.insert(oc) {
                    continue;
                }
                if old_members[oc].iter().all(|m| group_set.contains(m)) {
                    carried.push(&old_ages[oc]);
                } else {
                    events.resets += 1; // split cluster: members arrive reset
                }
            }
            let mut age = match carried.split_first() {
                Some((first, rest)) => {
                    let mut a = (*first).clone();
                    for other in rest {
                        match self.rule {
                            MergeRule::Min => a.merge_min(other),
                            MergeRule::Max => a.merge_max(other),
                        }
                        events.merges += 1;
                    }
                    a
                }
                None => AgeVector::new(self.d),
            };
            // ages are indexed per cluster; dimension must be preserved
            debug_assert_eq!(age.d(), self.d);
            if carried.is_empty() {
                age.reset();
            }
            new_ages.push(age);
        }

        for (cid, group) in groups.iter().enumerate() {
            for &client in group {
                self.assignment[client] = cid;
            }
        }
        self.members = groups;
        self.ages = new_ages;
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_as_singletons() {
        let m = ClusterManager::new(4, 10, MergeRule::Min);
        assert_eq!(m.n_clusters(), 4);
        for i in 0..4 {
            assert_eq!(m.cluster_of(i), i);
            assert_eq!(m.members_of(i), &[i]);
        }
    }

    #[test]
    fn pairing_merges_age_vectors() {
        let mut m = ClusterManager::new(4, 6, MergeRule::Min);
        m.update_ages(0, &[0]); // client 0's vector: idx 0 fresh
        m.update_ages(1, &[3]); // client 1's vector: idx 3 fresh
        let ev = m.recluster(&[0, 0, 1, 1]);
        assert_eq!(ev.n_clusters, 2);
        assert_eq!(ev.merges, 2); // one per pair
        assert_eq!(m.cluster_of(0), m.cluster_of(1));
        // merged min: both 0 and 3 fresh
        let a = m.age_of_client(0);
        assert_eq!(a.get(0), 0);
        assert_eq!(a.get(3), 0);
        assert_eq!(a.get(1), 1);
    }

    #[test]
    fn split_resets() {
        let mut m = ClusterManager::new(4, 6, MergeRule::Min);
        m.recluster(&[0, 0, 1, 1]);
        let c0 = m.cluster_of(0);
        m.update_ages(c0, &[2]);
        // now split the pair (0 stays with 2; 1 goes with 3)
        let ev = m.recluster(&[0, 1, 0, 1]);
        assert!(ev.resets >= 2, "{ev:?}");
        // both new clusters start from zeroed vectors
        assert_eq!(m.age_of_client(0).max_age(), 0);
        assert_eq!(m.age_of_client(1).max_age(), 0);
    }

    #[test]
    fn noise_clients_stay_singletons_and_keep_state() {
        let mut m = ClusterManager::new(3, 4, MergeRule::Min);
        m.update_ages(2, &[1]);
        let before = m.age_of_client(2).clone();
        let ev = m.recluster(&[0, 0, NOISE]);
        assert_eq!(ev.n_clusters, 2);
        // singleton old cluster {2} is fully contained in new group {2}
        assert_eq!(m.age_of_client(2), &before);
        assert_ne!(m.cluster_of(0), m.cluster_of(2));
    }

    #[test]
    fn shard_slices_singletons_are_contiguous_and_balanced() {
        let m = ClusterManager::new(10, 4, MergeRule::Min);
        assert_eq!(
            m.shard_slices(3),
            vec![vec![0, 1, 2, 3], vec![4, 5, 6], vec![7, 8, 9]],
            "singleton clusters shard into contiguous balanced slices"
        );
        assert_eq!(m.shard_slices(1), vec![(0..10).collect::<Vec<_>>()]);
    }

    #[test]
    fn shard_slices_never_split_clusters() {
        let mut m = ClusterManager::new(6, 4, MergeRule::Min);
        m.recluster(&[0, 0, 0, 0, 1, 2]); // clusters {0..3}, {4}, {5}
        let slices = m.shard_slices(3);
        // the big cluster overfills shard 0; the rest spread one each
        assert_eq!(slices, vec![vec![0, 1, 2, 3], vec![4], vec![5]]);
        for slices in [m.shard_slices(2), m.shard_slices(3)] {
            // disjoint cover of all clients, no cluster split across shards
            let mut seen = vec![false; 6];
            for slice in &slices {
                assert!(!slice.is_empty(), "no shard may be empty: {slices:?}");
                for &c in slice {
                    assert!(!seen[c]);
                    seen[c] = true;
                }
                for &c in slice {
                    let cluster = m.cluster_of(c);
                    assert!(
                        m.members_of(cluster).iter().all(|mm| slice.contains(mm)),
                        "cluster {cluster} split across shards: {slices:?}"
                    );
                }
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn from_parts_reconstitutes_cluster_state() {
        let mut m = ClusterManager::new(4, 6, MergeRule::Min);
        m.recluster(&[0, 0, 1, 1]);
        m.update_ages(m.cluster_of(0), &[2]);
        let groups = vec![m.members_of(0).to_vec(), m.members_of(1).to_vec()];
        let ages = vec![m.age_of_cluster(0).clone(), m.age_of_cluster(1).clone()];
        let back = ClusterManager::from_parts(4, 6, MergeRule::Min, groups, ages);
        assert_eq!(back.n_clusters(), 2);
        for c in 0..4 {
            assert_eq!(back.cluster_of(c), m.cluster_of(c));
            assert_eq!(back.age_of_client(c), m.age_of_client(c));
        }
    }

    #[test]
    fn stable_reclustering_preserves_everything() {
        let mut m = ClusterManager::new(4, 4, MergeRule::Min);
        m.recluster(&[0, 0, 1, 1]);
        let c = m.cluster_of(0);
        m.update_ages(c, &[3]);
        let before = m.age_of_cluster(c).clone();
        let ev = m.recluster(&[5, 5, 9, 9]); // same partition, new label ids
        assert_eq!(ev.resets, 0);
        assert_eq!(m.age_of_client(0), &before);
    }
}
