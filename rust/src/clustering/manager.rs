//! Cluster lifecycle: every client starts as a singleton cluster; every M
//! rounds DBSCAN labels are folded into persistent cluster state.
//!
//! Age-vector carry-over rules (DESIGN.md §5, from the paper's §II):
//! * a new group inherits the **merged** (elementwise-min by default) age
//!   vectors of every old cluster whose member set survived intact into
//!   the group — "when a client is added to an existing cluster, its age
//!   vector is merged with that of the cluster";
//! * clients arriving from a *split* cluster contribute nothing — "if a
//!   client ... is reassigned to a different group, the age vector
//!   relevant for that client is automatically reset".

use super::dbscan::NOISE;
use crate::age::AgeVector;

/// How member age vectors combine on cluster formation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeRule {
    /// freshest-wins (default; an index any member just updated is not
    /// stale for the cluster)
    Min,
    /// stalest-wins (pessimistic ablation)
    Max,
}

/// Persistent cluster state across reclustering events.
#[derive(Debug)]
pub struct ClusterManager {
    d: usize,
    rule: MergeRule,
    /// client -> cluster id (dense, 0..n_clusters)
    assignment: Vec<usize>,
    /// cluster id -> members (sorted)
    members: Vec<Vec<usize>>,
    /// cluster id -> age vector
    ages: Vec<AgeVector>,
}

/// What a reclustering event did (for logs/metrics).
#[derive(Debug, Default, PartialEq, Eq)]
pub struct ReclusterEvents {
    pub merges: usize,
    pub resets: usize,
    pub n_clusters: usize,
}

impl ClusterManager {
    /// Every client starts as its own cluster (paper §II).
    pub fn new(n_clients: usize, d: usize, rule: MergeRule) -> Self {
        ClusterManager {
            d,
            rule,
            assignment: (0..n_clients).collect(),
            members: (0..n_clients).map(|i| vec![i]).collect(),
            ages: (0..n_clients).map(|_| AgeVector::new(d)).collect(),
        }
    }

    pub fn n_clients(&self) -> usize {
        self.assignment.len()
    }

    pub fn n_clusters(&self) -> usize {
        self.members.len()
    }

    pub fn cluster_of(&self, client: usize) -> usize {
        self.assignment[client]
    }

    pub fn members_of(&self, cluster: usize) -> &[usize] {
        &self.members[cluster]
    }

    pub fn age_of_cluster(&self, cluster: usize) -> &AgeVector {
        &self.ages[cluster]
    }

    pub fn age_of_client(&self, client: usize) -> &AgeVector {
        &self.ages[self.assignment[client]]
    }

    /// eq. (2) for one cluster after a global round: one +1 sweep, then
    /// reset of every index requested from any member this round.
    pub fn update_ages(&mut self, cluster: usize, requested_union: &[u32]) {
        self.ages[cluster].update(requested_union);
    }

    /// Current assignment as ground-truth-comparable labels.
    pub fn labels(&self) -> Vec<usize> {
        self.assignment.clone()
    }

    /// Fold DBSCAN output into persistent clusters. `labels[i]` is the
    /// DBSCAN label of client i ([`NOISE`] allowed).
    pub fn recluster(&mut self, labels: &[isize]) -> ReclusterEvents {
        assert_eq!(labels.len(), self.n_clients());
        // group clients by new label; noise -> singleton groups
        let mut groups: Vec<Vec<usize>> = Vec::new();
        {
            let mut by_label: std::collections::BTreeMap<isize, Vec<usize>> =
                std::collections::BTreeMap::new();
            for (i, &l) in labels.iter().enumerate() {
                if l == NOISE {
                    groups.push(vec![i]);
                } else {
                    by_label.entry(l).or_default().push(i);
                }
            }
            groups.extend(by_label.into_values());
        }
        groups.sort(); // deterministic ids by smallest member

        let mut events = ReclusterEvents { n_clusters: groups.len(), ..Default::default() };
        let old_members = std::mem::take(&mut self.members);
        let old_ages = std::mem::take(&mut self.ages);
        let old_assignment = self.assignment.clone();

        let mut new_ages: Vec<AgeVector> = Vec::with_capacity(groups.len());
        for group in &groups {
            // old clusters fully contained in this group carry their vector
            let group_set: std::collections::HashSet<usize> = group.iter().cloned().collect();
            let mut carried: Vec<&AgeVector> = Vec::new();
            let mut seen_old: std::collections::HashSet<usize> = Default::default();
            for &client in group {
                let oc = old_assignment[client];
                if !seen_old.insert(oc) {
                    continue;
                }
                if old_members[oc].iter().all(|m| group_set.contains(m)) {
                    carried.push(&old_ages[oc]);
                } else {
                    events.resets += 1; // split cluster: members arrive reset
                }
            }
            let mut age = match carried.split_first() {
                Some((first, rest)) => {
                    let mut a = (*first).clone();
                    for other in rest {
                        match self.rule {
                            MergeRule::Min => a.merge_min(other),
                            MergeRule::Max => a.merge_max(other),
                        }
                        events.merges += 1;
                    }
                    a
                }
                None => AgeVector::new(self.d),
            };
            // ages are indexed per cluster; dimension must be preserved
            debug_assert_eq!(age.d(), self.d);
            if carried.is_empty() {
                age.reset();
            }
            new_ages.push(age);
        }

        for (cid, group) in groups.iter().enumerate() {
            for &client in group {
                self.assignment[client] = cid;
            }
        }
        self.members = groups;
        self.ages = new_ages;
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_as_singletons() {
        let m = ClusterManager::new(4, 10, MergeRule::Min);
        assert_eq!(m.n_clusters(), 4);
        for i in 0..4 {
            assert_eq!(m.cluster_of(i), i);
            assert_eq!(m.members_of(i), &[i]);
        }
    }

    #[test]
    fn pairing_merges_age_vectors() {
        let mut m = ClusterManager::new(4, 6, MergeRule::Min);
        m.update_ages(0, &[0]); // client 0's vector: idx 0 fresh
        m.update_ages(1, &[3]); // client 1's vector: idx 3 fresh
        let ev = m.recluster(&[0, 0, 1, 1]);
        assert_eq!(ev.n_clusters, 2);
        assert_eq!(ev.merges, 2); // one per pair
        assert_eq!(m.cluster_of(0), m.cluster_of(1));
        // merged min: both 0 and 3 fresh
        let a = m.age_of_client(0);
        assert_eq!(a.get(0), 0);
        assert_eq!(a.get(3), 0);
        assert_eq!(a.get(1), 1);
    }

    #[test]
    fn split_resets() {
        let mut m = ClusterManager::new(4, 6, MergeRule::Min);
        m.recluster(&[0, 0, 1, 1]);
        let c0 = m.cluster_of(0);
        m.update_ages(c0, &[2]);
        // now split the pair (0 stays with 2; 1 goes with 3)
        let ev = m.recluster(&[0, 1, 0, 1]);
        assert!(ev.resets >= 2, "{ev:?}");
        // both new clusters start from zeroed vectors
        assert_eq!(m.age_of_client(0).max_age(), 0);
        assert_eq!(m.age_of_client(1).max_age(), 0);
    }

    #[test]
    fn noise_clients_stay_singletons_and_keep_state() {
        let mut m = ClusterManager::new(3, 4, MergeRule::Min);
        m.update_ages(2, &[1]);
        let before = m.age_of_client(2).clone();
        let ev = m.recluster(&[0, 0, NOISE]);
        assert_eq!(ev.n_clusters, 2);
        // singleton old cluster {2} is fully contained in new group {2}
        assert_eq!(m.age_of_client(2), &before);
        assert_ne!(m.cluster_of(0), m.cluster_of(2));
    }

    #[test]
    fn stable_reclustering_preserves_everything() {
        let mut m = ClusterManager::new(4, 4, MergeRule::Min);
        m.recluster(&[0, 0, 1, 1]);
        let c = m.cluster_of(0);
        m.update_ages(c, &[3]);
        let before = m.age_of_cluster(c).clone();
        let ev = m.recluster(&[5, 5, 9, 9]); // same partition, new label ids
        assert_eq!(ev.resets, 0);
        assert_eq!(m.age_of_client(0), &before);
    }
}
