//! eq. (3): pairwise frequency-vector similarity.
//!
//! The paper's measure is asymmetric —
//! `d[i1, i2] = <f[i1], f[i2]> / <f[i1], f[i1]>` — i.e. the overlap of
//! i2's request history with i1's, normalized by i1's own mass. DBSCAN
//! needs a symmetric distance; we symmetrize by averaging the two
//! directions and clamp into [0, 1] (DESIGN.md §5).

use crate::age::FrequencyVector;

/// The asymmetric similarity matrix of eq. (3) (the "connectivity matrix"
/// whose heatmaps are Fig. 2 / Fig. 4).
pub fn connectivity_matrix(freqs: &[FrequencyVector]) -> Vec<Vec<f64>> {
    let n = freqs.len();
    let self_dots: Vec<f64> = freqs.iter().map(|f| f.self_dot()).collect();
    let mut m = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in 0..n {
            if self_dots[i] <= 0.0 {
                m[i][j] = if i == j { 1.0 } else { 0.0 };
            } else if i == j {
                m[i][j] = 1.0;
            } else {
                m[i][j] = freqs[i].dot(&freqs[j]) / self_dots[i];
            }
        }
    }
    m
}

/// Symmetrized distance for DBSCAN: 1 - clamp(mean(c[i][j], c[j][i])).
pub fn distance_matrix(connectivity: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let n = connectivity.len();
    let mut d = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in 0..n {
            let s = 0.5 * (connectivity[i][j] + connectivity[j][i]);
            d[i][j] = (1.0 - s).clamp(0.0, 1.0);
        }
    }
    d
}

/// Sparse neighborhood oracle over the eq.-(3) geometry (fleet-scale
/// refit, DESIGN.md §12): an inverted index from feature (parameter
/// index) to the clients whose request history touches it.
///
/// The dense pipeline materializes the full n×n connectivity and distance
/// matrices — O(n²) memory and O(n² · nnz) time, the structure that caps
/// reclustering at a few hundred clients. But two clients are at distance
/// < 1.0 **only if** their frequency supports intersect (both dot
/// products are zero otherwise and the distance clamps to exactly 1.0),
/// so for any `eps < 1.0` the neighbor set of `i` lives inside the union
/// of the posting lists of `i`'s own support. [`Self::neighbors`]
/// enumerates those candidates and evaluates the *same* f64 expression
/// per pair as [`connectivity_matrix`] + [`distance_matrix`] — both dot
/// directions, the same operand order, the same clamp — so the labels
/// that come out of [`crate::clustering::dbscan_with`] are bit-identical
/// to the matrix path (pinned in `lean_neighbors_match_dense_matrix`).
/// `eps >= 1.0` degenerates to everything-is-a-neighbor and is answered
/// without touching the index.
pub struct SimilarityIndex<'a> {
    freqs: &'a [FrequencyVector],
    self_dots: Vec<f64>,
    /// feature -> ascending client ids whose support contains it
    postings: std::collections::HashMap<u32, Vec<u32>>,
}

impl<'a> SimilarityIndex<'a> {
    /// Build in O(total support) — no pairwise work.
    pub fn new(freqs: &'a [FrequencyVector]) -> Self {
        let self_dots: Vec<f64> = freqs.iter().map(|f| f.self_dot()).collect();
        let mut postings: std::collections::HashMap<u32, Vec<u32>> =
            std::collections::HashMap::new();
        for (i, f) in freqs.iter().enumerate() {
            for (j, _) in f.iter() {
                postings.entry(j).or_default().push(i as u32);
            }
        }
        SimilarityIndex { freqs, self_dots, postings }
    }

    /// The symmetrized eq.-(3) distance of the dense pipeline, term for
    /// term: `connectivity_matrix` computes c[i][j] with `freqs[i]` as
    /// the dot receiver and c[j][i] with `freqs[j]` — replicated exactly
    /// so f64 summation order (and thus every last bit) matches.
    fn distance(&self, i: usize, j: usize) -> f64 {
        let c = |a: usize, b: usize| -> f64 {
            if self.self_dots[a] <= 0.0 {
                if a == b {
                    1.0
                } else {
                    0.0
                }
            } else if a == b {
                1.0
            } else {
                self.freqs[a].dot(&self.freqs[b]) / self.self_dots[a]
            }
        };
        let s = 0.5 * (c(i, j) + c(j, i));
        (1.0 - s).clamp(0.0, 1.0)
    }

    /// All points within `eps` of `i` (including `i`), ascending — the
    /// oracle [`crate::clustering::dbscan_with`] expects. Cost is
    /// O(candidate support) per call, never O(n).
    pub fn neighbors(&self, i: usize, eps: f64) -> Vec<usize> {
        let n = self.freqs.len();
        if eps >= 1.0 {
            // every pairwise distance clamps to <= 1.0
            return (0..n).collect();
        }
        let mut cand: Vec<usize> = vec![i];
        for (j, _) in self.freqs[i].iter() {
            if let Some(post) = self.postings.get(&j) {
                cand.extend(post.iter().map(|&c| c as usize));
            }
        }
        cand.sort_unstable();
        cand.dedup();
        cand.retain(|&j| self.distance(i, j) <= eps);
        cand
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fv(rounds: &[&[u32]]) -> FrequencyVector {
        let mut f = FrequencyVector::new();
        for r in rounds {
            f.record(r);
        }
        f
    }

    #[test]
    fn identical_histories_have_similarity_one() {
        let a = fv(&[&[1, 2, 3], &[1, 2, 3]]);
        let b = fv(&[&[1, 2, 3], &[1, 2, 3]]);
        let m = connectivity_matrix(&[a, b]);
        assert!((m[0][1] - 1.0).abs() < 1e-12);
        assert!((m[1][0] - 1.0).abs() < 1e-12);
        let d = distance_matrix(&m);
        assert!(d[0][1] < 1e-12);
    }

    #[test]
    fn disjoint_histories_have_similarity_zero() {
        let a = fv(&[&[1, 2]]);
        let b = fv(&[&[8, 9]]);
        let m = connectivity_matrix(&[a, b]);
        assert_eq!(m[0][1], 0.0);
        let d = distance_matrix(&m);
        assert_eq!(d[0][1], 1.0);
    }

    #[test]
    fn asymmetry_normalization() {
        // a's mass is 4x b's: overlap relative to a is smaller
        let a = fv(&[&[1, 2], &[1, 2], &[1, 2], &[1, 2]]);
        let b = fv(&[&[1, 2]]);
        let m = connectivity_matrix(&[a, b]);
        // <a,b> = 4*1 + 4*1 = 8; <a,a> = 32; <b,b> = 2
        assert!((m[0][1] - 8.0 / 32.0).abs() < 1e-12);
        assert!((m[1][0] - 8.0 / 2.0).abs() < 1e-12);
        // distance symmetrizes and clamps the >1 direction
        let d = distance_matrix(&m);
        assert_eq!(d[0][1], d[1][0]);
        assert_eq!(d[0][1], 0.0); // mean(0.25, 4.0) > 1 -> clamped
    }

    #[test]
    fn empty_history_is_isolated() {
        let a = FrequencyVector::new();
        let b = fv(&[&[1]]);
        let m = connectivity_matrix(&[a, b]);
        assert_eq!(m[0][0], 1.0);
        assert_eq!(m[0][1], 0.0);
    }

    /// Randomized histories (overlapping supports, empty clients,
    /// heavy-hitter features): the posting-list oracle must return
    /// exactly the dense matrix's neighbor rows, bit for bit.
    #[test]
    fn lean_neighbors_match_dense_matrix() {
        let mut rng = crate::util::rng::Rng::new(0xC1u64);
        for trial in 0..20 {
            let n = 2 + rng.below(30);
            let freqs: Vec<FrequencyVector> = (0..n)
                .map(|_| {
                    let mut f = FrequencyVector::new();
                    for _ in 0..rng.below(6) {
                        let idx: Vec<u32> =
                            (0..1 + rng.below(8)).map(|_| rng.below(40) as u32).collect();
                        f.record(&idx);
                    }
                    f
                })
                .collect();
            let dist = distance_matrix(&connectivity_matrix(&freqs));
            let index = SimilarityIndex::new(&freqs);
            for eps in [0.05, 0.35, 0.8, 1.0, 1.5] {
                for i in 0..n {
                    let dense: Vec<usize> = (0..n).filter(|&j| dist[i][j] <= eps).collect();
                    assert_eq!(
                        index.neighbors(i, eps),
                        dense,
                        "trial {trial}, eps {eps}, point {i}"
                    );
                }
            }
        }
    }
}
