//! eq. (3): pairwise frequency-vector similarity.
//!
//! The paper's measure is asymmetric —
//! `d[i1, i2] = <f[i1], f[i2]> / <f[i1], f[i1]>` — i.e. the overlap of
//! i2's request history with i1's, normalized by i1's own mass. DBSCAN
//! needs a symmetric distance; we symmetrize by averaging the two
//! directions and clamp into [0, 1] (DESIGN.md §5).

use crate::age::FrequencyVector;

/// The asymmetric similarity matrix of eq. (3) (the "connectivity matrix"
/// whose heatmaps are Fig. 2 / Fig. 4).
pub fn connectivity_matrix(freqs: &[FrequencyVector]) -> Vec<Vec<f64>> {
    let n = freqs.len();
    let self_dots: Vec<f64> = freqs.iter().map(|f| f.self_dot()).collect();
    let mut m = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in 0..n {
            if self_dots[i] <= 0.0 {
                m[i][j] = if i == j { 1.0 } else { 0.0 };
            } else if i == j {
                m[i][j] = 1.0;
            } else {
                m[i][j] = freqs[i].dot(&freqs[j]) / self_dots[i];
            }
        }
    }
    m
}

/// Symmetrized distance for DBSCAN: 1 - clamp(mean(c[i][j], c[j][i])).
pub fn distance_matrix(connectivity: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let n = connectivity.len();
    let mut d = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in 0..n {
            let s = 0.5 * (connectivity[i][j] + connectivity[j][i]);
            d[i][j] = (1.0 - s).clamp(0.0, 1.0);
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fv(rounds: &[&[u32]]) -> FrequencyVector {
        let mut f = FrequencyVector::new();
        for r in rounds {
            f.record(r);
        }
        f
    }

    #[test]
    fn identical_histories_have_similarity_one() {
        let a = fv(&[&[1, 2, 3], &[1, 2, 3]]);
        let b = fv(&[&[1, 2, 3], &[1, 2, 3]]);
        let m = connectivity_matrix(&[a, b]);
        assert!((m[0][1] - 1.0).abs() < 1e-12);
        assert!((m[1][0] - 1.0).abs() < 1e-12);
        let d = distance_matrix(&m);
        assert!(d[0][1] < 1e-12);
    }

    #[test]
    fn disjoint_histories_have_similarity_zero() {
        let a = fv(&[&[1, 2]]);
        let b = fv(&[&[8, 9]]);
        let m = connectivity_matrix(&[a, b]);
        assert_eq!(m[0][1], 0.0);
        let d = distance_matrix(&m);
        assert_eq!(d[0][1], 1.0);
    }

    #[test]
    fn asymmetry_normalization() {
        // a's mass is 4x b's: overlap relative to a is smaller
        let a = fv(&[&[1, 2], &[1, 2], &[1, 2], &[1, 2]]);
        let b = fv(&[&[1, 2]]);
        let m = connectivity_matrix(&[a, b]);
        // <a,b> = 4*1 + 4*1 = 8; <a,a> = 32; <b,b> = 2
        assert!((m[0][1] - 8.0 / 32.0).abs() < 1e-12);
        assert!((m[1][0] - 8.0 / 2.0).abs() < 1e-12);
        // distance symmetrizes and clamps the >1 direction
        let d = distance_matrix(&m);
        assert_eq!(d[0][1], d[1][0]);
        assert_eq!(d[0][1], 0.0); // mean(0.25, 4.0) > 1 -> clamped
    }

    #[test]
    fn empty_history_is_isolated() {
        let a = FrequencyVector::new();
        let b = fv(&[&[1]]);
        let m = connectivity_matrix(&[a, b]);
        assert_eq!(m[0][0], 1.0);
        assert_eq!(m[0][1], 0.0);
    }
}
