//! DBSCAN (Ester et al., KDD'96) from scratch over a precomputed distance
//! matrix — no external clustering crate exists offline, and the client
//! counts here (N <= a few hundred) make the O(N^2) neighborhood queries
//! irrelevant.
//!
//! Semantics follow the original paper: `eps`-neighborhoods *include* the
//! point itself; a point is a core point iff its neighborhood has at
//! least `min_pts` members; clusters grow by expanding core points;
//! non-core points reachable from a core point become border points;
//! everything else is labelled [`NOISE`].

/// Label for unclustered (noise) points.
pub const NOISE: isize = -1;

#[derive(Debug, Clone, Copy)]
pub struct DbscanParams {
    /// neighborhood radius on the symmetrized eq.-3 distance
    pub eps: f64,
    /// minimum neighborhood size (incl. self) to be a core point
    pub min_pts: usize,
}

impl Default for DbscanParams {
    fn default() -> Self {
        // the paper's pair structure: two similar clients form a cluster
        DbscanParams { eps: 0.35, min_pts: 2 }
    }
}

/// Cluster a symmetric `dist` matrix. Returns one label per point:
/// cluster ids 0, 1, ... in discovery order, or [`NOISE`].
pub fn dbscan(dist: &[Vec<f64>], params: DbscanParams) -> Vec<isize> {
    let n = dist.len();
    for (i, row) in dist.iter().enumerate() {
        assert_eq!(row.len(), n, "distance matrix must be square (row {i})");
    }
    dbscan_with(n, params, |i| (0..n).filter(|&j| dist[i][j] <= params.eps).collect())
}

/// DBSCAN over an abstract neighborhood oracle: `neighbors(i)` returns
/// every point within `eps` of `i` (including `i` itself), **ascending**.
/// This is the fleet-scale entry point — paired with
/// [`crate::clustering::SimilarityIndex`] the oracle answers from sparse
/// posting lists in O(candidates) instead of an O(n²) materialized
/// matrix, while the expansion logic (and therefore the labelling) stays
/// byte-identical to the matrix form above, which now delegates here.
pub fn dbscan_with<F>(n: usize, params: DbscanParams, mut neighbors: F) -> Vec<isize>
where
    F: FnMut(usize) -> Vec<usize>,
{
    let mut labels = vec![NOISE; n];
    let mut visited = vec![false; n];
    let mut next_cluster: isize = 0;

    for p in 0..n {
        if visited[p] {
            continue;
        }
        visited[p] = true;
        let nbrs = neighbors(p);
        if nbrs.len() < params.min_pts {
            continue; // stays noise unless later captured as border point
        }
        let cluster = next_cluster;
        next_cluster += 1;
        labels[p] = cluster;
        // expand
        let mut queue: std::collections::VecDeque<usize> = nbrs.into();
        while let Some(q) = queue.pop_front() {
            if labels[q] == NOISE {
                labels[q] = cluster; // border or core, captured either way
            }
            if visited[q] {
                continue;
            }
            visited[q] = true;
            let qn = neighbors(q);
            if qn.len() >= params.min_pts {
                for x in qn {
                    queue.push_back(x);
                }
            }
        }
    }
    labels
}

/// Adjusted-for-our-tests helper: number of clusters found (excl. noise).
pub fn n_clusters(labels: &[isize]) -> usize {
    labels.iter().filter(|&&l| l >= 0).map(|&l| l).max().map(|m| m as usize + 1).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// distances from 1-D points for easy test construction
    fn dist_1d(xs: &[f64]) -> Vec<Vec<f64>> {
        xs.iter()
            .map(|&a| xs.iter().map(|&b| (a - b).abs()).collect())
            .collect()
    }

    #[test]
    fn two_blobs_and_noise() {
        // blobs {0,1,2} at ~0 and {3,4} at ~10, noise at 100
        let d = dist_1d(&[0.0, 0.1, 0.2, 10.0, 10.1, 100.0]);
        let labels = dbscan(&d, DbscanParams { eps: 0.5, min_pts: 2 });
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_ne!(labels[0], labels[3]);
        assert_eq!(labels[5], NOISE);
        assert_eq!(n_clusters(&labels), 2);
    }

    #[test]
    fn chain_connectivity() {
        // density-reachable chain: all one cluster even though ends are far
        let d = dist_1d(&[0.0, 0.4, 0.8, 1.2, 1.6]);
        let labels = dbscan(&d, DbscanParams { eps: 0.5, min_pts: 2 });
        assert!(labels.iter().all(|&l| l == 0), "{labels:?}");
    }

    #[test]
    fn min_pts_three_rejects_pairs() {
        let d = dist_1d(&[0.0, 0.1, 5.0, 5.1, 5.2]);
        let labels = dbscan(&d, DbscanParams { eps: 0.5, min_pts: 3 });
        assert_eq!(labels[0], NOISE);
        assert_eq!(labels[1], NOISE);
        assert_eq!(labels[2], 0);
        assert_eq!(labels[3], 0);
        assert_eq!(labels[4], 0);
    }

    #[test]
    fn border_point_capture() {
        // 0,1,2 dense core; 3 within eps of 2 but with only 2 neighbors
        let d = dist_1d(&[0.0, 0.2, 0.4, 0.85]);
        let labels = dbscan(&d, DbscanParams { eps: 0.5, min_pts: 3 });
        assert_eq!(labels[0], 0);
        assert_eq!(labels[3], 0, "border point must join the cluster");
    }

    #[test]
    fn all_noise_and_empty() {
        let d = dist_1d(&[0.0, 10.0, 20.0]);
        let labels = dbscan(&d, DbscanParams { eps: 0.5, min_pts: 2 });
        assert!(labels.iter().all(|&l| l == NOISE));
        assert_eq!(n_clusters(&labels), 0);
        assert!(dbscan(&[], DbscanParams::default()).is_empty());
    }

    #[test]
    fn permutation_invariance_of_partition() {
        // relabeling points must produce the same partition structure
        let xs = [0.0, 0.1, 5.0, 5.1, 9.0, 9.05];
        let d1 = dist_1d(&xs);
        let perm = [3usize, 0, 5, 1, 4, 2];
        let xs2: Vec<f64> = perm.iter().map(|&i| xs[i]).collect();
        let d2 = dist_1d(&xs2);
        let p = DbscanParams { eps: 0.5, min_pts: 2 };
        let l1 = dbscan(&d1, p);
        let l2 = dbscan(&d2, p);
        // same-cluster relation must be preserved under the permutation
        for a in 0..xs.len() {
            for b in 0..xs.len() {
                let (pa, pb) = (
                    perm.iter().position(|&x| x == a).unwrap(),
                    perm.iter().position(|&x| x == b).unwrap(),
                );
                assert_eq!(
                    l1[a] == l1[b],
                    l2[pa] == l2[pb],
                    "pair ({a},{b})"
                );
            }
        }
    }
}
