//! Client clustering: the eq. (3) similarity matrix, a from-scratch
//! DBSCAN, and the cluster lifecycle manager (merge-on-join /
//! reset-on-reassignment).

pub mod dbscan;
pub mod manager;
pub mod similarity;

pub use dbscan::{dbscan, DbscanParams, NOISE};
pub use manager::{ClusterManager, MergeRule};
pub use similarity::{connectivity_matrix, distance_matrix};
