//! Client clustering: the eq. (3) similarity matrix, a from-scratch
//! DBSCAN, and the cluster lifecycle manager (merge-on-join /
//! reset-on-reassignment).

pub mod dbscan;
pub mod manager;
pub mod similarity;

pub use dbscan::{dbscan, dbscan_with, DbscanParams, NOISE};
pub use manager::{ClusterManager, MergeRule};
pub use similarity::{connectivity_matrix, distance_matrix, SimilarityIndex};

use crate::age::FrequencyVector;

/// The full frequency -> labels pipeline of Algorithm 1's reclustering
/// step: eq.-(3) similarity, symmetrized distance, DBSCAN. The
/// **single** definition shared by the flat PS
/// (`ParameterServer::force_recluster`) and the sharded root
/// (`ShardedEngine`'s fleet-wide recluster), so the
/// `Flat == Sharded(1)` parity is structural, not comment-enforced.
///
/// Since PR 9 this runs on the posting-list [`SimilarityIndex`] +
/// [`dbscan_with`] instead of materializing the O(n²) matrices — same
/// labels bit for bit (`similarity::tests::lean_neighbors_match_dense_matrix`
/// pins the oracle, the dbscan expansion is shared code), but memory and
/// time scale with actual support overlap, which is what lets the
/// M-periodic recluster run at 10⁵ clients.
pub fn recluster_labels(freqs: &[FrequencyVector], params: DbscanParams) -> Vec<isize> {
    let index = SimilarityIndex::new(freqs);
    dbscan_with(freqs.len(), params, |i| index.neighbors(i, params.eps))
}
