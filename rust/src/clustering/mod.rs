//! Client clustering: the eq. (3) similarity matrix, a from-scratch
//! DBSCAN, and the cluster lifecycle manager (merge-on-join /
//! reset-on-reassignment).

pub mod dbscan;
pub mod manager;
pub mod similarity;

pub use dbscan::{dbscan, DbscanParams, NOISE};
pub use manager::{ClusterManager, MergeRule};
pub use similarity::{connectivity_matrix, distance_matrix};

use crate::age::FrequencyVector;

/// The full frequency -> labels pipeline of Algorithm 1's reclustering
/// step: eq.-(3) connectivity, symmetrized distance, DBSCAN. The
/// **single** definition shared by the flat PS
/// (`ParameterServer::force_recluster`) and the sharded root
/// (`ShardedEngine`'s fleet-wide recluster), so the
/// `Flat == Sharded(1)` parity is structural, not comment-enforced.
pub fn recluster_labels(freqs: &[FrequencyVector], params: DbscanParams) -> Vec<isize> {
    let conn = connectivity_matrix(freqs);
    let dist = distance_matrix(&conn);
    dbscan(&dist, params)
}
