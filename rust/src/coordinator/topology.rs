//! Hierarchical (multi-PS) aggregation: shard the round protocol behind a
//! topology layer.
//!
//! The paper's PS is a single aggregation point; at fleet scale it is the
//! bottleneck for both compute (selection, clustering) and connections.
//! [`ShardedEngine`] splits the fleet into N **shard engines** — each a
//! full [`RoundEngine`] owning a disjoint, cluster-aligned slice of the
//! clients and driving its own [`ClientPool`] cohort round — plus a
//! **root aggregator** that:
//!
//! 1. re-broadcasts the authoritative global model into every shard,
//! 2. runs all shard collect phases in parallel on scoped threads (the
//!    same pattern as the in-process pool's client lanes),
//! 3. merges the shard [`Aggregate`]s and applies **one** server update
//!    ([`merge_and_apply`], the exact code path the flat engine runs),
//! 4. lets each shard commit its own age/frequency bookkeeping, then
//!    runs the M-periodic DBSCAN **fleet-wide at the root** and, at that
//!    recluster boundary, **re-partitions the fleet** with
//!    [`ClusterManager::shard_slices`] — client state and transport
//!    streams are handed off between shard pools through the [`Reshard`]
//!    trait, so the assignment tracks the evolving clustering instead of
//!    staying the static contiguous split (DESIGN.md §8).
//!
//! Rounds are **partial** end to end: each shard's collect phase returns
//! a [`PartialRound`] (survivors + casualties), the root applies the
//! fleet-wide survivor aggregate, and a shard whose entire cohort
//! dropped simply contributes nothing that round.
//!
//! Age semantics survive sharding exactly: each shard's per-cluster
//! [`AgeVector`]s evolve under eq. (2) locally, and the root can combine
//! them at any time with [`AgeVector::merge_min`]/[`merge_max`] — the
//! lazy representation rebases epochs on merge, so the root's fleet-wide
//! staleness view equals the dense oracle bit-for-bit
//! (`rust/tests/parity.rs`, `rust/tests/properties.rs`) — including
//! across a re-shard hand-off, where cluster age vectors move (or, when
//! there are fewer clusters than shards, are split with cloned vectors)
//! between shard managers without being rewritten.
//!
//! [`Topology::Flat`] and `Sharded { shards: 1 }` are **bit-for-bit
//! identical**: shard 0 keeps the experiment seed, the slice is the
//! identity, the root applies the same aggregate with the same scale to
//! the same server-optimizer state, root-level reclustering over one
//! shard is exactly the flat PS's recluster, and the per-shard wire
//! accounting rolls up to the flat numbers (pinned in
//! `rust/tests/parity.rs`).
//!
//! [`AgeVector`]: crate::age::AgeVector
//! [`AgeVector::merge_min`]: crate::age::AgeVector::merge_min
//! [`merge_max`]: crate::age::AgeVector::merge_max

use crate::age::{AgeVector, FrequencyVector};
use crate::backend::{Backend, GlobalState};
use crate::clustering::{recluster_labels, ClusterManager, MergeRule};
use crate::config::{Downlink, ExperimentConfig};
use crate::coordinator::aggregator::Aggregate;
use crate::coordinator::engine::{
    merge_and_apply, ClientPool, PartialRound, RoundEngine, RoundOutcome, UPLOADED_LOG_CAP,
};
use crate::coordinator::fleet::MemberRecord;
use crate::fl::metrics::CommStats;
use crate::util::timer::Profile;
use anyhow::{ensure, Result};
use std::collections::VecDeque;

/// How the round protocol is laid out across parameter servers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// One monolithic PS (the paper's setup): a single [`RoundEngine`]
    /// owns every client.
    Flat,
    /// Two-level: `shards` shard engines under one root aggregator.
    /// `root_merge` is how the root combines shard age vectors into its
    /// fleet-wide staleness view ([`ShardedEngine::merged_ages`]).
    Sharded { shards: usize, root_merge: MergeRule },
}

impl Topology {
    /// Parse the config/CLI surface: `0` = flat (the default), `n >= 1` =
    /// sharded with n shards (`1` runs the sharded code path pinned
    /// bit-for-bit to flat).
    pub fn from_shards(shards: usize, root_merge: MergeRule) -> Self {
        if shards == 0 {
            Topology::Flat
        } else {
            Topology::Sharded { shards, root_merge }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Topology::Flat => "flat",
            Topology::Sharded { .. } => "sharded",
        }
    }

    /// Number of shard engines this topology runs (1 for flat).
    pub fn n_shards(&self) -> usize {
        match self {
            Topology::Flat => 1,
            Topology::Sharded { shards, .. } => *shards,
        }
    }

    /// The `shards` config/CLI encoding (0 = flat).
    pub fn shards_knob(&self) -> usize {
        match self {
            Topology::Flat => 0,
            Topology::Sharded { shards, .. } => *shards,
        }
    }

    pub fn root_merge(&self) -> MergeRule {
        match self {
            Topology::Flat => MergeRule::Min,
            Topology::Sharded { root_merge, .. } => *root_merge,
        }
    }
}

/// The **initial** client -> shard assignment: contiguous balanced slices
/// of `0..n`, which is exactly [`ClusterManager::shard_slices`] over the
/// initial all-singleton clustering (pinned by a test). Both the root PS
/// and every remote worker compute this independently from (n, shards),
/// so no assignment ever crosses the wire at join time; once dynamic
/// re-sharding moves clients, the authoritative assignment lives in
/// [`ShardedEngine::slices`] (the workers never need it — their streams
/// are handed between shard pools PS-side).
pub fn client_shards(n: usize, shards: usize) -> Vec<Vec<usize>> {
    assert!(shards >= 1 && shards <= n, "need 1 <= shards ({shards}) <= n ({n})");
    let base = n / shards;
    let extra = n % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0;
    for s in 0..shards {
        let len = base + usize::from(s < extra);
        out.push((start..start + len).collect());
        start += len;
    }
    out
}

/// Map a global client id to its `(shard, local_id)` under the initial
/// assignment of [`client_shards`] (join-time only; see its docs).
pub fn locate(n: usize, shards: usize, global_id: usize) -> (usize, usize) {
    assert!(global_id < n);
    let base = n / shards;
    let extra = n % shards;
    let big = (base + 1) * extra; // clients living in the `base+1` shards
    if global_id < big {
        (global_id / (base + 1), global_id % (base + 1))
    } else {
        (extra + (global_id - big) / base, (global_id - big) % base)
    }
}

/// Shard-local experiment config: the slice's client count, the flat
/// topology (a shard engine never nests), a per-shard seed offset so the
/// stochastic schedulers of different shards draw independent streams,
/// and **no shard-local reclustering** — the root runs the M-periodic
/// DBSCAN fleet-wide (see the module docs). Shard 0 keeps the experiment
/// seed unchanged — the `Sharded { shards: 1 } == Flat` pin depends on
/// it.
fn shard_config(cfg: &ExperimentConfig, shard: usize, n_local: usize) -> ExperimentConfig {
    let mut c = cfg.clone();
    c.n_clients = n_local;
    c.topology = Topology::Flat;
    c.recluster_every = 0; // the root reclusters fleet-wide
    c.seed = cfg.seed ^ (shard as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    c
}

/// Pool-side client hand-off for dynamic re-sharding: drain every
/// client's transferable state (simulated client + memory, or a worker's
/// TCP stream) in local-slot order, and repopulate in the new order. The
/// [`ShardedEngine`] drives the transfer — pools never see global ids.
pub trait Reshard {
    type Carry: Send;

    /// Drain every client's state, in current local-slot order. The pool
    /// is unusable until [`Self::install_parts`] repopulates it.
    fn take_parts(&mut self) -> Vec<Self::Carry>;

    /// Repopulate from parts in (new) local-slot order; the pool's
    /// client count becomes `parts.len()`.
    fn install_parts(&mut self, parts: Vec<Self::Carry>);
}

/// Restrict a fleet-wide cluster manager to one shard's slice: members
/// map to their slice positions (the shard's local ids), clusters keep
/// their age vectors, and a cluster straddling the slice boundary (only
/// possible when re-sharding was skipped for want of clusters) is split
/// with a **cloned** vector per part — merging the parts back under
/// `min`/`max` reproduces the original vector exactly, so the root's
/// merged-age view is unaffected (property-pinned in
/// `rust/tests/properties.rs`).
pub fn split_cluster_manager(
    fleet: &ClusterManager,
    slice: &[usize],
    d: usize,
    rule: MergeRule,
) -> ClusterManager {
    debug_assert!(slice.windows(2).all(|w| w[0] < w[1]));
    let mut parts: Vec<(Vec<usize>, AgeVector)> = Vec::new();
    for c in 0..fleet.n_clusters() {
        let members: Vec<usize> = fleet
            .members_of(c)
            .iter()
            .filter_map(|&g| slice.binary_search(&g).ok())
            .collect();
        if members.is_empty() {
            continue;
        }
        parts.push((members, fleet.age_of_cluster(c).clone()));
    }
    // fleet clusters are ordered by smallest *global* member; local ids
    // must be re-ordered by smallest local member (slices need not be
    // contiguous after a re-shard)
    parts.sort_by_key(|(members, _)| members[0]);
    let (groups, ages): (Vec<_>, Vec<_>) = parts.into_iter().unzip();
    ClusterManager::from_parts(slice.len(), d, rule, groups, ages)
}

/// The two-level round driver: N shard [`RoundEngine`]s + the root
/// aggregator state (authoritative global model, server-optimizer
/// moments, root profile, global uploaded-index log).
pub struct ShardedEngine {
    cfg: ExperimentConfig,
    engines: Vec<RoundEngine>,
    /// shard -> sorted global client ids (disjoint cover of `0..n`);
    /// starts as the contiguous [`client_shards`] split and tracks the
    /// clustering across re-shard events
    slices: Vec<Vec<usize>>,
    global: GlobalState,
    root_merge: MergeRule,
    profile: Profile,
    /// per round, per **global** client id: the uploaded indices (ring of
    /// the last [`UPLOADED_LOG_CAP`] rounds, like the flat engine's)
    uploaded_log: VecDeque<Vec<Vec<u32>>>,
    rounds_done: usize,
    /// root-level reclustering events: (round, n_clusters), mirroring
    /// the flat PS's log
    pub recluster_log: Vec<(usize, usize)>,
    /// re-shard events: (round, clients that changed shard)
    pub reshard_log: Vec<(usize, usize)>,
    /// scratch for the root's fleet-wide updated-index union (delta
    /// downlink, DESIGN.md §9) — reused every round
    union_scratch: Vec<u32>,
}

impl ShardedEngine {
    /// Build the topology from the global config (`cfg.topology` decides
    /// the shard count; `Flat` behaves as one shard). `init_params` seeds
    /// both the root model and every shard's broadcast copy.
    pub fn new(cfg: &ExperimentConfig, init_params: Vec<f32>) -> Result<Self> {
        let shards = cfg.topology.n_shards();
        ensure!(
            shards >= 1 && shards <= cfg.n_clients,
            "topology wants {shards} shards for {} clients",
            cfg.n_clients
        );
        let slices = client_shards(cfg.n_clients, shards);
        let engines: Vec<RoundEngine> = slices
            .iter()
            .enumerate()
            .map(|(s, slice)| {
                RoundEngine::new(&shard_config(cfg, s, slice.len()), init_params.clone())
            })
            .collect();
        Ok(ShardedEngine {
            cfg: cfg.clone(),
            engines,
            slices,
            global: GlobalState::new(init_params),
            root_merge: cfg.topology.root_merge(),
            profile: Profile::new(),
            uploaded_log: VecDeque::new(),
            rounds_done: 0,
            recluster_log: Vec::new(),
            reshard_log: Vec::new(),
            union_scratch: Vec::new(),
        })
    }

    pub fn n_shards(&self) -> usize {
        self.engines.len()
    }

    /// The shard engines, in shard order (diagnostics, per-shard stats).
    pub fn shards(&self) -> &[RoundEngine] {
        &self.engines
    }

    /// shard -> sorted global client ids (current assignment).
    pub fn slices(&self) -> &[Vec<usize>] {
        &self.slices
    }

    pub fn global_params(&self) -> &[f32] {
        &self.global.params
    }

    pub fn profile(&self) -> &Profile {
        &self.profile
    }

    pub fn round(&self) -> usize {
        self.rounds_done
    }

    /// Per-round, per-global-client uploaded index sets (the sharded
    /// counterpart of [`RoundEngine::uploaded_log`]).
    pub fn uploaded_log(&self) -> &VecDeque<Vec<Vec<u32>>> {
        &self.uploaded_log
    }

    /// Rolled-up communication accounting: the field-wise sum of the
    /// shard engines' counters (DESIGN.md §7 — the root <-> shard hop is
    /// in-process and contributes zero wire bytes, so the roll-up still
    /// equals the bytes observed on the shard PS sockets).
    pub fn comm(&self) -> CommStats {
        let mut total = CommStats::default();
        for e in &self.engines {
            total.absorb(&e.comm());
        }
        total
    }

    /// Total cluster count across shards (a cluster spans shards only
    /// when a re-shard was skipped for want of clusters; each part then
    /// counts once per shard).
    pub fn n_clusters(&self) -> usize {
        self.engines.iter().map(|e| e.ps().clusters().n_clusters()).sum()
    }

    /// Global cluster labels: shard-local cluster ids offset so ids are
    /// unique fleet-wide, indexed by global client id.
    pub fn cluster_labels(&self) -> Vec<usize> {
        let mut labels = vec![0usize; self.cfg.n_clients];
        let mut offset = 0;
        for (engine, slice) in self.engines.iter().zip(&self.slices) {
            let local = engine.ps().clusters().labels();
            for (l, &g) in local.iter().zip(slice) {
                labels[g] = offset + l;
            }
            offset += engine.ps().clusters().n_clusters();
        }
        labels
    }

    /// The root's fleet-wide staleness view: every shard's per-cluster
    /// age vector combined under the topology's `root_merge` rule. The
    /// lazy vectors rebase epochs on merge, so this equals the dense
    /// elementwise min/max over all cluster vectors exactly — O(d *
    /// n_clusters), intended for scheduling/diagnostics cadence, not the
    /// per-round hot path.
    pub fn merged_ages(&self) -> AgeVector {
        let mut acc: Option<AgeVector> = None;
        for engine in &self.engines {
            let clusters = engine.ps().clusters();
            for c in 0..clusters.n_clusters() {
                let v = clusters.age_of_cluster(c);
                match &mut acc {
                    None => acc = Some(v.clone()),
                    Some(a) => match self.root_merge {
                        MergeRule::Min => a.merge_min(v),
                        MergeRule::Max => a.merge_max(v),
                    },
                }
            }
        }
        acc.unwrap_or_else(|| AgeVector::new(self.cfg.d()))
    }

    /// One global round across every shard, with the shard collect phases
    /// running **in parallel on scoped threads** (`P: Send`; in-process
    /// pools built via [`crate::fl::pool::SendPool`] qualify, as does any
    /// `Send` transport). Results are merged in shard order, so the round
    /// is deterministic regardless of thread interleaving. At recluster
    /// boundaries the root then reclusters fleet-wide and re-shards (see
    /// the module docs).
    pub fn run_round<P>(&mut self, pools: &mut [P]) -> Result<RoundOutcome>
    where
        P: ClientPool + Reshard + Send,
    {
        self.check_pools(pools)?;
        let params = &self.global.params;
        let srs: Vec<PartialRound> = if self.engines.len() == 1 {
            let e = &mut self.engines[0];
            e.set_global(params);
            vec![e.collect_round(&mut pools[0])?]
        } else {
            self.profile.time("root.collect", || {
                std::thread::scope(|s| {
                    let handles: Vec<_> = self
                        .engines
                        .iter_mut()
                        .zip(pools.iter_mut())
                        .map(|(e, p)| {
                            s.spawn(move || -> Result<PartialRound> {
                                e.set_global(params);
                                e.collect_round(p)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("shard thread panicked"))
                        .collect::<Result<Vec<_>>>()
                })
            })?
        };
        let (pool0, _) = pools.split_first_mut().expect("checked non-empty");
        let mut out = self.apply_and_finish(srs, pool0.backend())?;
        self.maybe_recluster_and_reshard(pools, &mut out)?;
        Ok(out)
    }

    /// [`Self::run_round`] with the shard collect phases driven serially
    /// in shard order — for pools that cannot cross threads (e.g. a
    /// TCP pool whose PS backend is a single PJRT runtime). Produces
    /// results identical to the parallel driver: shards are independent
    /// and merged in shard order either way.
    pub fn run_round_serial<P>(&mut self, pools: &mut [P]) -> Result<RoundOutcome>
    where
        P: ClientPool + Reshard,
    {
        self.check_pools(pools)?;
        let params = &self.global.params;
        let srs: Vec<PartialRound> = self
            .engines
            .iter_mut()
            .zip(pools.iter_mut())
            .map(|(e, p)| {
                e.set_global(params);
                e.collect_round(p)
            })
            .collect::<Result<Vec<_>>>()?;
        let (pool0, _) = pools.split_first_mut().expect("checked non-empty");
        let mut out = self.apply_and_finish(srs, pool0.backend())?;
        self.maybe_recluster_and_reshard(pools, &mut out)?;
        Ok(out)
    }

    fn check_pools<P: ClientPool>(&self, pools: &[P]) -> Result<()> {
        ensure!(
            pools.len() == self.engines.len(),
            "{} pools for {} shards",
            pools.len(),
            self.engines.len()
        );
        for (s, (pool, slice)) in pools.iter().zip(&self.slices).enumerate() {
            ensure!(
                pool.n_clients() == slice.len(),
                "shard {s}: pool has {} clients, slice has {}",
                pool.n_clients(),
                slice.len()
            );
        }
        Ok(())
    }

    /// The root half of a round: merge the shard aggregates (shard order,
    /// so `Sharded { shards: 1 }` pushes the identical update sequence
    /// the flat engine does), apply one server update to the root model
    /// (skipped when every scheduled client fleet-wide dropped), then let
    /// every shard commit its bookkeeping.
    fn apply_and_finish(
        &mut self,
        srs: Vec<PartialRound>,
        backend: &mut dyn Backend,
    ) -> Result<RoundOutcome> {
        let n = self.cfg.n_clients;
        let m_total: usize = srs.iter().map(|sr| sr.survivors.len()).sum();
        let loss_sum: f64 = srs.iter().map(|sr| sr.loss_sum).sum();
        let mean_loss =
            if m_total == 0 { f32::NAN } else { (loss_sum / m_total as f64) as f32 };

        let mut agg = Aggregate::new();
        let mut uploaded_global: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut cohort_global: Vec<usize> = Vec::with_capacity(m_total);
        let mut casualties_global: Vec<usize> = Vec::new();
        let mut cancelled_global: Vec<usize> = Vec::new();
        let mut finish = Vec::with_capacity(srs.len());
        for (sr, slice) in srs.into_iter().zip(&self.slices) {
            for u in sr.updates {
                agg.push(u);
            }
            for (local, up) in sr.uploaded.iter().enumerate() {
                if !up.is_empty() {
                    uploaded_global[slice[local]] = up.clone();
                }
            }
            cohort_global.extend(sr.survivors.iter().map(|&c| slice[c]));
            casualties_global.extend(sr.casualties.iter().map(|&c| slice[c]));
            cancelled_global.extend(sr.cancelled.iter().map(|&c| slice[c]));
            finish.push((sr.uploaded, sr.survivors));
        }
        // slices are sorted but need not be contiguous after a re-shard,
        // so shard-order concatenation must be re-sorted
        cohort_global.sort_unstable();
        casualties_global.sort_unstable();
        cancelled_global.sort_unstable();

        if m_total > 0 {
            merge_and_apply(
                &self.cfg,
                backend,
                &mut self.global,
                &agg,
                m_total,
                n,
                &self.profile,
            )?;
        }

        // ---- delta downlink (DESIGN.md §9): every shard re-broadcasts
        // the same root model next round, so every shard's generation
        // ring must carry the same fleet-wide update union — computed
        // once here from the root aggregate (`Flat ≡ Sharded(1)` and
        // shard-count invariance both hang on this)
        if self.cfg.downlink == Downlink::Delta {
            if m_total > 0 {
                agg.updated_indices_into(&mut self.union_scratch);
            } else {
                self.union_scratch.clear();
            }
            for engine in &mut self.engines {
                engine.note_model_update_union(&self.union_scratch);
            }
        }

        for (engine, (uploaded, survivors)) in self.engines.iter_mut().zip(finish) {
            // shard-local reclustering is disabled (shard_config); the
            // root reclusters fleet-wide after this returns
            let reclustered = engine.finish_round(uploaded, &survivors);
            debug_assert!(reclustered.is_none());
        }
        self.uploaded_log.push_back(uploaded_global);
        if self.uploaded_log.len() > UPLOADED_LOG_CAP {
            self.uploaded_log.pop_front();
        }
        self.rounds_done += 1;

        Ok(RoundOutcome {
            mean_loss,
            reclustered: None,
            n_clusters: self.n_clusters(),
            cohort: cohort_global,
            casualties: casualties_global,
            cancelled: cancelled_global,
        })
    }

    /// Is the root's M-periodic recluster due this round? (Mirrors the
    /// flat `ParameterServer::maybe_recluster` gating.)
    fn recluster_due(&self) -> bool {
        self.cfg.strategy.uses_age()
            && self.cfg.recluster_every > 0
            && self.rounds_done > 0
            && self.rounds_done % self.cfg.recluster_every == 0
    }

    fn maybe_recluster_and_reshard<P>(
        &mut self,
        pools: &mut [P],
        out: &mut RoundOutcome,
    ) -> Result<()>
    where
        P: ClientPool + Reshard,
    {
        if !self.recluster_due() {
            return Ok(());
        }
        let n_clusters = self.recluster_and_reshard(pools)?;
        out.reclustered = Some(n_clusters);
        out.n_clusters = self.n_clusters();
        Ok(())
    }

    /// Reconstitute the fleet-wide cluster state from the shard managers
    /// (global ids, cloned age vectors), ordered by smallest member as
    /// [`ClusterManager`] requires.
    fn gather_fleet_clusters(&self) -> ClusterManager {
        let mut parts: Vec<(Vec<usize>, AgeVector)> = Vec::new();
        for (engine, slice) in self.engines.iter().zip(&self.slices) {
            let clusters = engine.ps().clusters();
            for c in 0..clusters.n_clusters() {
                let members: Vec<usize> =
                    clusters.members_of(c).iter().map(|&l| slice[l]).collect();
                parts.push((members, clusters.age_of_cluster(c).clone()));
            }
        }
        parts.sort_by_key(|(members, _)| members[0]);
        let (groups, ages): (Vec<_>, Vec<_>) = parts.into_iter().unzip();
        ClusterManager::from_parts(
            self.cfg.n_clients,
            self.cfg.d(),
            self.cfg.merge_rule,
            groups,
            ages,
        )
    }

    /// The root's recluster boundary: fleet-wide DBSCAN over the
    /// gathered frequency vectors (exactly the flat PS's connectivity ->
    /// distance -> DBSCAN -> carry-over sequence, so `Sharded(1)` stays
    /// bit-for-bit flat), then — when the clustering supports it and
    /// `cfg.reshard` is on — a re-partition via
    /// [`ClusterManager::shard_slices`] with client state and pool
    /// streams handed off to their new shards. Returns the fleet-wide
    /// cluster count.
    fn recluster_and_reshard<P>(&mut self, pools: &mut [P]) -> Result<usize>
    where
        P: ClientPool + Reshard,
    {
        let n = self.cfg.n_clients;
        let d = self.cfg.d();
        let nshards = self.engines.len();

        // ---- gather the fleet-wide membership view (global id order)
        let mut parts: Vec<Option<(FrequencyVector, u32, MemberRecord)>> =
            (0..n).map(|_| None).collect();
        for (engine, slice) in self.engines.iter().zip(&self.slices) {
            for (local, part) in engine.membership_parts().into_iter().enumerate() {
                parts[slice[local]] = Some(part);
            }
        }
        // borrow the gathered frequency vectors for the DBSCAN without a
        // second deep clone: take them out of `parts` for the pipeline
        // call and hand them straight back
        let freqs: Vec<FrequencyVector> = parts
            .iter_mut()
            .map(|p| std::mem::take(&mut p.as_mut().expect("slices cover 0..n").0))
            .collect();

        // ---- fleet-wide clustering: the exact pipeline the flat PS
        // runs (shared definition — see `clustering::recluster_labels`)
        let labels = recluster_labels(&freqs, self.cfg.dbscan);
        for (p, f) in parts.iter_mut().zip(freqs) {
            p.as_mut().expect("slices cover 0..n").0 = f;
        }
        let mut fleet_mgr = self.gather_fleet_clusters();
        let ev = fleet_mgr.recluster(&labels);
        let n_clusters = ev.n_clusters;
        self.recluster_log.push((self.rounds_done, n_clusters));
        crate::debug!(
            "root recluster @round {}: {} clusters ({} merges, {} resets)",
            self.rounds_done,
            n_clusters,
            ev.merges,
            ev.resets
        );

        // ---- re-partition: cluster-aligned balanced slices. Skipped
        // when the clustering has fewer clusters than shards (slices
        // keep their shape; straddling clusters are split per shard with
        // cloned age vectors) or when the knob is off.
        let new_slices = if self.cfg.reshard && n_clusters >= nshards {
            fleet_mgr.shard_slices(nshards)
        } else {
            self.slices.clone()
        };

        // ---- install the new per-shard cluster/membership state
        for (s, slice) in new_slices.iter().enumerate() {
            let manager = split_cluster_manager(&fleet_mgr, slice, d, self.cfg.merge_rule);
            let shard_parts: Vec<(FrequencyVector, u32, MemberRecord)> = slice
                .iter()
                .map(|&g| parts[g].take().expect("slices are disjoint"))
                .collect();
            self.engines[s].install_membership(manager, shard_parts);
        }

        // ---- hand pool-side client state / worker streams to their new
        // shards (skipped when nothing moved)
        if new_slices != self.slices {
            let moved: usize = new_slices
                .iter()
                .zip(&self.slices)
                .map(|(new, old)| new.iter().filter(|&g| !old.contains(g)).count())
                .sum();
            crate::info!(
                "reshard @round {}: {moved} clients change shard (slices {new_slices:?})",
                self.rounds_done
            );
            self.reshard_log.push((self.rounds_done, moved));
            let mut carries: Vec<Option<P::Carry>> = (0..n).map(|_| None).collect();
            for (pool, slice) in pools.iter_mut().zip(&self.slices) {
                for (local, carry) in pool.take_parts().into_iter().enumerate() {
                    carries[slice[local]] = Some(carry);
                }
            }
            for (pool, slice) in pools.iter_mut().zip(&new_slices) {
                let pool_parts: Vec<P::Carry> = slice
                    .iter()
                    .map(|&g| carries[g].take().expect("slices cover 0..n"))
                    .collect();
                pool.install_parts(pool_parts);
            }
            self.slices = new_slices;
        }
        Ok(n_clusters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::ClusterManager;

    #[test]
    fn client_shards_cover_disjointly_and_balanced() {
        for (n, s) in [(10, 3), (8, 2), (6, 6), (7, 1), (5, 4)] {
            let slices = client_shards(n, s);
            assert_eq!(slices.len(), s);
            let all: Vec<usize> = slices.iter().flatten().copied().collect();
            assert_eq!(all, (0..n).collect::<Vec<_>>(), "contiguous disjoint cover");
            let max = slices.iter().map(Vec::len).max().unwrap();
            let min = slices.iter().map(Vec::len).min().unwrap();
            assert!(max - min <= 1, "balanced: {slices:?}");
        }
    }

    #[test]
    fn client_shards_match_singleton_cluster_slices() {
        // the static assignment IS the cluster-aligned assignment over
        // the initial all-singleton clustering
        for (n, s) in [(10, 3), (8, 2), (5, 5), (9, 4)] {
            let manager = ClusterManager::new(n, 1, MergeRule::Min);
            assert_eq!(client_shards(n, s), manager.shard_slices(s));
        }
    }

    #[test]
    fn locate_inverts_client_shards() {
        for (n, s) in [(10, 3), (8, 2), (6, 6), (7, 1), (5, 4), (9, 4)] {
            let slices = client_shards(n, s);
            for g in 0..n {
                let (shard, local) = locate(n, s, g);
                assert_eq!(slices[shard][local], g, "n={n} s={s} g={g}");
            }
        }
    }

    #[test]
    fn topology_knob_roundtrip() {
        assert_eq!(Topology::from_shards(0, MergeRule::Min), Topology::Flat);
        assert_eq!(
            Topology::from_shards(3, MergeRule::Max),
            Topology::Sharded { shards: 3, root_merge: MergeRule::Max }
        );
        for t in [Topology::Flat, Topology::from_shards(2, MergeRule::Min)] {
            assert_eq!(Topology::from_shards(t.shards_knob(), t.root_merge()), t);
        }
        assert_eq!(Topology::Flat.n_shards(), 1);
        assert_eq!(Topology::from_shards(1, MergeRule::Min).n_shards(), 1);
    }

    /// Splitting a fleet manager across (non-contiguous) slices keeps
    /// cluster/age state intact: clusters map to local ids, straddling
    /// clusters clone their vector, and the merged view is unchanged.
    #[test]
    fn split_cluster_manager_preserves_ages_and_membership() {
        let d = 8;
        let mut fleet = ClusterManager::new(5, d, MergeRule::Min);
        fleet.recluster(&[0, 1, 0, 2, 2]); // clusters {0,2}, {1}, {3,4}
        let c02 = fleet.cluster_of(0);
        fleet.update_ages(c02, &[3]);
        fleet.update_ages(fleet.cluster_of(3), &[5]);

        // a non-contiguous split that respects clusters: {0,2} | {1,3,4}
        let a = split_cluster_manager(&fleet, &[0, 2], d, MergeRule::Min);
        let b = split_cluster_manager(&fleet, &[1, 3, 4], d, MergeRule::Min);
        assert_eq!(a.n_clusters(), 1);
        assert_eq!(a.members_of(0), &[0, 1], "global {{0,2}} -> local slots 0,1");
        assert_eq!(a.age_of_cluster(0), fleet.age_of_cluster(c02));
        assert_eq!(b.n_clusters(), 2);

        // a split that cuts cluster {3,4}: both parts carry the vector
        let c = split_cluster_manager(&fleet, &[0, 2, 3], d, MergeRule::Min);
        let dm = split_cluster_manager(&fleet, &[1, 4], d, MergeRule::Min);
        let g34 = fleet.cluster_of(3);
        assert_eq!(c.age_of_client(2), fleet.age_of_cluster(g34));
        assert_eq!(dm.age_of_client(1), fleet.age_of_cluster(g34));
    }
}
