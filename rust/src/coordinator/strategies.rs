//! Sparsification strategies: rAge-k and the baselines the paper compares
//! against (§III-C evaluates rTop-k at identical (r, k); top-k, rand-k
//! and dense are standard additions exercised by the ablation benches).
//!
//! A strategy is split along the wire protocol:
//! * **PS-side** strategies (rAge-k) need the client's top-r index report
//!   and answer with a request (`needs_report() == true`);
//! * **client-side** strategies (rTop-k, top-k, rand-k, dense) decide
//!   locally; no report/request messages are exchanged.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    /// The paper's algorithm: PS picks the k oldest of the reported
    /// top-r, disjointly across cluster members.
    RageK,
    /// Ablation: rAge-k without the disjoint coordination (each member
    /// selected independently against the shared age vector).
    RageKIndependent,
    /// rTop-k (Barnes et al.): client uniformly samples k of its top-r.
    RTopK,
    /// Plain top-k sparsification (k largest |g|).
    TopK,
    /// k uniformly random coordinates of the full gradient.
    RandK,
    /// No compression (upper-bound baseline).
    Dense,
}

impl StrategyKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "ragek" | "rage-k" => StrategyKind::RageK,
            "ragek-indep" | "ragek_independent" => StrategyKind::RageKIndependent,
            "rtopk" | "rtop-k" => StrategyKind::RTopK,
            "topk" | "top-k" => StrategyKind::TopK,
            "randk" | "rand-k" => StrategyKind::RandK,
            "dense" => StrategyKind::Dense,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::RageK => "rAge-k",
            StrategyKind::RageKIndependent => "rAge-k(indep)",
            StrategyKind::RTopK => "rTop-k",
            StrategyKind::TopK => "top-k",
            StrategyKind::RandK => "rand-k",
            StrategyKind::Dense => "dense",
        }
    }

    /// Does the PS receive a top-r index report and answer with a request?
    pub fn needs_report(&self) -> bool {
        matches!(self, StrategyKind::RageK | StrategyKind::RageKIndependent)
    }

    /// Does the client need its *full* gradient (vs just the top-r)?
    pub fn needs_dense_grad(&self) -> bool {
        matches!(self, StrategyKind::RandK | StrategyKind::Dense)
    }

    /// Does the PS run age/frequency/clustering state for this strategy?
    pub fn uses_age(&self) -> bool {
        self.needs_report()
    }

    /// Uplink bytes one client spends per global round (DESIGN.md §6):
    /// report (4r) if any + sparse update (8 per entry).
    pub fn uplink_bytes(&self, d: usize, r: usize, k: usize) -> usize {
        match self {
            StrategyKind::RageK | StrategyKind::RageKIndependent => 4 * r + 8 * k,
            StrategyKind::RTopK | StrategyKind::TopK | StrategyKind::RandK => 8 * k,
            StrategyKind::Dense => 4 * d,
        }
    }

    /// Extra downlink bytes per client per round beyond the model
    /// broadcast: the index request (4k) for PS-side strategies.
    pub fn request_bytes(&self, k: usize) -> usize {
        if self.needs_report() {
            4 * k
        } else {
            0
        }
    }
}

/// Client-side selection for the non-age strategies. `report` is the
/// magnitude-ordered top-r index list; returns the indices to upload.
pub fn client_select(
    kind: StrategyKind,
    rng: &mut Rng,
    report: &[u32],
    d: usize,
    k: usize,
) -> Vec<u32> {
    match kind {
        StrategyKind::RTopK => {
            // uniform k-subset of the top-r (the rTop-k algorithm)
            rng.choose_k(report.len(), k).into_iter().map(|p| report[p]).collect()
        }
        StrategyKind::TopK => report[..k].to_vec(),
        StrategyKind::RandK => rng.choose_k(d, k).into_iter().map(|j| j as u32).collect(),
        StrategyKind::Dense => (0..d as u32).collect(),
        StrategyKind::RageK | StrategyKind::RageKIndependent => {
            unreachable!("rAge-k selection happens at the PS")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for (s, k) in [
            ("ragek", StrategyKind::RageK),
            ("rtopk", StrategyKind::RTopK),
            ("topk", StrategyKind::TopK),
            ("randk", StrategyKind::RandK),
            ("dense", StrategyKind::Dense),
            ("ragek-indep", StrategyKind::RageKIndependent),
        ] {
            assert_eq!(StrategyKind::parse(s), Some(k));
        }
        assert_eq!(StrategyKind::parse("nope"), None);
    }

    #[test]
    fn byte_accounting() {
        let d = 39760;
        assert_eq!(StrategyKind::RageK.uplink_bytes(d, 75, 10), 4 * 75 + 80);
        assert_eq!(StrategyKind::RTopK.uplink_bytes(d, 75, 10), 80);
        assert_eq!(StrategyKind::Dense.uplink_bytes(d, 0, 0), 4 * d);
        assert_eq!(StrategyKind::RageK.request_bytes(10), 40);
        assert_eq!(StrategyKind::TopK.request_bytes(10), 0);
    }

    #[test]
    fn rtopk_is_subset_of_report() {
        let mut rng = Rng::new(0);
        let report: Vec<u32> = (100..175).collect();
        for _ in 0..20 {
            let sel = client_select(StrategyKind::RTopK, &mut rng, &report, 1000, 10);
            assert_eq!(sel.len(), 10);
            let set: std::collections::HashSet<_> = sel.iter().collect();
            assert_eq!(set.len(), 10);
            assert!(sel.iter().all(|j| report.contains(j)));
        }
    }

    #[test]
    fn rtopk_actually_explores() {
        // across many rounds, selections must not always equal the top-k
        let mut rng = Rng::new(1);
        let report: Vec<u32> = (0..75).collect();
        let mut varied = false;
        for _ in 0..10 {
            let sel = client_select(StrategyKind::RTopK, &mut rng, &report, 1000, 10);
            if sel.iter().any(|&j| j >= 10) {
                varied = true;
            }
        }
        assert!(varied);
    }

    #[test]
    fn topk_takes_prefix() {
        let mut rng = Rng::new(0);
        let report: Vec<u32> = vec![9, 4, 7, 1, 3];
        let sel = client_select(StrategyKind::TopK, &mut rng, &report, 100, 3);
        assert_eq!(sel, vec![9, 4, 7]);
    }

    #[test]
    fn randk_distinct_in_range() {
        let mut rng = Rng::new(2);
        let sel = client_select(StrategyKind::RandK, &mut rng, &[], 50, 20);
        let set: std::collections::HashSet<_> = sel.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(sel.iter().all(|&j| j < 50));
    }

    #[test]
    fn dense_selects_everything() {
        let mut rng = Rng::new(2);
        let sel = client_select(StrategyKind::Dense, &mut rng, &[], 7, 0);
        assert_eq!(sel, (0..7).collect::<Vec<u32>>());
    }
}
