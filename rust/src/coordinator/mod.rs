//! The parameter-server coordinator — the paper's system contribution.
//!
//! * [`engine`] — the unified round protocol ([`engine::RoundEngine`]):
//!   Algorithm 1 implemented once, driven identically by the in-process
//!   simulator and the TCP deployment through the [`engine::ClientPool`]
//!   abstraction.
//! * [`scheduler`] — cohort selection under partial participation:
//!   round-robin, seeded uniform random, and the age-debt policy that
//!   polls the stalest clients first.
//! * [`selection`] — Algorithm 2's PS side: age-ranked choice of k indices
//!   out of each client's top-r report, with disjoint assignment across
//!   the members of a cluster.
//! * [`strategies`] — the pluggable sparsification policies: rAge-k and
//!   the baselines it is evaluated against (rTop-k, top-k, rand-k, dense).
//! * [`aggregator`] — g~ = sum_i g~_i and its dense/sparse materialization.
//! * [`server`] — the PS state machine gluing age vectors, frequency
//!   vectors, clustering and selection into the per-round protocol.
//! * [`topology`] — the hierarchical multi-PS layer: shard engines over
//!   disjoint client slices plus a root aggregator merging their
//!   aggregates and age vectors ([`topology::ShardedEngine`]).

pub mod aggregator;
pub mod engine;
pub mod fleet;
pub mod scheduler;
pub mod selection;
pub mod server;
pub mod strategies;
pub mod topology;

pub use engine::{ClientPool, RoundEngine};
pub use scheduler::{CohortScheduler, SchedulerKind};
pub use server::ParameterServer;
pub use strategies::StrategyKind;
pub use topology::{ShardedEngine, Topology};
