//! Fleet membership: the per-client lifecycle the round protocol runs
//! against.
//!
//! The paper's age vectors exist precisely so the PS can keep training
//! when some clients are silent — eq. (2) ages of unpolled clusters keep
//! growing and steer future index requests. [`Fleet`] is the registry
//! that makes that operational: every client carries a [`Membership`]
//! state and a **generation** counter, the scheduler ranks cohorts by
//! live state (Dead last, Suspect penalized), and a round that loses a
//! client finishes with the survivors instead of erroring
//! ([`crate::coordinator::engine::RoundEngine::collect_round`] returns a
//! `PartialRound` carrying the casualty list).
//!
//! State machine (deterministic — every transition is unit-tested):
//!
//! ```text
//!             casualty                casualty / unreachable
//!   Active ------------> Suspect -----------------------------> Dead
//!     ^  ^                  |                                    |
//!     |  '----- survived ---'                                    | Rejoin frame /
//!     |                                                          | pool re-admission
//!     '------- survived ------- Rejoining <----------------------'
//!                                   |                 (generation += 1)
//!                                   '---- casualty / unreachable --> Dead
//! ```
//!
//! * **casualty** — the client was scheduled this round and failed to
//!   deliver (timeout, reset, bad frame, simulated drop).
//! * **unreachable** — the transport reports the client's stream gone
//!   ([`crate::coordinator::engine::ClientPool::health`]).
//! * **survived** — the client completed a round end to end.
//! * **rejoin** — a recovered worker re-admitted itself (the TCP `Rejoin`
//!   frame, or a pool-level re-admission in the simulator); the
//!   generation counter bumps so stale duplicates are detectable.
//!
//! With no failures every client stays `Active` forever and the fleet is
//! invisible — the all-answer path is bit-for-bit the pre-fleet protocol
//! (pinned by `rust/tests/parity.rs`).

/// One client's lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Membership {
    /// Reachable and completing rounds.
    Active,
    /// Failed its last scheduled round (or the transport degraded) but
    /// not yet written off — penalized by the scheduler, recovered by
    /// surviving a round.
    Suspect,
    /// Unreachable; only a rejoin brings it back. Its clusters' eq.-(2)
    /// ages keep growing the whole time.
    Dead,
    /// Re-admitted after death; treated as live by the scheduler and
    /// promoted to `Active` by its first completed round.
    Rejoining,
}

impl Membership {
    /// Scheduler tier: live states first, Suspect after every live
    /// client, Dead last (see `coordinator::scheduler::AgeDebt`).
    pub fn schedule_tier(self) -> u8 {
        match self {
            Membership::Active | Membership::Rejoining => 0,
            Membership::Suspect => 1,
            Membership::Dead => 2,
        }
    }

    /// A state the pool can plausibly complete a round from.
    pub fn is_live(self) -> bool {
        self != Membership::Dead
    }

    pub fn name(self) -> &'static str {
        match self {
            Membership::Active => "active",
            Membership::Suspect => "suspect",
            Membership::Dead => "dead",
            Membership::Rejoining => "rejoining",
        }
    }
}

/// Sentinel for [`MemberRecord::acked_model`]: the PS does not know what
/// model this client holds (it died mid-broadcast), so the next
/// broadcast it receives must be a full dense `Model` frame.
pub const ACKED_NONE: u32 = u32::MAX;

/// Smoothing factor for the per-client round-trip EWMA: one observation
/// moves the estimate 30% of the way to the new sample — reactive enough
/// to track a worker that slows down, damped enough that one glitch
/// doesn't halve its deadline.
pub const RTT_EWMA_ALPHA: f32 = 0.3;

/// One client's fleet record. Plain data so a sharded topology can hand
/// records between shard engines on a re-shard.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemberRecord {
    pub state: Membership,
    /// admission generation: 0 for the original join, +1 per accepted
    /// rejoin — lets the PS refuse stale duplicate rejoins and tells
    /// diagnostics how flappy a client is
    pub generation: u32,
    /// total rounds this client was scheduled for and failed
    pub casualties: u32,
    /// last **model generation** this client provably holds (the round
    /// number of the last broadcast it survived or resynced to; 0 = the
    /// initial model every worker starts from; [`ACKED_NONE`] = unknown
    /// -> the delta downlink falls back to a dense frame). DESIGN.md §9.
    pub acked_model: u32,
    /// EWMA of this client's observed per-phase round-trip in
    /// milliseconds, fed by the transport's reactor timings (0 = no
    /// observation yet). Drives adaptive per-connection deadlines
    /// (`clamp(ewma * deadline_factor, deadline_min_ms, io_timeout_ms)`,
    /// DESIGN.md §11) and is scheduler-visible cost-model input.
    pub rtt_ewma_ms: f32,
}

impl Default for MemberRecord {
    fn default() -> Self {
        MemberRecord {
            state: Membership::Active,
            generation: 0,
            casualties: 0,
            acked_model: 0,
            rtt_ewma_ms: 0.0,
        }
    }
}

/// The membership registry one engine schedules against (client ids are
/// the engine's local `0..n`).
#[derive(Debug, Clone)]
pub struct Fleet {
    members: Vec<MemberRecord>,
}

impl Fleet {
    /// Everyone starts Active at generation 0.
    pub fn new(n: usize) -> Self {
        Fleet { members: vec![MemberRecord::default(); n] }
    }

    /// Rebuild from records (re-shard hand-off).
    pub fn from_records(members: Vec<MemberRecord>) -> Self {
        Fleet { members }
    }

    pub fn n(&self) -> usize {
        self.members.len()
    }

    pub fn state(&self, i: usize) -> Membership {
        self.members[i].state
    }

    pub fn generation(&self, i: usize) -> u32 {
        self.members[i].generation
    }

    /// Last model generation client `i` provably holds ([`ACKED_NONE`] =
    /// unknown).
    pub fn acked_model(&self, i: usize) -> u32 {
        self.members[i].acked_model
    }

    /// Record what model generation client `i` now holds: the round of a
    /// broadcast it survived, a rejoin resync, or [`ACKED_NONE`] when it
    /// died mid-broadcast and the PS can no longer assume anything.
    pub fn set_acked_model(&mut self, i: usize, round: u32) {
        self.members[i].acked_model = round;
    }

    pub fn record(&self, i: usize) -> &MemberRecord {
        &self.members[i]
    }

    /// EWMA round-trip estimate for client `i` in ms (0 = never timed).
    pub fn rtt_ewma_ms(&self, i: usize) -> f32 {
        self.members[i].rtt_ewma_ms
    }

    /// Fold one observed phase round-trip (ms) into client `i`'s EWMA.
    /// The first observation seeds the estimate directly.
    pub fn observe_rtt(&mut self, i: usize, ms: f32) {
        if !(ms.is_finite() && ms >= 0.0) {
            return;
        }
        let m = &mut self.members[i];
        m.rtt_ewma_ms = if m.rtt_ewma_ms == 0.0 {
            ms
        } else {
            RTT_EWMA_ALPHA * ms + (1.0 - RTT_EWMA_ALPHA) * m.rtt_ewma_ms
        };
    }

    /// Per-client states, in id order (the scheduler's view).
    pub fn states(&self) -> Vec<Membership> {
        self.members.iter().map(|m| m.state).collect()
    }

    /// [`Self::states`] into a caller-owned buffer — the engine reuses
    /// one scratch vector across rounds so the per-round scheduler view
    /// costs zero allocations even at fleet scale.
    pub fn states_into(&self, out: &mut Vec<Membership>) {
        out.clear();
        out.extend(self.members.iter().map(|m| m.state));
    }

    /// Clients not written off (Active, Suspect, or Rejoining).
    pub fn n_live(&self) -> usize {
        self.members.iter().filter(|m| m.state.is_live()).count()
    }

    /// Drain the records (re-shard hand-off), leaving an empty fleet.
    pub fn take_records(&mut self) -> Vec<MemberRecord> {
        std::mem::take(&mut self.members)
    }

    /// The client was scheduled this round and failed to deliver:
    /// Active -> Suspect; Suspect / Rejoining -> Dead.
    pub fn casualty(&mut self, i: usize) {
        let m = &mut self.members[i];
        m.casualties += 1;
        m.state = match m.state {
            Membership::Active => Membership::Suspect,
            _ => Membership::Dead,
        };
    }

    /// The client completed a round end to end: any state -> Active.
    pub fn survived(&mut self, i: usize) {
        self.members[i].state = Membership::Active;
    }

    /// A recovered worker was re-admitted: -> Rejoining, generation += 1.
    pub fn rejoin(&mut self, i: usize) {
        let m = &mut self.members[i];
        m.generation += 1;
        m.state = Membership::Rejoining;
    }

    /// Fold the transport's reachability report in: an unreachable
    /// client degrades one step (Active -> Suspect, Suspect / Rejoining
    /// -> Dead); a reachable one is left as-is (promotion back to Active
    /// requires *surviving* a round, not merely an open socket).
    pub fn observe_health(&mut self, health: &[bool]) {
        assert_eq!(health.len(), self.members.len());
        for (m, &up) in self.members.iter_mut().zip(health) {
            if !up {
                m.state = match m.state {
                    Membership::Active => Membership::Suspect,
                    _ => Membership::Dead,
                };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_active_generation_zero() {
        let f = Fleet::new(3);
        assert_eq!(f.n(), 3);
        assert_eq!(f.n_live(), 3);
        for i in 0..3 {
            assert_eq!(f.state(i), Membership::Active);
            assert_eq!(f.generation(i), 0);
        }
    }

    #[test]
    fn active_casualty_becomes_suspect() {
        let mut f = Fleet::new(2);
        f.casualty(0);
        assert_eq!(f.state(0), Membership::Suspect);
        assert_eq!(f.record(0).casualties, 1);
        assert_eq!(f.state(1), Membership::Active, "other clients untouched");
        assert_eq!(f.n_live(), 2, "a suspect is still live");
    }

    #[test]
    fn suspect_casualty_becomes_dead() {
        let mut f = Fleet::new(1);
        f.casualty(0);
        f.casualty(0);
        assert_eq!(f.state(0), Membership::Dead);
        assert_eq!(f.record(0).casualties, 2);
        assert_eq!(f.n_live(), 0);
    }

    #[test]
    fn suspect_survival_recovers_to_active() {
        let mut f = Fleet::new(1);
        f.casualty(0);
        f.survived(0);
        assert_eq!(f.state(0), Membership::Active);
    }

    #[test]
    fn rejoin_bumps_generation_and_survival_completes_it() {
        let mut f = Fleet::new(1);
        f.casualty(0);
        f.casualty(0);
        assert_eq!(f.state(0), Membership::Dead);
        f.rejoin(0);
        assert_eq!(f.state(0), Membership::Rejoining);
        assert_eq!(f.generation(0), 1);
        f.survived(0);
        assert_eq!(f.state(0), Membership::Active);
        assert_eq!(f.generation(0), 1, "survival keeps the generation");
    }

    #[test]
    fn rejoining_casualty_goes_straight_to_dead() {
        let mut f = Fleet::new(1);
        f.casualty(0);
        f.casualty(0);
        f.rejoin(0);
        f.casualty(0);
        assert_eq!(f.state(0), Membership::Dead, "a flapping rejoiner is not given slack");
    }

    #[test]
    fn unreachable_health_degrades_one_step() {
        let mut f = Fleet::new(3);
        f.casualty(1); // suspect
        f.observe_health(&[false, false, true]);
        assert_eq!(f.state(0), Membership::Suspect, "active degrades to suspect");
        assert_eq!(f.state(1), Membership::Dead, "suspect degrades to dead");
        assert_eq!(f.state(2), Membership::Active, "healthy stays put");
        // a rejoining client whose stream died again is written off
        f.rejoin(1);
        f.observe_health(&[true, false, true]);
        assert_eq!(f.state(1), Membership::Dead);
    }

    #[test]
    fn healthy_report_never_promotes() {
        let mut f = Fleet::new(1);
        f.casualty(0);
        f.observe_health(&[true]);
        assert_eq!(
            f.state(0),
            Membership::Suspect,
            "an open socket alone does not clear suspicion — surviving a round does"
        );
    }

    #[test]
    fn schedule_tiers_order_live_suspect_dead() {
        assert_eq!(Membership::Active.schedule_tier(), 0);
        assert_eq!(Membership::Rejoining.schedule_tier(), 0);
        assert_eq!(Membership::Suspect.schedule_tier(), 1);
        assert_eq!(Membership::Dead.schedule_tier(), 2);
        assert!(Membership::Suspect.is_live() && !Membership::Dead.is_live());
    }

    #[test]
    fn records_roundtrip_for_handoff() {
        let mut f = Fleet::new(2);
        f.casualty(0);
        f.rejoin(1);
        f.set_acked_model(0, 7);
        f.set_acked_model(1, ACKED_NONE);
        let records = f.take_records();
        let g = Fleet::from_records(records);
        assert_eq!(g.state(0), Membership::Suspect);
        assert_eq!(g.state(1), Membership::Rejoining);
        assert_eq!(g.generation(1), 1);
        assert_eq!(g.acked_model(0), 7, "the model ledger survives a re-shard hand-off");
        assert_eq!(g.acked_model(1), ACKED_NONE);
    }

    #[test]
    fn rtt_ewma_seeds_then_smooths() {
        let mut f = Fleet::new(2);
        assert_eq!(f.rtt_ewma_ms(0), 0.0, "no observation yet");
        f.observe_rtt(0, 100.0);
        assert_eq!(f.rtt_ewma_ms(0), 100.0, "first sample seeds the estimate");
        f.observe_rtt(0, 200.0);
        // 0.3 * 200 + 0.7 * 100
        assert!((f.rtt_ewma_ms(0) - 130.0).abs() < 1e-3, "{}", f.rtt_ewma_ms(0));
        assert_eq!(f.rtt_ewma_ms(1), 0.0, "other clients untouched");
        // garbage observations are ignored, not folded in
        f.observe_rtt(1, f32::NAN);
        f.observe_rtt(1, -5.0);
        assert_eq!(f.rtt_ewma_ms(1), 0.0);
    }

    #[test]
    fn rtt_ewma_survives_a_handoff() {
        let mut f = Fleet::new(2);
        f.observe_rtt(1, 80.0);
        let g = Fleet::from_records(f.take_records());
        assert_eq!(g.rtt_ewma_ms(1), 80.0);
    }

    #[test]
    fn acked_model_starts_at_the_initial_generation() {
        let mut f = Fleet::new(2);
        assert_eq!(f.acked_model(0), 0, "every worker starts holding the init model");
        f.set_acked_model(0, 3);
        assert_eq!(f.acked_model(0), 3);
        assert_eq!(f.acked_model(1), 0, "other clients untouched");
    }
}
