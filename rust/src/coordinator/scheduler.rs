//! Cohort scheduling for partial participation: which clients the PS
//! polls each round.
//!
//! Under `participation < 1.0` the engine selects a **cohort** of
//! `ceil(participation * n)` clients per round and drives the protocol
//! only for them; everyone else skips the round entirely (no broadcast,
//! no training, no upload) and their cluster's age vector simply keeps
//! growing per eq. (2) — absent clients are *maximally stale*, which is
//! exactly the signal the [`AgeDebt`] policy feeds back into selection.
//! This is the cross-device regime of "Timely Communication in Federated
//! Learning" (Buyukates & Ulukus) and "Balancing Client Participation in
//! Federated Learning Using AoI" (Javani & Wang): age debt drives who
//! participates next.
//!
//! Policies consume the **live fleet membership**
//! ([`crate::coordinator::fleet::Membership`], via `ScheduleCtx::fleet`)
//! instead of a boolean reachability bit: [`AgeDebt`] ranks `Dead`
//! clients last and penalizes `Suspect` ones (a tier below every live
//! client), while `Rejoining` clients schedule like `Active` so a
//! re-admitted worker is promptly probed back into service.
//!
//! Policies are pluggable behind [`CohortScheduler`]; all three return
//! the cohort **sorted ascending** so uploads/requests stay in stable
//! client order (the determinism the sim/TCP parity tests pin). At
//! `participation = 1.0` every policy degenerates to "all clients", so
//! full-participation runs are bit-for-bit identical to the
//! pre-scheduler engine.

use crate::coordinator::fleet::Membership;
use crate::coordinator::server::ParameterServer;
use crate::util::rng::Rng;

/// Which cohort policy the engine runs (config/CLI surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Deterministic rotation: a sliding window over client ids. The
    /// default — with full participation it is the identity schedule.
    RoundRobin,
    /// Uniformly random m-subset per round (seeded from the experiment
    /// seed; deterministic across transports).
    UniformRandom,
    /// Age-aware: rank clients by the staleness of their cluster's age
    /// vector (`max_age + mean_age`) plus the rounds since the client
    /// itself was last polled; oldest first, fleet state first.
    AgeDebt,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "round-robin" | "roundrobin" | "rr" => SchedulerKind::RoundRobin,
            "random" | "uniform" | "uniform-random" => SchedulerKind::UniformRandom,
            "age-debt" | "agedebt" | "age" => SchedulerKind::AgeDebt,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::RoundRobin => "round-robin",
            SchedulerKind::UniformRandom => "random",
            SchedulerKind::AgeDebt => "age-debt",
        }
    }

    /// Instantiate the policy. `seed` feeds the stochastic policies so
    /// both transports of the same experiment draw identical cohorts.
    pub fn build(self, seed: u64) -> Box<dyn CohortScheduler> {
        match self {
            SchedulerKind::RoundRobin => Box::new(RoundRobin { cursor: 0 }),
            SchedulerKind::UniformRandom => {
                // offset the stream tag so the scheduler never aliases the
                // client RNGs forked from the same experiment seed
                Box::new(UniformRandom { rng: Rng::new(seed ^ 0x5EED_5C4E_D01E_u64) })
            }
            SchedulerKind::AgeDebt => Box::new(AgeDebt),
        }
    }
}

/// Everything a policy may look at when picking the round's cohort.
pub struct ScheduleCtx<'a> {
    /// rounds completed so far (the cohort is for round `round + 1`)
    pub round: usize,
    /// total number of clients
    pub n: usize,
    /// cohort size to return (1 <= m <= n)
    pub m: usize,
    /// PS state: cluster membership and per-cluster age vectors
    pub ps: &'a ParameterServer,
    /// per client: global rounds since it last participated
    pub since_polled: &'a [u32],
    /// per client: the engine's fleet membership state
    /// ([`crate::coordinator::fleet::Fleet::states`]). All-Active for a
    /// healthy fleet; fleet-aware policies rank Dead clients last and
    /// penalize Suspect ones (a dead stream would burn a cohort slot on
    /// a round that cannot complete).
    pub fleet: &'a [Membership],
}

/// A cohort policy. Must return exactly `ctx.m` distinct client ids in
/// `0..ctx.n`, **sorted ascending** (the engine validates this).
pub trait CohortScheduler: Send {
    fn name(&self) -> &'static str;
    fn select(&mut self, ctx: &ScheduleCtx) -> Vec<usize>;
}

/// Sliding-window rotation over client ids. Fleet-blind by design: the
/// rotation periodically probes even Dead clients, which costs a casualty
/// slot but gives crashed-and-recovered in-process clients a natural
/// recovery path without a rejoin signal.
pub struct RoundRobin {
    cursor: usize,
}

impl CohortScheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn select(&mut self, ctx: &ScheduleCtx) -> Vec<usize> {
        let mut out: Vec<usize> = (0..ctx.m).map(|i| (self.cursor + i) % ctx.n).collect();
        self.cursor = (self.cursor + ctx.m) % ctx.n;
        out.sort_unstable();
        out
    }
}

/// Seeded uniform m-subset per round.
pub struct UniformRandom {
    rng: Rng,
}

impl CohortScheduler for UniformRandom {
    fn name(&self) -> &'static str {
        "random"
    }

    fn select(&mut self, ctx: &ScheduleCtx) -> Vec<usize> {
        let mut out = self.rng.choose_k(ctx.n, ctx.m);
        out.sort_unstable();
        out
    }
}

/// Oldest-first: clients whose cluster ages are stalest — plus the
/// client's own time since last poll — go first. Ties resolve to the
/// smaller client id, so the policy is fully deterministic.
pub struct AgeDebt;

impl AgeDebt {
    /// Per-client debt scores: cluster staleness (`max_age + mean_age`,
    /// the eq. 2 signal — O(1) on the hybrid `AgeVector` in its sparse
    /// regime) + the client's own rounds-since-last-poll. The cluster
    /// term is memoized per **cluster** — members share the age vector.
    /// For strategies that keep no age state the term is zero and the
    /// policy degenerates to longest-unpolled-first.
    fn scores(ctx: &ScheduleCtx) -> Vec<f64> {
        let clusters = ctx.ps.clusters();
        let mut cluster_term: Vec<Option<f64>> = vec![None; clusters.n_clusters()];
        (0..ctx.n)
            .map(|i| {
                let cid = clusters.cluster_of(i);
                let term = *cluster_term[cid].get_or_insert_with(|| {
                    let age = clusters.age_of_cluster(cid);
                    age.max_age() as f64 + age.mean_age()
                });
                term + ctx.since_polled[i] as f64
            })
            .collect()
    }

    /// The ranking comparator: fleet tier, then descending score, then
    /// ascending id. The id tiebreak makes this a **strict total order**
    /// — no two clients ever compare Equal — which is what lets the
    /// partial selection below return exactly the full sort's prefix.
    fn rank(ctx: &ScheduleCtx, scores: &[f64], a: usize, b: usize) -> std::cmp::Ordering {
        ctx.fleet[a]
            .schedule_tier()
            .cmp(&ctx.fleet[b].schedule_tier())
            .then(scores[b].partial_cmp(&scores[a]).expect("age scores are finite"))
            .then(a.cmp(&b))
    }

    /// Reference ranking: the full O(n log n) sort the partial selection
    /// replaced. Kept (test-visible) as the equivalence oracle for
    /// `partial_selection_matches_full_sort`.
    #[cfg(test)]
    fn select_by_full_sort(ctx: &ScheduleCtx) -> Vec<usize> {
        let scores = Self::scores(ctx);
        let mut ids: Vec<usize> = (0..ctx.n).collect();
        ids.sort_by(|&a, &b| Self::rank(ctx, &scores, a, b));
        ids.truncate(ctx.m);
        ids.sort_unstable();
        ids
    }
}

impl CohortScheduler for AgeDebt {
    fn name(&self) -> &'static str {
        "age-debt"
    }

    /// Rank by [`Self::scores`] and take the top m via **partial
    /// selection** (`select_nth_unstable_by` at position m-1): O(n +
    /// m log m) per round instead of the full O(n log n) sort — at a
    /// fleet of 10⁵ with m = 100 that is the difference between sorting
    /// 100k ids every round and one quickselect pass. Because the
    /// comparator is a strict total order, the partitioned prefix is
    /// exactly the set the full sort would have taken (regression-pinned
    /// in `partial_selection_matches_full_sort`).
    ///
    /// Fleet state ranks before debt
    /// ([`Membership::schedule_tier`]): every Active/Rejoining client
    /// outranks every Suspect one, and every Suspect outranks every
    /// Dead one, regardless of staleness — a dead stream's unbounded
    /// staleness can no longer monopolize cohort slots on rounds that
    /// cannot complete, while a re-admitted (Rejoining) worker is
    /// scheduled like a live one so its first post-rejoin round promotes
    /// it back to Active. Suspect and Dead clients are still
    /// *selectable*: when fewer than m clients are live the cohort fills
    /// with the stalest degraded ones rather than shrinking below m
    /// (probing them is how a Suspect recovers). With an all-Active
    /// fleet the ranking is bit-for-bit the pure age-debt order.
    fn select(&mut self, ctx: &ScheduleCtx) -> Vec<usize> {
        if ctx.m == 0 {
            return Vec::new();
        }
        let scores = Self::scores(ctx);
        let mut ids: Vec<usize> = (0..ctx.n).collect();
        if ctx.m < ctx.n {
            ids.select_nth_unstable_by(ctx.m - 1, |&a, &b| Self::rank(ctx, &scores, a, b));
            ids.truncate(ctx.m);
        }
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::{DbscanParams, MergeRule};
    use crate::coordinator::server::PsConfig;
    use crate::coordinator::strategies::StrategyKind;

    fn ps(n: usize) -> ParameterServer {
        ParameterServer::new(PsConfig {
            d: 32,
            n_clients: n,
            k: 2,
            strategy: StrategyKind::RageK,
            recluster_every: 0,
            dbscan: DbscanParams::default(),
            merge_rule: MergeRule::Min,
        })
    }

    static ALL_ACTIVE: [Membership; 8] = [Membership::Active; 8];

    fn ctx<'a>(ps: &'a ParameterServer, since: &'a [u32], m: usize) -> ScheduleCtx<'a> {
        ScheduleCtx {
            round: 0,
            n: since.len(),
            m,
            ps,
            since_polled: since,
            fleet: &ALL_ACTIVE[..since.len()],
        }
    }

    #[test]
    fn round_robin_rotates_and_covers_everyone() {
        let server = ps(5);
        let since = [0u32; 5];
        let mut s = RoundRobin { cursor: 0 };
        let c1 = s.select(&ctx(&server, &since, 2));
        let c2 = s.select(&ctx(&server, &since, 2));
        let c3 = s.select(&ctx(&server, &since, 2));
        assert_eq!(c1, vec![0, 1]);
        assert_eq!(c2, vec![2, 3]);
        assert_eq!(c3, vec![0, 4]); // wraps — sorted ascending
        let all: std::collections::HashSet<usize> =
            c1.into_iter().chain(c2).chain(c3).collect();
        assert_eq!(all.len(), 5, "3 windows of 2 cover all 5 clients");
    }

    #[test]
    fn uniform_random_is_seeded_sorted_and_distinct() {
        let server = ps(8);
        let since = [0u32; 8];
        let draw = |seed: u64| {
            let mut s = SchedulerKind::UniformRandom.build(seed);
            (0..4).map(|_| s.select(&ctx(&server, &since, 3))).collect::<Vec<_>>()
        };
        let a = draw(7);
        let b = draw(7);
        assert_eq!(a, b, "same seed, same cohorts");
        for cohort in &a {
            assert_eq!(cohort.len(), 3);
            assert!(cohort.windows(2).all(|w| w[0] < w[1]), "sorted + distinct: {cohort:?}");
            assert!(cohort.iter().all(|&c| c < 8));
        }
        assert_ne!(draw(8), a, "different seed must differ");
    }

    #[test]
    fn age_debt_polls_longest_unpolled_first() {
        // fresh PS: every cluster age is zero, so poll debt decides alone
        let server = ps(4);
        let since = [3u32, 9, 1, 9];
        let mut s = AgeDebt;
        assert_eq!(s.select(&ctx(&server, &since, 1)), vec![1], "tie 1-vs-3 -> smaller id");
        assert_eq!(s.select(&ctx(&server, &since, 2)), vec![1, 3]);
        assert_eq!(s.select(&ctx(&server, &since, 3)), vec![0, 1, 3]);
    }

    #[test]
    fn age_debt_prefers_stale_clusters() {
        // age clients 0/1's clusters to zero every round while 2/3 go
        // unserved: their age debt dominates equal poll debt
        let mut server = ps(4);
        for _ in 0..6 {
            let req = server.select_requests(&[
                vec![1, 2, 3],
                vec![4, 5, 6],
                vec![7, 8, 9],
                vec![10, 11, 12],
            ]);
            // clients 2 and 3 never actually upload
            server.record_round(&[req[0].clone(), req[1].clone(), Vec::new(), Vec::new()]);
        }
        let since = [0u32; 4];
        let mut s = AgeDebt;
        assert_eq!(s.select(&ctx(&server, &since, 2)), vec![2, 3]);
    }

    fn fleet_ctx<'a>(
        ps: &'a ParameterServer,
        since: &'a [u32],
        fleet: &'a [Membership],
        m: usize,
    ) -> ScheduleCtx<'a> {
        ScheduleCtx { round: 0, n: since.len(), m, ps, since_polled: since, fleet }
    }

    /// State transition: Active -> Suspect. A suspect is penalized below
    /// every Active client regardless of its (large) debt.
    #[test]
    fn age_debt_penalizes_suspect_clients() {
        let server = ps(4);
        let since = [3u32, 99, 1, 9];
        let fleet = [
            Membership::Active,
            Membership::Suspect, // highest debt, but penalized
            Membership::Active,
            Membership::Active,
        ];
        let mut s = AgeDebt;
        let c = s.select(&fleet_ctx(&server, &since, &fleet, 2));
        assert_eq!(c, vec![0, 3], "suspect client 1 must not outrank active clients");
        // ...but a suspect still fills the cohort before any Dead client
        let c = s.select(&fleet_ctx(&server, &since, &fleet, 4));
        assert_eq!(c, vec![0, 1, 2, 3]);
    }

    /// State transition: Suspect -> Dead. Dead ranks below Suspect,
    /// which ranks below Active.
    #[test]
    fn age_debt_ranks_dead_last() {
        let server = ps(4);
        let since = [3u32, 99, 1, 99];
        let fleet = [
            Membership::Active,
            Membership::Dead, // highest debt, ranked last
            Membership::Active,
            Membership::Suspect,
        ];
        let mut s = AgeDebt;
        assert_eq!(s.select(&fleet_ctx(&server, &since, &fleet, 2)), vec![0, 2]);
        assert_eq!(
            s.select(&fleet_ctx(&server, &since, &fleet, 3)),
            vec![0, 2, 3],
            "the suspect fills before the dead client"
        );
        // with only one Active client, the cohort falls back to filling
        // from suspect then dead rather than shrinking below m
        let fleet = [Membership::Dead, Membership::Dead, Membership::Active, Membership::Dead];
        let c = s.select(&fleet_ctx(&server, &since, &fleet, 2));
        assert_eq!(c, vec![1, 2], "active first, then the stalest dead one");
    }

    /// Tie-break regression pin: a `Suspect` client must never outrank a
    /// never-polled `Active` one on a `since_polled` tie — the fleet tier
    /// is the **first** comparator, before any debt score. (Score-first
    /// ordering would rank the two equal-debt clients by id and let the
    /// suspect steal the slot whenever its id is smaller.)
    #[test]
    fn age_debt_breaks_since_polled_ties_by_tier_first() {
        let server = ps(4);
        // clients 0 (Suspect) and 2 (Active, never polled) tie on debt;
        // fresh PS means the cluster term is identical too
        let since = [7u32, 0, 7, 0];
        let fleet = [
            Membership::Suspect, // same debt as client 2, smaller id
            Membership::Active,
            Membership::Active, // never polled since joining
            Membership::Active,
        ];
        let mut s = AgeDebt;
        assert_eq!(
            s.select(&fleet_ctx(&server, &since, &fleet, 1)),
            vec![2],
            "the never-polled Active client wins the tie, not the Suspect"
        );
        assert_eq!(
            s.select(&fleet_ctx(&server, &since, &fleet, 3)),
            vec![1, 2, 3],
            "every Active client fills before the tied Suspect"
        );
    }

    /// State transition: Dead -> Rejoining. A re-admitted client
    /// schedules like an Active one so its first round promotes it.
    #[test]
    fn age_debt_schedules_rejoining_like_active() {
        let server = ps(3);
        let since = [0u32, 50, 1];
        let fleet = [Membership::Active, Membership::Rejoining, Membership::Suspect];
        let mut s = AgeDebt;
        assert_eq!(
            s.select(&fleet_ctx(&server, &since, &fleet, 1)),
            vec![1],
            "rejoining client with the highest debt wins a live-tier slot"
        );
        assert_eq!(s.select(&fleet_ctx(&server, &since, &fleet, 2)), vec![0, 1]);
    }

    /// The O(n + m log m) partial selection must return exactly the
    /// cohort of the old full O(n log n) sort for every m — randomized
    /// poll debts, degraded fleet states, and clustered age structure
    /// (score ties across cluster members are where a sloppy comparator
    /// would diverge; the strict id tiebreak keeps the two paths equal).
    #[test]
    fn partial_selection_matches_full_sort() {
        let mut rng = Rng::new(0xA6EDEB7);
        let mut server = ps(16);
        // build real age structure: several rounds with a fixed uploader
        // subset so cluster terms differ
        for _ in 0..5 {
            let reports: Vec<Vec<u32>> = (0..16).map(|i| vec![i as u32, i as u32 + 1]).collect();
            let req = server.select_requests(&reports);
            let mut uploaded = vec![Vec::new(); 16];
            for i in [0usize, 2, 3, 7, 11] {
                uploaded[i] = req[i].clone();
            }
            server.record_round(&uploaded);
        }
        let states = [
            Membership::Active,
            Membership::Suspect,
            Membership::Dead,
            Membership::Rejoining,
        ];
        for _ in 0..50 {
            let since: Vec<u32> = (0..16).map(|_| rng.below(8) as u32).collect();
            let fleet: Vec<Membership> = (0..16).map(|_| states[rng.below(states.len())]).collect();
            for m in 1..=16usize {
                let ctx = fleet_ctx(&server, &since, &fleet, m);
                let mut s = AgeDebt;
                assert_eq!(
                    s.select(&ctx),
                    AgeDebt::select_by_full_sort(&ctx),
                    "m = {m}, since = {since:?}, fleet = {fleet:?}"
                );
            }
        }
    }

    #[test]
    fn full_participation_is_the_identity_for_every_policy() {
        let server = ps(6);
        let since = [2u32, 0, 5, 1, 0, 7];
        for kind in
            [SchedulerKind::RoundRobin, SchedulerKind::UniformRandom, SchedulerKind::AgeDebt]
        {
            let mut s = kind.build(42);
            assert_eq!(
                s.select(&ctx(&server, &since, 6)),
                (0..6).collect::<Vec<_>>(),
                "{} at m = n must select everyone in order",
                s.name()
            );
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for kind in
            [SchedulerKind::RoundRobin, SchedulerKind::UniformRandom, SchedulerKind::AgeDebt]
        {
            assert_eq!(SchedulerKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(SchedulerKind::parse("fifo"), None);
    }
}
