//! The unified round protocol — Algorithm 1 of the paper, once.
//!
//! Historically the per-round flow (broadcast -> local train -> top-r
//! report -> age-based index request -> sparse upload -> aggregate ->
//! server apply -> age/frequency bookkeeping -> M-periodic DBSCAN) was
//! implemented twice: in the in-process simulator and, with drift, in the
//! TCP server. [`RoundEngine`] is the single implementation; *where* the
//! clients run is abstracted behind [`ClientPool`]:
//!
//! * [`crate::fl::pool::InProcessPool`] — simulated clients in this
//!   process, trained **in parallel** on scoped threads (one backend lane
//!   per thread for the pure-Rust backend; a single shared PJRT runtime
//!   driven serially for XLA).
//! * [`crate::fl::distributed::TcpClientPool`] — one OS process per
//!   client, speaking the length-prefixed protocol of
//!   [`crate::fl::transport`].
//!
//! `Trainer::run_round` and `run_server` are thin adapters over
//! `RoundEngine::run_round`; the *client* side of the protocol is shared
//! too ([`client_train_phase`] / [`client_update_phase`] are called both
//! by the in-process pool and by `run_worker`), so the two deployments are
//! bit-for-bit identical — pinned by `rust/tests/parity.rs`.
//!
//! **Fleet membership** (DESIGN.md §8): the engine owns a
//! [`Fleet`] registry tracking every client's lifecycle
//! (`Active | Suspect | Dead | Rejoining`, with rejoin generations). A
//! pool reports per-client outcomes — [`ClientPool::train_and_report`] /
//! [`ClientPool::exchange`] return `None` for a client whose round-path
//! I/O failed — and [`RoundEngine::collect_round`] returns a
//! [`PartialRound`] (survivor reports + casualty list) instead of `Err`:
//! the round finishes with the survivors, a casualty's uploaded record
//! stays empty so its cluster's eq.-(2) ages keep growing exactly as for
//! an off-cohort client, and the scheduler consumes the live membership.
//! With no failures every client stays Active and the protocol is
//! bit-for-bit the all-answer path.
//!
//! The engine owns everything the PS owns in the paper: index selection
//! (Algorithm 2), aggregation, the server optimizer step, byte-accurate
//! communication accounting (DESIGN.md §6), the per-cluster
//! [`crate::age::AgeVector`]s / per-client frequency vectors, and the
//! M-periodic reclustering.

use crate::backend::{Backend, GlobalState};
use crate::clustering::ClusterManager;
use crate::config::{Downlink, ExperimentConfig, Payload};
use crate::coordinator::aggregator::Aggregate;
use crate::coordinator::fleet::{Fleet, MemberRecord, ACKED_NONE};
use crate::fl::codec::params_digest;
use crate::coordinator::scheduler::{CohortScheduler, ScheduleCtx};
use crate::coordinator::server::{ParameterServer, PsConfig};
use crate::coordinator::strategies::{client_select, StrategyKind};
use crate::data::{gather_batch, Dataset};
use crate::age::FrequencyVector;
use crate::fl::client::Client;
use crate::fl::metrics::CommStats;
use crate::fl::transport as wire;
use crate::sparse::{topk_abs_sparse, SparseVec};
use crate::util::timer::Profile;
use anyhow::{ensure, Result};
use std::collections::VecDeque;

/// What one client hands the PS after its local round (Algorithm 1
/// lines 4-7): the top-r report and the mean local training loss.
#[derive(Debug, Clone)]
pub struct ClientReport {
    pub report: SparseVec,
    pub mean_loss: f32,
}

/// How one round's model broadcast reaches each cohort member under the
/// delta downlink (`Downlink::Delta`, DESIGN.md §9). The engine owns the
/// generation ledger ([`Fleet::acked_model`]) and the per-round
/// updated-index ring, decides dense-vs-delta per member, and hands the
/// pool this plan *before* [`ClientPool::train_and_report`]; the pool
/// executes it frame for frame, which is what keeps the engine's wire
/// mirror equal to the observed socket bytes (`rust/tests/parity.rs`).
#[derive(Debug, Clone, Default)]
pub struct BroadcastPlan {
    /// the model generation being broadcast (= the round being played,
    /// 1-based) — the `round` field of every `Model`/`Delta` frame
    pub round: u32,
    /// [`params_digest`] of the broadcast model: a delta receiver proves
    /// convergence against it, a diverged receiver deterministically
    /// fails it and falls back to a full resync
    pub digest: u64,
    /// per client id: `Some(i)` = send `deltas[i]` as a sparse `Delta`
    /// frame; `None` = full dense `Model` frame (off-cohort and
    /// unreachable clients are `None` too — the pool never consults them)
    pub assign: Vec<Option<usize>>,
    /// distinct delta payloads this round: (base generation, sorted
    /// indices changed between that generation and `round`) — one entry
    /// per distinct base so the pool encodes each delta frame once
    pub deltas: Vec<(u32, Vec<u32>)>,
}

impl BroadcastPlan {
    /// The delta assigned to client `c`: (base generation, changed
    /// indices), or `None` when `c` gets the dense model.
    pub fn delta_for(&self, c: usize) -> Option<(u32, &[u32])> {
        self.assign.get(c).copied().flatten().map(|i| {
            let (base, idx) = &self.deltas[i];
            (*base, idx.as_slice())
        })
    }
}

/// Where the clients of a round live. Implementations hold the clients'
/// training state (and, under the Delta payload, their error-feedback
/// memories) plus the PS-side compute backend; [`RoundEngine`] drives the
/// protocol through this interface without knowing whether the clients
/// are threads in this process or sockets to other machines.
///
/// The per-client `Option` returns are the fleet-membership contract: a
/// pool must **not** fail the whole round because one client's round-path
/// I/O failed — it reports that client `None` (a casualty) and the engine
/// finishes the round with the survivors. The outer `Result` is reserved
/// for unrecoverable pool-level errors (protocol misuse, a poisoned
/// backend), which still abort.
pub trait ClientPool {
    fn n_clients(&self) -> usize;

    /// Per-client transport reachability, indexed by client id (`true` =
    /// the pool believes a round driven at this client could succeed).
    /// The default is all-true; transports that observe failures (e.g. a
    /// TCP stream that errored or timed out) report those clients `false`
    /// so the engine's [`Fleet`] degrades them
    /// (`Active -> Suspect -> Dead`) and fleet-aware schedulers stop
    /// spending cohort slots on them.
    fn health(&self) -> Vec<bool> {
        vec![true; self.n_clients()]
    }

    /// Re-admissions since the last round: client ids whose recovered
    /// worker reconnected (the TCP `Rejoin` frame) or was re-admitted at
    /// the pool level (simulated chaos). `global` is the current global
    /// model so the transport can resync the rejoined worker. The engine
    /// moves each returned id to `Rejoining` and bumps its generation.
    fn poll_rejoins(&mut self, global: &[f32]) -> Result<Vec<usize>> {
        let _ = global;
        Ok(Vec::new())
    }

    /// The engine's broadcast plan for the upcoming
    /// [`Self::train_and_report`] call (delta downlink, DESIGN.md §9):
    /// which cohort members receive a sparse `Delta` frame instead of the
    /// dense model, and the digest the applied result must hash to. Only
    /// called under `Downlink::Delta` — a transport without a delta path
    /// can ignore it (the engine still *accounts* dense frames for every
    /// member the plan marked dense). Called at most once per round,
    /// always before `train_and_report`.
    fn set_broadcast_plan(&mut self, _plan: &BroadcastPlan) {}

    /// Speculative over-scheduling (DESIGN.md §11): how many phase-1
    /// reports the engine will commit the upcoming round with. When the
    /// quota is smaller than the scheduled cohort, the pool should stop
    /// waiting as soon as `quota` reports have landed and **cancel** the
    /// stragglers — tear down their round state machines cleanly,
    /// return `None` for them from [`Self::train_and_report`], and list
    /// them in [`Self::take_cancelled`]. Cancelled members are *not*
    /// casualties: they received the broadcast and trained, the round
    /// simply committed without them. Called at most once per round,
    /// before `train_and_report`; the quota applies to that call only.
    /// The default ignores the quota (every member then reports as
    /// usual and the engine commits them all).
    fn set_commit_quota(&mut self, _quota: usize) {}

    /// The cohort members the commit quota cancelled in the last
    /// [`Self::train_and_report`] (any order; the engine sorts). A
    /// cancelled member provably received this round's broadcast (its
    /// frame was fully delivered before the round committed), so the
    /// engine keeps its generation ledger at the broadcast generation
    /// instead of forgetting it, and its fleet state is untouched — its
    /// cluster's eq.-(2) ages grow exactly as for off-cohort absence.
    /// Draining: the call transfers ownership (a second call returns
    /// empty).
    fn take_cancelled(&mut self) -> Vec<usize> {
        Vec::new()
    }

    /// Per-client phase round-trips observed since the last call:
    /// `(client id, milliseconds)` per completed write+reply phase, in
    /// observation order. The engine folds these into each
    /// [`crate::coordinator::fleet::MemberRecord`]'s EWMA
    /// (DESIGN.md §11), which transports with adaptive deadlines feed
    /// back into `clamp(ewma · k, min, io_timeout_ms)` windows. The
    /// default (simulators have no wire clock) reports nothing.
    fn take_phase_timings(&mut self) -> Vec<(usize, f32)> {
        Vec::new()
    }

    /// Algorithm 1 lines 3-7 for the round's **cohort** (sorted, distinct
    /// client ids): broadcast `global` to the cohort, have each member
    /// adopt it (local optimizer state persists — `sync_to`, not a
    /// reset), run H local steps, fold the error-feedback memory under
    /// the Delta payload, and return the top-r reports **in cohort
    /// order** — `None` for members that dropped mid-phase. Off-cohort
    /// clients must not train, upload, or receive the model (the TCP pool
    /// sends them a lightweight `Sit` frame instead; dead streams are
    /// skipped entirely).
    fn train_and_report(&mut self, global: &[f32], cohort: &[usize])
        -> Result<Vec<Option<ClientReport>>>;

    /// Algorithm 1 line 8 for the phase-1 survivors: deliver the PS's
    /// index requests (`requests[p]` is for client `cohort[p]`; `None`
    /// for client-side strategies — rTop-k/top-k/rand-k/dense select
    /// locally) and collect the sparse uploads in cohort order (`None`
    /// per dropped member). `cohort` may be a subset of the cohort passed
    /// to [`Self::train_and_report`] (phase-1 casualties are excluded).
    /// Sent coordinates leave the error-feedback memory.
    fn exchange(&mut self, requests: Option<&[Vec<u32>]>, cohort: &[usize])
        -> Result<Vec<Option<SparseVec>>>;

    /// The PS-side compute backend (server optimizer apply, evaluation).
    /// Kept on the pool so a process never holds more than one PJRT
    /// runtime.
    fn backend(&mut self) -> &mut dyn Backend;
}

/// Inverse cohort map: client id -> position into the cohort-aligned
/// reports/requests/uploads. Shared by the pools and the PS so every
/// layer agrees on the alignment (cohorts are sorted, distinct ids in
/// `0..n`).
///
/// Stamp-versioned (the `select_disjoint` trick): `set` is O(m) in the
/// cohort size — no O(n) clear or reallocation per round — so a reused
/// map costs nothing for the off-cohort majority of a large fleet.
/// Property-pinned against the naive rebuild-a-`Vec` implementation in
/// `rust/tests/properties.rs`.
#[derive(Debug, Default)]
pub struct CohortMap {
    /// client id -> cohort position, valid only where `stamp` is current
    pos: Vec<usize>,
    stamp: Vec<u32>,
    cur: u32,
}

impl CohortMap {
    pub fn new() -> Self {
        Self::default()
    }

    /// Re-key the map to `cohort` over id space `0..n`. O(m) once the
    /// buffers reached `n` capacity.
    pub fn set(&mut self, n: usize, cohort: &[usize]) {
        if self.pos.len() < n {
            self.pos.resize(n, usize::MAX);
            self.stamp.resize(n, 0);
        }
        self.cur = self.cur.wrapping_add(1);
        if self.cur == 0 {
            // stamp wrapped: invalidate everything once per 2^32 rounds
            self.stamp.fill(0);
            self.cur = 1;
        }
        for (p, &c) in cohort.iter().enumerate() {
            self.pos[c] = p;
            self.stamp[c] = self.cur;
        }
    }

    /// The client's position in the current cohort, or `usize::MAX` if it
    /// sits the round out (the sentinel the pools branch on).
    pub fn slot(&self, client: usize) -> usize {
        if client < self.pos.len() && self.stamp[client] == self.cur {
            self.pos[client]
        } else {
            usize::MAX
        }
    }

    /// `slot` as an `Option` for callers that prefer it.
    pub fn get(&self, client: usize) -> Option<usize> {
        match self.slot(client) {
            usize::MAX => None,
            p => Some(p),
        }
    }
}

/// What one engine round reports back to its driver.
#[derive(Debug)]
pub struct RoundOutcome {
    /// mean local training loss across this round's survivors (NaN on a
    /// round every scheduled client dropped out of)
    pub mean_loss: f32,
    /// Some(n_clusters) when the M-periodic DBSCAN ran this round
    pub reclustered: Option<usize>,
    pub n_clusters: usize,
    /// the clients that completed the round (sorted; all of them at
    /// participation = 1.0 with a healthy fleet)
    pub cohort: Vec<usize>,
    /// scheduled clients that dropped mid-round (sorted; empty on a
    /// healthy fleet) — their cluster ages kept growing per eq. (2)
    pub casualties: Vec<usize>,
    /// speculatively over-scheduled clients the round committed without
    /// (sorted; always empty at `overschedule = 0`). Not casualties —
    /// their fleet state is untouched and their ages grow exactly like
    /// off-cohort absence (DESIGN.md §11).
    pub cancelled: Vec<usize>,
}

/// Everything one engine's collect phases produced *before* the server
/// update: the raw material a flat round applies directly and a sharded
/// topology hands to its root aggregator
/// ([`crate::coordinator::topology::ShardedEngine`]) for the global
/// merge. Client ids here are engine-local (the owning engine's `0..n`).
///
/// This is the membership redesign's core type: a round that loses
/// clients mid-flight returns a `PartialRound` with those clients in
/// `casualties` instead of an `Err` — the driver applies the survivors'
/// aggregate, the casualties' `uploaded` entries stay empty (their
/// clusters' eq.-(2) ages keep growing, exactly like off-cohort
/// absence), and training continues.
#[derive(Debug)]
pub struct PartialRound {
    /// the scheduled cohort (sorted, distinct local ids; `m + ε` members
    /// under speculative over-scheduling) — purely informational: it is
    /// exactly the sorted union of `survivors`, `casualties`, and
    /// `cancelled`, and no driver consumes it today
    pub cohort: Vec<usize>,
    /// cohort members that completed both phases (sorted)
    pub survivors: Vec<usize>,
    /// cohort members that dropped mid-round (sorted)
    pub casualties: Vec<usize>,
    /// over-scheduled members the round committed without (sorted; see
    /// [`RoundOutcome::cancelled`])
    pub cancelled: Vec<usize>,
    /// sum over the survivors of per-client mean local losses (f64 terms
    /// in survivor order, exactly the summation `util::mean` performs —
    /// so `loss_sum / survivors.len()` reproduces the flat mean
    /// bit-for-bit)
    pub loss_sum: f64,
    /// the survivors' sparse uploads, in survivor order
    pub updates: Vec<SparseVec>,
    /// per client (all `n`, empty for non-uploaders): the indices it
    /// uploaded
    pub uploaded: Vec<Vec<u32>>,
}

/// How many rounds of uploaded-index history the engine retains (parity
/// tests / diagnostics). Bounds PS memory on long deployments: at the
/// CIFAR scale (n=6, k=100) the full log would otherwise grow by ~5 KB
/// per round forever.
pub const UPLOADED_LOG_CAP: usize = 512;

/// How many completed rounds of updated-index unions the delta downlink
/// retains ([`RoundEngine::note_model_update`]). A client whose last
/// acked generation fell further behind than this gets a dense resync —
/// at the paper's scales (k·n ≤ a few hundred indices per round) the cap
/// bounds ring memory to a few hundred KB while covering every gap a
/// live fleet produces.
pub const DELTA_RING_CAP: usize = 64;

/// The parameter-server side of Algorithm 1, shared by the in-process
/// simulator and the TCP deployment (see module docs).
pub struct RoundEngine {
    cfg: ExperimentConfig,
    ps: ParameterServer,
    global: GlobalState,
    comm: CommStats,
    profile: Profile,
    /// per round, per client: the indices actually uploaded (empty for
    /// off-cohort clients) — the most recent [`UPLOADED_LOG_CAP`] rounds
    /// only, as a ring (push_back/pop_front; a Vec here cost an O(cap)
    /// memmove every round once the cap was hit)
    uploaded_log: VecDeque<Vec<Vec<u32>>>,
    /// the cohort policy for partial participation
    scheduler: Box<dyn CohortScheduler>,
    /// per client: global rounds since it last participated (the poll
    /// debt the age-debt scheduler consumes)
    since_polled: Vec<u32>,
    /// per-client lifecycle registry (DESIGN.md §8)
    fleet: Fleet,
    /// per completed round, newest at the back: the union of indices that
    /// round's server update touched — the material the delta downlink
    /// accumulates across a client's generation gap (DESIGN.md §9). Fed
    /// only under `Downlink::Delta`; capped at [`DELTA_RING_CAP`] with
    /// slot recycling, so steady-state rounds allocate nothing here.
    delta_ring: VecDeque<Vec<u32>>,
    /// scratch for per-base union accumulation in plan construction
    union_scratch: Vec<u32>,
    /// reused per-round buffer for the scheduler's fleet-state view
    /// (zero steady-state allocations at fleet scale)
    states_scratch: Vec<crate::coordinator::fleet::Membership>,
}

impl RoundEngine {
    pub fn new(cfg: &ExperimentConfig, init_params: Vec<f32>) -> Self {
        let ps = ParameterServer::new(PsConfig {
            d: cfg.d(),
            n_clients: cfg.n_clients,
            k: cfg.k,
            strategy: cfg.strategy,
            recluster_every: cfg.recluster_every,
            dbscan: cfg.dbscan,
            merge_rule: cfg.merge_rule,
        });
        RoundEngine {
            cfg: cfg.clone(),
            ps,
            global: GlobalState::new(init_params),
            comm: CommStats::default(),
            profile: Profile::new(),
            uploaded_log: VecDeque::new(),
            scheduler: cfg.scheduler.build(cfg.seed),
            since_polled: vec![0; cfg.n_clients],
            fleet: Fleet::new(cfg.n_clients),
            delta_ring: VecDeque::new(),
            union_scratch: Vec::new(),
            states_scratch: Vec::new(),
        }
    }

    pub fn ps(&self) -> &ParameterServer {
        &self.ps
    }

    pub fn global_params(&self) -> &[f32] {
        &self.global.params
    }

    pub fn comm(&self) -> CommStats {
        self.comm
    }

    pub fn profile(&self) -> &Profile {
        &self.profile
    }

    /// The engine's live membership registry.
    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    /// Rounds completed so far.
    pub fn round(&self) -> usize {
        self.ps.round()
    }

    /// Per-round, per-client uploaded index sets (empty entries for
    /// clients that sat the round out) — the most recent
    /// [`UPLOADED_LOG_CAP`] rounds (parity/diagnostics).
    pub fn uploaded_log(&self) -> &VecDeque<Vec<Vec<u32>>> {
        &self.uploaded_log
    }

    /// Overwrite the engine's working copy of the global model (the
    /// vector the next round broadcasts). Under a sharded topology the
    /// root aggregator owns the authoritative model and re-broadcasts it
    /// into each shard engine every round; the flat path never calls this.
    pub fn set_global(&mut self, params: &[f32]) {
        self.global.params.copy_from_slice(params);
    }

    /// Record the round that just finished into the delta ring: the union
    /// of indices its server update touched (`None` = an all-casualty
    /// round whose update was skipped — an *empty* union, because the
    /// broadcast model did not move). Call between the server apply and
    /// [`Self::finish_round`]; the flat [`Self::run_round`] does this
    /// itself, a sharded topology calls
    /// [`Self::note_model_update_union`] with the root's fleet-wide
    /// union instead. No-op under `Downlink::Dense`.
    pub fn note_model_update(&mut self, agg: Option<&Aggregate>) {
        if self.cfg.downlink != Downlink::Delta {
            return;
        }
        let mut slot = self.recycled_ring_slot();
        if let Some(agg) = agg {
            agg.updated_indices_into(&mut slot);
        }
        self.delta_ring.push_back(slot);
    }

    /// Sharded-topology form of [`Self::note_model_update`]: the root
    /// aggregator's **fleet-wide** sorted index union for the round just
    /// applied. Every shard engine re-broadcasts the same root model, so
    /// every shard's ring must carry the same unions — the root computes
    /// one union and feeds it to each shard (Flat ≡ Sharded(1) is pinned
    /// on exactly this). No-op under `Downlink::Dense`.
    pub fn note_model_update_union(&mut self, union: &[u32]) {
        if self.cfg.downlink != Downlink::Delta {
            return;
        }
        let mut slot = self.recycled_ring_slot();
        slot.extend_from_slice(union);
        self.delta_ring.push_back(slot);
    }

    /// An empty `Vec<u32>` for the next ring entry, recycled from the
    /// evicted oldest slot once the ring is full.
    fn recycled_ring_slot(&mut self) -> Vec<u32> {
        if self.delta_ring.len() >= DELTA_RING_CAP {
            let mut slot = self.delta_ring.pop_front().unwrap();
            slot.clear();
            slot
        } else {
            Vec::new()
        }
    }

    /// Build this round's [`BroadcastPlan`] (delta downlink only): for
    /// each reachable cohort member, send the sparse delta from its last
    /// acked generation iff the ledger knows that generation, the ring
    /// still covers the gap, and the delta frame is strictly smaller
    /// than the dense model frame — otherwise the full model. Members
    /// sharing a base generation share one encoded delta payload.
    fn build_broadcast_plan(&mut self, cohort: &[usize], health: &[bool]) -> BroadcastPlan {
        let r = self.ps.round() as u32 + 1; // the round being played
        let d = self.cfg.d();
        let codec = self.cfg.codec;
        let dense_bytes = wire::model_frame_bytes(d);
        let mut plan = BroadcastPlan {
            round: r,
            digest: params_digest(&self.global.params),
            assign: vec![None; self.cfg.n_clients],
            deltas: Vec::new(),
        };
        for &c in cohort {
            if !health[c] {
                continue; // no frame is written to an unreachable stream
            }
            let base = self.fleet.acked_model(c);
            if base == ACKED_NONE || base > r {
                continue; // unknown (or nonsensical) base: dense resync
            }
            // the broadcast of round `base` reflects server updates
            // through round base-1, this round's through r-1, so the gap
            // is the update unions of rounds max(base,1)..=r-1 — the
            // last `r - max(base,1)` ring entries (G(0) := G(1): round 1
            // is an empty delta on top of the init model every worker
            // already holds)
            if let Some(i) = plan.deltas.iter().position(|(b, _)| *b == base) {
                plan.assign[c] = Some(i); // same base, same delta payload
                continue;
            }
            let gap = (r - base.max(1)) as usize;
            if gap > self.delta_ring.len() {
                continue; // fell off the ring: dense resync
            }
            let union = &mut self.union_scratch;
            union.clear();
            for round_union in self.delta_ring.iter().rev().take(gap) {
                union.extend_from_slice(round_union);
            }
            union.sort_unstable();
            union.dedup();
            // a delta only rides when it beats the dense frame on the
            // wire under the active codec (it essentially always does —
            // the union is ~k·n indices against d parameters)
            if wire::delta_frame_bytes(codec, union) < dense_bytes {
                plan.assign[c] = Some(plan.deltas.len());
                plan.deltas.push((base, union.clone()));
            }
        }
        plan
    }

    /// Snapshot this engine's per-client membership state (frequency
    /// vector, poll debt, fleet record) in local-id order — the material
    /// a dynamic re-shard hands between shard engines.
    pub fn membership_parts(&self) -> Vec<(FrequencyVector, u32, MemberRecord)> {
        (0..self.cfg.n_clients)
            .map(|c| (self.ps.frequency(c).clone(), self.since_polled[c], *self.fleet.record(c)))
            .collect()
    }

    /// Install a re-sharded client set: `clusters` is this engine's new
    /// cluster state (local ids = positions in the new slice) and `parts`
    /// the per-client membership state in the same order. Resizes the
    /// engine to `parts.len()` clients; accounting, the global-model
    /// copy, the round counter, and the uploaded-index log are preserved
    /// (historical log entries keep their old width — they describe the
    /// old assignment).
    pub fn install_membership(
        &mut self,
        clusters: ClusterManager,
        parts: Vec<(FrequencyVector, u32, MemberRecord)>,
    ) {
        assert_eq!(clusters.n_clients(), parts.len());
        let n = parts.len();
        let mut freqs = Vec::with_capacity(n);
        let mut since = Vec::with_capacity(n);
        let mut records = Vec::with_capacity(n);
        for (f, s, r) in parts {
            freqs.push(f);
            since.push(s);
            records.push(r);
        }
        self.cfg.n_clients = n;
        self.ps.install(clusters, freqs);
        self.since_polled = since;
        self.fleet = Fleet::from_records(records);
    }

    /// One global round (Algorithm 1 lines 3-16) against `pool`, scoped
    /// to a scheduler-selected cohort of `cfg.cohort_size()` clients.
    /// At `participation = 1.0` with a healthy fleet the cohort is every
    /// client and the round is bit-for-bit the pre-cohort protocol.
    ///
    /// A mid-round client failure no longer aborts: the round finishes
    /// with the survivors (see [`PartialRound`]); the server update is
    /// skipped only when *every* scheduled client dropped.
    ///
    /// This is the flat composition of the three phase functions the
    /// sharded topology re-uses: [`Self::collect_round`] (broadcast,
    /// local training, selection, uploads, wire accounting),
    /// [`merge_and_apply`] (aggregate + server update), and
    /// [`Self::finish_round`] (age/frequency bookkeeping + M-periodic
    /// reclustering).
    pub fn run_round(&mut self, pool: &mut dyn ClientPool) -> Result<RoundOutcome> {
        let pr = self.collect_round(pool)?;
        let PartialRound { survivors, casualties, cancelled, loss_sum, updates, uploaded, .. } =
            pr;
        let mean_loss = if survivors.is_empty() {
            f32::NAN
        } else {
            (loss_sum / survivors.len() as f64) as f32
        };
        if !survivors.is_empty() {
            let mut agg = Aggregate::new();
            for u in updates {
                agg.push(u);
            }
            merge_and_apply(
                &self.cfg,
                pool.backend(),
                &mut self.global,
                &agg,
                survivors.len(),
                self.cfg.n_clients,
                &self.profile,
            )?;
            self.note_model_update(Some(&agg));
        } else {
            // the update was skipped: the next broadcast differs from
            // this one by nothing — an empty ring entry
            self.note_model_update(None);
        }
        let reclustered = self.finish_round(uploaded, &survivors);
        Ok(RoundOutcome {
            mean_loss,
            reclustered,
            n_clusters: self.ps.clusters().n_clusters(),
            cohort: survivors,
            casualties,
            cancelled,
        })
    }

    /// Phases 1-3 of a round: membership intake (rejoins + transport
    /// health), cohort selection, broadcast + local training + top-r
    /// reports, PS index selection, sparse uploads, and the full (§6 +
    /// exact wire) communication accounting — everything up to but
    /// excluding the server update and bookkeeping. The caller decides
    /// where the returned [`PartialRound`] is applied: locally
    /// ([`Self::run_round`]) or merged with sibling shards at a root
    /// aggregator.
    pub fn collect_round(&mut self, pool: &mut dyn ClientPool) -> Result<PartialRound> {
        let n = self.cfg.n_clients;
        let (k, r, d) = (self.cfg.k, self.cfg.r, self.cfg.d());
        ensure!(
            pool.n_clients() == n,
            "pool has {} clients, config says {n}",
            pool.n_clients()
        );

        // ---- membership intake: re-admissions, then transport health
        let rejoined = pool.poll_rejoins(&self.global.params)?;
        for &c in &rejoined {
            ensure!(c < n, "pool re-admitted unknown client {c} (n = {n})");
            self.fleet.rejoin(c);
            // the pool resynced the rejoiner to the *current* global (a
            // full model, or a digest proof that it still holds it), so
            // it provably holds this round's broadcast generation
            self.fleet.set_acked_model(c, self.ps.round() as u32 + 1);
            crate::info!(
                "round {}: client {c} rejoined (generation {})",
                self.ps.round() + 1,
                self.fleet.generation(c)
            );
        }
        let health = pool.health();
        ensure!(
            health.len() == n,
            "pool reported health for {} of {n} clients",
            health.len()
        );
        self.fleet.observe_health(&health);

        // ---- cohort selection (partial participation, fleet-aware).
        // Under speculative over-scheduling (DESIGN.md §11) the
        // scheduler selects m + ε members; the round still commits on
        // the first m reports and the ε stragglers are cancelled.
        let m = self.cfg.cohort_size();
        let m_sched = self.cfg.scheduled_cohort_size();
        self.fleet.states_into(&mut self.states_scratch);
        let cohort = self.scheduler.select(&ScheduleCtx {
            round: self.ps.round(),
            n,
            m: m_sched,
            ps: &self.ps,
            since_polled: &self.since_polled,
            fleet: &self.states_scratch,
        });
        ensure!(
            cohort.len() == m_sched
                && cohort.windows(2).all(|w| w[0] < w[1])
                && cohort.iter().all(|&c| c < n),
            "scheduler {} returned an invalid cohort {cohort:?} (want {m_sched} sorted ids < {n})",
            self.scheduler.name()
        );
        if m_sched > m {
            pool.set_commit_quota(m);
        }

        // ---- delta-downlink broadcast plan (DESIGN.md §9): decided by
        // the engine from its generation ledger + update ring, executed
        // frame for frame by the pool. Never built under Dense — that
        // path stays bit-for-bit the classical dense broadcast.
        let plan = if self.cfg.downlink == Downlink::Delta {
            let plan = self.build_broadcast_plan(&cohort, &health);
            pool.set_broadcast_plan(&plan);
            Some(plan)
        } else {
            None
        };

        // ---- broadcast + local training + top-r reports (lines 3-7)
        let phase1 = self
            .profile
            .time("pool.train", || pool.train_and_report(&self.global.params, &cohort))?;
        ensure!(
            phase1.len() == m_sched,
            "pool returned {} report slots for a cohort of {m_sched}",
            phase1.len()
        );
        // stragglers the commit quota cancelled: `None` in phase1 but
        // *not* casualties (DESIGN.md §11) — their broadcast was fully
        // delivered, so the generation ledger advances like a survivor's
        let mut cancelled: Vec<usize> =
            if m_sched > m { pool.take_cancelled() } else { Vec::new() };
        cancelled.sort_unstable();
        ensure!(
            cancelled.windows(2).all(|w| w[0] < w[1])
                && cancelled.iter().all(|&c| cohort.binary_search(&c).is_ok()),
            "pool cancelled {cancelled:?}, not a distinct subset of the cohort {cohort:?}"
        );
        let mut casualties: Vec<usize> = Vec::new();
        // phase-1 survivors and their reports, in (sorted) cohort order
        let mut alive: Vec<usize> = Vec::with_capacity(m);
        let mut reports: Vec<ClientReport> = Vec::with_capacity(m);
        let broadcast_gen = self.ps.round() as u32 + 1;
        for (&c, rep) in cohort.iter().zip(phase1) {
            match rep {
                Some(rep) => {
                    ensure!(
                        cancelled.binary_search(&c).is_err(),
                        "pool both reported and cancelled client {c}"
                    );
                    alive.push(c);
                    reports.push(rep);
                    // a returned report proves the member received and
                    // applied this round's broadcast (a diverged delta
                    // receiver bails before reporting)
                    self.fleet.set_acked_model(c, broadcast_gen);
                }
                None if cancelled.binary_search(&c).is_ok() => {
                    // cancelled straggler: it holds this round's
                    // broadcast and trained on it — the round just
                    // committed without its report. No fleet damage; it
                    // ages like an off-cohort client from here.
                    self.fleet.set_acked_model(c, broadcast_gen);
                    crate::info!(
                        "round {}: client {c} cancelled (round committed with {m} of {m_sched})",
                        self.ps.round() + 1,
                    );
                }
                None => {
                    // a member whose stream was never written keeps its
                    // old (still valid) generation; one that dropped
                    // mid-broadcast may or may not hold the new model —
                    // the ledger must forget it (next broadcast dense)
                    if health[c] {
                        self.fleet.set_acked_model(c, ACKED_NONE);
                    }
                    casualties.push(c);
                }
            }
        }

        // ---- index selection (Algorithm 2 at the PS, over the phase-1
        // survivors; client-side strategies select inside the pool)
        let requests: Option<Vec<Vec<u32>>> = if self.cfg.strategy.needs_report() {
            let idx: Vec<Vec<u32>> = reports.iter().map(|c| c.report.idx.clone()).collect();
            Some(self
                .profile
                .time("ps.select", || self.ps.select_requests_cohort(&alive, &idx)))
        } else {
            None
        };

        // ---- sparse uploads (line 8), again tolerating casualties
        let phase2 = if alive.is_empty() {
            Vec::new()
        } else {
            self.profile
                .time("pool.exchange", || pool.exchange(requests.as_deref(), &alive))?
        };
        ensure!(
            phase2.len() == alive.len(),
            "pool returned {} update slots for {} survivors",
            phase2.len(),
            alive.len()
        );
        // what each client actually uploaded drives the bookkeeping — for
        // PS-side strategies this equals the request (requested ⊆ report),
        // for client-side strategies it is the client's own selection.
        // Non-uploaders (off-cohort or casualty) get an empty entry: a
        // frequency no-op, and a cluster whose members all sat out ages
        // uniformly (eq. 2).
        let mut survivors: Vec<usize> = Vec::with_capacity(alive.len());
        let mut updates: Vec<SparseVec> = Vec::with_capacity(alive.len());
        let mut loss_sum = 0.0f64;
        let mut uploaded: Vec<Vec<u32>> = vec![Vec::new(); n];
        for ((&c, up), rep) in alive.iter().zip(phase2).zip(&reports) {
            match up {
                Some(u) => {
                    uploaded[c] = u.idx.clone();
                    loss_sum += rep.mean_loss as f64;
                    updates.push(u);
                    survivors.push(c);
                }
                None => casualties.push(c),
            }
        }
        casualties.sort_unstable();

        // ---- fleet bookkeeping for this round's outcomes
        for &c in &casualties {
            self.fleet.casualty(c);
            crate::info!(
                "round {}: client {c} dropped mid-round -> {}",
                self.ps.round() + 1,
                self.fleet.state(c).name()
            );
        }
        for &c in &survivors {
            self.fleet.survived(c);
        }

        // ---- communication accounting (DESIGN.md §6, cohort-scoped).
        // Broadcast/Sit frames count for the streams the pool actually
        // writes (cohort members / off-cohort clients whose transport was
        // reachable at round start); report/request/update frames count
        // per phase survivor. On a casualty-free round this is exactly
        // the classical cohort accounting.
        let m_bcast = cohort.iter().filter(|&&c| health[c]).count();
        let m1 = alive.len();
        for u in &updates {
            self.comm.update_up += (u.len() * 8) as u64;
        }
        if self.cfg.strategy.needs_report() {
            self.comm.report_up += (m1 * r * 4) as u64;
            self.comm.request_down += (m1 * k * 4) as u64;
        }
        // ---- exact wire accounting: the frame bytes this round costs
        // under the active codec, mirrored frame for frame from the TCP
        // deployment (model/delta + request + sit down; report + update
        // up) and pinned equal to the observed socket bytes on
        // casualty-free rounds by rust/tests/parity.rs (a stream that
        // dies mid-frame leaves the observed count short by that partial
        // frame — see DESIGN.md §8). The in-process pool has no wire, so
        // for the simulator these are the bytes the same round *would*
        // cost.
        let codec = self.cfg.codec;
        match &plan {
            // dense downlink: the classical broadcast, byte-identical to
            // the pre-delta protocol
            None => {
                self.comm.broadcast_down += (m_bcast * d * 4) as u64;
                self.comm.wire_down += (m_bcast * wire::model_frame_bytes(d)) as u64;
            }
            // delta downlink: each reachable cohort member costs exactly
            // what the plan told the pool to write it — a sparse Delta
            // frame (8 semantic bytes per changed coordinate) or the
            // dense fallback
            Some(p) => {
                for &c in cohort.iter().filter(|&&c| health[c]) {
                    match p.delta_for(c) {
                        Some((_, idx)) => {
                            self.comm.broadcast_down += (idx.len() * 8) as u64;
                            self.comm.wire_down +=
                                wire::delta_frame_bytes(codec, idx) as u64;
                        }
                        None => {
                            self.comm.broadcast_down += (d * 4) as u64;
                            self.comm.wire_down += wire::model_frame_bytes(d) as u64;
                        }
                    }
                }
            }
        }
        // off-cohort reachable streams = all reachable minus the cohort's
        // reachable members (no O(n) membership mask needed)
        let sits = health.iter().filter(|&&h| h).count() - m_bcast;
        self.comm.wire_down += (sits * wire::SIT_FRAME_BYTES) as u64;
        // each cancelled straggler is unwedged with one Sit frame at the
        // moment the round commits (DESIGN.md §11); its late report is
        // drained off the stream and tallied separately by the transport
        // (`drained_up`), never here
        self.comm.wire_down += (cancelled.len() * wire::SIT_FRAME_BYTES) as u64;
        for rep in &reports {
            self.comm.wire_up += wire::report_frame_bytes(codec, &rep.report.idx) as u64;
        }
        match &requests {
            // the Request frame flows even for client-side strategies
            // (empty), keeping the wire flow uniform — count it the same
            Some(reqs) => {
                for req in reqs {
                    self.comm.wire_down += wire::request_frame_bytes(codec, req) as u64;
                }
            }
            None => {
                self.comm.wire_down += (m1 * wire::request_frame_bytes(codec, &[])) as u64;
            }
        }
        for u in &updates {
            self.comm.wire_up += wire::update_frame_bytes(codec, &u.idx) as u64;
        }

        // ---- adaptive-deadline feedback: fold the transport's observed
        // per-phase round-trips into the fleet's EWMAs (DESIGN.md §11).
        // Simulated pools report nothing and this is a no-op.
        for (c, ms) in pool.take_phase_timings() {
            if c < n {
                self.fleet.observe_rtt(c, ms);
            }
        }

        Ok(PartialRound { cohort, survivors, casualties, cancelled, loss_sum, updates, uploaded })
    }

    /// Phase 5 of a round: commit the round's uploads to the age and
    /// frequency bookkeeping (Algorithm 2 lines 7-8 / eq. 2), run the
    /// M-periodic clustering (Algorithm 1 lines 13-16), and update the
    /// uploaded-index log and poll-debt counters. `survivors` are the
    /// clients that completed the round — casualties keep accruing poll
    /// debt exactly like off-cohort clients. Returns `Some(n_clusters)`
    /// when reclustering ran.
    pub fn finish_round(&mut self, uploaded: Vec<Vec<u32>>, survivors: &[usize]) -> Option<usize> {
        self.profile.time("ps.record", || self.ps.record_round(&uploaded));
        let reclustered = self.ps.maybe_recluster();
        self.uploaded_log.push_back(uploaded);
        if self.uploaded_log.len() > UPLOADED_LOG_CAP {
            self.uploaded_log.pop_front();
        }
        for s in self.since_polled.iter_mut() {
            *s = s.saturating_add(1);
        }
        for &c in survivors {
            self.since_polled[c] = 0;
        }
        reclustered
    }
}

/// Phase 4 of a round — Algorithm 1 lines 9-11, shared by the flat engine
/// and the sharded root aggregator: materialize the aggregated update and
/// step the global model. `uploaders` is how many clients contributed to
/// `agg` (the whole-fleet count at the root) and `n_clients` the total
/// client count behind it, so the Grad scale `n/m` stays the unbiased
/// full-participation estimate at every level of the topology.
pub fn merge_and_apply(
    cfg: &ExperimentConfig,
    backend: &mut dyn Backend,
    global: &mut GlobalState,
    agg: &Aggregate,
    uploaders: usize,
    n_clients: usize,
    profile: &Profile,
) -> Result<()> {
    ensure!(uploaders > 0, "a round must have at least one uploader");
    let d = global.params.len();
    match cfg.payload {
        Payload::Delta => {
            // FedAvg-style: apply the mean sparse drift directly,
            // averaged over the clients that actually uploaded
            let update = agg.to_dense(d, 1.0 / uploaders as f32);
            profile.time("ps.apply", || {
                for (p, &u) in global.params.iter_mut().zip(&update) {
                    *p += u;
                }
            });
        }
        Payload::Grad if cfg.server_opt == "sgd" => {
            // unbiased cohort estimate of the full-participation sum:
            // scale the m-client aggregate by n/m (exactly 1.0 at full
            // participation), so the server step magnitude does not
            // shrink with the participation knob
            let update = agg.to_dense(d, n_clients as f32 / uploaders as f32);
            let lr = cfg.lr_server;
            profile.time("ps.apply", || {
                for (p, &u) in global.params.iter_mut().zip(&update) {
                    *p -= lr * u;
                }
            });
        }
        Payload::Grad => {
            let scale = n_clients as f32 / uploaders as f32; // see the sgd branch note
            profile.time("ps.apply", || backend.server_apply(global, agg, scale, cfg.lr_server))?;
        }
    }
    Ok(())
}

// ================================================== client-side protocol

/// The slice of the experiment config the per-client protocol phases
/// need; shared by the in-process pool and the TCP worker so both
/// deployments execute the identical client code path.
#[derive(Debug, Clone, Copy)]
pub struct PhaseCfg {
    pub strategy: StrategyKind,
    pub payload: Payload,
    pub d: usize,
    pub r: usize,
    pub k: usize,
    pub h: usize,
    pub batch: usize,
}

impl PhaseCfg {
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        PhaseCfg {
            strategy: cfg.strategy,
            payload: cfg.payload,
            d: cfg.d(),
            r: cfg.r,
            k: cfg.k,
            h: cfg.h,
            batch: cfg.batch,
        }
    }
}

/// One client's first half of a round (Algorithm 1 lines 4-7): adopt the
/// broadcast global model via `sync_to` — the local Adam moments persist
/// across rounds — run H local steps, and build the top-r report. Under
/// the Delta payload the round's drift theta_i - theta is folded into the
/// error-feedback `memory` first and the report is the top-r of the
/// *accumulated* unsent update — the Qsparse-local-SGD mechanism the
/// paper's convergence argument relies on (DESIGN.md §5).
pub fn client_train_phase(
    client: &mut Client,
    backend: &mut dyn Backend,
    memory: Option<&mut Vec<f32>>,
    global: &[f32],
    pc: &PhaseCfg,
) -> Result<ClientReport> {
    client.state.sync_to(global);
    let out = client.local_round(backend, pc.h, pc.batch)?;
    let report = match memory {
        Some(mem) => {
            for (m, (p, g)) in mem
                .iter_mut()
                .zip(client.state.params.iter().zip(global))
            {
                *m += p - g;
            }
            topk_abs_sparse(mem, pc.r)
        }
        None => out.report,
    };
    Ok(ClientReport { report, mean_loss: out.mean_loss })
}

/// One client's second half of a round (Algorithm 1 line 8): build the
/// sparse upload for the PS's `request` (PS-side strategies) or for a
/// locally selected index set (`request == None`; rTop-k / top-k / rand-k
/// / dense). Sent coordinates leave the error-feedback memory.
pub fn client_update_phase(
    client: &mut Client,
    backend: &mut dyn Backend,
    mut memory: Option<&mut Vec<f32>>,
    report: &SparseVec,
    request: Option<&[u32]>,
    pc: &PhaseCfg,
) -> Result<SparseVec> {
    let selected: Vec<u32> = match request {
        Some(req) => req.to_vec(),
        None => client_select(pc.strategy, &mut client.rng, &report.idx, pc.d, pc.k),
    };
    let update = if pc.strategy.needs_dense_grad() {
        // rand-k / dense need coordinates outside the top-r report
        match memory.as_deref() {
            Some(mem) => Client::gather_from_grad(mem, &selected),
            None => {
                let (xs, ys) = client.draw_round_batches(1, pc.batch);
                let (grad, _) = backend.dense_grad(&client.state.params, &xs, &ys)?;
                Client::gather_from_grad(&grad, &selected)
            }
        }
    } else {
        Client::answer_request(report, &selected)
    };
    if let Some(mem) = memory.as_deref_mut() {
        for &j in &update.idx {
            mem[j as usize] = 0.0;
        }
    }
    Ok(update)
}

// =============================================================== eval

/// Batched accuracy/loss of `params` over `indices` of `ds`, shared by
/// the simulator and the TCP server. The trailing partial batch is padded
/// with copies of the last sample (the XLA artifacts require a fixed
/// batch size); one extra backend call on a batch made solely of that
/// sample isolates its per-sample stats exactly, so the padded duplicates
/// are subtracted back out and never bias the metric.
pub fn eval_dataset(
    backend: &mut dyn Backend,
    params: &[f32],
    ds: &Dataset,
    indices: &[usize],
    batch: usize,
) -> Result<(f32, f32)> {
    ensure!(!indices.is_empty(), "empty eval subset");
    let n = indices.len();
    let n_batches = n.div_ceil(batch);
    let mut loss_sum = 0.0f32;
    let mut correct = 0usize;
    for i in 0..n_batches {
        let idx: Vec<usize> =
            (i * batch..(i + 1) * batch).map(|j| indices[j.min(n - 1)]).collect();
        let (x, y) = gather_batch(ds, &idx);
        let (ls, c) = backend.eval(params, &x, &y)?;
        loss_sum += ls;
        correct += c;
    }
    let pad = n_batches * batch - n;
    if pad > 0 {
        let idx = vec![indices[n - 1]; batch];
        let (x, y) = gather_batch(ds, &idx);
        let (ls, c) = backend.eval(params, &x, &y)?;
        // a batch of `batch` copies of one sample: per-sample correctness
        // is c / batch (0 or 1), per-sample loss is ls / batch
        debug_assert_eq!(c % batch, 0, "identical samples must agree");
        correct -= (c / batch) * pad;
        loss_sum -= ls / batch as f32 * pad as f32;
    }
    Ok((correct as f32 / n as f32, loss_sum / n as f32))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::fleet::Membership;
    use std::collections::HashSet;

    /// A scripted pool: canned reports/uploads, no real training. Lets the
    /// engine's selection/accounting/bookkeeping be checked in isolation —
    /// including casualty handling (`fail_phase1` / `fail_phase2` clients
    /// answer `None`).
    struct FakePool {
        n: usize,
        k: usize,
        backend: crate::backend::RustBackend,
        /// requests seen at the last exchange (None = client-side)
        last_requests: Option<Vec<Vec<u32>>>,
        fail_phase1: HashSet<usize>,
        fail_phase2: HashSet<usize>,
        /// members that lose every speculative race (quota rounds only)
        stalled: HashSet<usize>,
        /// the engine's commit quota for the next train_and_report
        quota: Option<usize>,
        cancelled: Vec<usize>,
        /// scripted phase round-trips handed back via take_phase_timings
        timings: Vec<(usize, f32)>,
    }

    impl FakePool {
        fn healthy(cfg: &ExperimentConfig) -> Self {
            FakePool {
                n: cfg.n_clients,
                k: cfg.k,
                backend: crate::backend::RustBackend::new(cfg.r, cfg.lr_client, cfg.seed),
                last_requests: None,
                fail_phase1: HashSet::new(),
                fail_phase2: HashSet::new(),
                stalled: HashSet::new(),
                quota: None,
                cancelled: Vec::new(),
                timings: Vec::new(),
            }
        }
    }

    impl ClientPool for FakePool {
        fn n_clients(&self) -> usize {
            self.n
        }

        fn set_commit_quota(&mut self, quota: usize) {
            self.quota = Some(quota);
        }

        fn take_cancelled(&mut self) -> Vec<usize> {
            std::mem::take(&mut self.cancelled)
        }

        fn take_phase_timings(&mut self) -> Vec<(usize, f32)> {
            std::mem::take(&mut self.timings)
        }

        fn train_and_report(
            &mut self,
            _global: &[f32],
            cohort: &[usize],
        ) -> Result<Vec<Option<ClientReport>>> {
            assert!(cohort.iter().all(|&c| c < self.n));
            // client i reports indices 10i..10i+r by descending magnitude
            let mut out: Vec<Option<ClientReport>> = cohort
                .iter()
                .map(|&i| {
                    if self.fail_phase1.contains(&i) {
                        return None;
                    }
                    let idx: Vec<u32> = (0..40u32).map(|j| 10 * i as u32 + j).collect();
                    let val: Vec<f32> = (0..40).map(|j| 40.0 - j as f32).collect();
                    Some(ClientReport {
                        report: SparseVec::new(idx, val),
                        mean_loss: 1.0,
                    })
                })
                .collect();
            // speculative commit: the first `quota` non-stalled members
            // (cohort order) land; every other live member is cancelled
            if let Some(quota) = self.quota.take() {
                let mut landed = 0;
                for (p, &c) in cohort.iter().enumerate() {
                    if out[p].is_none() {
                        continue; // a real casualty, not a cancellation
                    }
                    if landed < quota && !self.stalled.contains(&c) {
                        landed += 1;
                    } else {
                        out[p] = None;
                        self.cancelled.push(c);
                    }
                }
            }
            Ok(out)
        }

        fn exchange(
            &mut self,
            requests: Option<&[Vec<u32>]>,
            cohort: &[usize],
        ) -> Result<Vec<Option<SparseVec>>> {
            self.last_requests = requests.map(|r| r.to_vec());
            Ok(match requests {
                Some(reqs) => cohort
                    .iter()
                    .zip(reqs)
                    .map(|(&i, req)| {
                        if self.fail_phase2.contains(&i) {
                            return None;
                        }
                        Some(SparseVec::new(
                            req.clone(),
                            req.iter().map(|&j| j as f32).collect(),
                        ))
                    })
                    .collect(),
                None => cohort
                    .iter()
                    .map(|&i| {
                        if self.fail_phase2.contains(&i) {
                            return None;
                        }
                        let idx: Vec<u32> =
                            (0..self.k as u32).map(|j| 10 * i as u32 + j).collect();
                        Some(SparseVec::new(idx.clone(), vec![1.0; idx.len()]))
                    })
                    .collect(),
            })
        }

        fn backend(&mut self) -> &mut dyn Backend {
            &mut self.backend
        }
    }

    fn smoke_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.n_clients = 2;
        cfg.payload = Payload::Delta;
        cfg
    }

    #[test]
    fn engine_round_accounts_and_records() {
        let cfg = smoke_cfg();
        let d = cfg.d();
        let mut pool = FakePool::healthy(&cfg);
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);
        let out = engine.run_round(&mut pool).unwrap();
        assert_eq!(out.mean_loss, 1.0);
        assert_eq!(out.cohort, vec![0, 1], "full participation polls everyone");
        assert!(out.casualties.is_empty());
        assert_eq!(engine.round(), 1);
        // rAge-k: requests went out and equal the uploads
        let reqs = pool.last_requests.clone().unwrap();
        assert_eq!(
            engine.uploaded_log().iter().cloned().collect::<Vec<_>>(),
            vec![reqs.clone()]
        );
        assert!(reqs.iter().all(|r| r.len() == cfg.k));
        // byte accounting matches the DESIGN.md formulas for one round
        let comm = engine.comm();
        let n = cfg.n_clients as u64;
        assert_eq!(comm.report_up, n * 4 * cfg.r as u64);
        assert_eq!(comm.update_up, n * 8 * cfg.k as u64);
        assert_eq!(comm.request_down, n * 4 * cfg.k as u64);
        assert_eq!(comm.broadcast_down, n * 4 * d as u64);
        // exact raw-codec wire bytes: header 9 + fields, per frame (the
        // FakePool reports carry 40 indices, requests/updates cfg.k = 8)
        assert_eq!(comm.wire_up, n * ((9 + 12 + 2 * (4 + 4 * 40)) + (9 + 8 + 2 * (4 + 4 * 8))));
        assert_eq!(
            comm.wire_down,
            n * ((9 + 8 + 4 * d as u64) + (9 + 4 + 4 + 4 * 8)),
            "model + request frames for the full cohort"
        );
        // Delta payload: global moved by the mean of the uploads
        let mut expect = vec![0.0f32; d];
        for r in &engine.uploaded_log()[0] {
            for &j in r {
                expect[j as usize] += j as f32 / cfg.n_clients as f32;
            }
        }
        for (p, e) in engine.global_params().iter().zip(&expect) {
            assert!((p - e).abs() < 1e-6);
        }
    }

    #[test]
    fn partial_participation_scopes_the_round_to_the_cohort() {
        let mut cfg = smoke_cfg();
        cfg.n_clients = 4;
        cfg.participation = 0.5; // m = 2 with the default round-robin
        let d = cfg.d();
        let mut pool = FakePool::healthy(&cfg);
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);

        let out1 = engine.run_round(&mut pool).unwrap();
        assert_eq!(out1.cohort, vec![0, 1]);
        assert_eq!(out1.mean_loss, 1.0);
        let out2 = engine.run_round(&mut pool).unwrap();
        assert_eq!(out2.cohort, vec![2, 3], "round-robin rotates the window");

        // uploads recorded only for cohort members; absentees are empty
        let log = engine.uploaded_log();
        assert_eq!(log[0][0].len(), cfg.k);
        assert_eq!(log[0][1].len(), cfg.k);
        assert!(log[0][2].is_empty() && log[0][3].is_empty());
        assert!(log[1][0].is_empty() && log[1][1].is_empty());
        assert_eq!(log[1][2].len(), cfg.k);

        // byte accounting scales with the cohort (m = 2), not n = 4
        let comm = engine.comm();
        let (m, rounds) = (2u64, 2u64);
        assert_eq!(comm.report_up, rounds * m * 4 * cfg.r as u64);
        assert_eq!(comm.update_up, rounds * m * 8 * cfg.k as u64);
        assert_eq!(comm.request_down, rounds * m * 4 * cfg.k as u64);
        assert_eq!(comm.broadcast_down, rounds * m * 4 * d as u64);

        // eq. (2) under absence: client 0 uploaded index 0 in round 1 and
        // sat out round 2, so that index aged exactly once; index 9 (never
        // uploaded) aged both rounds
        let a0 = engine.ps().clusters().age_of_client(0);
        assert_eq!(a0.get(0), 1);
        assert_eq!(a0.get(9), 2);

        // Delta payload: the global moved by the mean over the m = 2
        // uploaders. Round 1: clients 0/1 upload indices 0..8 / 10..18
        // (value = index); round 2: clients 2/3 upload 20..28 / 30..38.
        assert!((engine.global_params()[10] - 10.0 / 2.0).abs() < 1e-6);
        assert!((engine.global_params()[20] - 20.0 / 2.0).abs() < 1e-6);
        assert_eq!(engine.global_params()[9], 0.0);
    }

    #[test]
    fn client_side_strategy_skips_requests() {
        let mut cfg = smoke_cfg();
        cfg.strategy = StrategyKind::TopK;
        let d = cfg.d();
        let mut pool = FakePool::healthy(&cfg);
        pool.last_requests = Some(Vec::new());
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);
        engine.run_round(&mut pool).unwrap();
        assert!(pool.last_requests.is_none(), "top-k must not receive PS requests");
        let comm = engine.comm();
        assert_eq!(comm.report_up, 0);
        assert_eq!(comm.request_down, 0);
        // bookkeeping recorded what the clients actually uploaded
        assert_eq!(engine.uploaded_log()[0][1][0], 10);
    }

    /// The membership tentpole at engine granularity: a client failing
    /// phase 1 becomes a casualty, the round completes with the survivor,
    /// the casualty's ages keep growing per eq. (2), and the fleet walks
    /// Active -> Suspect -> Dead -> (survival) back to Active.
    #[test]
    fn casualties_do_not_abort_the_round() {
        let cfg = smoke_cfg();
        let d = cfg.d();
        let mut pool = FakePool::healthy(&cfg);
        pool.fail_phase1.insert(1);
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);

        let out = engine.run_round(&mut pool).unwrap();
        assert_eq!(out.cohort, vec![0], "the survivor finishes the round");
        assert_eq!(out.casualties, vec![1]);
        assert_eq!(out.mean_loss, 1.0, "mean loss is over the survivors");
        assert_eq!(engine.fleet().state(1), Membership::Suspect);
        assert_eq!(engine.fleet().state(0), Membership::Active);
        // the casualty uploaded nothing: empty log entry, ages grew
        assert!(engine.uploaded_log()[0][1].is_empty());
        assert_eq!(engine.ps().clusters().age_of_client(1).get(0), 1);
        // accounting: exactly one report/request/update flowed
        let comm = engine.comm();
        assert_eq!(comm.report_up, 4 * cfg.r as u64);
        assert_eq!(comm.update_up, 8 * cfg.k as u64);

        // a second failed round writes the client off...
        let out = engine.run_round(&mut pool).unwrap();
        assert_eq!(out.casualties, vec![1]);
        assert_eq!(engine.fleet().state(1), Membership::Dead);
        // ...and a clean round brings it back to Active
        pool.fail_phase1.clear();
        let out = engine.run_round(&mut pool).unwrap();
        assert_eq!(out.cohort, vec![0, 1]);
        assert!(out.casualties.is_empty());
        assert_eq!(engine.fleet().state(1), Membership::Active);
    }

    /// The speculation tentpole at engine granularity (DESIGN.md §11):
    /// with `overschedule = 1` the scheduler selects m + 1 members, the
    /// round commits with the first m reports, and the straggler is
    /// cancelled — no fleet damage, ledger advanced (it holds the
    /// broadcast), ages growing exactly like off-cohort absence.
    #[test]
    fn speculative_round_commits_first_m_and_cancels_stragglers() {
        let mut cfg = smoke_cfg();
        cfg.n_clients = 4;
        cfg.participation = 0.5; // m = 2
        cfg.overschedule = 1; // schedule 3
        let d = cfg.d();
        let mut pool = FakePool::healthy(&cfg);
        pool.stalled.insert(1); // the straggler of every speculative race
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);

        let out = engine.run_round(&mut pool).unwrap();
        assert_eq!(out.cohort, vec![0, 2], "exactly m fast members commit");
        assert_eq!(out.cancelled, vec![1]);
        assert!(out.casualties.is_empty(), "a cancelled straggler is not a casualty");
        assert_eq!(engine.fleet().state(1), Membership::Active, "no fleet damage");
        assert_eq!(engine.fleet().record(1).casualties, 0);
        // it provably received the broadcast: the ledger advances like a
        // survivor's, so the next delta downlink could still reach it
        assert_eq!(engine.fleet().acked_model(1), 1);
        // but it uploaded nothing and its ages grew per eq. (2)
        assert!(engine.uploaded_log()[0][1].is_empty());
        assert_eq!(engine.ps().clusters().age_of_client(1).get(0), 1);
        // and it keeps accruing poll debt like an off-cohort client
        assert_eq!(engine.since_polled[1], 1);
        assert_eq!(engine.since_polled[0], 0, "a survivor's debt resets");

        // exact wire mirror: 3 broadcast frames went out (the straggler's
        // was fully delivered before the commit), 1 off-cohort Sit, 1
        // cancel Sit, and m = 2 report/request/update flows
        let comm = engine.comm();
        // raw-codec request frame: header 9 + round 4 + len 4 + 4k indices
        let req = (9 + 4 + 4 + 4 * cfg.k) as u64;
        assert_eq!(
            comm.wire_down,
            3 * wire::model_frame_bytes(d) as u64
                + 2 * req
                + 2 * wire::SIT_FRAME_BYTES as u64,
            "m+1 broadcasts, one off-cohort Sit, one cancel Sit"
        );
        assert_eq!(comm.broadcast_down, 3 * 4 * d as u64);
        assert_eq!(comm.report_up, 2 * 4 * cfg.r as u64, "only committed reports count");
        assert_eq!(comm.update_up, 2 * 8 * cfg.k as u64);
    }

    /// Without stalls every member is equally fast: the commit is
    /// deterministic — the first m in cohort order land, the ε tail is
    /// cancelled. And at overschedule = 0 the quota path is never
    /// engaged at all (bit-for-bit the PR-7 round).
    #[test]
    fn speculation_is_deterministic_and_off_by_default() {
        let mut cfg = smoke_cfg();
        cfg.n_clients = 4;
        cfg.participation = 0.5; // m = 2
        cfg.overschedule = 2; // schedule 4
        let d = cfg.d();
        let mut pool = FakePool::healthy(&cfg);
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);
        let out = engine.run_round(&mut pool).unwrap();
        assert_eq!(out.cohort, vec![0, 1], "first m in cohort order commit");
        assert_eq!(out.cancelled, vec![2, 3]);

        // epsilon = 0: the engine must not even arm the quota
        let mut cfg0 = smoke_cfg();
        cfg0.n_clients = 4;
        cfg0.participation = 0.5;
        let mut pool0 = FakePool::healthy(&cfg0);
        pool0.stalled.insert(1); // irrelevant without a quota
        let mut engine0 = RoundEngine::new(&cfg0, vec![0.0; d]);
        let out0 = engine0.run_round(&mut pool0).unwrap();
        assert!(pool0.quota.is_none(), "no quota was ever set");
        assert_eq!(out0.cohort, vec![0, 1]);
        assert!(out0.cancelled.is_empty());
    }

    /// A speculative round where a member *also* genuinely fails: the
    /// dead one is a casualty (fleet damage, ledger forgotten), the
    /// cancelled one is not — the two outcomes stay distinct.
    #[test]
    fn speculative_round_distinguishes_casualty_from_cancelled() {
        let mut cfg = smoke_cfg();
        cfg.n_clients = 4;
        cfg.participation = 0.5; // m = 2
        cfg.overschedule = 2; // schedule all 4
        let d = cfg.d();
        let mut pool = FakePool::healthy(&cfg);
        pool.fail_phase1.insert(0); // dies outright
        pool.stalled.insert(1); // merely slow
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);
        let out = engine.run_round(&mut pool).unwrap();
        assert_eq!(out.cohort, vec![2, 3], "the two fast live members commit");
        assert_eq!(out.casualties, vec![0]);
        assert_eq!(out.cancelled, vec![1]);
        assert_eq!(engine.fleet().state(0), Membership::Suspect);
        assert_eq!(engine.fleet().state(1), Membership::Active);
        assert_eq!(engine.fleet().acked_model(0), ACKED_NONE, "casualty: ledger forgets");
        assert_eq!(engine.fleet().acked_model(1), 1, "cancelled: ledger advances");
    }

    /// The adaptive-deadline feedback loop: per-phase timings reported by
    /// the pool land in the fleet's EWMA records.
    #[test]
    fn phase_timings_feed_the_fleet_ewma() {
        let cfg = smoke_cfg();
        let d = cfg.d();
        let mut pool = FakePool::healthy(&cfg);
        pool.timings = vec![(0, 120.0), (1, 40.0)];
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);
        engine.run_round(&mut pool).unwrap();
        assert_eq!(engine.fleet().rtt_ewma_ms(0), 120.0);
        assert_eq!(engine.fleet().rtt_ewma_ms(1), 40.0);
        pool.timings = vec![(0, 220.0)];
        engine.run_round(&mut pool).unwrap();
        assert!((engine.fleet().rtt_ewma_ms(0) - (0.3 * 220.0 + 0.7 * 120.0)).abs() < 1e-3);
    }

    /// A phase-2 drop (report received, update lost) is also a casualty:
    /// its report must not reach the aggregate or the bookkeeping.
    #[test]
    fn phase_two_casualty_uploads_nothing() {
        let cfg = smoke_cfg();
        let d = cfg.d();
        let mut pool = FakePool::healthy(&cfg);
        pool.fail_phase2.insert(0);
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);
        let out = engine.run_round(&mut pool).unwrap();
        assert_eq!(out.cohort, vec![1]);
        assert_eq!(out.casualties, vec![0]);
        assert!(engine.uploaded_log()[0][0].is_empty());
        assert_eq!(engine.uploaded_log()[0][1].len(), cfg.k);
        // the request frame still flowed to the phase-1 survivor; only
        // one update came back
        let comm = engine.comm();
        assert_eq!(comm.request_down, 2 * 4 * cfg.k as u64);
        assert_eq!(comm.update_up, 8 * cfg.k as u64);
        assert_eq!(engine.fleet().state(0), Membership::Suspect);
    }

    /// Losing every scheduled client skips the server update but still
    /// commits the eq.-(2) bookkeeping (ages grow) — training resumes
    /// when anyone comes back.
    #[test]
    fn all_casualty_round_skips_apply_but_ages_grow() {
        let cfg = smoke_cfg();
        let d = cfg.d();
        let mut pool = FakePool::healthy(&cfg);
        pool.fail_phase1.extend([0, 1]);
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);
        let out = engine.run_round(&mut pool).unwrap();
        assert!(out.cohort.is_empty());
        assert_eq!(out.casualties, vec![0, 1]);
        assert!(out.mean_loss.is_nan());
        assert_eq!(engine.round(), 1, "the round still counts");
        assert!(engine.global_params().iter().all(|&p| p == 0.0), "no server update");
        assert_eq!(engine.ps().clusters().age_of_client(0).get(0), 1);
    }

    /// A pool-level rejoin moves the fleet to Rejoining with a bumped
    /// generation; surviving the round promotes to Active.
    #[test]
    fn rejoin_is_admitted_and_promoted_on_survival() {
        struct RejoiningPool {
            inner: FakePool,
            pending: Vec<usize>,
        }
        impl ClientPool for RejoiningPool {
            fn n_clients(&self) -> usize {
                self.inner.n_clients()
            }
            fn poll_rejoins(&mut self, _global: &[f32]) -> Result<Vec<usize>> {
                Ok(std::mem::take(&mut self.pending))
            }
            fn train_and_report(
                &mut self,
                global: &[f32],
                cohort: &[usize],
            ) -> Result<Vec<Option<ClientReport>>> {
                self.inner.train_and_report(global, cohort)
            }
            fn exchange(
                &mut self,
                requests: Option<&[Vec<u32>]>,
                cohort: &[usize],
            ) -> Result<Vec<Option<SparseVec>>> {
                self.inner.exchange(requests, cohort)
            }
            fn backend(&mut self) -> &mut dyn Backend {
                self.inner.backend()
            }
        }

        let cfg = smoke_cfg();
        let d = cfg.d();
        let mut pool = RejoiningPool { inner: FakePool::healthy(&cfg), pending: Vec::new() };
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);
        // kill client 1 twice -> Dead
        pool.inner.fail_phase1.insert(1);
        engine.run_round(&mut pool).unwrap();
        engine.run_round(&mut pool).unwrap();
        assert_eq!(engine.fleet().state(1), Membership::Dead);
        // it rejoins and survives
        pool.inner.fail_phase1.clear();
        pool.pending.push(1);
        let out = engine.run_round(&mut pool).unwrap();
        assert_eq!(out.cohort, vec![0, 1]);
        assert_eq!(engine.fleet().state(1), Membership::Active);
        assert_eq!(engine.fleet().generation(1), 1);
        // the rejoin resync handed it the current global = round-3
        // broadcast, and surviving the round confirmed it
        assert_eq!(engine.fleet().acked_model(1), 3);
    }

    #[test]
    fn update_phase_answers_request_from_report() {
        use crate::data::synth::synthetic_mnist;
        let cfg = smoke_cfg();
        let pc = PhaseCfg::from_config(&cfg);
        let ds = synthetic_mnist(0, 64);
        let mut client = Client::new(0, crate::data::Shard::from_owned(ds), vec![0.0; pc.d], 1);
        let mut backend = crate::backend::RustBackend::new(cfg.r, cfg.lr_client, cfg.seed);
        let mut memory = vec![0.0f32; pc.d];
        memory[5] = 2.5;
        memory[9] = -1.0;
        let report = SparseVec::new(vec![5, 9], vec![2.5, -1.0]);
        let up = client_update_phase(
            &mut client,
            &mut backend,
            Some(&mut memory),
            &report,
            Some(&[9, 5]),
            &pc,
        )
        .unwrap();
        assert_eq!(up.idx, vec![9, 5]);
        assert_eq!(up.val, vec![-1.0, 2.5]);
        // sent coordinates left the error-feedback memory
        assert_eq!(memory[5], 0.0);
        assert_eq!(memory[9], 0.0);
    }

    /// Delta downlink, engine granularity: round 1 is an empty delta on
    /// the init model every worker already holds; steady-state rounds
    /// ride a shared delta whose indices are the previous round's upload
    /// union; the accounting mirrors those frames exactly.
    #[test]
    fn delta_downlink_accounts_sparse_broadcast_frames() {
        let mut cfg = smoke_cfg();
        cfg.downlink = Downlink::Delta;
        let d = cfg.d();
        let n = cfg.n_clients as u64;
        let req = (9 + 4 + 4 + 4 * cfg.k) as u64; // raw request frame
        let mut pool = FakePool::healthy(&cfg);
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);

        engine.run_round(&mut pool).unwrap();
        let comm1 = engine.comm();
        assert_eq!(comm1.broadcast_down, 0, "an empty delta moves no semantic bytes");
        assert_eq!(
            comm1.wire_down,
            n * (wire::delta_frame_bytes(cfg.codec, &[]) as u64 + req)
        );
        assert_eq!(engine.fleet().acked_model(0), 1);
        assert_eq!(engine.fleet().acked_model(1), 1);

        engine.run_round(&mut pool).unwrap();
        let mut union: Vec<u32> =
            engine.uploaded_log()[0].iter().flatten().copied().collect();
        union.sort_unstable();
        union.dedup();
        let comm2 = engine.comm();
        assert_eq!(comm2.broadcast_down, n * 8 * union.len() as u64);
        assert_eq!(
            comm2.wire_down - comm1.wire_down,
            n * (wire::delta_frame_bytes(cfg.codec, &union) as u64 + req),
            "round 2 broadcasts one shared delta built from round 1's uploads"
        );
        // the whole point: two delta rounds cost a fraction of one dense
        // model frame
        assert!(comm2.wire_down * 20 < n * wire::model_frame_bytes(d) as u64);
        // the raw/dense uplink is untouched by the downlink knob
        assert_eq!(comm2.update_up, 2 * n * 8 * cfg.k as u64);
    }

    /// A mid-broadcast casualty may or may not hold the new model — the
    /// ledger forgets it (next broadcast dense); a phase-2 casualty
    /// provably received the broadcast and keeps its generation.
    #[test]
    fn delta_ledger_forgets_mid_broadcast_casualties() {
        let mut cfg = smoke_cfg();
        cfg.downlink = Downlink::Delta;
        let d = cfg.d();
        let mut pool = FakePool::healthy(&cfg);
        pool.fail_phase1.insert(1);
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);
        engine.run_round(&mut pool).unwrap();
        assert_eq!(engine.fleet().acked_model(0), 1);
        assert_eq!(engine.fleet().acked_model(1), ACKED_NONE);

        pool.fail_phase1.clear();
        let before = engine.comm().wire_down;
        engine.run_round(&mut pool).unwrap();
        // client 0 rode the delta (round 1's union = its own uploads —
        // the casualty uploaded nothing), client 1 was resynced dense
        let mut union: Vec<u32> = engine.uploaded_log()[0][0].clone();
        union.sort_unstable();
        union.dedup();
        let req = (9 + 4 + 4 + 4 * cfg.k) as u64;
        assert_eq!(
            engine.comm().wire_down - before,
            wire::delta_frame_bytes(cfg.codec, &union) as u64
                + wire::model_frame_bytes(d) as u64
                + 2 * req
        );
        assert_eq!(engine.fleet().acked_model(1), 2, "the dense resync re-acked it");

        // a phase-2 drop happens *after* the broadcast round-tripped:
        // the generation survives
        pool.fail_phase2.insert(0);
        engine.run_round(&mut pool).unwrap();
        assert_eq!(engine.fleet().acked_model(0), 3);
        assert_eq!(engine.fleet().state(0), Membership::Suspect);
    }

    /// The plan builder's fallback ladder: shared deltas per distinct
    /// base, empty delta for a current client, dense for an unknown base
    /// or a gap the ring no longer covers.
    #[test]
    fn broadcast_plan_chooses_delta_dense_and_shares_bases() {
        let mut cfg = smoke_cfg();
        cfg.downlink = Downlink::Delta;
        cfg.n_clients = 4;
        let d = cfg.d();
        let mut pool = FakePool::healthy(&cfg);
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);
        for _ in 0..3 {
            engine.run_round(&mut pool).unwrap();
        }
        assert_eq!(engine.delta_ring.len(), 3);

        // stage one client per case for round 4
        engine.fleet.set_acked_model(0, 3);
        engine.fleet.set_acked_model(1, 3); // same base as 0
        engine.fleet.set_acked_model(2, ACKED_NONE);
        engine.fleet.set_acked_model(3, 4); // already current
        let plan = engine.build_broadcast_plan(&[0, 1, 2, 3], &[true; 4]);
        assert_eq!(plan.round, 4);
        assert_eq!(plan.digest, params_digest(engine.global_params()));
        assert_eq!(plan.assign[0], plan.assign[1], "one encoded delta per base");
        let (b01, idx01) = plan.delta_for(0).unwrap();
        assert_eq!(b01, 3);
        let back = engine.delta_ring.back().unwrap();
        assert_eq!(idx01, &back[..], "a gap-1 delta is the last round's union");
        assert!(plan.delta_for(2).is_none(), "unknown base gets the dense model");
        let (b3, idx3) = plan.delta_for(3).unwrap();
        assert_eq!((b3, idx3.len()), (4, 0), "a current client gets an empty delta");
        assert_eq!(plan.deltas.len(), 2);

        // shrink the ring below a gap of 3 -> dense fallback
        engine.fleet.set_acked_model(0, 1);
        engine.delta_ring.pop_front();
        engine.delta_ring.pop_front();
        let plan = engine.build_broadcast_plan(&[0], &[true; 4]);
        assert!(plan.delta_for(0).is_none(), "a gap beyond the ring resyncs dense");
    }

    /// The ring recycles evicted slots once it hits its cap, and an
    /// all-casualty round records an (accurate) empty union.
    #[test]
    fn delta_ring_caps_and_records_empty_rounds() {
        let mut cfg = smoke_cfg();
        cfg.downlink = Downlink::Delta;
        let d = cfg.d();
        let mut engine = RoundEngine::new(&cfg, vec![0.0; d]);
        for i in 0..(DELTA_RING_CAP as u32 + 5) {
            engine.note_model_update_union(&[i]);
        }
        assert_eq!(engine.delta_ring.len(), DELTA_RING_CAP);
        assert_eq!(engine.delta_ring.front().unwrap(), &vec![5u32]);
        engine.note_model_update(None);
        assert_eq!(engine.delta_ring.len(), DELTA_RING_CAP);
        assert!(engine.delta_ring.back().unwrap().is_empty());
        // Dense knob: the ring is never fed
        let mut dense = RoundEngine::new(&smoke_cfg(), vec![0.0; d]);
        dense.note_model_update_union(&[1, 2]);
        assert!(dense.delta_ring.is_empty());
    }

    #[test]
    fn cohort_map_reuses_buffers_across_rounds() {
        let mut map = CohortMap::new();
        map.set(6, &[1, 4]);
        assert_eq!(map.slot(1), 0);
        assert_eq!(map.slot(4), 1);
        assert_eq!(map.slot(0), usize::MAX);
        assert_eq!(map.get(5), None);
        // re-keying invalidates the old cohort without clearing
        map.set(6, &[0, 2, 5]);
        assert_eq!(map.get(1), None, "stale entry must not leak");
        assert_eq!(map.slot(2), 1);
        assert_eq!(map.slot(5), 2);
        // growing n mid-stream is fine (re-shard resizes the id space)
        map.set(8, &[7]);
        assert_eq!(map.slot(7), 0);
        assert_eq!(map.get(6), None);
    }
}
