//! The parameter-server state machine: owns the per-cluster age vectors,
//! per-client frequency vectors, and the M-periodic DBSCAN reclustering —
//! Algorithms 1 + 2 of the paper from the PS's point of view.

use crate::age::FrequencyVector;
use crate::clustering::{
    connectivity_matrix, recluster_labels, ClusterManager, DbscanParams, MergeRule,
};
use crate::coordinator::engine::CohortMap;
use crate::coordinator::selection::{select_disjoint, select_oldest_k};
use crate::coordinator::strategies::StrategyKind;

/// PS configuration subset (see `config::ExperimentConfig` for the full
/// experiment config this is derived from).
#[derive(Debug, Clone)]
pub struct PsConfig {
    pub d: usize,
    pub n_clients: usize,
    pub k: usize,
    pub strategy: StrategyKind,
    /// recluster every M global rounds (0 disables clustering)
    pub recluster_every: usize,
    pub dbscan: DbscanParams,
    pub merge_rule: MergeRule,
}

#[derive(Debug)]
pub struct ParameterServer {
    cfg: PsConfig,
    clusters: ClusterManager,
    freqs: Vec<FrequencyVector>,
    round: usize,
    /// reclustering events log: (round, n_clusters)
    pub recluster_log: Vec<(usize, usize)>,
    /// reused client-id -> cohort-position map (stamp-versioned, O(m)
    /// per selection instead of an O(n) rebuild)
    cohort_map: CohortMap,
}

impl ParameterServer {
    pub fn new(cfg: PsConfig) -> Self {
        let clusters = ClusterManager::new(cfg.n_clients, cfg.d, cfg.merge_rule);
        let freqs = (0..cfg.n_clients).map(|_| FrequencyVector::new()).collect();
        ParameterServer {
            cfg,
            clusters,
            freqs,
            round: 0,
            recluster_log: Vec::new(),
            cohort_map: CohortMap::new(),
        }
    }

    /// Replace this PS's client set (dynamic re-sharding): `clusters` is
    /// the new cluster state over the new local id space and `freqs` the
    /// per-client frequency vectors in the same order. The round counter
    /// and recluster log are preserved.
    pub fn install(&mut self, clusters: ClusterManager, freqs: Vec<FrequencyVector>) {
        assert_eq!(clusters.n_clients(), freqs.len());
        self.cfg.n_clients = freqs.len();
        self.clusters = clusters;
        self.freqs = freqs;
    }

    pub fn round(&self) -> usize {
        self.round
    }

    pub fn config(&self) -> &PsConfig {
        &self.cfg
    }

    pub fn clusters(&self) -> &ClusterManager {
        &self.clusters
    }

    /// Algorithm 2, PS side: map each client's top-r report to the k
    /// indices the PS requests. Only meaningful for the rAge-k kinds.
    /// Reports are magnitude-ordered index lists, one per client.
    pub fn select_requests(&mut self, reports: &[Vec<u32>]) -> Vec<Vec<u32>> {
        let cohort: Vec<usize> = (0..self.cfg.n_clients).collect();
        self.select_requests_cohort(&cohort, reports)
    }

    /// [`Self::select_requests`] scoped to a participation cohort:
    /// `reports[p]` is the report of client `cohort[p]` and the returned
    /// requests are aligned the same way. Inside a cluster only the
    /// *participating* members coordinate disjointly this round — an
    /// absent sibling (off-cohort or a mid-round casualty) uploads
    /// nothing, so there is nothing to be disjoint from. With the full
    /// cohort this is exactly the old behavior.
    pub fn select_requests_cohort(
        &mut self,
        cohort: &[usize],
        reports: &[Vec<u32>],
    ) -> Vec<Vec<u32>> {
        assert_eq!(cohort.len(), reports.len());
        assert!(self.cfg.strategy.needs_report());
        // client id -> cohort position (stamp-reused across rounds)
        self.cohort_map.set(self.cfg.n_clients, cohort);
        let pos = &self.cohort_map;
        let disjoint = self.cfg.strategy == StrategyKind::RageK;
        let mut out: Vec<Vec<u32>> = vec![Vec::new(); cohort.len()];
        // Group the *cohort* by cluster — O(m log m) — instead of
        // scanning every cluster for cohort members (O(n_clusters) per
        // round, the fleet-scale killer at 10⁵ singleton clusters).
        // Clusters come out ascending, members within a cluster ascending
        // (the cohort is sorted) — exactly the order the old
        // cluster-major scan produced, so selections are bit-identical.
        let mut grouped: Vec<(usize, usize)> =
            cohort.iter().map(|&c| (self.clusters.cluster_of(c), c)).collect();
        grouped.sort_unstable();
        let mut g = 0;
        let mut members: Vec<usize> = Vec::new();
        while g < grouped.len() {
            let cluster = grouped[g].0;
            members.clear();
            while g < grouped.len() && grouped[g].0 == cluster {
                members.push(grouped[g].1);
                g += 1;
            }
            let age = self.clusters.age_of_cluster(cluster);
            if disjoint && members.len() > 1 {
                let member_reports: Vec<&[u32]> =
                    members.iter().map(|&m| reports[pos.slot(m)].as_slice()).collect();
                let sels = select_disjoint(age, &member_reports, self.cfg.k);
                for (m, sel) in members.iter().zip(sels) {
                    out[pos.slot(*m)] = sel;
                }
            } else {
                for &m in &members {
                    out[pos.slot(m)] = select_oldest_k(age, &reports[pos.slot(m)], self.cfg.k);
                }
            }
        }
        out
    }

    /// Commit a completed round: frequency bookkeeping for every client
    /// and the eq. (2) sweep for every cluster (union of its members'
    /// requested indices). `requested[i]` is what client i uploaded —
    /// **empty for clients off this round's cohort**, which is exactly
    /// right: an empty record is a frequency no-op, and a cluster whose
    /// members all sat out gets an empty union, so `update_ages` bumps
    /// its epoch and every index ages by one (absent clients' staleness
    /// keeps growing, the signal the age-debt scheduler consumes).
    pub fn record_round(&mut self, requested: &[Vec<u32>]) {
        assert_eq!(requested.len(), self.cfg.n_clients);
        for (f, req) in self.freqs.iter_mut().zip(requested) {
            f.record(req);
        }
        if self.cfg.strategy.uses_age() {
            // Union-building is driven by the round's *uploaders* (<= the
            // cohort size), not by a members_of scan over every cluster —
            // a cluster with no uploader contributes an empty union, and
            // its eq. (2) sweep is just the O(1) epoch bump below. Same
            // unions, same update order (ascending cluster id) as the old
            // cluster-major loop.
            let mut touched: Vec<(usize, usize)> = requested
                .iter()
                .enumerate()
                .filter(|(_, req)| !req.is_empty())
                .map(|(i, _)| (self.clusters.cluster_of(i), i))
                .collect();
            touched.sort_unstable();
            let mut union: Vec<u32> = Vec::new();
            let mut bumped = 0; // clusters below this already updated
            let mut t = 0;
            while t < touched.len() {
                let cluster = touched[t].0;
                for c in bumped..cluster {
                    self.clusters.update_ages(c, &[]);
                }
                union.clear();
                while t < touched.len() && touched[t].0 == cluster {
                    union.extend_from_slice(&requested[touched[t].1]);
                    t += 1;
                }
                union.sort_unstable();
                union.dedup();
                self.clusters.update_ages(cluster, &union);
                bumped = cluster + 1;
            }
            for c in bumped..self.clusters.n_clusters() {
                self.clusters.update_ages(c, &[]);
            }
        }
        self.round += 1;
    }

    /// The eq. (3) connectivity matrix (Fig. 2 / Fig. 4 heatmap payload).
    pub fn connectivity(&self) -> Vec<Vec<f64>> {
        connectivity_matrix(&self.freqs)
    }

    /// Run the M-periodic clustering step if due. Returns the new number
    /// of clusters when reclustering ran.
    pub fn maybe_recluster(&mut self) -> Option<usize> {
        if !self.cfg.strategy.uses_age()
            || self.cfg.recluster_every == 0
            || self.round == 0
            || self.round % self.cfg.recluster_every != 0
        {
            return None;
        }
        Some(self.force_recluster())
    }

    /// Unconditional clustering pass (used by `maybe_recluster` and the
    /// clustering examples/benches).
    pub fn force_recluster(&mut self) -> usize {
        let labels = recluster_labels(&self.freqs, self.cfg.dbscan);
        let ev = self.clusters.recluster(&labels);
        self.recluster_log.push((self.round, ev.n_clusters));
        crate::debug!(
            "recluster @round {}: {} clusters ({} merges, {} resets)",
            self.round,
            ev.n_clusters,
            ev.merges,
            ev.resets
        );
        ev.n_clusters
    }

    pub fn frequency(&self, client: usize) -> &FrequencyVector {
        &self.freqs[client]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ps(n: usize, d: usize, k: usize, strategy: StrategyKind, m: usize) -> ParameterServer {
        ParameterServer::new(PsConfig {
            d,
            n_clients: n,
            k,
            strategy,
            recluster_every: m,
            dbscan: DbscanParams::default(),
            merge_rule: MergeRule::Min,
        })
    }

    #[test]
    fn requests_come_from_reports() {
        let mut server = ps(2, 100, 2, StrategyKind::RageK, 10);
        let reports = vec![vec![5u32, 7, 9, 11], vec![20u32, 22, 24, 26]];
        let req = server.select_requests(&reports);
        assert_eq!(req[0].len(), 2);
        assert!(req[0].iter().all(|j| reports[0].contains(j)));
        assert!(req[1].iter().all(|j| reports[1].contains(j)));
    }

    #[test]
    fn fresh_ages_select_top_magnitude() {
        let mut server = ps(1, 50, 3, StrategyKind::RageK, 10);
        let req = server.select_requests(&[vec![9, 1, 5, 30, 2]]);
        assert_eq!(req[0], vec![9, 1, 5]); // all ages 0 -> rank order
    }

    #[test]
    fn age_rotation_across_rounds() {
        let mut server = ps(1, 50, 2, StrategyKind::RageK, 0);
        let report = vec![10u32, 11, 12, 13];
        let r1 = server.select_requests(&[report.clone()]);
        server.record_round(&r1);
        let r2 = server.select_requests(&[report.clone()]);
        server.record_round(&r2);
        // round 1 takes {10,11}; their age resets; round 2 must take {12,13}
        assert_eq!(r1[0], vec![10, 11]);
        assert_eq!(r2[0], vec![12, 13]);
    }

    #[test]
    fn clustered_pair_gets_disjoint_requests() {
        let mut server = ps(2, 100, 2, StrategyKind::RageK, 1);
        // identical request histories -> similarity 1 -> same cluster
        let same = vec![vec![1u32, 2, 3, 4], vec![1u32, 2, 3, 4]];
        let req = server.select_requests(&same);
        server.record_round(&req);
        let n = server.maybe_recluster().unwrap();
        assert_eq!(n, 1, "identical clients must cluster");
        let req2 = server.select_requests(&same);
        let s0: std::collections::HashSet<_> = req2[0].iter().collect();
        assert!(req2[1].iter().all(|j| !s0.contains(j)), "{req2:?}");
    }

    #[test]
    fn independent_variant_overlaps() {
        let mut server = ps(2, 100, 2, StrategyKind::RageKIndependent, 1);
        let same = vec![vec![1u32, 2, 3, 4], vec![1u32, 2, 3, 4]];
        let req = server.select_requests(&same);
        server.record_round(&req);
        server.maybe_recluster();
        let req2 = server.select_requests(&same);
        assert_eq!(req2[0], req2[1], "independent members share the oldest picks");
    }

    #[test]
    fn dissimilar_clients_stay_separate() {
        let mut server = ps(2, 100, 2, StrategyKind::RageK, 1);
        for _ in 0..3 {
            let reports = vec![vec![1u32, 2, 3, 4], vec![50u32, 51, 52, 53]];
            let req = server.select_requests(&reports);
            server.record_round(&req);
        }
        server.force_recluster();
        assert_eq!(server.clusters().n_clusters(), 2);
    }

    #[test]
    fn recluster_cadence() {
        let mut server = ps(2, 10, 1, StrategyKind::RageK, 3);
        let reports = vec![vec![1u32, 2], vec![1u32, 2]];
        for round in 1..=7 {
            let req = server.select_requests(&reports);
            server.record_round(&req);
            let did = server.maybe_recluster().is_some();
            assert_eq!(did, round % 3 == 0, "round {round}");
        }
        assert_eq!(server.recluster_log.len(), 2);
    }
}
