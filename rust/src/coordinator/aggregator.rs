//! Sparse-gradient aggregation: g~ = sum_i g~_i (Algorithm 1 line 10).
//!
//! Two materializations, matching the two server-apply artifacts:
//! * [`Aggregate::to_dense`] — a dense f32[d] update (`apply_dense`);
//! * [`Aggregate::to_padded_pairs`] — fixed-width (idx, val) arrays padded
//!   with (0, 0.0) no-ops (`apply_sparse`, whose K_total is baked at AOT
//!   time).

use crate::sparse::SparseVec;

/// One global round's collected client updates.
#[derive(Debug, Default)]
pub struct Aggregate {
    parts: Vec<SparseVec>,
    total_entries: usize,
}

impl Aggregate {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, update: SparseVec) {
        self.total_entries += update.len();
        self.parts.push(update);
    }

    pub fn n_clients(&self) -> usize {
        self.parts.len()
    }

    pub fn total_entries(&self) -> usize {
        self.total_entries
    }

    /// Sum into a dense vector, scaling each client's update by `scale`
    /// (the paper sums; pass 1/N for averaging ablations).
    pub fn to_dense(&self, d: usize, scale: f32) -> Vec<f32> {
        let mut out = vec![0.0f32; d];
        for p in &self.parts {
            p.add_into(&mut out, scale);
        }
        out
    }

    /// Concatenated (idx, val) pairs padded/truncated to exactly
    /// `k_total` entries; padding entries are (0, 0.0) which scatter-add
    /// treats as no-ops. Values are pre-scaled by `scale`.
    pub fn to_padded_pairs(&self, k_total: usize, scale: f32) -> (Vec<i32>, Vec<f32>) {
        let mut idx = Vec::with_capacity(k_total);
        let mut val = Vec::with_capacity(k_total);
        'outer: for p in &self.parts {
            for (&i, &v) in p.idx.iter().zip(&p.val) {
                if idx.len() == k_total {
                    break 'outer;
                }
                idx.push(i as i32);
                val.push(v * scale);
            }
        }
        idx.resize(k_total, 0);
        val.resize(k_total, 0.0);
        (idx, val)
    }

    /// Union of updated indices this round, **sorted ascending**. The
    /// delta downlink (DESIGN.md §9) feeds this into the engine's
    /// generation ring every round, so the per-round path uses
    /// [`Aggregate::updated_indices_into`] with a reused buffer; this
    /// allocating form remains for diagnostics and tests.
    pub fn updated_indices(&self) -> Vec<u32> {
        let mut all = Vec::new();
        self.updated_indices_into(&mut all);
        all
    }

    /// Union of updated indices into a caller-owned buffer (cleared
    /// first) — the hot-path form: steady-state rounds reuse capacity
    /// and allocate nothing.
    ///
    /// Concatenate + sort + dedup instead of the former per-call
    /// `HashSet`: the parts are small (k entries each) and arrive in
    /// request order — (age desc, magnitude rank asc), deliberately
    /// preserved by the wire codec for bit-for-bit parity — so a pure
    /// k-way sorted merge is not available and one O(T log T) sort of
    /// the concatenation is the cheap, allocation-light union.
    pub fn updated_indices_into(&self, out: &mut Vec<u32>) {
        out.clear();
        out.reserve(self.total_entries);
        for p in &self.parts {
            out.extend_from_slice(&p.idx);
        }
        out.sort_unstable();
        out.dedup();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sums_with_duplicates() {
        let mut agg = Aggregate::new();
        agg.push(SparseVec::new(vec![1, 2], vec![1.0, 2.0]));
        agg.push(SparseVec::new(vec![2, 3], vec![10.0, 30.0]));
        let dense = agg.to_dense(5, 1.0);
        assert_eq!(dense, vec![0.0, 1.0, 12.0, 30.0, 0.0]);
        assert_eq!(agg.n_clients(), 2);
        assert_eq!(agg.total_entries(), 4);
    }

    #[test]
    fn scaling_is_linear() {
        let mut agg = Aggregate::new();
        agg.push(SparseVec::new(vec![0], vec![4.0]));
        assert_eq!(agg.to_dense(2, 0.25)[0], 1.0);
    }

    #[test]
    fn padded_pairs_roundtrip_to_dense() {
        let mut agg = Aggregate::new();
        agg.push(SparseVec::new(vec![1, 4], vec![1.0, 2.0]));
        agg.push(SparseVec::new(vec![1], vec![5.0]));
        let (idx, val) = agg.to_padded_pairs(6, 1.0);
        assert_eq!(idx.len(), 6);
        // scatter them manually
        let mut dense = vec![0.0f32; 5];
        for (&i, &v) in idx.iter().zip(&val) {
            dense[i as usize] += v;
        }
        assert_eq!(dense, agg.to_dense(5, 1.0));
    }

    #[test]
    fn truncation_drops_overflow() {
        let mut agg = Aggregate::new();
        agg.push(SparseVec::new(vec![0, 1, 2], vec![1.0, 1.0, 1.0]));
        let (idx, val) = agg.to_padded_pairs(2, 1.0);
        assert_eq!(idx, vec![0, 1]);
        assert_eq!(val, vec![1.0, 1.0]);
    }

    #[test]
    fn updated_indices_union_is_sorted() {
        let mut agg = Aggregate::new();
        // request order (age desc, rank asc) — deliberately not sorted
        agg.push(SparseVec::new(vec![2, 1], vec![1.0, 1.0]));
        agg.push(SparseVec::new(vec![9, 2], vec![1.0, 1.0]));
        assert_eq!(agg.updated_indices(), vec![1, 2, 9]);
        assert!(Aggregate::new().updated_indices().is_empty());
    }

    #[test]
    fn updated_indices_into_reuses_capacity() {
        let mut agg = Aggregate::new();
        agg.push(SparseVec::new(vec![5, 3, 5], vec![1.0, 1.0, 1.0]));
        agg.push(SparseVec::new(vec![4], vec![1.0]));
        let mut buf = vec![99u32; 64]; // stale contents must be cleared
        agg.updated_indices_into(&mut buf);
        assert_eq!(buf, vec![3, 4, 5]);
        assert_eq!(buf, agg.updated_indices(), "both forms agree");
        let cap = buf.capacity();
        agg.updated_indices_into(&mut buf);
        assert_eq!(buf.capacity(), cap, "a same-shape reuse must not reallocate");
    }
}
