//! Algorithm 2, PS side: given a client's top-r index report (magnitude-
//! ordered) and the cluster age vector, request the k **oldest** of the
//! reported indices.
//!
//! Tie-breaking matches `jax.lax.top_k` over `age[top_ind]`: equal ages
//! resolve to the earlier report position, i.e. the larger |gradient|
//! (python/tests/test_ragek_semantics.py pins the same contract).

use crate::age::AgeVector;

/// Pick `k` indices from `report` (positions ordered by |g| desc) with the
/// highest age. Returns them ordered by (age desc, report rank asc).
pub fn select_oldest_k(age: &AgeVector, report: &[u32], k: usize) -> Vec<u32> {
    assert!(k <= report.len(), "k={k} > r={}", report.len());
    let mut pos: Vec<usize> = (0..report.len()).collect();
    pos.sort_by(|&a, &b| {
        let (aa, ab) = (age.get(report[a] as usize), age.get(report[b] as usize));
        ab.cmp(&aa).then_with(|| a.cmp(&b))
    });
    pos.truncate(k);
    pos.into_iter().map(|p| report[p]).collect()
}

/// Cluster-coordinated selection (paper §I: "the merged vectors can be
/// used by the PS to strategically choose a **disjoint** set of indices to
/// request updates on from each individual client within the same
/// cluster").
///
/// Clients are processed in the given order against one shared age
/// vector; indices already assigned to a sibling this round are skipped.
/// If a report has fewer than k unassigned indices left, the remainder is
/// filled with already-assigned indices (graceful overlap) so every client
/// still uploads exactly k values.
///
/// Assignment state is a client-stamped marker vector keyed by index (one
/// allocation per call, sized by the age vector's dimension d — never by
/// the reported indices, which on the TCP path are remote input),
/// replacing the former `HashSet` + O(k) `sel.contains` scans:
/// `stamp[j] == 0` means unassigned, any other value names 1 + the
/// position of the client that took `j` — so "taken by anyone" is a zero
/// test and "in *my* selection" compares against the current client's
/// stamp. Out-of-range report indices are rejected up front. Output is
/// pinned identical to the set-based reference by
/// `matches_reference_implementation_randomly`.
pub fn select_disjoint(
    age: &AgeVector,
    reports: &[&[u32]],
    k: usize,
) -> Vec<Vec<u32>> {
    let d = age.d();
    for report in reports {
        for &j in report.iter() {
            assert!((j as usize) < d, "report index {j} out of range (d = {d})");
        }
    }
    let mut stamp: Vec<u32> = vec![0; d];
    let mut pos: Vec<usize> = Vec::new();
    let mut out = Vec::with_capacity(reports.len());
    for (c, report) in reports.iter().enumerate() {
        assert!(k <= report.len(), "k={k} > r={}", report.len());
        let s = c as u32 + 1;
        pos.clear();
        pos.extend(0..report.len());
        pos.sort_by(|&a, &b| {
            let (aa, ab) = (age.get(report[a] as usize), age.get(report[b] as usize));
            ab.cmp(&aa).then_with(|| a.cmp(&b))
        });
        let mut sel: Vec<u32> = Vec::with_capacity(k);
        // first pass: unassigned indices in age order
        for &p in &pos {
            if sel.len() == k {
                break;
            }
            let j = report[p];
            if stamp[j as usize] == 0 {
                stamp[j as usize] = s;
                sel.push(j);
            }
        }
        // fallback: allow overlap with *siblings* to fill up to k (never
        // a duplicate within this client's own selection)
        for &p in &pos {
            if sel.len() == k {
                break;
            }
            let j = report[p];
            if stamp[j as usize] != s {
                stamp[j as usize] = s;
                sel.push(j);
            }
        }
        out.push(sel);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn age_from(ages: &[u32]) -> AgeVector {
        // build an AgeVector with the given raw ages via repeated updates
        let mut a = AgeVector::new(ages.len());
        let maxage = ages.iter().cloned().max().unwrap_or(0);
        for round in 0..maxage {
            // an index with target age t must be last reset at maxage - t
            let resets: Vec<u32> = (0..ages.len() as u32)
                .filter(|&j| maxage - ages[j as usize] > round)
                .collect();
            a.update(&resets);
        }
        // indices with age == maxage were never reset; their age equals
        // rounds elapsed which is maxage. verify:
        for (j, &want) in ages.iter().enumerate() {
            assert_eq!(a.get(j), want, "setup failed at {j}");
        }
        a
    }

    #[test]
    fn picks_oldest_with_magnitude_tiebreak() {
        let age = age_from(&[5, 0, 2, 2, 9]);
        // report ordered by |g| desc: indices 1 (age 0), 2 (2), 3 (2), 4 (9)
        let sel = select_oldest_k(&age, &[1, 2, 3, 4], 2);
        assert_eq!(sel, vec![4, 2]); // oldest first; tie 2-vs-3 -> rank
    }

    #[test]
    fn k_equals_r_returns_whole_report() {
        let age = age_from(&[1, 1, 1]);
        let sel = select_oldest_k(&age, &[2, 0, 1], 3);
        assert_eq!(sel.len(), 3);
        let set: std::collections::HashSet<u32> = sel.into_iter().collect();
        assert_eq!(set, [0u32, 1, 2].into_iter().collect());
    }

    #[test]
    fn uniform_age_degenerates_to_topk() {
        let age = AgeVector::new(10);
        let sel = select_oldest_k(&age, &[7, 3, 9, 1], 2);
        assert_eq!(sel, vec![7, 3]); // report rank order = |g| order
    }

    #[test]
    fn disjoint_assignment_covers_more_indices() {
        let age = AgeVector::new(8);
        let r1: &[u32] = &[0, 1, 2, 3];
        let r2: &[u32] = &[0, 1, 2, 3];
        let sels = select_disjoint(&age, &[r1, r2], 2);
        assert_eq!(sels[0], vec![0, 1]);
        assert_eq!(sels[1], vec![2, 3], "sibling must get disjoint indices");
        let all: std::collections::HashSet<u32> =
            sels.iter().flatten().cloned().collect();
        assert_eq!(all.len(), 4);
    }

    #[test]
    fn disjoint_falls_back_to_overlap_when_exhausted() {
        let age = AgeVector::new(4);
        let r1: &[u32] = &[0, 1];
        let r2: &[u32] = &[0, 1];
        let sels = select_disjoint(&age, &[r1, r2], 2);
        assert_eq!(sels[0], vec![0, 1]);
        assert_eq!(sels[1], vec![0, 1]); // nothing left: overlap allowed
    }

    #[test]
    fn disjoint_respects_age_priority() {
        let age = age_from(&[0, 9, 0, 9]);
        let r: &[u32] = &[0, 1, 2, 3];
        let sels = select_disjoint(&age, &[r, r], 2);
        assert_eq!(sels[0], vec![1, 3]); // the two old ones
        assert_eq!(sels[1], vec![0, 2]); // freshest remain for sibling
    }

    /// The pre-stamp-vector `select_disjoint`: a `HashSet` of taken
    /// indices plus linear `sel.contains` scans. Kept as the behavioral
    /// oracle for the marker-based implementation.
    fn select_disjoint_reference(
        age: &AgeVector,
        reports: &[&[u32]],
        k: usize,
    ) -> Vec<Vec<u32>> {
        let mut taken: std::collections::HashSet<u32> = Default::default();
        let mut out = Vec::with_capacity(reports.len());
        for report in reports {
            assert!(k <= report.len(), "k={k} > r={}", report.len());
            let mut pos: Vec<usize> = (0..report.len()).collect();
            pos.sort_by(|&a, &b| {
                let (aa, ab) = (age.get(report[a] as usize), age.get(report[b] as usize));
                ab.cmp(&aa).then_with(|| a.cmp(&b))
            });
            let mut sel: Vec<u32> = Vec::with_capacity(k);
            for &p in &pos {
                if sel.len() == k {
                    break;
                }
                let j = report[p];
                if !taken.contains(&j) && !sel.contains(&j) {
                    sel.push(j);
                }
            }
            for &p in &pos {
                if sel.len() == k {
                    break;
                }
                let j = report[p];
                if !sel.contains(&j) {
                    sel.push(j);
                }
            }
            for &j in &sel {
                taken.insert(j);
            }
            out.push(sel);
        }
        out
    }

    /// The stamp-vector rewrite must reproduce the set-based original
    /// exactly — over random cluster sizes, ages, overlap degrees, and
    /// the overlap-fallback regime (k close to r with heavy sharing).
    #[test]
    fn matches_reference_implementation_randomly() {
        crate::testing::prop_check("disjoint-matches-reference", 150, |g| {
            let d = g.usize_in(10, 400);
            let members = g.usize_in(1, 6);
            let r = g.usize_in(2, d.min(40));
            let k = g.usize_in(1, r);
            let mut age = AgeVector::new(d);
            for _ in 0..g.usize_in(0, 25) {
                let take = g.usize_in(1, 8.min(d));
                age.update(&g.vec_u32_distinct(d, take));
            }
            // heavy index sharing across members so the fallback path runs
            let pool_size = g.usize_in(r, (2 * r).min(d));
            let pool = g.vec_u32_distinct(d, pool_size);
            let reports: Vec<Vec<u32>> = (0..members)
                .map(|_| {
                    // each member reports r of the shared pool, shuffled
                    let order = g.rng.choose_k(pool.len(), pool.len());
                    let mut rep: Vec<u32> =
                        order.into_iter().map(|p| pool[p]).collect();
                    rep.truncate(r);
                    rep
                })
                .collect();
            let refs: Vec<&[u32]> = reports.iter().map(|r| r.as_slice()).collect();
            let fast = select_disjoint(&age, &refs, k);
            let slow = select_disjoint_reference(&age, &refs, k);
            if fast != slow {
                return Err(format!("stamp {fast:?} != reference {slow:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn selection_properties_hold_randomly() {
        let mut rng = crate::util::rng::Rng::new(0);
        for _ in 0..50 {
            let d = 50 + rng.below(200);
            let r = 5 + rng.below(20);
            let k = 1 + rng.below(r.min(10));
            let mut age = AgeVector::new(d);
            for _ in 0..rng.below(30) {
                let take = rng.below(8) + 1;
                let sel: Vec<u32> =
                    rng.choose_k(d, take).into_iter().map(|x| x as u32).collect();
                age.update(&sel);
            }
            let report: Vec<u32> =
                rng.choose_k(d, r).into_iter().map(|x| x as u32).collect();
            let sel = select_oldest_k(&age, &report, k);
            // property 1: k distinct indices, all from the report
            assert_eq!(sel.len(), k);
            let set: std::collections::HashSet<u32> = sel.iter().cloned().collect();
            assert_eq!(set.len(), k);
            assert!(sel.iter().all(|j| report.contains(j)));
            // property 2: no unselected report index is strictly older
            let min_sel = sel.iter().map(|&j| age.get(j as usize)).min().unwrap();
            for &j in &report {
                if !set.contains(&j) {
                    assert!(age.get(j as usize) <= min_sel);
                }
            }
        }
    }
}
