//! ASCII rendering of the paper's figures: heatmaps (Fig. 2/4 connectivity
//! matrices) and line charts (Fig. 3/5 accuracy & loss curves) straight in
//! the terminal, plus CSV dumps for external plotting.

/// Render a square matrix as an ASCII heatmap with a shade ramp.
/// Values are normalized to [0, max] across the matrix.
pub fn heatmap(m: &[Vec<f64>], labels: bool) -> String {
    const RAMP: [char; 10] = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let n = m.len();
    let maxv = m
        .iter()
        .flat_map(|r| r.iter())
        .cloned()
        .fold(f64::MIN, f64::max)
        .max(1e-12);
    let mut s = String::new();
    if labels {
        s.push_str("    ");
        for j in 0..n {
            s.push_str(&format!("{j:>3}"));
        }
        s.push('\n');
    }
    for (i, row) in m.iter().enumerate() {
        if labels {
            s.push_str(&format!("{i:>3} "));
        }
        for &v in row {
            let t = (v / maxv).clamp(0.0, 1.0);
            let c = RAMP[((t * (RAMP.len() - 1) as f64).round() as usize).min(RAMP.len() - 1)];
            s.push(' ');
            s.push(c);
            s.push(c);
        }
        s.push('\n');
    }
    s
}

/// Render one or more named series as an ASCII line chart.
pub fn line_chart(series: &[(&str, &[f64])], width: usize, height: usize) -> String {
    const MARKS: [char; 6] = ['o', 'x', '+', '*', '^', '~'];
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    let mut maxlen = 0usize;
    for (_, ys) in series {
        for &y in ys.iter() {
            if y.is_finite() {
                lo = lo.min(y);
                hi = hi.max(y);
            }
        }
        maxlen = maxlen.max(ys.len());
    }
    if !lo.is_finite() || maxlen < 2 {
        return String::from("(no data)\n");
    }
    if (hi - lo).abs() < 1e-12 {
        hi = lo + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for (i, &y) in ys.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let x = i * (width - 1) / (maxlen - 1).max(1);
            let t = (y - lo) / (hi - lo);
            let row = height - 1 - ((t * (height - 1) as f64).round() as usize).min(height - 1);
            grid[row][x] = mark;
        }
    }
    let mut s = String::new();
    for (ri, row) in grid.iter().enumerate() {
        let label = if ri == 0 {
            format!("{hi:>9.3} |")
        } else if ri == height - 1 {
            format!("{lo:>9.3} |")
        } else {
            format!("{:>9} |", "")
        };
        s.push_str(&label);
        s.extend(row.iter());
        s.push('\n');
    }
    s.push_str(&format!("{:>10}+{}\n", "", "-".repeat(width)));
    let mut legend = format!("{:>11}", "");
    for (si, (name, _)) in series.iter().enumerate() {
        legend.push_str(&format!("{} = {}   ", MARKS[si % MARKS.len()], name));
    }
    s.push_str(&legend);
    s.push('\n');
    s
}

/// CSV dump: header + one row per index across all series (ragged series
/// padded with empty cells).
pub fn to_csv(columns: &[(&str, &[f64])]) -> String {
    let mut s = String::new();
    s.push_str("step");
    for (name, _) in columns {
        s.push(',');
        s.push_str(name);
    }
    s.push('\n');
    let maxlen = columns.iter().map(|(_, v)| v.len()).max().unwrap_or(0);
    for i in 0..maxlen {
        s.push_str(&i.to_string());
        for (_, v) in columns {
            s.push(',');
            if let Some(x) = v.get(i) {
                s.push_str(&format!("{x}"));
            }
        }
        s.push('\n');
    }
    s
}

/// CSV for a matrix (used for the Fig. 2/4 heatmap dumps).
pub fn matrix_csv(m: &[Vec<f64>]) -> String {
    let mut s = String::new();
    for row in m {
        let cells: Vec<String> = row.iter().map(|x| format!("{x:.6}")).collect();
        s.push_str(&cells.join(","));
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heatmap_shape() {
        let m = vec![vec![1.0, 0.0], vec![0.5, 1.0]];
        let out = heatmap(&m, true);
        assert_eq!(out.lines().count(), 3); // header + 2 rows
        assert!(out.contains('@')); // max value shade
    }

    #[test]
    fn line_chart_renders_all_series() {
        let a: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..50).map(|i| 50.0 - i as f64).collect();
        let out = line_chart(&[("up", &a), ("down", &b)], 40, 10);
        assert!(out.contains("o = up"));
        assert!(out.contains("x = down"));
        assert!(out.lines().count() >= 12);
    }

    #[test]
    fn csv_layout() {
        let a = [1.0, 2.0];
        let b = [3.0];
        let csv = to_csv(&[("a", &a), ("b", &b)]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "step,a,b");
        assert_eq!(lines[1], "0,1,3");
        assert_eq!(lines[2], "1,2,");
    }

    #[test]
    fn degenerate_chart_no_panic() {
        assert!(line_chart(&[("e", &[])], 10, 5).contains("no data"));
        let flat = [2.0, 2.0, 2.0];
        let _ = line_chart(&[("flat", &flat)], 10, 5);
    }
}
