//! Scoped wall-clock timing + a cumulative per-phase profile, used by the
//! perf pass (EXPERIMENTS.md §Perf) to attribute global-round time to
//! selection / aggregation / clustering / compute.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Cumulative profile: phase name -> (total seconds, calls).
#[derive(Debug, Default)]
pub struct Profile {
    inner: Mutex<BTreeMap<String, (f64, u64)>>,
}

impl Profile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` under `phase`. The one blessed clock read for profiling —
    /// everything else calls through here (clippy.toml bans the rest).
    #[allow(clippy::disallowed_methods)]
    pub fn time<T>(&self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed().as_secs_f64());
        out
    }

    pub fn add(&self, phase: &str, secs: f64) {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(phase.to_string()).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += 1;
    }

    pub fn snapshot(&self) -> Vec<(String, f64, u64)> {
        let m = self.inner.lock().unwrap();
        let mut v: Vec<_> = m.iter().map(|(k, (s, n))| (k.clone(), *s, *n)).collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }

    pub fn report(&self) -> String {
        let snap = self.snapshot();
        let total: f64 = snap.iter().map(|e| e.1).sum();
        let mut s = format!("{:<28} {:>10} {:>8} {:>7}\n", "phase", "total(s)", "calls", "share");
        for (name, secs, calls) in snap {
            let share = if total > 0.0 { secs / total * 100.0 } else { 0.0 };
            s.push_str(&format!("{name:<28} {secs:>10.4} {calls:>8} {share:>6.1}%\n"));
        }
        s
    }
}

/// One `<key>: <n> kB` line of `/proc/self/status`, in bytes.
fn proc_status_bytes(key: &str) -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            let kb: u64 = rest.trim().split_whitespace().next()?.parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Peak resident set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`), or `None` where procfs is unavailable. The
/// high-water mark is kernel-maintained and monotone, so it captures the
/// true allocation peak even after buffers are freed — what
/// `bench_fleetscale` reports as bytes/client.
pub fn peak_rss_bytes() -> Option<u64> {
    proc_status_bytes("VmHWM:")
}

/// Current resident set size in bytes (`VmRSS`), or `None` where procfs
/// is unavailable. Deltas of this across a pool construction give the
/// *incremental* footprint of that structure.
pub fn current_rss_bytes() -> Option<u64> {
    proc_status_bytes("VmRSS:")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_probes_report_on_linux() {
        if !cfg!(target_os = "linux") {
            return;
        }
        let peak = peak_rss_bytes().expect("VmHWM readable on linux");
        let cur = current_rss_bytes().expect("VmRSS readable on linux");
        assert!(peak > 0 && cur > 0);
        // the high-water mark can never sit below the current RSS
        assert!(peak >= cur, "peak {peak} < current {cur}");
    }

    #[test]
    fn accumulates_phases() {
        let p = Profile::new();
        let x = p.time("a", || 21 * 2);
        assert_eq!(x, 42);
        p.time("a", || ());
        p.time("b", || ());
        let snap = p.snapshot();
        assert_eq!(snap.len(), 2);
        let a = snap.iter().find(|e| e.0 == "a").unwrap();
        assert_eq!(a.2, 2);
        assert!(p.report().contains("calls"));
    }
}
