//! Minimal-but-complete JSON substrate (no serde offline): a recursive-
//! descent parser and a writer, used for the artifact manifest, experiment
//! configs and metrics output.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes
//! incl. `\uXXXX`, numbers, bools, null). Numbers are stored as f64 —
//! fine for every field this repo exchanges (indices < 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are sorted (BTreeMap) so output is
/// deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ------------------------------------------------------------ access
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for key in path {
            cur = cur.get(key).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ----------------------------------------------------------- builders
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ------------------------------------------------------------ parsing
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() && x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else if x.is_finite() {
        out.push_str(&format!("{x}"));
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            self.pos -= 1; // compensate the +1 below
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(c) if c < 0x80 => {
                    s.push(c as char);
                    self.pos += 1;
                }
                Some(_) => {
                    // multi-byte UTF-8: copy the full scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [1.5, -2e3, true, null, "x\ny"], "c": {"d": "ü"}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.at(&["c", "d"]).as_str(), Some("ü"));
        assert_eq!(v.at(&["b"]).as_arr().unwrap().len(), 5);
        assert_eq!(v.at(&["a"]).as_usize(), Some(1));
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.5])),
            ("name", Json::Str("hello \"world\"".into())),
            ("empty", Json::Arr(vec![])),
        ]);
        let re = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""ü 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("ü 😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-0.5e2").unwrap().as_f64(), Some(-50.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        // integers print without decimal point
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn missing_paths_are_null() {
        let v = Json::parse(r#"{"a": {"b": 1}}"#).unwrap();
        assert_eq!(v.at(&["a", "z", "q"]), &Json::Null);
    }
}
