//! Tiny leveled logger (stderr) with a global verbosity switch.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(2); // info
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

// Log lines carry a wall-clock offset by design (clippy.toml bans clock
// reads elsewhere to keep the simulation layers deterministic).
#[allow(clippy::disallowed_methods)]
pub fn log(level: Level, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{t:9.3}s {tag}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
