//! Deterministic PRNG substrate: splitmix64 seeding + xoshiro256++ core,
//! with gaussian sampling (Box–Muller), choice-without-replacement and
//! shuffling — everything the simulator needs, reference-vector tested.
//!
//! (The offline registry has no `rand`; `rand_core` alone carries no
//! generators, so we implement the standard algorithms directly.)

/// xoshiro256++ generator seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second gaussian from Box–Muller
    spare: Option<f64>,
}

/// Stream-tag namespace for [`stream_seed`]: a client's mini-batch
/// schedule ([`crate::data::BatchIter`]).
pub const STREAM_BATCHES: u64 = 0xB47C_11E5;
/// Stream-tag namespace for [`stream_seed`]: a client's local RNG
/// (rTop-k's random k-subset etc.).
pub const STREAM_CLIENT_RNG: u64 = 0xC11E_47A6;

/// Derive the seed for per-client stream `tag` of client `id` under
/// experiment seed `seed`.
///
/// Every (seed, tag, id) triple must map to a distinct, well-separated
/// generator seed — at fleet scale (n >= 1e5) the earlier ad-hoc mixing
/// (`seed ^ id * 0x9E37` for batches, `seed ^ CONST ^ id << 17` for the
/// client RNG) kept both products inside the same ~32-bit window, so a
/// *batch* stream of one client could collide with the *rng* stream of
/// another. Three chained splitmix64 passes (each a bijection on its
/// word) spread the triple over the full 64-bit space; collisions now
/// require a splitmix preimage. Property-pinned in
/// `stream_seeds_distinct_at_fleet_scale`.
#[inline]
pub fn stream_seed(seed: u64, tag: u64, id: u64) -> u64 {
    let mut x = seed;
    let a = splitmix64(&mut x);
    x = a ^ tag;
    let b = splitmix64(&mut x);
    x = b ^ id;
    splitmix64(&mut x)
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed deterministically; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent child stream (used to give each simulated
    /// client its own RNG from the experiment seed).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 bits (xoshiro256++).
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // top 53 bits -> f64 mantissa
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n) (Lemire's multiply-shift with rejection).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal (Box–Muller, cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.spare.take() {
            return g;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fill a slice with N(0, std) f32 samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = self.gaussian() as f32 * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (partial Fisher–Yates; k <= n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        // For small k relative to n use a set-based sampler; otherwise shuffle.
        if k * 8 < n {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let j = self.below(n);
                if seen.insert(j) {
                    out.push(j);
                }
            }
            out
        } else {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let a: Vec<u64> = {
            let mut r = Rng::new(7);
            (0..5).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(7);
            (0..5).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut r = Rng::new(8);
            (0..5).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn xoshiro_reference_vector() {
        // Reference: xoshiro256++ from state [1, 2, 3, 4] (Blackman & Vigna
        // reference implementation output).
        let mut r = Rng { s: [1, 2, 3, 4], spare: None };
        let expect: [u64; 4] = [
            41943041,
            58720359,
            3588806011781223,
            3591011842654386,
        ];
        for e in expect {
            assert_eq!(r.next_u64(), e);
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let xs: Vec<f64> = (0..20000).map(|_| r.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..100_000).map(|_| r.gaussian()).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
    }

    #[test]
    fn choose_k_distinct_and_in_range() {
        let mut r = Rng::new(4);
        for (n, k) in [(10, 10), (1000, 3), (50, 25)] {
            let picks = r.choose_k(n, k);
            assert_eq!(picks.len(), k);
            let set: std::collections::HashSet<_> = picks.iter().collect();
            assert_eq!(set.len(), k);
            assert!(picks.iter().all(|&p| p < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn stream_seeds_distinct_at_fleet_scale() {
        // Fleet-scale audit (ISSUE 9 satellite): across BOTH per-client
        // stream namespaces, no two clients in a 2e5-wide id range may
        // share a generator seed — including cross-tag collisions (client
        // A's batch stream vs client B's rng stream), the exact failure
        // mode of the old mixing where id * 0x9E37 and id << 17 landed in
        // overlapping windows.
        let mut seen = std::collections::HashSet::new();
        for tag in [STREAM_BATCHES, STREAM_CLIENT_RNG] {
            for id in 0..200_000u64 {
                assert!(
                    seen.insert(stream_seed(42, tag, id)),
                    "stream seed collision at tag {tag:#x}, id {id}"
                );
            }
        }
        // distinct experiment seeds decorrelate every stream
        assert!(!seen.contains(&stream_seed(43, STREAM_BATCHES, 0)));
    }

    #[test]
    fn stream_seeds_yield_uncorrelated_prefixes() {
        // adjacent ids must not produce overlapping output sequences:
        // compare the first outputs of neighbouring clients' streams
        let mut firsts = std::collections::HashSet::new();
        for id in 0..4096u64 {
            for tag in [STREAM_BATCHES, STREAM_CLIENT_RNG] {
                let mut r = Rng::new(stream_seed(7, tag, id));
                assert!(firsts.insert(r.next_u64()), "correlated stream at id {id}");
            }
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(9);
        let mut a = parent.fork(1);
        let mut b = parent.fork(2);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
