//! CLI argument parsing substrate (no clap offline): subcommands, typed
//! options with defaults, flags, and generated `--help` text.

use std::collections::BTreeMap;

/// One declared option or flag.
#[derive(Debug, Clone)]
struct Decl {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative parser for one (sub)command.
#[derive(Debug, Default)]
pub struct ArgSpec {
    program: String,
    about: String,
    decls: Vec<Decl>,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum ArgError {
    #[error("unknown option --{0}")]
    Unknown(String),
    #[error("option --{0} requires a value")]
    MissingValue(String),
    #[error("invalid value for --{0}: {1}")]
    Invalid(String, String),
    #[error("help requested")]
    HelpRequested,
}

impl ArgSpec {
    pub fn new(program: &str, about: &str) -> Self {
        ArgSpec { program: program.into(), about: about.into(), decls: vec![] }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.decls.push(Decl {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
        });
        self
    }

    /// Declare a required `--name <value>` (no default).
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.decls.push(Decl {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.decls.push(Decl {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for d in &self.decls {
            let left = if d.is_flag {
                format!("  --{}", d.name)
            } else {
                format!("  --{} <v>", d.name)
            };
            let def = match (&d.default, d.is_flag) {
                (Some(v), false) => format!(" [default: {v}]"),
                _ => String::new(),
            };
            s.push_str(&format!("{left:<26} {}{def}\n", d.help));
        }
        s.push_str("  --help                     show this message\n");
        s
    }

    /// Parse a token list (not including argv[0] / the subcommand).
    pub fn parse(&self, tokens: &[String]) -> Result<Args, ArgError> {
        let mut args = Args::default();
        for d in &self.decls {
            if let Some(def) = &d.default {
                args.values.insert(d.name.clone(), def.clone());
            }
            if d.is_flag {
                args.flags.insert(d.name.clone(), false);
            }
        }
        let mut it = tokens.iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(ArgError::HelpRequested);
            }
            if let Some(name) = tok.strip_prefix("--") {
                // --name=value form
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let decl = self
                    .decls
                    .iter()
                    .find(|d| d.name == name)
                    .ok_or_else(|| ArgError::Unknown(name.to_string()))?;
                if decl.is_flag {
                    args.flags.insert(name.to_string(), true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| ArgError::MissingValue(name.to_string()))?,
                    };
                    args.values.insert(name.to_string(), v);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        // check required
        for d in &self.decls {
            if !d.is_flag && !args.values.contains_key(&d.name) {
                return Err(ArgError::MissingValue(d.name.clone()));
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, ArgError> {
        self.get(name)
            .parse()
            .map_err(|_| ArgError::Invalid(name.into(), self.get(name).into()))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, ArgError> {
        self.get(name)
            .parse()
            .map_err(|_| ArgError::Invalid(name.into(), self.get(name).into()))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test", "about")
            .opt("iters", "100", "iteration count")
            .opt("strategy", "ragek", "selection strategy")
            .flag("verbose", "log more")
            .req("model", "model name")
    }

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = spec()
            .parse(&toks(&["--model", "mnist", "--iters=250", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("iters").unwrap(), 250);
        assert_eq!(a.get("strategy"), "ragek");
        assert_eq!(a.get("model"), "mnist");
        assert!(!a.get_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn flags_and_equals_form() {
        let a = spec()
            .parse(&toks(&["--verbose", "--model=cifar"]))
            .unwrap();
        assert!(a.get_flag("verbose"));
        assert_eq!(a.get("model"), "cifar");
    }

    #[test]
    fn missing_required_rejected() {
        assert!(matches!(
            spec().parse(&toks(&["--iters", "5"])),
            Err(ArgError::MissingValue(_))
        ));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            spec().parse(&toks(&["--model", "m", "--nope", "1"])),
            Err(ArgError::Unknown(_))
        ));
    }

    #[test]
    fn help_requested() {
        assert!(matches!(
            spec().parse(&toks(&["--help"])),
            Err(ArgError::HelpRequested)
        ));
        assert!(spec().usage().contains("--iters"));
    }

    #[test]
    fn invalid_numeric_value() {
        let a = spec().parse(&toks(&["--model", "m", "--iters", "abc"])).unwrap();
        assert!(matches!(a.get_usize("iters"), Err(ArgError::Invalid(..))));
    }
}
