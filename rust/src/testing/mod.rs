//! Mini property-testing substrate (proptest is not in the offline
//! registry): seeded generators + a `prop_check` runner that reports the
//! failing case and its seed for reproduction — plus the deterministic
//! membership-chaos harness [`FlakyPool`] shared by the integration
//! tests and `bench_membership`.

use crate::backend::{Backend, Lanes};
use crate::config::ExperimentConfig;
use crate::coordinator::engine::{BroadcastPlan, ClientPool, ClientReport};
use crate::data::{load_dataset, partition_shards, Shard};
use crate::fl::compact::CompactPool;
use crate::fl::pool::InProcessPool;
use crate::sparse::SparseVec;
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;

/// Pools the chaos harness can wrap: a [`ClientPool`] that can also
/// reset one client's local state to the current global model, mimicking
/// a worker-process restart before a `Rejoin`.
pub trait ResyncPool: ClientPool {
    fn resync_client(&mut self, i: usize, global: &[f32]);
}

impl<L: Lanes> ResyncPool for InProcessPool<L> {
    fn resync_client(&mut self, i: usize, global: &[f32]) {
        InProcessPool::resync_client(self, i, global);
    }
}

impl<L: Lanes> ResyncPool for CompactPool<L> {
    fn resync_client(&mut self, i: usize, global: &[f32]) {
        CompactPool::resync_client(self, i, global);
    }
}

/// The standard data pipeline: the same per-client shard views the
/// [`crate::fl::trainer::Trainer`] would build.
fn standard_shards(cfg: &ExperimentConfig) -> Vec<Shard> {
    let (train, _) = load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
    let train = Arc::new(train);
    partition_shards(&train, cfg.n_clients, &cfg.partition, cfg.seed)
}

/// The round fate chaos deals a scheduled cohort member.
#[derive(Clone, Copy, PartialEq)]
enum Fate {
    /// crashed mid-phase (the classic drop chaos)
    Dead,
    /// alive but slow: its report would land after the commit
    Stalled,
    /// reports on time
    Fast,
}

/// A deterministic chaos wrapper over any [`ResyncPool`] (the dense
/// [`InProcessPool`] by default, the fleet-scale [`CompactPool`] via
/// [`FlakyPool::new_compact`]): scheduled clients drop with a seeded
/// per-phase probability (mid-round, exactly like a crashed TCP worker)
/// and re-admit themselves `rejoin_after` rounds later through
/// [`ClientPool::poll_rejoins`] — the simulator face of the
/// fleet-membership protocol (DESIGN.md §8). A dropped client's local
/// state is reset to the current global model on rejoin
/// ([`ResyncPool::resync_client`]), mimicking a restarted worker
/// process. All chaos is drawn from its own seeded RNG in cohort order,
/// so a run is bit-for-bit reproducible — and identical across inner
/// pool representations, which is exactly what the compact-vs-dense
/// chaos parity pin below leans on.
pub struct FlakyPool<P = InProcessPool> {
    inner: P,
    chaos: Rng,
    /// per-phase drop probability for a scheduled live client
    drop_rate: f32,
    /// rounds a dropped client stays gone before it rejoins
    rejoin_after: usize,
    alive: Vec<bool>,
    rejoin_at: Vec<Option<usize>>,
    round: usize,
    /// stall chaos (slow, not dead — DESIGN.md §11) draws from its own
    /// seeded stream so `stall_rate = 0` leaves the drop chaos
    /// bit-for-bit unchanged
    stall: Rng,
    /// per-round probability a scheduled live client is slow
    stall_rate: f32,
    /// probability a due rejoiner's handshake stalls mid-frame: the
    /// reactor drops the pending handshake at its deadline and the
    /// worker retries, so admission slips a round instead of wedging
    handshake_stall_rate: f32,
    /// commit quota for the next phase 1 (not forwarded to the inner
    /// pool: chaos, not cohort order, decides who is slow here)
    quota: Option<usize>,
    cancelled: Vec<usize>,
    handshake_stalls: usize,
}

impl FlakyPool<InProcessPool> {
    /// Build over the standard data pipeline (same shards the [`crate::fl::trainer::Trainer`]
    /// would build). Returns the pool and the initial global params.
    pub fn new(
        cfg: &ExperimentConfig,
        drop_rate: f32,
        rejoin_after: usize,
        chaos_seed: u64,
    ) -> Result<(Self, Vec<f32>)> {
        let (inner, init) = InProcessPool::new(cfg, standard_shards(cfg))?;
        Ok((FlakyPool::wrap(cfg, inner, drop_rate, rejoin_after, chaos_seed), init))
    }
}

impl FlakyPool<CompactPool> {
    /// Like [`FlakyPool::new`] but chaos flows through the fleet-scale
    /// compact client store — drop/stall/rejoin churn exercises the
    /// materialize/resync/arena lifecycle.
    pub fn new_compact(
        cfg: &ExperimentConfig,
        drop_rate: f32,
        rejoin_after: usize,
        chaos_seed: u64,
    ) -> Result<(Self, Vec<f32>)> {
        let (inner, init) = CompactPool::new(cfg, standard_shards(cfg))?;
        Ok((FlakyPool::wrap(cfg, inner, drop_rate, rejoin_after, chaos_seed), init))
    }
}

impl<P: ResyncPool> FlakyPool<P> {
    fn wrap(
        cfg: &ExperimentConfig,
        inner: P,
        drop_rate: f32,
        rejoin_after: usize,
        chaos_seed: u64,
    ) -> Self {
        let n = cfg.n_clients;
        FlakyPool {
            inner,
            chaos: Rng::new(chaos_seed ^ 0xF1A_C4A0_5),
            drop_rate,
            rejoin_after,
            alive: vec![true; n],
            rejoin_at: vec![None; n],
            round: 0,
            stall: Rng::new(chaos_seed ^ 0x57A_11ED),
            stall_rate: 0.0,
            handshake_stall_rate: 0.0,
            quota: None,
            cancelled: Vec::new(),
            handshake_stalls: 0,
        }
    }

    pub fn inner(&self) -> &P {
        &self.inner
    }

    /// Total clients currently down.
    pub fn n_down(&self) -> usize {
        self.alive.iter().filter(|&&a| !a).count()
    }

    /// Make a fraction of scheduled live clients *slow* each round
    /// (stalled, not dead): under a satisfiable commit quota they are
    /// cancelled cleanly and keep training; without one — or when too
    /// few fast members remain to fill the quota — the stall outlasts
    /// the phase deadline and they degrade to casualties, exactly like
    /// the TCP reactor tearing the stream down.
    pub fn set_stall_rate(&mut self, rate: f32) {
        self.stall_rate = rate;
    }

    /// Stall a fraction of rejoin handshakes mid-frame: admission slips
    /// at least one round per stall, but the round itself never blocks.
    pub fn set_handshake_stall_rate(&mut self, rate: f32) {
        self.handshake_stall_rate = rate;
    }

    /// Rejoin handshakes the chaos has stalled so far.
    pub fn n_handshake_stalls(&self) -> usize {
        self.handshake_stalls
    }

    /// Draw the chaos verdict for one scheduled client: `true` = it
    /// drops this phase (and is queued for a later rejoin).
    fn drops_now(&mut self, c: usize) -> bool {
        if self.chaos.uniform_in(0.0, 1.0) < self.drop_rate {
            self.alive[c] = false;
            self.rejoin_at[c] = Some(self.round + self.rejoin_after);
            true
        } else {
            false
        }
    }
}

impl<P: ResyncPool> ClientPool for FlakyPool<P> {
    fn n_clients(&self) -> usize {
        self.inner.n_clients()
    }

    fn health(&self) -> Vec<bool> {
        self.alive.clone()
    }

    /// Chaos is transparent to the delta plan: the inner pool still runs
    /// its digest tripwire on every delta-downlink chaos round.
    fn set_broadcast_plan(&mut self, plan: &BroadcastPlan) {
        self.inner.set_broadcast_plan(plan);
    }

    fn poll_rejoins(&mut self, global: &[f32]) -> Result<Vec<usize>> {
        let mut admitted = Vec::new();
        for c in 0..self.alive.len() {
            if let Some(due) = self.rejoin_at[c] {
                if due <= self.round {
                    if self.handshake_stall_rate > 0.0
                        && self.stall.uniform_in(0.0, 1.0) < self.handshake_stall_rate
                    {
                        // mid-handshake stall: the reactor drops the
                        // pending frame at its deadline; the worker
                        // retries next round
                        self.rejoin_at[c] = Some(self.round + 1);
                        self.handshake_stalls += 1;
                        continue;
                    }
                    self.rejoin_at[c] = None;
                    self.alive[c] = true;
                    self.inner.resync_client(c, global);
                    admitted.push(c);
                }
            }
        }
        Ok(admitted)
    }

    fn set_commit_quota(&mut self, quota: usize) {
        self.quota = Some(quota);
    }

    fn take_cancelled(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.cancelled)
    }

    fn train_and_report(
        &mut self,
        global: &[f32],
        cohort: &[usize],
    ) -> Result<Vec<Option<ClientReport>>> {
        self.round += 1;
        let quota = self.quota.take();
        // chaos verdicts in cohort order (deterministic given the seed):
        // the drop draw comes first, from the drop stream, so stall
        // chaos never perturbs it
        let mut fates = Vec::with_capacity(cohort.len());
        let mut n_fast = 0usize;
        for &c in cohort {
            let fate = if !self.alive[c] || self.drops_now(c) {
                Fate::Dead
            } else if self.stall_rate > 0.0
                && self.stall.uniform_in(0.0, 1.0) < self.stall_rate
            {
                Fate::Stalled
            } else {
                n_fast += 1;
                Fate::Fast
            };
            fates.push(fate);
        }
        // With enough fast members to fill the quota the round commits
        // early: every live member trains (stragglers hold the
        // broadcast) and the non-winners are cancelled cleanly. Without
        // a quota — or with too few fast members — a stall outlasts the
        // phase deadline and degrades to a casualty.
        let commit_with_cancel = quota.map_or(false, |q| n_fast >= q);
        let mut live = Vec::with_capacity(cohort.len());
        for (&c, fate) in cohort.iter().zip(&fates) {
            match fate {
                Fate::Dead => {}
                Fate::Stalled if !commit_with_cancel => {
                    self.alive[c] = false;
                    self.rejoin_at[c] = Some(self.round + self.rejoin_after);
                }
                _ => live.push(c),
            }
        }
        let mut outs = self.inner.train_and_report(global, &live)?.into_iter();
        let quota = quota.unwrap_or(usize::MAX);
        let cancelled = &mut self.cancelled;
        let mut landed = 0usize;
        Ok(cohort
            .iter()
            .zip(&fates)
            .map(|(&c, &fate)| match fate {
                Fate::Dead => None,
                Fate::Stalled if !commit_with_cancel => None,
                fate => {
                    let rep = outs.next().expect("one report per live member");
                    if fate == Fate::Fast && landed < quota {
                        landed += 1;
                        rep
                    } else {
                        cancelled.push(c);
                        None
                    }
                }
            })
            .collect())
    }

    fn exchange(
        &mut self,
        requests: Option<&[Vec<u32>]>,
        cohort: &[usize],
    ) -> Result<Vec<Option<SparseVec>>> {
        // phase-2 chaos: a client can also die between its report and
        // its upload, like a TCP stream resetting mid-exchange
        let mut live = Vec::with_capacity(cohort.len());
        let mut live_requests = requests.map(|_| Vec::with_capacity(cohort.len()));
        let mut fate = Vec::with_capacity(cohort.len());
        for (p, &c) in cohort.iter().enumerate() {
            let up = self.alive[c] && !self.drops_now(c);
            fate.push(up);
            if up {
                live.push(c);
                if let (Some(out), Some(reqs)) = (live_requests.as_mut(), requests) {
                    out.push(reqs[p].clone());
                }
            }
        }
        let mut outs = self
            .inner
            .exchange(live_requests.as_deref(), &live)?
            .into_iter();
        Ok(fate
            .into_iter()
            .map(|up| if up { outs.next().expect("one update per live member") } else { None })
            .collect())
    }

    fn backend(&mut self) -> &mut dyn Backend {
        self.inner.backend()
    }
}

/// Generation context handed to property bodies.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn vec_f32(&mut self, len: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        self.rng.fill_gaussian(&mut v, std);
        v
    }

    pub fn vec_u32_distinct(&mut self, n: usize, k: usize) -> Vec<u32> {
        self.rng.choose_k(n, k).into_iter().map(|x| x as u32).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }
}

/// Run a full multi-process-style deployment on localhost threads: bind
/// an ephemeral port **per shard** (one for the flat topology), start the
/// PS on them, connect `cfg.n_clients` workers (each to its shard's
/// port), return the PS report. Listeners are bound **before** any worker
/// spawns, so worker joins queue in the accept backlog — no sleeps, no
/// port races. Shared by the transport integration and sim/distributed
/// parity tests.
pub fn run_distributed_localhost(
    cfg: &crate::config::ExperimentConfig,
) -> anyhow::Result<crate::fl::distributed::ServeReport> {
    use crate::coordinator::topology::{locate, Topology};
    use crate::fl::distributed::{run_server_on, run_sharded_server_on, run_worker};
    let shards = cfg.topology.n_shards();
    let mut listeners = Vec::with_capacity(shards);
    let mut ports = Vec::with_capacity(shards);
    for _ in 0..shards {
        let l = std::net::TcpListener::bind("127.0.0.1:0")?;
        ports.push(l.local_addr()?.port());
        listeners.push(l);
    }
    let server_cfg = cfg.clone();
    let server = std::thread::spawn(move || {
        if server_cfg.topology == Topology::Flat {
            run_server_on(&server_cfg, listeners.pop().expect("one listener"))
        } else {
            run_sharded_server_on(&server_cfg, listeners)
        }
    });
    let mut workers = Vec::new();
    for id in 0..cfg.n_clients {
        let wcfg = cfg.clone();
        let shard = if shards > 1 { locate(cfg.n_clients, shards, id).0 } else { 0 };
        let addr = format!("127.0.0.1:{}", ports[shard]);
        workers.push(std::thread::spawn(move || run_worker(&wcfg, &addr, id)));
    }
    let report = server.join().expect("server thread panicked")?;
    for w in workers {
        w.join().expect("worker thread panicked")?;
    }
    Ok(report)
}

/// Run `body` over `cases` generated cases; panics with the case number
/// and seed on the first failure (re-run with `RAGEK_PROP_SEED=<seed>`).
pub fn prop_check(name: &str, cases: usize, mut body: impl FnMut(&mut Gen) -> Result<(), String>) {
    let base_seed: u64 = std::env::var("RAGEK_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xA9E5_EED);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut g = Gen { rng: Rng::new(seed), case };
        if let Err(msg) = body(&mut g) {
            panic!("property '{name}' failed at case {case} (seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::RoundEngine;

    /// Drive `rounds` chaos rounds, returning the global model and the
    /// per-client age vectors (the membership protocol's full surface).
    fn drive_chaos(
        cfg: &ExperimentConfig,
        pool: &mut dyn ClientPool,
        init: Vec<f32>,
        rounds: usize,
    ) -> (Vec<f32>, Vec<Vec<u32>>) {
        let mut engine = RoundEngine::new(cfg, init);
        for _ in 0..rounds {
            engine.run_round(pool).unwrap();
        }
        let ages = (0..cfg.n_clients)
            .map(|i| engine.ps().clusters().age_of_client(i).to_vec())
            .collect();
        (engine.global_params().to_vec(), ages)
    }

    /// Drop/stall/rejoin chaos through the compact client store is
    /// bit-for-bit the dense run: same casualties, same rejoin rounds,
    /// same ages, same global trajectory. The chaos RNG draws depend
    /// only on cohort composition and liveness, so any divergence in
    /// the compact materialize/resync/arena lifecycle would cascade
    /// into different verdicts and fail loudly here.
    #[test]
    fn compact_chaos_matches_dense_oracle() {
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.rounds = 8;
        cfg.participation = 0.75; // cohort of 3 out of 4
        let (drop_rate, rejoin_after, seed) = (0.35, 2, 0xC1A05);

        let (mut dense, init_d) = FlakyPool::new(&cfg, drop_rate, rejoin_after, seed).unwrap();
        let (mut compact, init_c) =
            FlakyPool::new_compact(&cfg, drop_rate, rejoin_after, seed).unwrap();
        assert_eq!(init_d, init_c);
        dense.set_stall_rate(0.25);
        compact.set_stall_rate(0.25);
        dense.set_handshake_stall_rate(0.5);
        compact.set_handshake_stall_rate(0.5);

        let (gd, ages_d) = drive_chaos(&cfg, &mut dense, init_d, cfg.rounds);
        let (gc, ages_c) = drive_chaos(&cfg, &mut compact, init_c, cfg.rounds);
        assert_eq!(ages_d, ages_c, "ages pinned to the dense oracle");
        assert_eq!(gd, gc, "global params must match exactly");
        assert_eq!(dense.n_down(), compact.n_down());
        assert_eq!(dense.n_handshake_stalls(), compact.n_handshake_stalls());
        assert_eq!(dense.health(), compact.health());
        // the chaos actually churned the compact lifecycle
        assert!(
            compact.inner().n_live() > 0,
            "chaos rounds should have materialized scheduled clients"
        );
    }

    #[test]
    fn passes_good_property() {
        prop_check("sum-commutes", 50, |g| {
            let a = g.f32_in(-10.0, 10.0);
            let b = g.f32_in(-10.0, 10.0);
            if a + b == b + a {
                Ok(())
            } else {
                Err(format!("{a} + {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn reports_failure() {
        prop_check("always-fails", 5, |_| Err("nope".to_string()));
    }

    #[test]
    fn generators_in_bounds() {
        prop_check("gen-bounds", 100, |g| {
            let x = g.usize_in(3, 9);
            if !(3..=9).contains(&x) {
                return Err(format!("usize_in out of range: {x}"));
            }
            let v = g.vec_u32_distinct(50, 10);
            let set: std::collections::HashSet<_> = v.iter().collect();
            if set.len() != 10 {
                return Err("duplicates".into());
            }
            Ok(())
        });
    }
}
