//! Experiment configuration: JSON-loadable, with the paper's §III-B
//! presets. Every example/bench builds on these so "run Fig. 3" is one
//! preset + one strategy flag.

use crate::clustering::{DbscanParams, MergeRule};
use crate::coordinator::scheduler::SchedulerKind;
use crate::coordinator::strategies::StrategyKind;
use crate::coordinator::topology::Topology;
use crate::data::partition::Scheme;
use crate::data::Corpus;
use crate::fl::codec::Codec;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Which compute backend trains the clients.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust MLP (MNIST only; artifact-free)
    Rust,
    /// PJRT execution of the AOT HLO artifacts (both models)
    Xla,
}

/// What the sparse upload carries (DESIGN.md §5 — the paper's Algorithm 1
/// says "gradient" but its convergence argument leans on Qsparse-local-SGD
/// [7], which sparsifies accumulated local *updates*; both readings are
/// implemented).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Payload {
    /// sparsified model delta (theta_i after H steps - global theta),
    /// server applies the mean — the reading that actually converges at
    /// the paper's hyper-parameters (default)
    Delta,
    /// paper-literal: the last local step's gradient, applied by the
    /// server optimizer (Adam on the aggregated sum)
    Grad,
}

/// How the PS ships the global model to cohort members each round
/// (DESIGN.md §9).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Downlink {
    /// full dense `Model` frame every round (the PR-5 behavior; byte-
    /// identical wire traffic, the default)
    #[default]
    Dense,
    /// generation-addressed sparse `Delta` frames against each client's
    /// last-acked model generation, with digest verification and a
    /// dense fallback when the generation gap is unbridgeable (or the
    /// dense frame is smaller). Bit-for-bit identical model trajectory
    /// — only the wire bytes change (pinned in rust/tests/parity.rs).
    Delta,
}

impl Downlink {
    pub fn name(self) -> &'static str {
        match self {
            Downlink::Dense => "dense",
            Downlink::Delta => "delta",
        }
    }

    pub fn parse(s: &str) -> Option<Downlink> {
        match s {
            "dense" => Some(Downlink::Dense),
            "delta" => Some(Downlink::Delta),
            _ => None,
        }
    }
}

/// How the in-process simulator stores per-client state (DESIGN.md §12).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ClientStore {
    /// every client fully materialized — three d-sized vectors each
    /// (params + Adam moments), ~470 KB/client for the MNIST MLP. The
    /// default; fine up to a few thousand clients.
    #[default]
    Dense,
    /// fleet-scale compact slots ([`crate::fl::CompactPool`]): a client
    /// holds zero model floats until the first round it is scheduled,
    /// so 10⁴–10⁶ mostly-idle clients fit in memory. Bit-for-bit
    /// identical trajectories (rust/src/fl/compact.rs parity pins);
    /// flat topology only.
    Compact,
}

impl ClientStore {
    pub fn name(self) -> &'static str {
        match self {
            ClientStore::Dense => "dense",
            ClientStore::Compact => "compact",
        }
    }

    pub fn parse(s: &str) -> Option<ClientStore> {
        match s {
            "dense" => Some(ClientStore::Dense),
            "compact" => Some(ClientStore::Compact),
            _ => None,
        }
    }
}

/// What "accuracy averaged over all users" (Fig. 3/5) evaluates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalMode {
    /// mean over clients of their post-local-round model on the test
    /// samples matching their own label distribution (the paper's
    /// per-user average)
    Personal,
    /// the server's global model on the full test set
    Global,
}

#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// registry name: "mnist" | "cifar"
    pub model: String,
    pub corpus: Corpus,
    pub backend: BackendKind,
    pub strategy: StrategyKind,

    pub n_clients: usize,
    /// fraction of clients polled per round (0 < p <= 1; 1.0 = everyone).
    /// The per-round cohort has ceil(p * n_clients) members; off-cohort
    /// clients skip the round entirely and their cluster ages keep
    /// growing per eq. (2).
    pub participation: f64,
    /// cohort policy under partial participation (ignored at p = 1.0,
    /// where every policy selects all clients)
    pub scheduler: SchedulerKind,
    /// PS layout: one monolithic engine (`Flat`, the default) or a
    /// two-level hierarchy of shard engines under a root aggregator
    /// (DESIGN.md §7). `Sharded { shards: 1 }` is pinned bit-for-bit
    /// identical to `Flat`. Config/CLI knob `shards` (0 = flat).
    pub topology: Topology,
    /// PS-side per-connection, per-phase reactor deadline in
    /// milliseconds (0 = none, the default; DESIGN.md §10). With a
    /// deadline set, a worker that has not finished the current
    /// write/reply phase within the window surfaces as a clean
    /// per-connection casualty (the round finishes with the survivors)
    /// instead of wedging the collect phase forever — and unlike the
    /// old per-syscall socket timeout, a byte-trickling peer cannot
    /// keep resetting the clock. Also applied as a blocking socket
    /// timeout to the join/rejoin handshakes. The worker side never
    /// sets timeouts (off-cohort workers block across whole rounds by
    /// design). Must comfortably exceed the local training time of one
    /// round.
    pub io_timeout_ms: u64,
    /// Speculative over-scheduling ε (DESIGN.md §11): the scheduler
    /// selects `m + ε` cohort members each round and the round commits
    /// as soon as the first `m` reports land; the ε stragglers are
    /// cancelled cleanly (not casualties — their clusters age exactly
    /// like off-cohort absence). 0 (the default) disables speculation
    /// and is bit-for-bit identical to the non-speculative path.
    pub overschedule: usize,
    /// Adaptive per-connection deadline factor k (DESIGN.md §11): with
    /// k > 0, each connection's per-phase deadline becomes
    /// `clamp(ewma_rtt · k, deadline_min_ms, io_timeout_ms)` where the
    /// EWMA tracks that client's observed phase round-trips, with one
    /// bounded retry (deadline re-armed once) before the client is
    /// dropped and degrades toward `Suspect`. 0 (the default) disables
    /// adaptive deadlines — every connection gets the flat
    /// `io_timeout_ms` window.
    pub deadline_factor: f64,
    /// Floor for adaptive deadlines in milliseconds, so a fast client's
    /// EWMA can never shrink its window below a sane minimum. Only
    /// consulted when `deadline_factor > 0`.
    pub deadline_min_ms: u64,
    /// Dynamic re-sharding (sharded topologies only, default on): at
    /// each root recluster boundary, re-partition the fleet across shard
    /// pools with `ClusterManager::shard_slices` so the assignment
    /// tracks the evolving clustering (DESIGN.md §8). Off = keep the
    /// static contiguous assignment (clusters spanning shards are then
    /// split per shard with cloned age vectors).
    pub reshard: bool,
    /// wire codec: `raw` (v1, 8 B per sparse entry) | `packed` (v2,
    /// delta+varint indices, lossless) | `packed-f16` (v2 + binary16
    /// update values, lossy). Negotiated at `Join` time — PS and workers
    /// must agree. Affects frame bytes (`CommStats::wire_*`), never the
    /// protocol semantics; `packed` runs are bit-for-bit identical to
    /// `raw` (rust/tests/parity.rs).
    pub codec: Codec,
    /// downlink broadcast mode: `dense` (full `Model` frame, default) |
    /// `delta` (generation-addressed sparse broadcasts, DESIGN.md §9).
    /// Like `codec`, this only changes bytes on the wire — never the
    /// model trajectory.
    pub downlink: Downlink,
    pub r: usize,
    pub k: usize,
    /// local iterations per global round (paper H)
    pub h: usize,
    /// recluster period in global rounds (paper M)
    pub recluster_every: usize,
    pub batch: usize,
    /// number of global rounds to run
    pub rounds: usize,
    pub lr_client: f32,
    pub lr_server: f32,
    /// server optimizer: "adam" | "sgd"
    pub server_opt: String,

    pub payload: Payload,
    pub eval_mode: EvalMode,

    pub partition: Scheme,
    pub dbscan: DbscanParams,
    pub merge_rule: MergeRule,

    pub seed: u64,
    pub train_n: usize,
    pub test_n: usize,
    /// evaluate the global model every this many rounds (0 = only at end)
    pub eval_every: usize,
    /// in-process client concurrency: lanes of the parallel pool
    /// (0 = auto-detect from available cores; 1 = serial). Purely a
    /// throughput knob — results are identical at any setting. Under a
    /// sharded topology this is **per shard** (auto divides the cores by
    /// the shard count, so `0` fills the machine exactly once).
    pub parallel: usize,
    /// per-client state storage in the in-process simulator: `dense`
    /// (every client fully materialized, the default) | `compact`
    /// (fleet-scale slots — only ever-scheduled clients hold model
    /// floats; flat topology only). Never changes results, only memory.
    pub client_store: ClientStore,
    pub data_dir: String,
    pub artifacts_dir: String,
}

impl ExperimentConfig {
    /// The paper's MNIST setup (§III-B): 10 clients, paired labels,
    /// r=75, k=10, H=4, M=20, Adam 1e-4, batch 256.
    pub fn mnist_paper() -> Self {
        ExperimentConfig {
            model: "mnist".into(),
            corpus: Corpus::Mnist,
            backend: BackendKind::Rust,
            strategy: StrategyKind::RageK,
            n_clients: 10,
            participation: 1.0,
            scheduler: SchedulerKind::RoundRobin,
            topology: Topology::Flat,
            io_timeout_ms: 0,
            overschedule: 0,
            deadline_factor: 0.0,
            deadline_min_ms: 50,
            reshard: true,
            codec: Codec::Raw,
            downlink: Downlink::Dense,
            r: 75,
            k: 10,
            h: 4,
            recluster_every: 20,
            batch: 256,
            rounds: 150,
            lr_client: 1e-4,
            // the paper's 1e-4 is the *client* Adam; it leaves the server
            // update unspecified. Server Adam at 1e-2 is the smallest rate
            // at which the k-sparse global model trains at all on this
            // testbed (EXPERIMENTS.md §Interpretation).
            lr_server: 1e-2,
            server_opt: "adam".into(),
            payload: Payload::Grad,
            eval_mode: EvalMode::Global,
            partition: Scheme::PaperPairs,
            dbscan: DbscanParams::default(),
            merge_rule: MergeRule::Min,
            seed: 42,
            train_n: 4000,
            test_n: 1000,
            eval_every: 5,
            parallel: 0,
            client_store: ClientStore::Dense,
            data_dir: "data".into(),
            artifacts_dir: "artifacts".into(),
        }
    }

    /// MNIST preset time-scaled for the CPU testbed: client lr 1e-3
    /// compresses the paper's training horizon ~10x so the Fig. 2/3
    /// shapes land within ~100 rounds (documented in EXPERIMENTS.md).
    pub fn mnist_scaled() -> Self {
        let mut c = Self::mnist_paper();
        c.lr_client = 1e-3;
        c
    }

    /// The paper's CIFAR10 setup (§III-B): 6 clients, 3/3/4 label blocks,
    /// r=2500, k=100, H=100, M=200, Adam 1e-4. Batch/rounds are reduced
    /// for the CPU testbed (documented in EXPERIMENTS.md); pass the real
    /// values to reproduce at paper scale on capable hardware.
    pub fn cifar_paper() -> Self {
        ExperimentConfig {
            model: "cifar".into(),
            corpus: Corpus::Cifar10,
            backend: BackendKind::Xla,
            strategy: StrategyKind::RageK,
            n_clients: 6,
            participation: 1.0,
            scheduler: SchedulerKind::RoundRobin,
            topology: Topology::Flat,
            io_timeout_ms: 0,
            overschedule: 0,
            deadline_factor: 0.0,
            deadline_min_ms: 50,
            reshard: true,
            codec: Codec::Raw,
            downlink: Downlink::Dense,
            r: 2500,
            k: 100,
            h: 8,               // paper: 100
            recluster_every: 8, // paper: 200; scaled with H
            batch: 64,          // paper: 256
            rounds: 30,
            lr_client: 1e-3, // paper: 1e-4; time-scaled like mnist_scaled
            lr_server: 1e-2, // see mnist_paper note
            server_opt: "adam".into(),
            payload: Payload::Grad,
            eval_mode: EvalMode::Global,
            partition: Scheme::PaperPairs,
            dbscan: DbscanParams::default(),
            merge_rule: MergeRule::Min,
            seed: 42,
            train_n: 1800,
            test_n: 600,
            eval_every: 5,
            parallel: 0,
            client_store: ClientStore::Dense,
            data_dir: "data".into(),
            artifacts_dir: "artifacts".into(),
        }
    }

    /// Small fast config for tests/CI.
    pub fn mnist_smoke() -> Self {
        let mut c = Self::mnist_scaled();
        c.n_clients = 4;
        c.rounds = 12;
        c.batch = 32;
        c.recluster_every = 4;
        c.train_n = 400;
        c.test_n = 200;
        c.r = 40;
        c.k = 8;
        c.eval_every = 3;
        c
    }

    pub fn d(&self) -> usize {
        match self.model.as_str() {
            "mnist" => 39760,
            "cifar" => 2515338,
            _ => 0,
        }
    }

    pub fn input_dim(&self) -> usize {
        match self.corpus {
            Corpus::Mnist => 784,
            Corpus::Cifar10 => 3072,
        }
    }

    /// Clients polled per round: ceil(participation * n), clamped to
    /// [1, n] so a round always has at least one participant.
    pub fn cohort_size(&self) -> usize {
        let m = (self.participation * self.n_clients as f64).ceil() as usize;
        m.clamp(1, self.n_clients)
    }

    /// Clients actually scheduled per round under speculation:
    /// `m + overschedule`, capped at the fleet size. Equal to
    /// [`cohort_size`](Self::cohort_size) when `overschedule = 0`.
    pub fn scheduled_cohort_size(&self) -> usize {
        (self.cohort_size() + self.overschedule).min(self.n_clients)
    }

    pub fn validate(&self) -> Result<()> {
        if self.k > self.r {
            bail!("k ({}) must be <= r ({})", self.k, self.r);
        }
        if self.r > self.d() {
            bail!("r ({}) must be <= d ({})", self.r, self.d());
        }
        if self.n_clients == 0 || self.rounds == 0 || self.h == 0 {
            bail!("n_clients, rounds and h must be positive");
        }
        if !(self.participation > 0.0 && self.participation <= 1.0) {
            bail!("participation ({}) must be in (0, 1]", self.participation);
        }
        if !(self.deadline_factor.is_finite() && self.deadline_factor >= 0.0) {
            bail!(
                "deadline_factor ({}) must be a finite value >= 0 (0 = adaptive deadlines off)",
                self.deadline_factor
            );
        }
        if self.topology.n_shards() > self.n_clients {
            bail!(
                "topology wants {} shards but there are only {} clients",
                self.topology.n_shards(),
                self.n_clients
            );
        }
        if self.topology.n_shards() > 1 && self.backend == BackendKind::Xla {
            // a process holds exactly one PJRT runtime; N shard pools in
            // the PS process would instantiate N (ROADMAP: XLA lane
            // replication)
            bail!("sharded topologies require the rust backend (one PJRT runtime per process)");
        }
        if self.partition == Scheme::PaperPairs && self.n_clients % 2 != 0 {
            bail!("PaperPairs partitioning needs an even client count");
        }
        if self.backend == BackendKind::Rust && self.model != "mnist" {
            bail!("the pure-Rust backend only implements the MNIST MLP");
        }
        if !matches!(self.server_opt.as_str(), "adam" | "sgd") {
            bail!("server_opt must be adam or sgd");
        }
        if self.client_store == ClientStore::Compact && self.topology.n_shards() > 1 {
            // shard pools own disjoint client slices with their own
            // dense arrays; the compact slot store is a flat-simulator
            // representation (DESIGN.md §12)
            bail!("client_store=compact requires the flat topology");
        }
        if self.downlink == Downlink::Delta
            && self.payload == Payload::Grad
            && self.server_opt != "sgd"
        {
            // a dense server optimizer (Adam moments) moves parameters
            // outside the uploaded index union, so the engine's
            // updated-indices ledger would no longer cover what changed
            bail!(
                "downlink=delta with payload=grad requires server_opt=sgd \
                 (a dense server optimizer changes parameters outside the \
                 uploaded index union)"
            );
        }
        Ok(())
    }

    // ------------------------------------------------------------- JSON
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            (
                "backend",
                Json::Str(match self.backend {
                    BackendKind::Rust => "rust".into(),
                    BackendKind::Xla => "xla".into(),
                }),
            ),
            ("strategy", Json::Str(match self.strategy {
                StrategyKind::RageK => "ragek",
                StrategyKind::RageKIndependent => "ragek-indep",
                StrategyKind::RTopK => "rtopk",
                StrategyKind::TopK => "topk",
                StrategyKind::RandK => "randk",
                StrategyKind::Dense => "dense",
            }.into())),
            ("n_clients", Json::Num(self.n_clients as f64)),
            ("participation", Json::Num(self.participation)),
            ("scheduler", Json::Str(self.scheduler.name().into())),
            ("shards", Json::Num(self.topology.shards_knob() as f64)),
            ("root_merge", Json::Str(match self.topology.root_merge() {
                MergeRule::Min => "min".into(),
                MergeRule::Max => "max".into(),
            })),
            ("io_timeout_ms", Json::Num(self.io_timeout_ms as f64)),
            ("overschedule", Json::Num(self.overschedule as f64)),
            ("deadline_factor", Json::Num(self.deadline_factor)),
            ("deadline_min_ms", Json::Num(self.deadline_min_ms as f64)),
            ("reshard", Json::Bool(self.reshard)),
            ("codec", Json::Str(self.codec.name().into())),
            ("downlink", Json::Str(self.downlink.name().into())),
            ("r", Json::Num(self.r as f64)),
            ("k", Json::Num(self.k as f64)),
            ("h", Json::Num(self.h as f64)),
            ("recluster_every", Json::Num(self.recluster_every as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("lr_client", Json::Num(self.lr_client as f64)),
            ("lr_server", Json::Num(self.lr_server as f64)),
            ("server_opt", Json::Str(self.server_opt.clone())),
            ("payload", Json::Str(match self.payload {
                Payload::Delta => "delta".into(),
                Payload::Grad => "grad".into(),
            })),
            ("eval_mode", Json::Str(match self.eval_mode {
                EvalMode::Personal => "personal".into(),
                EvalMode::Global => "global".into(),
            })),
            ("partition", Json::Str(match &self.partition {
                Scheme::PaperPairs => "paper-pairs".to_string(),
                Scheme::Dirichlet { alpha } => format!("dirichlet:{alpha}"),
                Scheme::Iid => "iid".to_string(),
            })),
            ("dbscan_eps", Json::Num(self.dbscan.eps)),
            ("dbscan_min_pts", Json::Num(self.dbscan.min_pts as f64)),
            ("merge_rule", Json::Str(match self.merge_rule {
                MergeRule::Min => "min".into(),
                MergeRule::Max => "max".into(),
            })),
            ("seed", Json::Num(self.seed as f64)),
            ("train_n", Json::Num(self.train_n as f64)),
            ("test_n", Json::Num(self.test_n as f64)),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("parallel", Json::Num(self.parallel as f64)),
            ("client_store", Json::Str(self.client_store.name().into())),
            ("data_dir", Json::Str(self.data_dir.clone())),
            ("artifacts_dir", Json::Str(self.artifacts_dir.clone())),
        ])
    }

    /// Load overrides on top of the model's paper preset.
    pub fn from_json(j: &Json) -> Result<Self> {
        let model = j.get("model").and_then(Json::as_str).unwrap_or("mnist");
        let mut c = match model {
            "mnist" => Self::mnist_paper(),
            "cifar" => Self::cifar_paper(),
            other => bail!("unknown model {other:?}"),
        };
        if let Some(s) = j.get("backend").and_then(Json::as_str) {
            c.backend = match s {
                "rust" => BackendKind::Rust,
                "xla" => BackendKind::Xla,
                other => bail!("unknown backend {other:?}"),
            };
        }
        if let Some(s) = j.get("strategy").and_then(Json::as_str) {
            c.strategy =
                StrategyKind::parse(s).with_context(|| format!("unknown strategy {s:?}"))?;
        }
        macro_rules! num {
            ($field:ident, $key:literal, $ty:ty) => {
                if let Some(x) = j.get($key).and_then(Json::as_f64) {
                    c.$field = x as $ty;
                }
            };
        }
        num!(n_clients, "n_clients", usize);
        num!(participation, "participation", f64);
        if let Some(s) = j.get("scheduler").and_then(Json::as_str) {
            c.scheduler = SchedulerKind::parse(s)
                .with_context(|| format!("unknown scheduler {s:?}"))?;
        }
        // like every other knob, absent keys keep the preset's topology;
        // either key alone updates just its half
        if j.get("shards").is_some() || j.get("root_merge").is_some() {
            let root_merge = match j.get("root_merge").and_then(Json::as_str) {
                None => c.topology.root_merge(),
                Some("min") => MergeRule::Min,
                Some("max") => MergeRule::Max,
                Some(other) => bail!("unknown root_merge {other:?}"),
            };
            let shards = j
                .get("shards")
                .and_then(Json::as_usize)
                .unwrap_or_else(|| c.topology.shards_knob());
            c.topology = Topology::from_shards(shards, root_merge);
        }
        num!(io_timeout_ms, "io_timeout_ms", u64);
        num!(overschedule, "overschedule", usize);
        num!(deadline_factor, "deadline_factor", f64);
        num!(deadline_min_ms, "deadline_min_ms", u64);
        if let Some(b) = j.get("reshard").and_then(Json::as_bool) {
            c.reshard = b;
        }
        if let Some(s) = j.get("codec").and_then(Json::as_str) {
            c.codec =
                Codec::parse(s).with_context(|| format!("unknown codec {s:?}"))?;
        }
        if let Some(s) = j.get("downlink").and_then(Json::as_str) {
            c.downlink =
                Downlink::parse(s).with_context(|| format!("unknown downlink {s:?}"))?;
        }
        num!(r, "r", usize);
        num!(k, "k", usize);
        num!(h, "h", usize);
        num!(recluster_every, "recluster_every", usize);
        num!(batch, "batch", usize);
        num!(rounds, "rounds", usize);
        num!(lr_client, "lr_client", f32);
        num!(lr_server, "lr_server", f32);
        num!(seed, "seed", u64);
        num!(train_n, "train_n", usize);
        num!(test_n, "test_n", usize);
        num!(eval_every, "eval_every", usize);
        num!(parallel, "parallel", usize);
        if let Some(s) = j.get("server_opt").and_then(Json::as_str) {
            c.server_opt = s.to_string();
        }
        if let Some(s) = j.get("payload").and_then(Json::as_str) {
            c.payload = match s {
                "delta" => Payload::Delta,
                "grad" => Payload::Grad,
                other => bail!("unknown payload {other:?}"),
            };
        }
        if let Some(s) = j.get("eval_mode").and_then(Json::as_str) {
            c.eval_mode = match s {
                "personal" => EvalMode::Personal,
                "global" => EvalMode::Global,
                other => bail!("unknown eval_mode {other:?}"),
            };
        }
        if let Some(s) = j.get("partition").and_then(Json::as_str) {
            c.partition = if s == "paper-pairs" {
                Scheme::PaperPairs
            } else if s == "iid" {
                Scheme::Iid
            } else if let Some(a) = s.strip_prefix("dirichlet:") {
                Scheme::Dirichlet { alpha: a.parse().context("dirichlet alpha")? }
            } else {
                bail!("unknown partition {s:?}")
            };
        }
        if let Some(x) = j.get("dbscan_eps").and_then(Json::as_f64) {
            c.dbscan.eps = x;
        }
        if let Some(x) = j.get("dbscan_min_pts").and_then(Json::as_usize) {
            c.dbscan.min_pts = x;
        }
        if let Some(s) = j.get("merge_rule").and_then(Json::as_str) {
            c.merge_rule = match s {
                "min" => MergeRule::Min,
                "max" => MergeRule::Max,
                other => bail!("unknown merge_rule {other:?}"),
            };
        }
        if let Some(s) = j.get("client_store").and_then(Json::as_str) {
            c.client_store =
                ClientStore::parse(s).with_context(|| format!("unknown client_store {s:?}"))?;
        }
        if let Some(s) = j.get("data_dir").and_then(Json::as_str) {
            c.data_dir = s.to_string();
        }
        if let Some(s) = j.get("artifacts_dir").and_then(Json::as_str) {
            c.artifacts_dir = s.to_string();
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_valid_and_match_paper() {
        let m = ExperimentConfig::mnist_paper();
        m.validate().unwrap();
        assert_eq!((m.n_clients, m.r, m.k, m.h, m.recluster_every), (10, 75, 10, 4, 20));
        assert_eq!(m.d(), 39760);
        let c = ExperimentConfig::cifar_paper();
        c.validate().unwrap();
        assert_eq!((c.n_clients, c.r, c.k), (6, 2500, 100));
        assert_eq!(c.d(), 2515338);
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = ExperimentConfig::mnist_paper();
        cfg.strategy = StrategyKind::RTopK;
        cfg.partition = Scheme::Dirichlet { alpha: 0.25 };
        cfg.rounds = 7;
        cfg.parallel = 3;
        cfg.participation = 0.3;
        cfg.scheduler = SchedulerKind::AgeDebt;
        cfg.codec = Codec::PackedF16;
        cfg.downlink = Downlink::Delta;
        cfg.payload = Payload::Delta; // delta downlink + grad would need server sgd
        cfg.topology = Topology::Sharded { shards: 3, root_merge: MergeRule::Max };
        cfg.io_timeout_ms = 1500;
        cfg.overschedule = 2;
        cfg.deadline_factor = 2.5;
        cfg.deadline_min_ms = 75;
        cfg.reshard = false;
        let j = cfg.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.strategy, StrategyKind::RTopK);
        assert_eq!(back.partition, Scheme::Dirichlet { alpha: 0.25 });
        assert_eq!(back.rounds, 7);
        assert_eq!(back.batch, cfg.batch);
        assert_eq!(back.parallel, 3);
        assert_eq!(back.participation, 0.3);
        assert_eq!(back.scheduler, SchedulerKind::AgeDebt);
        assert_eq!(back.codec, Codec::PackedF16);
        assert_eq!(back.downlink, Downlink::Delta);
        assert_eq!(
            ExperimentConfig::mnist_paper().downlink,
            Downlink::Dense,
            "the downlink defaults dense"
        );
        assert_eq!(back.topology, cfg.topology);
        assert_eq!(back.io_timeout_ms, 1500);
        assert_eq!(back.overschedule, 2);
        assert_eq!(back.deadline_factor, 2.5);
        assert_eq!(back.deadline_min_ms, 75);
        assert_eq!(
            ExperimentConfig::mnist_paper().overschedule,
            0,
            "speculation defaults off: overschedule = 0 is the non-speculative path"
        );
        assert_eq!(
            ExperimentConfig::mnist_paper().deadline_factor,
            0.0,
            "adaptive deadlines default off"
        );
        assert!(!back.reshard);
        assert!(ExperimentConfig::mnist_paper().reshard, "re-sharding defaults on");
        // the default stays flat
        assert_eq!(ExperimentConfig::mnist_paper().topology, Topology::Flat);
        assert_eq!(
            ExperimentConfig::mnist_paper().client_store,
            ClientStore::Dense,
            "the client store defaults dense"
        );
        // compact round-trips (on a flat config — compact is flat-only)
        let mut cfg = ExperimentConfig::mnist_paper();
        cfg.client_store = ClientStore::Compact;
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.client_store, ClientStore::Compact);
    }

    #[test]
    fn cohort_size_rounds_up_and_clamps() {
        let mut cfg = ExperimentConfig::mnist_paper(); // 10 clients
        assert_eq!(cfg.cohort_size(), 10);
        cfg.participation = 0.5;
        assert_eq!(cfg.cohort_size(), 5);
        cfg.participation = 0.31; // ceil(3.1) = 4
        assert_eq!(cfg.cohort_size(), 4);
        cfg.participation = 0.01; // never below one client
        assert_eq!(cfg.cohort_size(), 1);
    }

    #[test]
    fn scheduled_cohort_size_adds_epsilon_and_caps_at_n() {
        let mut cfg = ExperimentConfig::mnist_paper(); // 10 clients
        cfg.participation = 0.5; // m = 5
        assert_eq!(cfg.scheduled_cohort_size(), 5, "epsilon = 0 schedules exactly m");
        cfg.overschedule = 2;
        assert_eq!(cfg.scheduled_cohort_size(), 7);
        cfg.overschedule = 100; // can never schedule more clients than exist
        assert_eq!(cfg.scheduled_cohort_size(), 10);
        cfg.participation = 1.0; // full participation leaves no one to speculate on
        assert_eq!(cfg.scheduled_cohort_size(), 10);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = ExperimentConfig::mnist_paper();
        c.k = c.r + 1;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::mnist_paper();
        c.n_clients = 7; // odd with PaperPairs
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::cifar_paper();
        c.backend = BackendKind::Rust; // no rust CNN
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::mnist_paper();
        c.server_opt = "adagrad".into();
        assert!(c.validate().is_err());
        // delta downlink needs a sparse server update: grad+adam moves
        // parameters outside the uploaded index union
        let mut c = ExperimentConfig::mnist_paper(); // payload=grad, adam
        c.downlink = Downlink::Delta;
        assert!(c.validate().is_err());
        c.server_opt = "sgd".into();
        assert!(c.validate().is_ok());
        let mut c = ExperimentConfig::mnist_paper();
        c.downlink = Downlink::Delta;
        c.payload = Payload::Delta; // mean-drift apply is index-sparse
        assert!(c.validate().is_ok());
        let mut c = ExperimentConfig::mnist_paper();
        c.participation = 0.0;
        assert!(c.validate().is_err());
        c.participation = 1.5;
        assert!(c.validate().is_err());
        c.participation = 0.2;
        assert!(c.validate().is_ok());
        c.deadline_factor = -1.0;
        assert!(c.validate().is_err());
        c.deadline_factor = f64::NAN;
        assert!(c.validate().is_err());
        c.deadline_factor = 3.0;
        assert!(c.validate().is_ok());
        // more shards than clients is rejected; equal is fine
        c.topology = Topology::Sharded { shards: 11, root_merge: MergeRule::Min };
        assert!(c.validate().is_err());
        c.topology = Topology::Sharded { shards: 10, root_merge: MergeRule::Min };
        assert!(c.validate().is_ok());
        // sharding needs replicable backends: one PJRT runtime per process
        let mut c = ExperimentConfig::cifar_paper(); // backend = xla
        c.topology = Topology::Sharded { shards: 2, root_merge: MergeRule::Min };
        assert!(c.validate().is_err());
        c.topology = Topology::Sharded { shards: 1, root_merge: MergeRule::Min };
        assert!(c.validate().is_ok(), "a single shard never replicates the runtime");
        // the compact client store is a flat-simulator representation
        let mut c = ExperimentConfig::mnist_paper();
        c.client_store = ClientStore::Compact;
        assert!(c.validate().is_ok());
        c.topology = Topology::Sharded { shards: 2, root_merge: MergeRule::Min };
        assert!(c.validate().is_err());
    }

    #[test]
    fn from_json_rejects_unknown_enums() {
        let j = Json::parse(r#"{"model": "mnist", "strategy": "bogus"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"model": "vgg"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"model": "mnist", "scheduler": "fifo"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"model": "mnist", "codec": "zstd"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"model": "mnist", "codec": "packed"}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().codec, Codec::Packed);
        let j = Json::parse(r#"{"model": "mnist", "downlink": "gzip"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j =
            Json::parse(r#"{"model": "mnist", "downlink": "delta", "payload": "delta"}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().downlink, Downlink::Delta);
        let j = Json::parse(r#"{"model": "mnist", "root_merge": "avg"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"model": "mnist", "client_store": "sparse"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"model": "mnist", "client_store": "compact"}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().client_store, ClientStore::Compact);
        let j = Json::parse(r#"{"model": "mnist", "shards": 2}"#).unwrap();
        assert_eq!(
            ExperimentConfig::from_json(&j).unwrap().topology,
            Topology::Sharded { shards: 2, root_merge: MergeRule::Min }
        );
    }
}
