//! Compute backends: where client training actually runs.
//!
//! * [`RustBackend`] — the pure-Rust MLP (`nn::mlp`): artifact-free,
//!   fast for the simulator, and the numerics oracle. `Send`, stateless
//!   between calls, and cheap to instantiate — so the in-process pool can
//!   hold one per worker thread ([`BackendLanes::Parallel`]) and train
//!   clients concurrently.
//! * `XlaBackend` — executes the AOT HLO artifacts via PJRT
//!   ([`crate::runtime`]); the production path, required for the CNN.
//!   Gated behind the `xla-runtime` cargo feature (the PJRT bindings are
//!   an optional dependency); a process holds exactly one runtime, so the
//!   pool drives it serially ([`BackendLanes::Serial`]).
//!
//! Both expose the same [`Backend`] trait so the FL engine, examples and
//! benches are backend-agnostic. Parameter layouts, Adam constants and
//! the top-r tie-breaking contract are identical across the two (pinned
//! by `rust/tests/integration_runtime.rs`).

use crate::config::{BackendKind, ExperimentConfig};
use crate::coordinator::aggregator::Aggregate;
use crate::nn::adam::AdamState;
use crate::nn::mlp;
use crate::sparse::{topk_abs_sparse, SparseVec};
use anyhow::{bail, Result};

/// Per-client training state (flat params + Adam moments).
#[derive(Debug, Clone)]
pub struct ClientState {
    pub params: Vec<f32>,
    pub adam: AdamState,
}

impl ClientState {
    pub fn new(params: Vec<f32>) -> Self {
        let d = params.len();
        ClientState { params, adam: AdamState::new(d) }
    }

    /// Algorithm 1 line 12: adopt the broadcast global model (local
    /// optimizer state persists across rounds).
    pub fn sync_to(&mut self, global: &[f32]) {
        self.params.copy_from_slice(global);
    }
}

/// Global (server) model state.
#[derive(Debug, Clone)]
pub struct GlobalState {
    pub params: Vec<f32>,
    pub adam: AdamState,
}

impl GlobalState {
    pub fn new(params: Vec<f32>) -> Self {
        let d = params.len();
        GlobalState { params, adam: AdamState::new(d) }
    }
}

/// Result of one client's local round (H local steps).
#[derive(Debug)]
pub struct LocalRoundOut {
    pub mean_loss: f32,
    /// top-r report of the last local gradient: indices ordered by |g|
    /// desc with the signed values (so the PS request is answerable from
    /// the report alone)
    pub report: SparseVec,
}

pub trait Backend {
    fn d(&self) -> usize;

    /// Initial global parameters (deterministic).
    fn init_params(&mut self) -> Result<Vec<f32>>;

    /// Run `h` local Adam steps on batches (xs: [h*b*input_dim],
    /// ys: [h*b]) and report the top-r of the final gradient.
    fn local_round(
        &mut self,
        state: &mut ClientState,
        xs: &[f32],
        ys: &[i32],
        h: usize,
        b: usize,
    ) -> Result<LocalRoundOut>;

    /// Dense gradient at `params` (rand-k / dense baselines).
    fn dense_grad(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(Vec<f32>, f32)>;

    /// (loss_sum, correct) over one batch.
    fn eval(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, usize)>;

    /// Server-side apply of the aggregated update (Adam, lr_server).
    fn server_apply(
        &mut self,
        global: &mut GlobalState,
        agg: &Aggregate,
        scale: f32,
        lr: f32,
    ) -> Result<()>;
}

/// A backend that may cross a thread boundary (one per parallel pool lane).
pub type SendBackend = Box<dyn Backend + Send>;

/// The client-compute lanes of the in-process pool: either one shared
/// backend driven serially, or one `Send` backend per lane so clients
/// train concurrently on scoped threads.
pub enum BackendLanes {
    /// A single backend multiplexed over all clients in client order
    /// (XLA: exactly one PJRT runtime per process).
    Serial(Box<dyn Backend>),
    /// One independent backend per worker thread (pure Rust: stateless,
    /// so per-lane instances are numerically identical to one shared
    /// instance).
    Parallel(Vec<SendBackend>),
}

impl BackendLanes {
    /// Number of clients that can train concurrently.
    pub fn n_lanes(&self) -> usize {
        match self {
            BackendLanes::Serial(_) => 1,
            BackendLanes::Parallel(v) => v.len(),
        }
    }

    /// The lane used for PS-side work (server apply, eval, init).
    pub fn primary(&mut self) -> &mut dyn Backend {
        match self {
            BackendLanes::Serial(b) => b.as_mut(),
            BackendLanes::Parallel(v) => v[0].as_mut(),
        }
    }
}

/// Abstraction over a pool's lane storage, so
/// [`crate::fl::pool::InProcessPool`] can be generic over it:
/// [`BackendLanes`] supports every backend but is `!Send` (the XLA serial
/// lane pins its PJRT runtime to the constructing thread), while a bare
/// `Vec<SendBackend>` — all-parallel lanes — makes the whole pool `Send`,
/// which is what lets a sharded topology drive one pool per shard on
/// scoped threads.
pub trait Lanes {
    /// Number of clients that can train concurrently.
    fn n_lanes(&self) -> usize;

    /// The lane used for PS-side work (server apply, eval, init).
    fn primary(&mut self) -> &mut dyn Backend;

    /// Per-thread `Send` lanes when replication is available; `None`
    /// means the single [`Self::primary`] backend must be driven
    /// serially.
    fn parallel(&mut self) -> Option<&mut [SendBackend]>;
}

impl Lanes for BackendLanes {
    fn n_lanes(&self) -> usize {
        BackendLanes::n_lanes(self)
    }

    fn primary(&mut self) -> &mut dyn Backend {
        BackendLanes::primary(self)
    }

    fn parallel(&mut self) -> Option<&mut [SendBackend]> {
        match self {
            BackendLanes::Serial(_) => None,
            BackendLanes::Parallel(v) => Some(v.as_mut_slice()),
        }
    }
}

impl Lanes for Vec<SendBackend> {
    fn n_lanes(&self) -> usize {
        self.len()
    }

    fn primary(&mut self) -> &mut dyn Backend {
        self[0].as_mut()
    }

    fn parallel(&mut self) -> Option<&mut [SendBackend]> {
        Some(self.as_mut_slice())
    }
}

/// All-parallel `Send` lanes for backends that replicate (the pure-Rust
/// backend). Errors for XLA: a process holds exactly one PJRT runtime, so
/// an XLA pool cannot cross threads — use [`make_backend_lanes`] and a
/// flat topology there.
pub fn make_send_lanes(cfg: &ExperimentConfig, lanes: usize) -> Result<Vec<SendBackend>> {
    match cfg.backend {
        BackendKind::Rust => Ok((0..lanes.max(1))
            .map(|_| Box::new(RustBackend::new(cfg.r, cfg.lr_client, cfg.seed)) as SendBackend)
            .collect()),
        BackendKind::Xla => bail!(
            "the xla backend keeps a single non-Send PJRT runtime per process and \
             cannot be replicated across shard threads (ROADMAP: XLA lane \
             replication); run sharded topologies with the rust backend"
        ),
    }
}

/// Instantiate the backend an experiment config asks for.
pub fn make_backend(cfg: &ExperimentConfig) -> Result<Box<dyn Backend>> {
    match cfg.backend {
        BackendKind::Rust => Ok(Box::new(RustBackend::new(cfg.r, cfg.lr_client, cfg.seed))),
        BackendKind::Xla => make_xla_backend(cfg),
    }
}

/// Instantiate the client-compute lanes for the in-process pool. `lanes`
/// is the requested concurrency; backends that cannot be replicated
/// (XLA) fall back to a single serial lane.
pub fn make_backend_lanes(cfg: &ExperimentConfig, lanes: usize) -> Result<BackendLanes> {
    match cfg.backend {
        BackendKind::Rust => Ok(BackendLanes::Parallel(
            (0..lanes.max(1))
                .map(|_| {
                    Box::new(RustBackend::new(cfg.r, cfg.lr_client, cfg.seed)) as SendBackend
                })
                .collect(),
        )),
        BackendKind::Xla => Ok(BackendLanes::Serial(make_backend(cfg)?)),
    }
}

#[cfg(feature = "xla-runtime")]
fn make_xla_backend(cfg: &ExperimentConfig) -> Result<Box<dyn Backend>> {
    let mut be = XlaBackend::new(&cfg.artifacts_dir, &cfg.model, cfg.r)?;
    // Delta payload recomputes the report from the error-feedback
    // memory on the Rust side; skip the artifact's d log d top-r
    // sort (EXPERIMENTS.md §Perf)
    be.fast_round = cfg.payload == crate::config::Payload::Delta;
    Ok(Box::new(be))
}

#[cfg(not(feature = "xla-runtime"))]
fn make_xla_backend(_cfg: &ExperimentConfig) -> Result<Box<dyn Backend>> {
    bail!(
        "the 'xla' backend executes AOT PJRT artifacts and needs the \
         `xla-runtime` cargo feature: rebuild with `--features xla-runtime`"
    )
}

// ===================================================================== rust

/// Artifact-free backend: the MNIST MLP with hand-written backprop.
#[derive(Debug)]
pub struct RustBackend {
    r: usize,
    lr: f32,
    seed: u64,
}

impl RustBackend {
    pub fn new(r: usize, lr: f32, seed: u64) -> Self {
        RustBackend { r, lr, seed }
    }
}

impl Backend for RustBackend {
    fn d(&self) -> usize {
        mlp::D
    }

    fn init_params(&mut self) -> Result<Vec<f32>> {
        Ok(mlp::init(self.seed))
    }

    fn local_round(
        &mut self,
        state: &mut ClientState,
        xs: &[f32],
        ys: &[i32],
        h: usize,
        b: usize,
    ) -> Result<LocalRoundOut> {
        if xs.len() != h * b * mlp::IN || ys.len() != h * b {
            bail!("local_round: bad batch shapes");
        }
        let mut loss_sum = 0.0f32;
        let mut last_grad: Vec<f32> = Vec::new();
        for step in 0..h {
            let x = &xs[step * b * mlp::IN..(step + 1) * b * mlp::IN];
            let y = &ys[step * b..(step + 1) * b];
            let (loss, grad) = mlp::loss_and_grad(&state.params, x, y);
            state.adam.step(&mut state.params, &grad, self.lr);
            loss_sum += loss;
            if step + 1 == h {
                last_grad = grad;
            }
        }
        Ok(LocalRoundOut {
            mean_loss: loss_sum / h as f32,
            report: topk_abs_sparse(&last_grad, self.r),
        })
    }

    fn dense_grad(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(Vec<f32>, f32)> {
        let (loss, grad) = mlp::loss_and_grad(params, x, y);
        Ok((grad, loss))
    }

    fn eval(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, usize)> {
        Ok(mlp::evaluate(params, x, y))
    }

    fn server_apply(
        &mut self,
        global: &mut GlobalState,
        agg: &Aggregate,
        scale: f32,
        lr: f32,
    ) -> Result<()> {
        let update = agg.to_dense(global.params.len(), scale);
        global.adam.step(&mut global.params, &update, lr);
        Ok(())
    }
}

// ====================================================================== xla

#[cfg(feature = "xla-runtime")]
pub use xla_backend::XlaBackend;

#[cfg(feature = "xla-runtime")]
mod xla_backend {
    use super::{Aggregate, Backend, ClientState, GlobalState, LocalRoundOut};
    use crate::runtime::{lit_f32, lit_i32, lit_scalar, to_f32, to_i32, to_scalar, Runtime};
    use crate::sparse::{topk_abs_sparse, SparseVec};
    use anyhow::{bail, Result};

    /// PJRT-backed backend executing the AOT artifacts.
    pub struct XlaBackend {
        rt: Runtime,
        r: usize,
        /// use the report-free `local_round_fast` artifact (Delta payload)
        pub fast_round: bool,
    }

    impl std::fmt::Debug for XlaBackend {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("XlaBackend").field("model", &self.rt.model().name).finish()
        }
    }

    impl XlaBackend {
        pub fn new(artifacts_dir: &str, model: &str, r: usize) -> Result<Self> {
            let rt = Runtime::load(artifacts_dir, model)?;
            if r != rt.model().r {
                bail!(
                    "config r = {r} but artifacts were compiled with r = {} — \
                     re-run `make artifacts` with matching presets",
                    rt.model().r
                );
            }
            Ok(XlaBackend { rt, r, fast_round: false })
        }

        pub fn runtime(&self) -> &Runtime {
            &self.rt
        }

        /// The r this backend was compiled with (artifact-baked).
        pub fn r(&self) -> usize {
            self.r
        }
    }

    impl Backend for XlaBackend {
        fn d(&self) -> usize {
            self.rt.model().d
        }

        fn init_params(&mut self) -> Result<Vec<f32>> {
            self.rt.init_params()
        }

        fn local_round(
            &mut self,
            state: &mut ClientState,
            xs: &[f32],
            ys: &[i32],
            h: usize,
            b: usize,
        ) -> Result<LocalRoundOut> {
            let m = self.rt.model();
            let (hs, idim, d) = (m.h_scan, m.input_dim, m.d);
            if b != m.batch {
                bail!("xla backend: batch {b} != compiled batch {}", m.batch);
            }
            if h % hs != 0 {
                bail!("xla backend: h = {h} must be a multiple of h_scan = {hs}");
            }
            let chunks = h / hs;
            let arts = &self.rt.model().artifacts;
            let have_fast = arts.contains_key("local_round_fast");
            let have_grad = arts.contains_key("local_round_grad");
            let mut loss_acc = 0.0f32;
            let mut report = SparseVec::default();
            for c in 0..chunks {
                // only the LAST chunk's top-r report is consumed (Algorithm 1
                // sparsifies the final local gradient); earlier chunks — and
                // all chunks under fast_round — skip it entirely. For the
                // last chunk, prefer `local_round_grad` (dense gradient out +
                // Rust-side heap top-r) over the in-graph argsort of
                // `local_round`: ~200x cheaper on the pinned XLA CPU backend
                // (EXPERIMENTS.md §Perf).
                let last = c + 1 == chunks;
                let artifact = if have_fast && (self.fast_round || !last) {
                    "local_round_fast"
                } else if have_grad {
                    "local_round_grad"
                } else {
                    "local_round"
                };
                let xs_c = &xs[c * hs * b * idim..(c + 1) * hs * b * idim];
                let ys_c = &ys[c * hs * b..(c + 1) * hs * b];
                let outs = self.rt.call(
                    artifact,
                    &[
                        lit_f32(&state.params, &[d as i64])?,
                        lit_f32(&state.adam.m, &[d as i64])?,
                        lit_f32(&state.adam.v, &[d as i64])?,
                        lit_scalar(state.adam.t),
                        lit_f32(xs_c, &[hs as i64, b as i64, idim as i64])?,
                        lit_i32(ys_c, &[hs as i64, b as i64])?,
                    ],
                )?;
                state.params = to_f32(&outs[0])?;
                state.adam.m = to_f32(&outs[1])?;
                state.adam.v = to_f32(&outs[2])?;
                state.adam.t = to_scalar(&outs[3])?;
                loss_acc += to_scalar(&outs[4])?;
                if c + 1 == chunks && outs.len() == 6 {
                    // local_round_grad: dense last gradient out, top-r here
                    let grad = to_f32(&outs[5])?;
                    report = topk_abs_sparse(&grad, self.r);
                } else if c + 1 == chunks && outs.len() > 6 {
                    // local_round: in-graph (signed g[idx], idx) report,
                    // ordered by |g| desc — same contract as topk_abs_sparse
                    let vals = to_f32(&outs[5])?;
                    let idx: Vec<u32> =
                        to_i32(&outs[6])?.into_iter().map(|i| i as u32).collect();
                    report = SparseVec::new(idx, vals);
                }
            }
            Ok(LocalRoundOut { mean_loss: loss_acc / chunks as f32, report })
        }

        fn dense_grad(
            &mut self,
            params: &[f32],
            x: &[f32],
            y: &[i32],
        ) -> Result<(Vec<f32>, f32)> {
            let m = self.rt.model();
            let (b, idim, d) = (m.batch, m.input_dim, m.d);
            if y.len() != b {
                bail!("dense_grad: batch {} != compiled batch {b}", y.len());
            }
            let outs = self.rt.call(
                "grad",
                &[
                    lit_f32(params, &[d as i64])?,
                    lit_f32(x, &[b as i64, idim as i64])?,
                    lit_i32(y, &[b as i64])?,
                ],
            )?;
            Ok((to_f32(&outs[0])?, to_scalar(&outs[1])?))
        }

        fn eval(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, usize)> {
            let m = self.rt.model();
            let (b, idim, d) = (m.batch, m.input_dim, m.d);
            if y.len() != b {
                bail!("eval: batch {} != compiled batch {b}", y.len());
            }
            let outs = self.rt.call(
                "eval_batch",
                &[
                    lit_f32(params, &[d as i64])?,
                    lit_f32(x, &[b as i64, idim as i64])?,
                    lit_i32(y, &[b as i64])?,
                ],
            )?;
            Ok((to_scalar(&outs[0])?, to_scalar(&outs[1])? as usize))
        }

        fn server_apply(
            &mut self,
            global: &mut GlobalState,
            agg: &Aggregate,
            scale: f32,
            lr: f32,
        ) -> Result<()> {
            let m = self.rt.model();
            let d = m.d;
            let _ = lr; // baked into the artifact at AOT time
            let outs = if agg.total_entries() <= m.k_total {
                let (idx, val) = agg.to_padded_pairs(m.k_total, scale);
                self.rt.call(
                    "apply_sparse",
                    &[
                        lit_f32(&global.params, &[d as i64])?,
                        lit_f32(&global.adam.m, &[d as i64])?,
                        lit_f32(&global.adam.v, &[d as i64])?,
                        lit_scalar(global.adam.t),
                        lit_i32(&idx, &[m.k_total as i64])?,
                        lit_f32(&val, &[m.k_total as i64])?,
                    ],
                )?
            } else {
                let update = agg.to_dense(d, scale);
                self.rt.call(
                    "apply_dense",
                    &[
                        lit_f32(&global.params, &[d as i64])?,
                        lit_f32(&global.adam.m, &[d as i64])?,
                        lit_f32(&global.adam.v, &[d as i64])?,
                        lit_scalar(global.adam.t),
                        lit_f32(&update, &[d as i64])?,
                    ],
                )?
            };
            global.params = to_f32(&outs[0])?;
            global.adam.m = to_f32(&outs[1])?;
            global.adam.v = to_f32(&outs[2])?;
            global.adam.t = to_scalar(&outs[3])?;
            Ok(())
        }
    }
}
