//! The fleet-scale [`ClientPool`]: compact client state machines for
//! 10⁴–10⁶ simulated clients (DESIGN.md §12).
//!
//! [`crate::fl::pool::InProcessPool`] holds every client fully
//! materialized — three d-sized vectors (params + two Adam moments, plus
//! a fourth under the Delta payload) per client, ~470 KB each for the
//! MNIST MLP — which caps a single process at a few thousand clients.
//! [`CompactPool`] exploits the structure of partial participation: a
//! client's entire training state is **derivable** until the first round
//! it is scheduled. Its data shard is an `Arc`-shared [`Shard`] view (4
//! bytes per sample row, no corpus copy), its batch/selection RNG streams
//! are pure functions of `(seed, id)` that only advance when it trains,
//! and its params equal the initial global model. So an unscheduled
//! client is a [`Slot::Fresh`] — a single enum tag, zero floats — and
//! only the scheduled cohort ever materializes a [`Slot::Live`] state
//! machine, built from recycled [`StateArena`] buffers and trained across
//! the same [`Lanes`] fan-out as the dense pool.
//!
//! Once a client has trained its Adam moments are live state that
//! persists to its next scheduled round (`sync_to` only overwrites
//! params), so materialization is one-way; at fleet scale the scheduled
//! minority stays small and the fresh majority dominates. The pool is
//! **bit-for-bit** identical to `InProcessPool` on every protocol surface
//! — reports, uploads, ages, per-client params — pinned by the parity
//! tests below at small n.

use crate::backend::{make_backend_lanes, Backend, BackendLanes, ClientState, Lanes};
use crate::config::{ExperimentConfig, Payload};
use crate::coordinator::engine::{
    client_train_phase, client_update_phase, BroadcastPlan, ClientPool, ClientReport, CohortMap,
    PhaseCfg,
};
use crate::data::Shard;
use crate::fl::client::Client;
use crate::fl::codec::params_digest;
use crate::fl::pool::{lane_count, lane_map};
use crate::nn::adam::AdamState;
use crate::sparse::SparseVec;
use anyhow::{ensure, Context, Result};
use std::sync::Arc;

/// One client's storage slot.
enum Slot {
    /// Never scheduled (zero floats): state is derivable from
    /// `(seed, id, shard, init)` on demand.
    Fresh,
    /// Has been scheduled at least once: full live state machine.
    Live(Box<LiveClient>),
}

struct LiveClient {
    client: Client,
    /// error-feedback memory (Delta payload only; empty otherwise)
    memory: Vec<f32>,
}

/// Free-list of d-sized f32 buffers backing materialization and resync:
/// in steady chaos churn (drop → rejoin → resync) the pool stops
/// allocating model-sized vectors entirely.
pub struct StateArena {
    d: usize,
    free: Vec<Vec<f32>>,
}

/// Cap on pooled buffers — enough for a cohort's worth of churn without
/// quietly pinning cohort-scale memory forever.
const ARENA_CAP: usize = 256;

impl StateArena {
    fn new(d: usize) -> Self {
        StateArena { d, free: Vec::new() }
    }

    /// A zeroed d-sized buffer, recycled when one is pooled.
    fn take_zeroed(&mut self) -> Vec<f32> {
        match self.free.pop() {
            Some(mut v) => {
                v.fill(0.0);
                v
            }
            None => vec![0.0; self.d],
        }
    }

    /// Return a buffer to the pool (wrong-sized or overflow buffers are
    /// simply dropped).
    fn give(&mut self, v: Vec<f32>) {
        if v.len() == self.d && self.free.len() < ARENA_CAP {
            self.free.push(v);
        }
    }

    /// Buffers currently pooled.
    pub fn n_free(&self) -> usize {
        self.free.len()
    }
}

pub struct CompactPool<L = BackendLanes> {
    /// per-client data views over the `Arc`-shared corpus
    shards: Vec<Shard>,
    slots: Vec<Slot>,
    /// the initial global model every fresh client implicitly holds
    init: Arc<Vec<f32>>,
    seed: u64,
    lanes: L,
    arena: StateArena,
    /// phase-1 reports cached for the phase-2 uploads (see
    /// `InProcessPool` — identical contract)
    reports: Vec<SparseVec>,
    report_cohort: Vec<usize>,
    cmap: CohortMap,
    pc: PhaseCfg,
    plan_check: Option<(u32, u64)>,
    quota: Option<usize>,
    cancelled: Vec<usize>,
}

impl CompactPool {
    /// Build the pool from one shard view per client. Returns the pool
    /// and the deterministic initial parameters (the engine's initial
    /// global model). Construction is O(n) slot tags — no per-client
    /// model state is allocated.
    pub fn new(cfg: &ExperimentConfig, shards: Vec<Shard>) -> Result<(Self, Vec<f32>)> {
        let lanes = make_backend_lanes(cfg, lane_count(cfg, cfg.n_clients))
            .context("creating backend lanes")?;
        Self::with_lanes(cfg, shards, lanes)
    }
}

impl<L: Lanes> CompactPool<L> {
    fn with_lanes(
        cfg: &ExperimentConfig,
        shards: Vec<Shard>,
        mut lanes: L,
    ) -> Result<(Self, Vec<f32>)> {
        ensure!(
            shards.len() == cfg.n_clients,
            "{} shards for {} clients",
            shards.len(),
            cfg.n_clients
        );
        let init = lanes.primary().init_params()?;
        let slots = (0..cfg.n_clients).map(|_| Slot::Fresh).collect();
        Ok((
            CompactPool {
                shards,
                slots,
                init: Arc::new(init.clone()),
                seed: cfg.seed,
                lanes,
                arena: StateArena::new(cfg.d()),
                reports: Vec::new(),
                report_cohort: Vec::new(),
                cmap: CohortMap::new(),
                pc: PhaseCfg::from_config(cfg),
                plan_check: None,
                quota: None,
                cancelled: Vec::new(),
            },
            init,
        ))
    }

    /// Number of clients that train concurrently.
    pub fn n_lanes(&self) -> usize {
        self.lanes.n_lanes()
    }

    /// Clients currently holding live (materialized) state.
    pub fn n_live(&self) -> usize {
        self.slots.iter().filter(|s| matches!(s, Slot::Live(_))).count()
    }

    /// Arena buffers currently pooled for reuse.
    pub fn arena_free(&self) -> usize {
        self.arena.n_free()
    }

    /// Total f32s resident in per-client state (live params, Adam
    /// moments, EF memories — excluding the shared init model and the
    /// shared corpus). The deterministic face of the bench's RSS
    /// measurement: `bench_fleetscale` asserts it against the dense
    /// pool's analytic 3·d floats per client.
    pub fn resident_client_floats(&self) -> usize {
        self.slots
            .iter()
            .map(|s| match s {
                Slot::Fresh => 0,
                Slot::Live(lc) => {
                    let st = &lc.client.state;
                    st.params.len() + st.adam.m.len() + st.adam.v.len() + lc.memory.len()
                }
            })
            .sum()
    }

    /// A client's current local parameters: fresh clients implicitly
    /// hold the initial global model, exactly as the dense pool's
    /// never-scheduled clients do.
    pub fn client_params(&self, i: usize) -> &[f32] {
        match &self.slots[i] {
            Slot::Fresh => &self.init,
            Slot::Live(lc) => &lc.client.state.params,
        }
    }

    /// Labels present in client `i`'s shard — answered from the shard
    /// view without materializing the client.
    pub fn label_set(&self, i: usize) -> Vec<u8> {
        self.shards[i].label_set()
    }

    /// The PS-side backend without needing the [`ClientPool`] trait in
    /// scope.
    pub fn backend_mut(&mut self) -> &mut dyn Backend {
        self.lanes.primary()
    }

    /// Promote a fresh slot to a live state machine. Bit-for-bit the
    /// client the dense pool would hold at this point: its streams are
    /// virgin (they only advance when the client trains, and this client
    /// never has), its params are the initial model, its Adam moments
    /// zero. Buffers come from the arena.
    fn materialize(&mut self, i: usize) {
        if matches!(self.slots[i], Slot::Live(_)) {
            return;
        }
        let mut client = Client::new(i, self.shards[i].clone(), Vec::new(), self.seed);
        let mut params = self.arena.take_zeroed();
        params.copy_from_slice(&self.init);
        client.state.params = params;
        client.state.adam.m = self.arena.take_zeroed();
        client.state.adam.v = self.arena.take_zeroed();
        let memory =
            if self.pc.payload == Payload::Delta { self.arena.take_zeroed() } else { Vec::new() };
        self.slots[i] = Slot::Live(Box::new(LiveClient { client, memory }));
    }

    /// Mimic a worker-process restart followed by a `Rejoin` resync
    /// (chaos harnesses; same contract as
    /// [`crate::fl::pool::InProcessPool::resync_client`]): model state
    /// replaced by the current global model with fresh optimizer
    /// moments, error-feedback memory cleared. The replaced buffers
    /// cycle through the arena — a churning fleet stops allocating.
    pub fn resync_client(&mut self, i: usize, global: &[f32]) {
        self.materialize(i);
        let Slot::Live(lc) = &mut self.slots[i] else { unreachable!("just materialized") };
        let mut params = self.arena.take_zeroed();
        params.copy_from_slice(global);
        let mut adam = AdamState::new(0);
        adam.m = self.arena.take_zeroed();
        adam.v = self.arena.take_zeroed();
        let old = std::mem::replace(&mut lc.client.state, ClientState { params, adam });
        self.arena.give(old.params);
        self.arena.give(old.adam.m);
        self.arena.give(old.adam.v);
        lc.memory.fill(0.0);
    }

    /// Run `f` over the cohort's live clients, chunked across the
    /// backend lanes (shared [`lane_map`] fan-out — numerics identical
    /// to the dense pool's `cohort_map`). Every cohort member must be
    /// materialized.
    fn cohort_work<T, F>(&mut self, cohort: &[usize], f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize, &mut Client, &mut dyn Backend, Option<&mut Vec<f32>>) -> Result<T> + Sync,
    {
        let n = self.slots.len();
        let m = cohort.len();
        if m == 0 {
            return Ok(Vec::new());
        }
        debug_assert!(cohort.windows(2).all(|w| w[0] < w[1]) && cohort[m - 1] < n);
        self.cmap.set(n, cohort);
        let cmap = &self.cmap;
        let delta = self.pc.payload == Payload::Delta;
        let mut work: Vec<(usize, &mut Client, Option<&mut Vec<f32>>)> = self
            .slots
            .iter_mut()
            .enumerate()
            .filter(|(i, _)| cmap.slot(*i) != usize::MAX)
            .enumerate()
            .map(|(p, (i, slot))| {
                let Slot::Live(lc) = slot else {
                    panic!("cohort member {i} scheduled without materialization")
                };
                let LiveClient { client, memory } = &mut **lc;
                (p, client, delta.then_some(memory))
            })
            .collect();
        lane_map(&mut work, &mut self.lanes, f)
    }
}

impl<L: Lanes> ClientPool for CompactPool<L> {
    fn n_clients(&self) -> usize {
        self.slots.len()
    }

    /// Same digest tripwire as the dense pool: the sim has no wire to
    /// shrink, but plan/model drift still trips in every delta-downlink
    /// test.
    fn set_broadcast_plan(&mut self, plan: &BroadcastPlan) {
        self.plan_check = Some((plan.round, plan.digest));
    }

    fn set_commit_quota(&mut self, quota: usize) {
        self.quota = Some(quota);
    }

    fn take_cancelled(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.cancelled)
    }

    fn train_and_report(
        &mut self,
        global: &[f32],
        cohort: &[usize],
    ) -> Result<Vec<Option<ClientReport>>> {
        if let Some((round, digest)) = self.plan_check.take() {
            ensure!(
                params_digest(global) == digest,
                "broadcast plan digest (round {round}) does not match the broadcast model"
            );
        }
        for &c in cohort {
            self.materialize(c);
        }
        let pc = self.pc;
        let outs =
            self.cohort_work(cohort, |_, c, be, mem| client_train_phase(c, be, mem, global, &pc))?;
        self.reports = outs.iter().map(|o| o.report.clone()).collect();
        self.report_cohort = cohort.to_vec();
        match self.quota.take() {
            // deterministic sim speculation: the first `q` in cohort
            // order commit, the rest cancel cleanly after training (see
            // `InProcessPool::train_and_report`)
            Some(q) if q < cohort.len() => {
                self.cancelled.extend_from_slice(&cohort[q..]);
                Ok(outs
                    .into_iter()
                    .enumerate()
                    .map(|(p, o)| (p < q).then_some(o))
                    .collect())
            }
            _ => Ok(outs.into_iter().map(Some).collect()),
        }
    }

    fn exchange(
        &mut self,
        requests: Option<&[Vec<u32>]>,
        cohort: &[usize],
    ) -> Result<Vec<Option<SparseVec>>> {
        let pc = self.pc;
        let reports = std::mem::take(&mut self.reports);
        let report_cohort = std::mem::take(&mut self.report_cohort);
        ensure!(reports.len() == report_cohort.len(), "exchange before train_and_report");
        if let Some(reqs) = requests {
            ensure!(reqs.len() == cohort.len(), "request count mismatch");
        }
        // the exchange cohort may be a survivor subset of the trained
        // cohort: map each member back to its cached report
        self.cmap.set(self.slots.len(), &report_cohort);
        let mut report_of = vec![usize::MAX; cohort.len()];
        for (p, &c) in cohort.iter().enumerate() {
            let rp = self.cmap.slot(c);
            ensure!(rp != usize::MAX, "client {c} exchanged without a trained report");
            report_of[p] = rp;
        }
        let outs = self.cohort_work(cohort, |p, c, be, mem| {
            let req = requests.map(|r| r[p].as_slice());
            client_update_phase(c, be, mem, &reports[report_of[p]], req, &pc)
        })?;
        Ok(outs.into_iter().map(Some).collect())
    }

    fn backend(&mut self) -> &mut dyn Backend {
        self.lanes.primary()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::engine::RoundEngine;
    use crate::data::{load_dataset, partition_shards, Dataset};
    use crate::fl::pool::InProcessPool;

    fn shard_views(cfg: &ExperimentConfig) -> (Arc<Dataset>, Vec<Shard>) {
        let (train, _) =
            load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
        let train = Arc::new(train);
        let shards = partition_shards(&train, cfg.n_clients, &cfg.partition, cfg.seed);
        (train, shards)
    }

    /// Everything the parity pin compares after a driven run.
    struct Snapshot {
        global: Vec<f32>,
        client_params: Vec<Vec<f32>>,
        uploaded: Vec<Vec<Vec<u32>>>,
        ages: Vec<Vec<u32>>,
    }

    /// Run `rounds` engine rounds over a pool, snapshotting every
    /// protocol surface: global params, per-client params, uploaded
    /// index log, and per-client age vectors.
    fn drive<P: ClientPool>(
        cfg: &ExperimentConfig,
        pool: &mut P,
        init: Vec<f32>,
        rounds: usize,
        params_of: impl Fn(&P, usize) -> Vec<f32>,
    ) -> Snapshot {
        let mut engine = RoundEngine::new(cfg, init);
        for _ in 0..rounds {
            engine.run_round(pool).unwrap();
        }
        let client_params: Vec<Vec<f32>> =
            (0..cfg.n_clients).map(|i| params_of(pool, i)).collect();
        let uploaded: Vec<Vec<Vec<u32>>> = engine.uploaded_log().iter().cloned().collect();
        let ages: Vec<Vec<u32>> = (0..cfg.n_clients)
            .map(|i| engine.ps().clusters().age_of_client(i).to_vec())
            .collect();
        Snapshot { global: engine.global_params().to_vec(), client_params, uploaded, ages }
    }

    /// The tentpole acceptance pin: CompactPool must be bit-for-bit
    /// identical to InProcessPool — params, uploads, ages — under
    /// partial participation (so fresh slots survive rounds) for both
    /// payloads.
    #[test]
    fn compact_pool_matches_dense_pool_bit_for_bit() {
        for payload in [Payload::Grad, Payload::Delta] {
            let mut cfg = ExperimentConfig::mnist_smoke();
            cfg.payload = payload;
            cfg.participation = 0.5; // 4 clients -> cohort of 2
            cfg.rounds = 6;

            let (_train, shards) = shard_views(&cfg);
            let (mut dense, init_d) = InProcessPool::new(&cfg, shards.clone()).unwrap();
            let (mut compact, init_c) = CompactPool::new(&cfg, shards).unwrap();
            assert_eq!(init_d, init_c);

            let d = drive(&cfg, &mut dense, init_d.clone(), cfg.rounds, |p, i| {
                p.client_params(i).to_vec()
            });
            let c = drive(&cfg, &mut compact, init_c, cfg.rounds, |p, i| {
                p.client_params(i).to_vec()
            });
            assert_eq!(d.uploaded, c.uploaded, "uploaded index sets must match ({payload:?})");
            assert_eq!(d.ages, c.ages, "per-client ages must match ({payload:?})");
            assert_eq!(d.global, c.global, "global params must match exactly ({payload:?})");
            assert_eq!(
                d.client_params, c.client_params,
                "per-client params must match exactly ({payload:?})"
            );
            // under 50% participation some clients never trained and
            // must have stayed fresh
            assert!(compact.n_live() < cfg.n_clients);
        }
    }

    /// Commit quota semantics match the dense pool exactly: first `q`
    /// in cohort order commit, the rest cancel after training.
    #[test]
    fn quota_cancellation_matches_dense_pool() {
        let cfg = ExperimentConfig::mnist_smoke();
        let (_train, shards) = shard_views(&cfg);
        let (mut dense, init) = InProcessPool::new(&cfg, shards.clone()).unwrap();
        let (mut compact, _) = CompactPool::new(&cfg, shards).unwrap();
        let full: Vec<usize> = (0..cfg.n_clients).collect();

        dense.set_commit_quota(2);
        compact.set_commit_quota(2);
        let rd = dense.train_and_report(&init, &full).unwrap();
        let rc = compact.train_and_report(&init, &full).unwrap();
        let committed: Vec<bool> = rd.iter().map(Option::is_some).collect();
        assert_eq!(committed, vec![true, true, false, false]);
        for (a, b) in rd.iter().zip(&rc) {
            match (a, b) {
                (Some(x), Some(y)) => assert_eq!(x.report, y.report),
                (None, None) => {}
                _ => panic!("commit pattern diverged"),
            }
        }
        assert_eq!(dense.take_cancelled(), compact.take_cancelled());

        let winners = vec![0usize, 1];
        let reqs: Vec<Vec<u32>> = winners
            .iter()
            .map(|&c| rd[c].as_ref().unwrap().report.idx[..cfg.k].to_vec())
            .collect();
        let ud = dense.exchange(Some(&reqs), &winners).unwrap();
        let uc = compact.exchange(Some(&reqs), &winners).unwrap();
        for (a, b) in ud.iter().zip(&uc) {
            assert_eq!(a.as_ref().unwrap().idx, b.as_ref().unwrap().idx);
            assert_eq!(a.as_ref().unwrap().val, b.as_ref().unwrap().val);
        }
    }

    /// Fresh slots hold zero model floats; only scheduling materializes,
    /// and the count never exceeds the clients actually scheduled.
    #[test]
    fn fresh_slots_cost_nothing_until_scheduled() {
        let cfg = ExperimentConfig::mnist_smoke();
        let (_train, shards) = shard_views(&cfg);
        let (mut pool, init) = CompactPool::new(&cfg, shards).unwrap();
        assert_eq!(pool.n_live(), 0);
        assert_eq!(pool.resident_client_floats(), 0);
        assert_eq!(pool.client_params(3), &init[..], "fresh client reads the init model");

        let cohort = vec![1usize, 2];
        let reports = pool.train_and_report(&init, &cohort).unwrap();
        assert!(reports.iter().all(Option::is_some));
        let reqs: Vec<Vec<u32>> = reports
            .iter()
            .map(|r| r.as_ref().unwrap().report.idx[..cfg.k].to_vec())
            .collect();
        pool.exchange(Some(&reqs), &cohort).unwrap();
        assert_eq!(pool.n_live(), 2);
        assert_eq!(pool.resident_client_floats(), 2 * 3 * cfg.d());
        assert_ne!(pool.client_params(1), &init[..], "trained client moved");
        assert_eq!(pool.client_params(3), &init[..], "unscheduled client still fresh");
    }

    /// Resync cycles replaced buffers through the arena: a churning
    /// fleet stops allocating model-sized vectors.
    #[test]
    fn resync_recycles_buffers_through_arena() {
        let cfg = ExperimentConfig::mnist_smoke();
        let (_train, shards) = shard_views(&cfg);
        let (mut pool, init) = CompactPool::new(&cfg, shards).unwrap();
        // rAge-k selection is PS-side, so drive the exchange with
        // explicit index requests built from the phase-1 reports
        let reqs_for = |reports: &[Option<ClientReport>]| -> Vec<Vec<u32>> {
            reports
                .iter()
                .map(|r| r.as_ref().unwrap().report.idx[..cfg.k].to_vec())
                .collect()
        };
        let cohort = vec![0usize];
        let reports = pool.train_and_report(&init, &cohort).unwrap();
        pool.exchange(Some(&reqs_for(&reports)), &cohort).unwrap();
        assert_eq!(pool.arena_free(), 0);
        pool.resync_client(0, &init);
        assert_eq!(pool.arena_free(), 3, "old params + both moments returned");
        assert_eq!(pool.client_params(0), &init[..]);
        // the next materialization draws from the free list
        let reports = pool.train_and_report(&init, &[1]).unwrap();
        pool.exchange(Some(&reqs_for(&reports)), &[1]).unwrap();
        assert_eq!(pool.arena_free(), 0, "materialization reused the pooled buffers");
    }
}
