//! Readiness polling for the event-driven PS transport: a minimal,
//! dependency-free wrapper over `poll(2)`.
//!
//! The offline registry has no `mio`/`tokio`, and the PS reactor
//! (`fl::distributed`) needs exactly one primitive the standard library
//! does not expose: "which of these sockets can make progress right
//! now, or none within this deadline?". `std` already links libc on
//! every supported platform, so a single `extern "C"` declaration of
//! `poll` plus a `#[repr(C)]` mirror of `struct pollfd` is the whole
//! dependency surface — no event-loop framework, no new crates.
//!
//! Semantics kept deliberately tiny:
//!
//! * level-triggered — a socket that is still readable/writable shows up
//!   again on the next call, so resumable frame cursors
//!   ([`crate::fl::transport::RecvCursor`]/[`SendCursor`]) never need
//!   re-arming logic;
//! * `EINTR` is retried internally (the reactor re-derives per-connection
//!   deadlines every iteration, so a slightly stretched wait is harmless);
//! * error conditions (`POLLERR`/`POLLHUP`/`POLLNVAL`) are reported as
//!   readiness: the caller's next read/write surfaces the actual
//!   [`std::io::Error`] with the usual errno detail.
//!
//! [`SendCursor`]: crate::fl::transport::SendCursor

use anyhow::{Context, Result};
use std::os::fd::RawFd;
use std::time::Duration;

/// `POLLIN`: readable (or peer-closed, which reads as EOF).
pub const POLLIN: i16 = 0x001;
/// `POLLOUT`: writable without blocking.
pub const POLLOUT: i16 = 0x004;
/// `POLLERR`: error condition (revents only).
pub const POLLERR: i16 = 0x008;
/// `POLLHUP`: peer hung up (revents only).
pub const POLLHUP: i16 = 0x010;
/// `POLLNVAL`: fd not open (revents only).
pub const POLLNVAL: i16 = 0x020;

/// ABI mirror of libc's `struct pollfd` (identical layout on every
/// platform `poll(2)` exists on: int fd, short events, short revents).
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct PollFd {
    pub fd: RawFd,
    pub events: i16,
    pub revents: i16,
}

// The layout is ABI, not convention — `poll(2)` reads these bytes in
// place. Pinned at compile time (and re-checked under Miri by
// `tests/miri_memory.rs`, which also validates the pointer arithmetic).
const _: () = assert!(std::mem::size_of::<PollFd>() == 8);
const _: () = assert!(std::mem::align_of::<PollFd>() == 4);
const _: () = assert!(std::mem::offset_of!(PollFd, fd) == 0);
const _: () = assert!(std::mem::offset_of!(PollFd, events) == 4);
const _: () = assert!(std::mem::offset_of!(PollFd, revents) == 6);

impl PollFd {
    /// An interest entry for `fd`, with `revents` cleared.
    pub fn new(fd: RawFd, events: i16) -> Self {
        PollFd { fd, events, revents: 0 }
    }

    /// Any readiness or error condition reported for this fd — the
    /// caller should attempt its pending I/O (errors surface there).
    pub fn ready(&self) -> bool {
        self.revents != 0
    }
}

extern "C" {
    /// `poll(2)`; `nfds_t` is `c_ulong` on every libc Rust's std links.
    fn poll(fds: *mut PollFd, nfds: std::ffi::c_ulong, timeout: std::ffi::c_int) -> std::ffi::c_int;
}

/// Wait until at least one entry is ready, or `timeout` elapses
/// (`None` = wait forever). Returns how many entries have nonzero
/// `revents`; 0 means the timeout fired with nothing ready. `EINTR` is
/// retried with the same timeout.
pub fn poll_fds(fds: &mut [PollFd], timeout: Option<Duration>) -> Result<usize> {
    let timeout_ms: std::ffi::c_int = match timeout {
        None => -1,
        Some(t) => {
            // round a sub-millisecond remainder *up* so a deadline just
            // a few microseconds out does not degenerate into a busy
            // spin of zero-timeout polls
            let ms = t.as_millis();
            let ms = if ms == 0 && !t.is_zero() { 1 } else { ms };
            ms.min(std::ffi::c_int::MAX as u128) as std::ffi::c_int
        }
    };
    loop {
        // SAFETY: `fds` is a valid, exclusively-borrowed slice of
        // repr(C) pollfd-layout structs for the whole call, and nfds is
        // its exact length.
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as std::ffi::c_ulong, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = std::io::Error::last_os_error();
        if err.kind() == std::io::ErrorKind::Interrupted {
            continue;
        }
        return Err(err).context("poll(2)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;
    use std::time::Instant;

    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = listener.accept().unwrap();
        (a, b)
    }

    #[test]
    #[allow(clippy::disallowed_methods)] // asserting a real-time timeout needs a real clock
    fn timeout_fires_with_nothing_ready() {
        let (a, _b) = pair();
        let mut fds = [PollFd::new(a.as_raw_fd(), POLLIN)];
        let t0 = Instant::now();
        let n = poll_fds(&mut fds, Some(Duration::from_millis(30))).unwrap();
        assert_eq!(n, 0, "no data was ever written");
        assert!(!fds[0].ready());
        assert!(t0.elapsed() >= Duration::from_millis(25), "the wait must honor the timeout");
    }

    #[test]
    fn readable_after_peer_writes() {
        let (a, mut b) = pair();
        b.write_all(b"x").unwrap();
        let mut fds = [PollFd::new(a.as_raw_fd(), POLLIN)];
        let n = poll_fds(&mut fds, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].ready());
        assert_ne!(fds[0].revents & POLLIN, 0);
    }

    #[test]
    fn fresh_socket_is_writable_and_hangup_reports_ready() {
        let (a, b) = pair();
        let mut fds = [PollFd::new(a.as_raw_fd(), POLLOUT)];
        let n = poll_fds(&mut fds, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(n, 1, "an empty send buffer is writable immediately");
        // peer closes: the POLLIN wait reports readiness (EOF reads as
        // Ok(0) — the reactor's cursors turn that into a clean error)
        drop(b);
        let mut fds = [PollFd::new(a.as_raw_fd(), POLLIN)];
        let n = poll_fds(&mut fds, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].ready());
    }

    #[test]
    fn mixed_set_reports_only_the_ready_entries() {
        let (a, mut b) = pair();
        let (c, _d) = pair();
        b.write_all(b"y").unwrap();
        let mut fds =
            [PollFd::new(a.as_raw_fd(), POLLIN), PollFd::new(c.as_raw_fd(), POLLIN)];
        let n = poll_fds(&mut fds, Some(Duration::from_millis(50))).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].ready(), "a has queued data");
        assert!(!fds[1].ready(), "c is idle");
    }
}
