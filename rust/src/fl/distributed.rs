//! Multi-process deployment: the PS and each client as separate OS
//! processes speaking the length-prefixed TCP protocol of
//! [`crate::fl::transport`] — the same per-round message flow the
//! in-process simulator models, now with real sockets.
//!
//! * [`run_server`] — binds, waits for `n_clients` joins, then drives the
//!   rAge-k round loop (select -> request -> aggregate -> apply ->
//!   age/frequency bookkeeping -> M-periodic DBSCAN).
//! * [`run_worker`] — owns one client's shard (derived from the shared
//!   seed + its id, so no data ever crosses the wire), local Adam state
//!   and error-feedback memory.
//!
//! Both ends use the same `ExperimentConfig`; run e.g.:
//!
//! ```sh
//! ragek serve  --clients 4 --port 7700 --rounds 40 &
//! for i in 0 1 2 3; do ragek worker --connect 127.0.0.1:7700 --id $i & done
//! ```

use crate::backend::{make_backend, ClientState, GlobalState};
use crate::config::{ExperimentConfig, Payload};
use crate::coordinator::aggregator::Aggregate;
use crate::coordinator::server::{ParameterServer, PsConfig};
use crate::coordinator::strategies::client_select;
use crate::data::{load_dataset, partition::partition};
use crate::fl::client::Client;
use crate::fl::transport::{recv, send, Msg};
use crate::sparse::{topk_abs_sparse, SparseVec};
use anyhow::{bail, Context, Result};
use std::net::{TcpListener, TcpStream};

/// PS-side summary of a distributed run.
#[derive(Debug)]
pub struct ServeReport {
    pub rounds: usize,
    pub final_accuracy: f32,
    pub cluster_labels: Vec<usize>,
}

/// Run the parameter server until `cfg.rounds` rounds complete.
pub fn run_server(cfg: &ExperimentConfig, port: u16) -> Result<ServeReport> {
    cfg.validate()?;
    if cfg.payload != Payload::Delta {
        bail!("distributed mode implements the Delta payload");
    }
    let listener =
        TcpListener::bind(("0.0.0.0", port)).with_context(|| format!("binding :{port}"))?;
    crate::info!("serve: waiting for {} clients on :{port}", cfg.n_clients);

    let mut streams: Vec<Option<TcpStream>> = (0..cfg.n_clients).map(|_| None).collect();
    let mut joined = 0;
    while joined < cfg.n_clients {
        let (mut s, peer) = listener.accept()?;
        match recv(&mut s)? {
            Msg::Join { client_id } => {
                let id = client_id as usize;
                if id >= cfg.n_clients || streams[id].is_some() {
                    bail!("bad/duplicate client id {id} from {peer}");
                }
                crate::info!("serve: client {id} joined from {peer}");
                streams[id] = Some(s);
                joined += 1;
            }
            other => bail!("expected Join, got {other:?}"),
        }
    }
    let mut streams: Vec<TcpStream> = streams.into_iter().map(|s| s.unwrap()).collect();

    // PS state: global model + age/frequency/cluster machinery + test set
    let mut backend = make_backend(cfg)?;
    let mut global = GlobalState::new(backend.init_params()?);
    let mut ps = ParameterServer::new(PsConfig {
        d: cfg.d(),
        n_clients: cfg.n_clients,
        k: cfg.k,
        strategy: cfg.strategy,
        recluster_every: cfg.recluster_every,
        dbscan: cfg.dbscan,
        merge_rule: cfg.merge_rule,
    });
    let (_, test) = load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);

    for round in 1..=cfg.rounds as u32 {
        for s in streams.iter_mut() {
            send(s, &Msg::Model { round, params: global.params.clone() })?;
        }
        let mut reports: Vec<SparseVec> = Vec::with_capacity(cfg.n_clients);
        for s in streams.iter_mut() {
            match recv(s)? {
                Msg::Report { report, round: r, .. } if r == round => reports.push(report),
                other => bail!("round {round}: expected Report, got {other:?}"),
            }
        }
        let requested: Vec<Vec<u32>> = if cfg.strategy.needs_report() {
            let idx: Vec<Vec<u32>> = reports.iter().map(|r| r.idx.clone()).collect();
            ps.select_requests(&idx)
        } else {
            // client-side strategies select themselves; PS echoes back the
            // report prefix so the wire flow stays uniform
            reports.iter().map(|r| r.idx[..cfg.k.min(r.len())].to_vec()).collect()
        };
        let mut agg = Aggregate::new();
        for (s, req) in streams.iter_mut().zip(&requested) {
            send(s, &Msg::Request { round, indices: req.clone() })?;
            match recv(s)? {
                Msg::Update { update, round: r, .. } if r == round => agg.push(update),
                other => bail!("round {round}: expected Update, got {other:?}"),
            }
        }
        let update = agg.to_dense(cfg.d(), 1.0 / cfg.n_clients as f32);
        for (p, &u) in global.params.iter_mut().zip(&update) {
            *p += u;
        }
        ps.record_round(&requested);
        ps.maybe_recluster();

        if cfg.eval_every > 0 && round as usize % cfg.eval_every == 0 {
            let (acc, loss) = eval_global(backend.as_mut(), &global.params, &test, cfg.batch)?;
            crate::info!(
                "serve: round {round}/{}: acc {:.2}% loss {loss:.4} clusters {}",
                cfg.rounds,
                acc * 100.0,
                ps.clusters().n_clusters()
            );
        }
    }
    for s in streams.iter_mut() {
        send(s, &Msg::Shutdown)?;
    }
    let (acc, _) = eval_global(backend.as_mut(), &global.params, &test, cfg.batch)?;
    Ok(ServeReport {
        rounds: cfg.rounds,
        final_accuracy: acc,
        cluster_labels: ps.clusters().labels(),
    })
}

fn eval_global(
    backend: &mut dyn crate::backend::Backend,
    params: &[f32],
    test: &crate::data::Dataset,
    batch: usize,
) -> Result<(f32, f32)> {
    let n_batches = (test.len() / batch).max(1);
    let mut loss_sum = 0.0f32;
    let mut correct = 0usize;
    for i in 0..n_batches {
        let idx: Vec<usize> =
            (i * batch..(i + 1) * batch).map(|j| j % test.len()).collect();
        let (x, y) = crate::data::gather_batch(test, &idx);
        let (ls, c) = backend.eval(params, &x, &y)?;
        loss_sum += ls;
        correct += c;
    }
    let n = (n_batches * batch) as f32;
    Ok((correct as f32 / n, loss_sum / n))
}

/// Run one worker process until the PS sends Shutdown.
pub fn run_worker(cfg: &ExperimentConfig, addr: &str, id: usize) -> Result<()> {
    cfg.validate()?;
    if id >= cfg.n_clients {
        bail!("worker id {id} >= n_clients {}", cfg.n_clients);
    }
    let mut backend = make_backend(cfg)?;
    // derive this worker's shard exactly like the simulator does: same
    // seed -> same partition, no data on the wire
    let (train, _) = load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
    let shards = partition(&train, cfg.n_clients, &cfg.partition, cfg.seed);
    let mut client = Client::new(id, train.subset(&shards[id]), backend.init_params()?, cfg.seed);
    let mut memory = vec![0.0f32; cfg.d()];

    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    send(&mut stream, &Msg::Join { client_id: id as u32 })?;
    crate::info!("worker {id}: joined {addr}");

    loop {
        let (round, params) = match recv(&mut stream)? {
            Msg::Model { round, params } => (round, params),
            Msg::Shutdown => break,
            other => bail!("expected Model/Shutdown, got {other:?}"),
        };
        client.state = ClientState::new(params.clone());
        let out = client.local_round(backend.as_mut(), cfg.h, cfg.batch)?;
        // error-feedback fold + report (Delta payload)
        for (m, (p, g)) in memory.iter_mut().zip(client.state.params.iter().zip(&params)) {
            *m += p - g;
        }
        let report = topk_abs_sparse(&memory, cfg.r);
        send(
            &mut stream,
            &Msg::Report {
                client_id: id as u32,
                round,
                report: report.clone(),
                mean_loss: out.mean_loss,
            },
        )?;
        let requested = match recv(&mut stream)? {
            Msg::Request { indices, round: r } if r == round => indices,
            other => bail!("expected Request, got {other:?}"),
        };
        let update = if cfg.strategy.needs_report() {
            Client::answer_request(&report, &requested)
        } else {
            let sel = client_select(cfg.strategy, &mut client.rng, &report.idx, cfg.d(), cfg.k);
            Client::gather_from_grad(&memory, &sel)
        };
        for &j in &update.idx {
            memory[j as usize] = 0.0;
        }
        send(&mut stream, &Msg::Update { client_id: id as u32, round, update })?;
    }
    crate::info!("worker {id}: shutdown");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn distributed_round_trip_localhost() {
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.payload = Payload::Delta; // distributed mode implements Delta
        cfg.rounds = 3;
        cfg.n_clients = 2;
        cfg.train_n = 200;
        cfg.test_n = 64;
        cfg.eval_every = 0;
        // pick an ephemeral port by binding first
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let port = probe.local_addr().unwrap().port();
        drop(probe);

        let server_cfg = cfg.clone();
        let server = std::thread::spawn(move || run_server(&server_cfg, port).unwrap());
        std::thread::sleep(std::time::Duration::from_millis(200));
        let mut workers = Vec::new();
        for id in 0..cfg.n_clients {
            let wcfg = cfg.clone();
            let addr = format!("127.0.0.1:{port}");
            workers.push(std::thread::spawn(move || run_worker(&wcfg, &addr, id).unwrap()));
        }
        let report = server.join().unwrap();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(report.rounds, 3);
        assert_eq!(report.cluster_labels.len(), 2);
    }
}
