//! Multi-process deployment: the PS and each client as separate OS
//! processes speaking the length-prefixed TCP protocol of
//! [`crate::fl::transport`].
//!
//! Both sides are thin adapters over the shared protocol code:
//!
//! * [`run_server`] — binds, waits for `n_clients` joins (each carrying
//!   the worker's [`Codec`] as a protocol-version byte; mismatches are
//!   rejected at accept time), then drives the **same** [`RoundEngine`]
//!   the in-process simulator uses, through [`TcpClientPool`] (the
//!   sockets-backed [`ClientPool`]).
//! * [`run_worker`] — owns one client's shard (derived from the shared
//!   seed + its id, so no data ever crosses the wire) and executes the
//!   same [`client_train_phase`] / [`client_update_phase`] as the
//!   in-process pool — local Adam state persists across rounds via
//!   `sync_to`, exactly like the simulator.
//!
//! The two deployments are therefore bit-for-bit identical on the same
//! config + seed (per-round uploaded indices and final global parameters
//! alike) — pinned by `rust/tests/parity.rs` for the raw **and** the
//! lossless packed codec.
//!
//! Steady-state rounds perform **no per-frame buffer allocations** on
//! either end: every stream owns a [`FrameBuf`] (encode scratch + recv
//! payload buffer), the worker decodes the model broadcast into a reused
//! parameter vector, and the PS re-encodes the broadcast frame into the
//! same `Arc` buffer each round once every stream thread has dropped its
//! handle. (Decoded *messages* still own their payload `Vec`s — a
//! received report/update flows into the engine by value.)
//! [`ServeReport::frame_grows`] exposes the PS-side buffer-growth count
//! so tests can pin the reuse.
//!
//! Both ends use the same `ExperimentConfig`; run e.g.:
//!
//! ```sh
//! ragek serve  --clients 4 --port 7700 --rounds 40 &
//! for i in 0 1 2 3; do ragek worker --connect 127.0.0.1:7700 --id $i & done
//! ```

use crate::backend::{make_backend, Backend};
use crate::config::{ExperimentConfig, Payload};
use crate::coordinator::engine::{
    client_train_phase, client_update_phase, cohort_positions, eval_dataset, ClientPool,
    ClientReport, PhaseCfg, RoundEngine,
};
use crate::data::{load_dataset, partition::partition};
use crate::fl::client::Client;
use crate::fl::codec::{Codec, FrameBuf};
use crate::fl::metrics::CommStats;
use crate::fl::transport::{
    decode_model_into, encode_model_frame, encode_model_frame_into, recv, recv_frame,
    recv_payload, send, send_frame, send_report, send_request, Msg, TAG_MODEL,
};
use crate::sparse::SparseVec;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// PS-side summary of a distributed run.
#[derive(Debug)]
pub struct ServeReport {
    pub rounds: usize,
    pub final_accuracy: f32,
    pub cluster_labels: Vec<usize>,
    /// final global model (sim/distributed parity checks)
    pub final_params: Vec<f32>,
    /// per round, per client: the uploaded index sets (empty entries for
    /// clients off that round's cohort)
    pub uploaded_log: Vec<Vec<Vec<u32>>>,
    /// the engine's byte-accurate communication accounting
    pub comm: CommStats,
    /// how many times the PS serialized a `Model` frame — the zero-copy
    /// broadcast pin: exactly one per round, however many workers
    pub model_encodes: u64,
    /// round-path bytes the PS actually received on its sockets (report +
    /// update frames) — pinned equal to the engine's `comm.wire_up`
    pub wire_up_observed: u64,
    /// round-path bytes the PS actually wrote to its sockets (model +
    /// request + sit frames) — pinned equal to `comm.wire_down`
    pub wire_down_observed: u64,
    /// PS-side [`FrameBuf`] capacity-growth events across all streams —
    /// constant once the first rounds set the high-water mark (the
    /// buffer-reuse steady-state pin)
    pub frame_grows: u64,
}

/// One accepted worker stream plus its reused transport buffers.
struct WorkerConn {
    stream: TcpStream,
    fb: FrameBuf,
    /// a round-path send/recv on this stream failed (timeout, reset, bad
    /// frame): reported through [`ClientPool::available`] so
    /// availability-aware scheduling stops spending cohort slots here
    dead: bool,
}

/// Sparse frames are remote input: every index must address the model.
/// Rejecting here turns a corrupt/malicious worker into a clean protocol
/// error instead of a PS panic (aggregation) or an index-sized
/// allocation (selection's stamp vector).
fn check_indices(idx: &[u32], d: usize, what: &str) -> Result<()> {
    if let Some(&bad) = idx.iter().find(|&&j| j as usize >= d) {
        bail!("{what} index {bad} out of range (d = {d})");
    }
    Ok(())
}

/// The sockets-backed [`ClientPool`]: one TCP stream per remote worker,
/// indexed by client id. Owns the PS-side backend (server optimizer
/// apply + evaluation).
///
/// Broadcast/collect is **concurrent** — one scoped thread per cohort
/// stream, so a slow worker overlaps with its peers instead of
/// serializing the round in client order — and the model broadcast is
/// **zero-copy**: the `Model` frame is encoded once per round into an
/// `Arc<Vec<u8>>` that is *reused across rounds* (once the stream threads
/// drop their clones the buffer is re-encoded in place), and the same
/// bytes are written to every cohort stream. Workers outside the round's
/// cohort receive a 13-byte [`Msg::Sit`] frame instead of the d-vector,
/// so downlink scales with the cohort, not with n.
pub struct TcpClientPool {
    conns: Vec<WorkerConn>,
    backend: Box<dyn Backend>,
    round: u32,
    /// model dimension of the current run (set at the first broadcast;
    /// bounds-checks decoded sparse frames)
    d: usize,
    /// the wire format every worker negotiated at Join time
    codec: Codec,
    /// the reusable broadcast frame (see the struct docs)
    model_frame: Arc<Vec<u8>>,
    /// `Model` frame serializations so far (one per round — pinned by
    /// tests via [`ServeReport::model_encodes`])
    model_encodes: u64,
    /// round-path bytes received (report/update frames, header included)
    wire_up: u64,
    /// round-path bytes sent (model/request/sit frames, header included)
    wire_down: u64,
}

impl TcpClientPool {
    /// Block on an already-bound listener until all `cfg.n_clients`
    /// workers joined with a matching wire codec. Binding is the caller's
    /// job so tests can bind an ephemeral port *before* any worker spawns
    /// (joins then queue in the accept backlog — no sleeps, no port
    /// races).
    pub fn accept(cfg: &ExperimentConfig, listener: TcpListener) -> Result<Self> {
        crate::info!(
            "serve: waiting for {} clients on {:?} (codec {})",
            cfg.n_clients,
            listener.local_addr(),
            cfg.codec.name()
        );
        let mut slots: Vec<Option<TcpStream>> = (0..cfg.n_clients).map(|_| None).collect();
        let mut joined = 0;
        while joined < cfg.n_clients {
            let (mut s, peer) = listener.accept()?;
            // the straggler seed (`io_timeout_ms`): with a deadline set, a
            // hung worker fails its stream's read/write instead of wedging
            // the PS collect phase forever — applied before the Join recv
            // so even a connect-and-stall client cannot block accept
            if cfg.io_timeout_ms > 0 {
                let dl = Some(std::time::Duration::from_millis(cfg.io_timeout_ms));
                s.set_read_timeout(dl).context("set_read_timeout")?;
                s.set_write_timeout(dl).context("set_write_timeout")?;
            }
            match recv(&mut s, cfg.codec) {
                Ok(Msg::Join { client_id, codec }) => {
                    let id = client_id as usize;
                    if id >= cfg.n_clients || slots[id].is_some() {
                        let _ = send(&mut s, &Msg::Shutdown, cfg.codec);
                        Self::shutdown_joined(&mut slots, cfg.codec);
                        bail!("bad/duplicate client id {id} from {peer}");
                    }
                    if codec != cfg.codec {
                        let _ = send(&mut s, &Msg::Shutdown, cfg.codec);
                        Self::shutdown_joined(&mut slots, cfg.codec);
                        bail!(
                            "client {id} from {peer} joined with codec {}, PS runs {}",
                            codec.name(),
                            cfg.codec.name()
                        );
                    }
                    crate::info!("serve: client {id} joined from {peer}");
                    slots[id] = Some(s);
                    joined += 1;
                }
                Ok(other) => {
                    let _ = send(&mut s, &Msg::Shutdown, cfg.codec);
                    Self::shutdown_joined(&mut slots, cfg.codec);
                    bail!("expected Join, got {other:?}");
                }
                Err(e) => {
                    Self::shutdown_joined(&mut slots, cfg.codec);
                    return Err(e.context(format!("recv Join from {peer}")));
                }
            }
        }
        Ok(TcpClientPool {
            conns: slots
                .into_iter()
                .map(|s| WorkerConn { stream: s.unwrap(), fb: FrameBuf::new(), dead: false })
                .collect(),
            backend: make_backend(cfg)?,
            round: 0,
            d: cfg.d(),
            codec: cfg.codec,
            model_frame: Arc::new(Vec::new()),
            model_encodes: 0,
            wire_up: 0,
            wire_down: 0,
        })
    }

    /// Error path of [`Self::accept`]: a bad join must not leave every
    /// already-accepted worker blocked on a model broadcast that will
    /// never come — tell them training is over (best effort; a worker
    /// that died anyway is no reason to skip the rest).
    fn shutdown_joined(slots: &mut [Option<TcpStream>], codec: Codec) {
        for s in slots.iter_mut().flatten() {
            let _ = send(s, &Msg::Shutdown, codec);
        }
    }

    /// `Model` frame serializations so far (exactly one per round).
    pub fn model_encodes(&self) -> u64 {
        self.model_encodes
    }

    /// Round-path bytes actually (received, sent) on the PS sockets.
    pub fn wire_observed(&self) -> (u64, u64) {
        (self.wire_up, self.wire_down)
    }

    /// Total [`FrameBuf`] capacity-growth events across all streams.
    pub fn frame_grows(&self) -> u64 {
        self.conns.iter().map(|wc| wc.fb.grows()).sum()
    }

    /// Tell every worker training is over (dead streams are skipped —
    /// there is nobody listening).
    pub fn shutdown(&mut self) -> Result<()> {
        let codec = self.codec;
        for wc in self.conns.iter_mut().filter(|wc| !wc.dead) {
            send_frame(&mut wc.stream, &Msg::Shutdown, codec, &mut wc.fb)?;
        }
        Ok(())
    }
}

/// One stream's first round half: write the broadcast frame, collect the
/// worker's `Report` (bounds-checked), return it with the received frame
/// size.
fn stream_broadcast_collect(
    wc: &mut WorkerConn,
    frame: &[u8],
    codec: Codec,
    round: u32,
    d: usize,
) -> Result<(ClientReport, usize)> {
    wc.stream.write_all(frame).context("send model frame")?;
    match recv_frame(&mut wc.stream, codec, &mut wc.fb)? {
        Msg::Report { report, mean_loss, round: r, .. } if r == round => {
            // reports are remote input: reject indices outside the model
            // before they reach selection/aggregation
            check_indices(&report.idx, d, "report")?;
            let up = wc.fb.last_recv_frame_len();
            Ok((ClientReport { report, mean_loss }, up))
        }
        other => bail!("round {round}: expected Report, got {other:?}"),
    }
}

/// One stream's second round half: send the index request, collect the
/// worker's `Update` (bounds-checked), return it with the (sent,
/// received) frame sizes.
fn stream_request_collect(
    wc: &mut WorkerConn,
    indices: &[u32],
    codec: Codec,
    round: u32,
    d: usize,
) -> Result<(SparseVec, usize, usize)> {
    let down = send_request(&mut wc.stream, codec, &mut wc.fb, round, indices)?;
    match recv_frame(&mut wc.stream, codec, &mut wc.fb)? {
        Msg::Update { update, round: r, .. } if r == round => {
            // updates scatter-add into the global model: reject
            // out-of-range remote indices here, not as a panic inside
            // aggregation
            check_indices(&update.idx, d, "update")?;
            Ok((update, down, wc.fb.last_recv_frame_len()))
        }
        other => bail!("round {round}: expected Update, got {other:?}"),
    }
}

impl ClientPool for TcpClientPool {
    fn n_clients(&self) -> usize {
        self.conns.len()
    }

    /// Streams that errored (timed out, reset, sent a bad frame) report
    /// as unavailable, so the age-debt scheduler stops spending cohort
    /// slots on clients whose rounds cannot complete. Consumed by drivers
    /// that outlive a failed round (the stock `run_server` loop aborts on
    /// the discovering round; drop-and-continue is the ROADMAP item).
    fn available(&self) -> Vec<bool> {
        self.conns.iter().map(|wc| !wc.dead).collect()
    }

    fn train_and_report(
        &mut self,
        global: &[f32],
        cohort: &[usize],
    ) -> Result<Vec<ClientReport>> {
        self.round += 1;
        self.d = global.len();
        let round = self.round;
        let codec = self.codec;
        let d = self.d;
        let pos = cohort_positions(self.conns.len(), cohort);
        // off-cohort first, inline: a 13-byte Sit per absent worker keeps
        // its round counter in sync without the d-vector — no point
        // spawning a thread for a tiny recv-less write (in the
        // cross-device regime most streams are off-cohort)
        for (i, wc) in self.conns.iter_mut().enumerate() {
            if pos[i] == usize::MAX {
                let sent = send_frame(&mut wc.stream, &Msg::Sit { round }, codec, &mut wc.fb);
                if sent.is_err() {
                    wc.dead = true; // every failed round-path I/O is reported
                }
                let n = sent.with_context(|| format!("client {i} Sit (round {round})"))?;
                self.wire_down += n as u64;
            }
        }
        // zero-copy broadcast: serialize the d-vector frame once — into
        // the buffer reused from last round when every stream thread has
        // dropped its handle — and write the same bytes to every cohort
        // stream
        if let Some(buf) = Arc::get_mut(&mut self.model_frame) {
            encode_model_frame_into(round, global, buf);
        } else {
            self.model_frame = Arc::new(encode_model_frame(round, global));
        }
        self.model_encodes += 1;
        let frame = Arc::clone(&self.model_frame);
        self.wire_down += (cohort.len() * frame.len()) as u64;
        // one thread per cohort stream: a slow worker's local training
        // overlaps its peers' instead of serializing the round in client
        // order
        let collected = std::thread::scope(|scope| -> Result<Vec<(ClientReport, usize)>> {
            let mut handles = Vec::with_capacity(cohort.len());
            for (i, wc) in self.conns.iter_mut().enumerate() {
                if pos[i] == usize::MAX {
                    continue;
                }
                let frame = Arc::clone(&frame);
                handles.push(scope.spawn(move || -> Result<(ClientReport, usize)> {
                    let out = stream_broadcast_collect(wc, &frame, codec, round, d);
                    if out.is_err() {
                        wc.dead = true;
                    }
                    out.with_context(|| format!("client {i} stream (round {round})"))
                }));
            }
            // joining in stream order = ascending client id = cohort order
            handles
                .into_iter()
                .map(|h| h.join().expect("stream thread panicked"))
                .collect()
        })?;
        let mut reports = Vec::with_capacity(collected.len());
        for (rep, up) in collected {
            self.wire_up += up as u64;
            reports.push(rep);
        }
        Ok(reports)
    }

    fn exchange(
        &mut self,
        requests: Option<&[Vec<u32>]>,
        cohort: &[usize],
    ) -> Result<Vec<SparseVec>> {
        let round = self.round;
        let codec = self.codec;
        let d = self.d;
        let pos = cohort_positions(self.conns.len(), cohort);
        let collected = std::thread::scope(|scope| -> Result<Vec<(SparseVec, usize, usize)>> {
            let mut handles = Vec::with_capacity(cohort.len());
            for (i, wc) in self.conns.iter_mut().enumerate() {
                if pos[i] == usize::MAX {
                    continue; // off-cohort workers already got their Sit
                }
                // client-side strategies select locally; the Request frame
                // still flows (empty) so the wire flow stays uniform
                let indices: &[u32] =
                    requests.map(|r| r[pos[i]].as_slice()).unwrap_or(&[]);
                handles.push(scope.spawn(move || -> Result<(SparseVec, usize, usize)> {
                    let out = stream_request_collect(wc, indices, codec, round, d);
                    if out.is_err() {
                        wc.dead = true;
                    }
                    out.with_context(|| format!("client {i} stream (round {round})"))
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("stream thread panicked"))
                .collect()
        })?;
        let mut updates = Vec::with_capacity(collected.len());
        for (update, down, up) in collected {
            self.wire_down += down as u64;
            self.wire_up += up as u64;
            updates.push(update);
        }
        Ok(updates)
    }

    fn backend(&mut self) -> &mut dyn Backend {
        self.backend.as_mut()
    }
}

/// Run the parameter server until `cfg.rounds` rounds complete. Under a
/// sharded topology, shard `s`'s listener binds `port + s` and workers
/// connect to their shard's port (they compute their shard from the
/// shared config — see [`run_worker`]).
pub fn run_server(cfg: &ExperimentConfig, port: u16) -> Result<ServeReport> {
    if cfg.topology == crate::coordinator::topology::Topology::Flat {
        let listener =
            TcpListener::bind(("0.0.0.0", port)).with_context(|| format!("binding :{port}"))?;
        return run_server_on(cfg, listener);
    }
    let listeners = (0..cfg.topology.n_shards())
        .map(|s| {
            let p = port
                .checked_add(s as u16)
                .ok_or_else(|| anyhow::anyhow!("shard {s} port {port}+{s} exceeds 65535"))?;
            TcpListener::bind(("0.0.0.0", p)).with_context(|| format!("binding :{p} (shard {s})"))
        })
        .collect::<Result<Vec<_>>>()?;
    run_sharded_server_on(cfg, listeners)
}

/// [`run_server`] over an already-bound listener (lets tests bind an
/// ephemeral port before spawning workers).
pub fn run_server_on(cfg: &ExperimentConfig, listener: TcpListener) -> Result<ServeReport> {
    cfg.validate()?;
    let mut pool = TcpClientPool::accept(cfg, listener)?;
    let init = pool.backend.init_params()?;
    let mut engine = RoundEngine::new(cfg, init);
    let (_, test) = load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
    let test_idx: Vec<usize> = (0..test.len()).collect();

    for round in 1..=cfg.rounds {
        engine.run_round(&mut pool)?;
        if cfg.eval_every > 0 && round % cfg.eval_every == 0 {
            let (acc, loss) =
                eval_dataset(pool.backend(), engine.global_params(), &test, &test_idx, cfg.batch)?;
            crate::info!(
                "serve: round {round}/{}: acc {:.2}% loss {loss:.4} clusters {}",
                cfg.rounds,
                acc * 100.0,
                engine.ps().clusters().n_clusters()
            );
        }
    }
    pool.shutdown()?;
    let (acc, _) =
        eval_dataset(pool.backend(), engine.global_params(), &test, &test_idx, cfg.batch)?;
    let (wire_up_observed, wire_down_observed) = pool.wire_observed();
    Ok(ServeReport {
        rounds: cfg.rounds,
        final_accuracy: acc,
        cluster_labels: engine.ps().clusters().labels(),
        final_params: engine.global_params().to_vec(),
        uploaded_log: engine.uploaded_log().iter().cloned().collect(),
        comm: engine.comm(),
        model_encodes: pool.model_encodes(),
        wire_up_observed,
        wire_down_observed,
        frame_grows: pool.frame_grows(),
    })
}

/// [`run_server`] for a sharded topology over pre-bound listeners, one
/// per shard in shard order (lets tests bind ephemeral ports before
/// spawning workers). Each shard's [`TcpClientPool`] accepts its slice's
/// workers (joining with **shard-local** ids) and is driven by the shared
/// [`ShardedEngine`]; the root applies one merged server update per round
/// and re-broadcasts through the shards.
///
/// Shard collect phases run serially here — [`TcpClientPool`] owns a
/// non-`Send` PS backend, so it cannot cross shard threads. The per-shard
/// pools still overlap their own workers (thread per stream), and every
/// worker of every shard trains concurrently in its own process; only the
/// PS-side frame pumping serializes across shards.
pub fn run_sharded_server_on(
    cfg: &ExperimentConfig,
    listeners: Vec<TcpListener>,
) -> Result<ServeReport> {
    use crate::coordinator::topology::{client_shards, ShardedEngine};
    cfg.validate()?;
    let shards = cfg.topology.n_shards();
    ensure_listeners(shards, listeners.len())?;
    let slices = client_shards(cfg.n_clients, shards);
    let mut pools: Vec<TcpClientPool> = Vec::with_capacity(shards);
    for ((s, listener), slice) in listeners.into_iter().enumerate().zip(&slices) {
        let mut shard_cfg = cfg.clone();
        shard_cfg.n_clients = slice.len();
        crate::info!("serve: accepting shard {s} ({} clients)", slice.len());
        pools.push(TcpClientPool::accept(&shard_cfg, listener)?);
    }
    let init = pools[0].backend.init_params()?;
    let mut engine = ShardedEngine::new(cfg, init)?;
    let (_, test) = load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
    let test_idx: Vec<usize> = (0..test.len()).collect();

    for round in 1..=cfg.rounds {
        engine.run_round_serial(&mut pools)?;
        if cfg.eval_every > 0 && round % cfg.eval_every == 0 {
            let (acc, loss) = eval_dataset(
                pools[0].backend(),
                engine.global_params(),
                &test,
                &test_idx,
                cfg.batch,
            )?;
            crate::info!(
                "serve: round {round}/{}: acc {:.2}% loss {loss:.4} clusters {} ({} shards)",
                cfg.rounds,
                acc * 100.0,
                engine.n_clusters(),
                engine.n_shards()
            );
        }
    }
    for pool in &mut pools {
        pool.shutdown()?;
    }
    let (acc, _) = eval_dataset(
        pools[0].backend(),
        engine.global_params(),
        &test,
        &test_idx,
        cfg.batch,
    )?;
    // roll the per-shard transport observations up next to the engine's
    // rolled-up accounting: the wire pins hold shard-wise, so they hold
    // for the sums
    let mut wire_up_observed = 0;
    let mut wire_down_observed = 0;
    let mut model_encodes = 0;
    let mut frame_grows = 0;
    for pool in &pools {
        let (up, down) = pool.wire_observed();
        wire_up_observed += up;
        wire_down_observed += down;
        model_encodes += pool.model_encodes();
        frame_grows += pool.frame_grows();
    }
    Ok(ServeReport {
        rounds: cfg.rounds,
        final_accuracy: acc,
        cluster_labels: engine.cluster_labels(),
        final_params: engine.global_params().to_vec(),
        uploaded_log: engine.uploaded_log().iter().cloned().collect(),
        comm: engine.comm(),
        model_encodes,
        wire_up_observed,
        wire_down_observed,
        frame_grows,
    })
}

fn ensure_listeners(shards: usize, got: usize) -> Result<()> {
    if got != shards {
        bail!("sharded server needs {shards} listeners, got {got}");
    }
    Ok(())
}

/// Run one worker process until the PS sends Shutdown. Under a sharded
/// topology the worker joins its shard's PS with its **shard-local** id
/// (computed from the shared config via
/// [`crate::coordinator::topology::locate`] — nothing crosses the wire);
/// `addr` must already point at that shard's listener (the CLI derives
/// `port + shard` from the base port).
pub fn run_worker(cfg: &ExperimentConfig, addr: &str, id: usize) -> Result<()> {
    cfg.validate()?;
    if id >= cfg.n_clients {
        bail!("worker id {id} >= n_clients {}", cfg.n_clients);
    }
    let codec = cfg.codec;
    let pc = PhaseCfg::from_config(cfg);
    let mut backend = make_backend(cfg)?;
    // derive this worker's shard exactly like the simulator does: same
    // seed -> same partition, no data on the wire
    let (train, _) = load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
    let shards = partition(&train, cfg.n_clients, &cfg.partition, cfg.seed);
    let mut client = Client::new(id, train.subset(&shards[id]), backend.init_params()?, cfg.seed);
    let delta = cfg.payload == Payload::Delta;
    let mut memory = if delta { vec![0.0f32; cfg.d()] } else { Vec::new() };

    // under a sharded topology the shard PS indexes streams by
    // shard-local slot; the worker derives its slot from the shared
    // config exactly like the PS does (data/RNG stay keyed by the global
    // id, so training is topology-independent)
    let n_shards = cfg.topology.n_shards();
    let join_id = if n_shards > 1 {
        let (shard, local) = crate::coordinator::topology::locate(cfg.n_clients, n_shards, id);
        crate::info!("worker {id}: shard {shard}, local slot {local}");
        local
    } else {
        id
    };
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    send(&mut stream, &Msg::Join { client_id: join_id as u32, codec }, codec)?;
    crate::info!("worker {id}: joined {addr} (codec {})", codec.name());

    // steady-state transport buffers: one FrameBuf for every frame in and
    // out, plus the model broadcast decoded into a reused parameter vector
    let mut fb = FrameBuf::new();
    let mut params: Vec<f32> = Vec::new();
    loop {
        let payload = recv_payload(&mut stream, &mut fb)?;
        let round = match payload.first().copied() {
            Some(TAG_MODEL) => decode_model_into(payload, &mut params)?,
            _ => match Msg::decode(payload, codec)? {
                // off-cohort this round (partial participation): no
                // broadcast, no training, no upload — just wait for the
                // next frame
                Msg::Sit { .. } => continue,
                Msg::Shutdown => break,
                other => bail!("expected Model/Sit/Shutdown, got {other:?}"),
            },
        };
        // shared phase 1: sync_to (Adam moments persist), H local steps,
        // EF fold, top-r report — the same code the in-process pool runs
        let mem = if delta { Some(&mut memory) } else { None };
        let rep = client_train_phase(&mut client, backend.as_mut(), mem, &params, &pc)?;
        send_report(&mut stream, codec, &mut fb, id as u32, round, &rep.report, rep.mean_loss)?;
        let requested = match recv_frame(&mut stream, codec, &mut fb)? {
            Msg::Request { indices, round: r } if r == round => indices,
            other => bail!("expected Request, got {other:?}"),
        };
        // shared phase 2: answer the PS request, or select locally for
        // client-side strategies (the PS's echo frame is empty then)
        let request = if pc.strategy.needs_report() {
            Some(requested.as_slice())
        } else {
            None
        };
        let mem = if delta { Some(&mut memory) } else { None };
        let update =
            client_update_phase(&mut client, backend.as_mut(), mem, &rep.report, request, &pc)?;
        send_frame(
            &mut stream,
            &Msg::Update { client_id: id as u32, round, update },
            codec,
            &mut fb,
        )?;
    }
    crate::info!("worker {id}: shutdown");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn smoke_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.payload = Payload::Delta;
        cfg.rounds = 3;
        cfg.n_clients = 2;
        cfg.train_n = 200;
        cfg.test_n = 64;
        cfg.eval_every = 0;
        cfg
    }

    #[test]
    fn distributed_round_trip_localhost() {
        let cfg = smoke_cfg();
        let report = crate::testing::run_distributed_localhost(&cfg).unwrap();
        assert_eq!(report.rounds, 3);
        assert_eq!(report.cluster_labels.len(), 2);
        assert_eq!(report.uploaded_log.len(), 3);
        assert!(report.uploaded_log.iter().all(|r| r.len() == 2));
        // zero-copy broadcast: one Model serialization per round, shared
        // across both workers
        assert_eq!(report.model_encodes, 3);
        assert_eq!(report.comm.broadcast_down, 3 * 2 * 4 * cfg.d() as u64);
        // the engine's arithmetic wire accounting equals the bytes that
        // actually crossed the PS sockets
        assert_eq!(report.comm.wire_up, report.wire_up_observed);
        assert_eq!(report.comm.wire_down, report.wire_down_observed);
        assert!(report.wire_up_observed > 0 && report.wire_down_observed > 0);
    }

    /// Steady-state buffer-reuse pin: with fixed frame shapes (raw codec
    /// — every frame size is round-independent) the PS-side FrameBufs
    /// hit their high-water capacity in the first rounds and never grow
    /// again, so the growth count is independent of the round count.
    #[test]
    fn steady_state_rounds_reuse_frame_buffers() {
        let grows_of = |rounds: usize| {
            let mut cfg = smoke_cfg();
            cfg.rounds = rounds;
            crate::testing::run_distributed_localhost(&cfg).unwrap().frame_grows
        };
        let short = grows_of(2);
        let long = grows_of(6);
        assert_eq!(short, long, "per-round frame allocations leak into the growth count");
    }

    /// The packed codec shrinks what actually crosses the sockets; the
    /// raw-vs-packed ratio pin (>= 2x uplink) lives in bench_end2end on
    /// the standard scenario.
    #[test]
    fn packed_codec_shrinks_observed_wire_bytes() {
        let cfg = smoke_cfg();
        let raw = crate::testing::run_distributed_localhost(&cfg).unwrap();
        let mut pcfg = cfg.clone();
        pcfg.codec = Codec::Packed;
        let packed = crate::testing::run_distributed_localhost(&pcfg).unwrap();
        assert!(
            packed.wire_up_observed < raw.wire_up_observed,
            "packed uplink {} must undercut raw {}",
            packed.wire_up_observed,
            raw.wire_up_observed
        );
        assert!(packed.wire_down_observed < raw.wire_down_observed);
        // the semantic §6 counters are codec-independent
        assert_eq!(packed.comm.uplink(), raw.comm.uplink());
        assert_eq!(packed.comm.downlink(), raw.comm.downlink());
    }
}
