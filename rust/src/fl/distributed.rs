//! Multi-process deployment: the PS and each client as separate OS
//! processes speaking the length-prefixed TCP protocol of
//! [`crate::fl::transport`].
//!
//! Both sides are thin adapters over the shared protocol code:
//!
//! * [`run_server`] — binds, waits for `n_clients` joins (each carrying
//!   the protocol version and the worker's [`Codec`]; mismatches are
//!   rejected at accept time), then drives the **same** [`RoundEngine`]
//!   the in-process simulator uses, through [`TcpClientPool`] (the
//!   sockets-backed [`ClientPool`]).
//! * [`run_worker`] — owns one client's shard (derived from the shared
//!   seed + its id, so no data ever crosses the wire) and executes the
//!   same [`client_train_phase`] / [`client_update_phase`] as the
//!   in-process pool — local Adam state persists across rounds via
//!   `sync_to`, exactly like the simulator.
//!
//! The two deployments are therefore bit-for-bit identical on the same
//! config + seed (per-round uploaded indices and final global parameters
//! alike) — pinned by `rust/tests/parity.rs` for the raw **and** the
//! lossless packed codec.
//!
//! **Drop-and-continue** (DESIGN.md §8): a stream that errors or times
//! out mid-round no longer aborts training — the pool reports that
//! client `None` (a casualty), flags the stream dead, and the engine
//! finishes the round with the survivors while the casualty's cluster
//! ages keep growing per eq. (2). A recovered worker **re-admits**
//! itself with a [`Msg::Rejoin`] frame (id + generation): between
//! rounds the PS polls its (now nonblocking) listener, validates the
//! rejoin, answers with a `Model` frame resyncing the current global
//! model, and swaps the fresh stream into the dead slot —
//! [`run_worker_rejoin`] is the worker side.
//!
//! **Delta downlink** (DESIGN.md §9): under [`Downlink::Delta`] the PS
//! broadcasts generation-addressed sparse [`Msg::Delta`] frames —
//! only the parameters changed since each worker's last-acked model
//! generation — and workers patch their held model in place, verifying
//! a streamed content digest; any base/digest mismatch deterministically
//! bails the worker into the rejoin path, where a matching digest lets
//! the PS skip the dense resync entirely (a 13-byte `Sit` ack instead
//! of the 4d-byte `Model` frame).
//!
//! **Event-driven PS transport** (DESIGN.md §10): the PS drives all of
//! its worker sockets from **one reactor** — a hand-rolled `poll(2)`
//! readiness loop ([`crate::fl::reactor`]) over nonblocking streams,
//! with a per-connection state machine (writing-frame → awaiting-reply)
//! that resumes half-done frames across partial writes and short reads
//! via the resumable cursors of [`crate::fl::transport`]. No
//! thread-per-stream: connection count scales to the fd limit, a slow
//! worker never blocks its peers, and per-connection **phase deadlines**
//! (`io_timeout_ms`) replace the old blocking socket timeouts — a hung
//! or trickling worker is dropped as a clean per-client casualty when
//! its deadline expires, never by a thread join panic.
//!
//! Steady-state rounds perform **no per-frame buffer allocations** on
//! either end: every stream owns a [`FrameBuf`] (encode scratch + recv
//! payload buffer), the worker decodes/patches the broadcast into a
//! reused parameter vector, and the PS encodes each distinct broadcast
//! frame into a [`FrameRotation`] slot reclaimed as soon as its last
//! assigned connection finishes the write. (Decoded *messages* still
//! own their payload `Vec`s — a received report/update flows into the
//! engine by value.) [`ServeReport::frame_grows`] exposes the PS-side
//! buffer-growth count so tests can pin the reuse.
//!
//! Both ends use the same `ExperimentConfig`; run e.g.:
//!
//! ```sh
//! ragek serve  --clients 4 --port 7700 --rounds 40 &
//! for i in 0 1 2 3; do ragek worker --connect 127.0.0.1:7700 --id $i & done
//! # a crashed worker re-admits itself:
//! ragek worker --connect 127.0.0.1:7700 --id 2 --rejoin 1
//! ```

// The transport's semantics ARE wall-clock time — per-phase I/O
// deadlines, EWMA-adaptive reply windows, handshake expiry — so the
// clippy.toml `disallowed-methods` ban on clock reads (which keeps the
// simulation and codec layers deterministic) is lifted for this module
// as a whole. The *decisions* those clocks feed are pure and
// model-checked in `crate::fl::conn_fsm` (DESIGN.md §13).
#![allow(clippy::disallowed_methods)]

use crate::backend::{make_backend, Backend};
use crate::config::{Downlink, ExperimentConfig, Payload};
use crate::coordinator::engine::{
    client_train_phase, client_update_phase, eval_dataset, BroadcastPlan, ClientPool,
    ClientReport, CohortMap, PhaseCfg, RoundEngine,
};
use crate::coordinator::topology::Reshard;
use crate::data::{load_dataset, partition::partition};
use crate::fl::client::Client;
use crate::fl::codec::{params_digest, Codec, FrameBuf, IndexScratch};
use crate::fl::conn_fsm::{
    cancel_deadline_ms, conn_step, handshake_step, phase_deadline_ms, CasualtyKind, ConnEvent,
    ConnState, Effect, HandshakeDecision, HandshakeRead, ReadOutcome, WriteOutcome,
};
use crate::fl::metrics::CommStats;
use crate::fl::reactor::{poll_fds, PollFd, POLLIN, POLLOUT};
use crate::fl::transport::{
    apply_delta_in_place, decode_model_into, encode_delta_frame_into, encode_frame_into,
    encode_model_frame, encode_model_frame_into, encode_request_into, recv_frame,
    recv_payload, send, send_frame, send_report, IoStep, Msg, RecvCursor, SendCursor,
    SIT_FRAME_BYTES, TAG_DELTA, TAG_MODEL,
};
use crate::sparse::SparseVec;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// PS-side summary of a distributed run.
#[derive(Debug)]
pub struct ServeReport {
    pub rounds: usize,
    pub final_accuracy: f32,
    pub cluster_labels: Vec<usize>,
    /// final global model (sim/distributed parity checks)
    pub final_params: Vec<f32>,
    /// per round, per client: the uploaded index sets (empty entries for
    /// clients off that round's cohort)
    pub uploaded_log: Vec<Vec<Vec<u32>>>,
    /// the engine's byte-accurate communication accounting
    pub comm: CommStats,
    /// how many times the PS serialized a dense `Model` frame — the
    /// zero-copy broadcast pin: exactly one per round under the dense
    /// downlink, however many workers; zero on a healthy delta-downlink
    /// run (every broadcast is a sparse `Delta` frame)
    pub model_encodes: u64,
    /// round-path bytes the PS actually received on its sockets (report +
    /// update frames) — pinned equal to the engine's `comm.wire_up` on
    /// casualty-free runs
    pub wire_up_observed: u64,
    /// round-path bytes the PS wrote (or attempted — a frame is counted
    /// when its write starts, so a stream dying mid-frame does not skew
    /// the count) to its sockets — pinned equal to `comm.wire_down`
    pub wire_down_observed: u64,
    /// PS-side [`FrameBuf`] capacity-growth events across all streams —
    /// constant once the first rounds set the high-water mark (the
    /// buffer-reuse steady-state pin)
    pub frame_grows: u64,
    /// total casualty events (a client dropping mid-round) across the run
    pub casualties: u64,
    /// total accepted `Rejoin` re-admissions across the run
    pub rejoins: u64,
    /// total speculative cancellations (a straggler cleanly parked after
    /// the round committed with the first `m` reports — not a casualty)
    pub cancellations: u64,
    /// bytes of stale frames (late reports from cancelled rounds)
    /// drained off the PS sockets and discarded — counted here, never in
    /// `wire_up_observed`, so the engine's committed-frame wire mirror
    /// still pins exactly under speculation
    pub drained_up: u64,
}

/// One accepted worker stream (nonblocking) plus its reused transport
/// buffers and its reactor state machine.
struct WorkerConn {
    stream: TcpStream,
    fb: FrameBuf,
    /// resumable write offset into the queued outgoing frame
    send: SendCursor,
    /// resumable header/payload fill of the incoming frame
    recv: RecvCursor,
    /// position in the current reactor phase
    state: ConnState,
    /// a shared broadcast frame (a [`FrameRotation`] slot) queued for
    /// write; `None` means the outgoing frame lives in `fb.buf` (Sit,
    /// Request). Cleared the moment the last byte is out so the rotation
    /// slot's refcount can drop back to one and be reclaimed.
    shared: Option<Arc<Vec<u8>>>,
    /// when the current phase gives up on this connection (armed per
    /// phase from `io_timeout_ms`; `None` = wait forever)
    deadline: Option<Instant>,
    /// set by a routed (sharded) re-admission — [`ClientPool::poll_rejoins`]
    /// drains it so the engine learns of the rejoin at the usual point
    admitted: bool,
    /// a round-path send/recv on this stream failed (deadline expiry,
    /// reset, bad frame): the pool skips it and reports the client
    /// unreachable through [`ClientPool::health`] until a `Rejoin`
    /// replaces the stream
    dead: bool,
    /// EWMA of this stream's completed write→reply phase times in
    /// milliseconds (0 = no sample yet) — the estimate behind the
    /// adaptive per-client deadline (DESIGN.md §11)
    ewma_ms: f32,
    /// the adaptive deadline has already been re-armed once this phase
    /// (the one bounded retry before the drop)
    retried: bool,
    /// stale inbound frames to discard before the next real reply: a
    /// speculative cancel leaves exactly one late `Report` in flight
    /// (the worker sent it before reading the cancel `Sit`), drained
    /// here with its bytes tallied in the pool's `drained_up` — never
    /// in `wire_up`, which counts committed round-path frames only
    drain_frames: u32,
}

impl WorkerConn {
    /// Wrap a stream that is already in nonblocking mode.
    fn new(stream: TcpStream) -> Self {
        WorkerConn {
            stream,
            fb: FrameBuf::new(),
            send: SendCursor::new(),
            recv: RecvCursor::new(),
            state: ConnState::Idle,
            shared: None,
            deadline: None,
            admitted: false,
            dead: false,
            ewma_ms: 0.0,
            retried: false,
            drain_frames: 0,
        }
    }
}

/// A connection whose first frame (`Join` or `Rejoin`) is still
/// trickling in. Handshakes are part of the nonblocking state machine
/// (DESIGN.md §11): the listener and every pending stream are *polled*,
/// so a connect-and-stall client holds only its own slot in this list —
/// dropped at its deadline — and can never wedge accept or block the
/// round loop the way the old blocking per-stream `recv` did.
struct PendingHandshake {
    stream: TcpStream,
    peer: std::net::SocketAddr,
    /// resumable fill of the handshake frame
    recv: RecvCursor,
    fb: FrameBuf,
    /// when this handshake is given up on (`io_timeout_ms`; `None` = 0 =
    /// no deadline, consistent with every other deadline in this module)
    deadline: Option<Instant>,
}

/// What one nonblocking step of a pending handshake produced.
enum HandshakeStep {
    /// frame still incomplete, deadline not reached — keep it pending
    Pending,
    /// the handshake frame is complete in `fb.payload`
    Frame,
    /// the connection is done for (I/O error, EOF, bad framing, or its
    /// deadline expired mid-handshake) — drop it, log `why`
    Dropped(String),
}

impl PendingHandshake {
    fn new(stream: TcpStream, peer: std::net::SocketAddr, io_timeout_ms: u64) -> Self {
        PendingHandshake {
            stream,
            peer,
            recv: RecvCursor::new(),
            fb: FrameBuf::new(),
            deadline: phase_deadline_ms(io_timeout_ms, 0.0, 0, 0.0)
                .map(|ms| Instant::now() + Duration::from_millis(ms)),
        }
    }

    /// Pull whatever bytes are ready (the stream is nonblocking; this
    /// never blocks), classify the outcome, and let the pure decision
    /// table ([`handshake_step`]) say where the handshake stands.
    fn step(&mut self) -> HandshakeStep {
        let mut io_err: Option<anyhow::Error> = None;
        let read = match self.recv.advance(&mut self.stream, &mut self.fb) {
            Ok(IoStep::Done) => HandshakeRead::Frame,
            Ok(IoStep::Pending) => HandshakeRead::Pending,
            Err(e) => {
                io_err = Some(e);
                HandshakeRead::Failed
            }
        };
        let expired = self.deadline.is_some_and(|dl| Instant::now() >= dl);
        match handshake_step(read, expired) {
            HandshakeDecision::Complete => HandshakeStep::Frame,
            HandshakeDecision::Keep => HandshakeStep::Pending,
            HandshakeDecision::DropExpired => {
                HandshakeStep::Dropped("handshake deadline expired".into())
            }
            HandshakeDecision::DropFailed => HandshakeStep::Dropped(match io_err {
                Some(e) => format!("{e:#}"),
                None => "handshake I/O failed".into(),
            }),
        }
    }
}

/// One worker stream's transferable state — what a dynamic re-shard
/// hands between shard pools (the workers' sockets stay open; only the
/// PS-side ownership moves).
pub struct TcpCarry {
    conn: WorkerConn,
    last_generation: u32,
}

/// A rotation of reusable broadcast frame buffers.
///
/// PR 5's single reusable `Arc<Vec<u8>>` had a silent fallback: if any
/// stream thread still held a clone at encode time, `Arc::get_mut`
/// failed and the pool allocated a fresh frame — a per-round allocation
/// invisible to [`ServeReport::frame_grows`]. The delta downlink makes
/// the problem structural: one round may need *several* distinct frames
/// live at once (the dense fallback plus one delta frame per distinct
/// base generation). The rotation keeps a small pool of `Arc` slots;
/// [`FrameRotation::checkout`] fills the first slot whose refcount has
/// dropped back to one (the scoped broadcast threads join before
/// `train_and_report` returns, so by the next round every slot is
/// reclaimable) and only **adds a slot** — counted in
/// [`FrameRotation::grows`] — when none is free. Steady-state rounds
/// therefore allocate no frame buffers, and the growth count is
/// deterministic: it counts slot additions, not byte-capacity growth,
/// so varying delta frame sizes do not perturb the reuse pin.
struct FrameRotation {
    slots: Vec<Arc<Vec<u8>>>,
    grows: u64,
}

impl FrameRotation {
    fn new() -> Self {
        FrameRotation { slots: Vec::new(), grows: 0 }
    }

    /// Hand out a frame buffer filled by `fill`: the first unshared slot
    /// is reused in place; if every slot is still referenced a new one
    /// is added (a growth event).
    fn checkout(&mut self, fill: impl FnOnce(&mut Vec<u8>)) -> Arc<Vec<u8>> {
        for slot in &mut self.slots {
            if let Some(buf) = Arc::get_mut(slot) {
                fill(buf);
                return Arc::clone(slot);
            }
        }
        self.grows += 1;
        let mut buf = Vec::new();
        fill(&mut buf);
        let slot = Arc::new(buf);
        self.slots.push(Arc::clone(&slot));
        slot
    }

    /// Slot additions so far (the steady-state reuse pin).
    fn grows(&self) -> u64 {
        self.grows
    }
}

/// Sparse frames are remote input: every index must address the model.
/// Rejecting here turns a corrupt/malicious worker into a clean protocol
/// error instead of a PS panic (aggregation) or an index-sized
/// allocation (selection's stamp vector).
fn check_indices(idx: &[u32], d: usize, what: &str) -> Result<()> {
    if let Some(&bad) = idx.iter().find(|&&j| j as usize >= d) {
        bail!("{what} index {bad} out of range (d = {d})");
    }
    Ok(())
}

/// The sockets-backed [`ClientPool`]: one TCP stream per remote worker,
/// indexed by client id. Owns the PS-side backend (server optimizer
/// apply + evaluation) and keeps its listener (nonblocking after the
/// initial joins) so recovered workers can re-admit themselves with a
/// `Rejoin` frame between rounds.
///
/// Broadcast/collect is **event-driven** — every stream runs
/// nonblocking and a single [`poll(2)` reactor](crate::fl::reactor)
/// interleaves all of them, resuming each half-done frame whenever its
/// socket is ready, so a slow worker overlaps with its peers without a
/// thread per stream (connection count scales to the fd limit, not the
/// thread limit). The broadcast frames are **zero-copy**: each distinct
/// frame this round needs (one dense `Model` frame, and under
/// [`Downlink::Delta`] one `Delta` frame per distinct base generation
/// in the engine's [`BroadcastPlan`]) is encoded once into an
/// `Arc<Vec<u8>>` checked out of a [`FrameRotation`] of buffers
/// *reused across rounds*, and the same bytes are shared by every
/// cohort stream assigned that frame. Workers outside the round's
/// cohort get their 13-byte [`Msg::Sit`] frames in the same batched
/// reactor write pass, so downlink scales with the cohort, not with n.
/// A stream that fails — or overruns its per-phase deadline — is
/// flagged dead and its client reported as a casualty (`None`); the
/// round continues with the survivors.
pub struct TcpClientPool {
    conns: Vec<WorkerConn>,
    /// the accept listener, nonblocking once every initial join landed —
    /// polled for `Rejoin` frames between rounds
    listener: TcpListener,
    backend: Box<dyn Backend>,
    round: u32,
    /// model dimension of the current run (set at the first broadcast;
    /// bounds-checks decoded sparse frames)
    d: usize,
    /// the wire format every worker negotiated at Join time
    codec: Codec,
    /// per-connection per-phase reactor deadline (0 = none); also applied
    /// as a blocking socket timeout to join/rejoin handshakes
    io_timeout_ms: u64,
    /// adaptive-deadline multiplier `k` (0 = adaptive deadlines off; the
    /// per-phase window is then the flat `io_timeout_ms` for everyone)
    deadline_factor: f64,
    /// floor of the adaptive window in milliseconds
    deadline_min_ms: u64,
    /// speculative commit quota for the next `train_and_report` (set by
    /// the engine when `overschedule > 0`; `None` = commit everyone)
    quota: Option<usize>,
    /// stragglers cleanly cancelled by the last speculative commit,
    /// drained by [`ClientPool::take_cancelled`]
    cancelled: Vec<usize>,
    /// completed (client, ms) phase timings, drained by
    /// [`ClientPool::take_phase_timings`] into the fleet's EWMA records
    timings: Vec<(usize, f32)>,
    /// handshakes still trickling in (nonblocking accept machinery;
    /// persists across rounds so a slow joiner spans poll passes)
    pending: Vec<PendingHandshake>,
    /// bytes of stale frames (late reports from cancelled rounds)
    /// drained off the wire and discarded — kept out of `wire_up` so the
    /// engine's committed-frame mirror still pins exactly
    drained_up: u64,
    /// reused `poll(2)` interest set (rebuilt each reactor iteration,
    /// capacity retained across rounds)
    pollfds: Vec<PollFd>,
    /// reused map from `pollfds` entry to connection index
    pollidx: Vec<usize>,
    /// reused list of the connections armed for the current phase
    armed: Vec<usize>,
    /// sharded serving: `Rejoin` handshakes are drained and routed by
    /// [`route_rejoins`] (any shard's listener, landing at the current
    /// owner), so [`ClientPool::poll_rejoins`] only surfaces
    /// already-admitted slots instead of accepting itself
    routed_rejoins: bool,
    /// per client: the last admitted `Rejoin` generation (0 = original
    /// join) — a rejoin must carry a strictly larger one, so a flapping
    /// worker's stale duplicate connect is refused
    last_generation: Vec<u32>,
    /// reused client-id -> cohort-position map
    cmap: CohortMap,
    /// the rotation of reusable broadcast frame buffers (see the struct
    /// docs)
    rotation: FrameRotation,
    /// the engine's delta-downlink plan for the upcoming broadcast
    /// (delivered via [`ClientPool::set_broadcast_plan`]; `None` under
    /// the dense downlink — then every cohort stream gets the full
    /// `Model` frame)
    plan: Option<BroadcastPlan>,
    /// reused delta-value gather scratch (encode_delta_frame_into)
    val_scratch: Vec<f32>,
    /// reused index-packing scratch (packed-codec delta frames)
    idx_scratch: IndexScratch,
    /// dense `Model` frame serializations so far (one per round under the
    /// dense downlink; only fallback resyncs under the delta downlink —
    /// pinned by tests via [`ServeReport::model_encodes`])
    model_encodes: u64,
    /// round-path bytes received (report/update frames, header included)
    wire_up: u64,
    /// round-path bytes sent — attempted-frame accounting: a frame
    /// counts when its write starts, so it matches the engine's
    /// arithmetic mirror even when a stream dies mid-frame
    wire_down: u64,
    /// accepted rejoins (diagnostics; [`ServeReport::rejoins`])
    rejoins: u64,
}

impl TcpClientPool {
    /// Wait on an already-bound listener until all `cfg.n_clients`
    /// workers joined with a matching wire codec. Binding is the caller's
    /// job so tests can bind an ephemeral port *before* any worker spawns
    /// (joins then queue in the accept backlog — no sleeps, no port
    /// races). The listener and every half-done handshake run
    /// **nonblocking** from the first byte (DESIGN.md §11): a client
    /// that connects and then stalls — or trickles its `Join` a byte at
    /// a time — occupies only its own [`PendingHandshake`] slot, is
    /// dropped cleanly when its `io_timeout_ms` deadline expires, and
    /// never blocks the other joiners the way the old blocking
    /// per-stream `recv` did. Protocol violations on a *complete* frame
    /// (bad/duplicate id, codec mismatch, a non-`Join` message) still
    /// abort the accept exactly as before.
    pub fn accept(cfg: &ExperimentConfig, listener: TcpListener) -> Result<Self> {
        crate::info!(
            "serve: waiting for {} clients on {:?} (codec {})",
            cfg.n_clients,
            listener.local_addr(),
            cfg.codec.name()
        );
        listener
            .set_nonblocking(true)
            .context("switching the join listener to nonblocking accept")?;
        let mut slots: Vec<Option<TcpStream>> = (0..cfg.n_clients).map(|_| None).collect();
        let mut joined = 0;
        let mut pending: Vec<PendingHandshake> = Vec::new();
        let mut pollfds: Vec<PollFd> = Vec::new();
        while joined < cfg.n_clients {
            // one readiness pass over the listener plus every pending
            // handshake; the poll timeout is the nearest handshake
            // deadline (None = no deadline anywhere = wait forever)
            pollfds.clear();
            pollfds.push(PollFd::new(listener.as_raw_fd(), POLLIN));
            for ph in &pending {
                pollfds.push(PollFd::new(ph.stream.as_raw_fd(), POLLIN));
            }
            let timeout = pending
                .iter()
                .filter_map(|ph| ph.deadline)
                .min()
                .map(|dl| dl.saturating_duration_since(Instant::now()));
            poll_fds(&mut pollfds, timeout)?;
            // accept every queued connect into a fresh pending handshake
            loop {
                match listener.accept() {
                    Ok((s, peer)) => {
                        s.set_nonblocking(true)
                            .context("switching a joining stream to nonblocking mode")?;
                        pending.push(PendingHandshake::new(s, peer, cfg.io_timeout_ms));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) => return Err(anyhow::Error::new(e).context("accepting a join")),
                }
            }
            // advance every pending handshake one nonblocking step
            let mut k = 0;
            while k < pending.len() {
                match pending[k].step() {
                    HandshakeStep::Pending => k += 1,
                    HandshakeStep::Dropped(why) => {
                        let ph = pending.swap_remove(k);
                        crate::info!(
                            "serve: dropped a joining connection from {}: {why}",
                            ph.peer
                        );
                    }
                    HandshakeStep::Frame => {
                        let mut ph = pending.swap_remove(k);
                        let peer = ph.peer;
                        match Msg::decode(&ph.fb.payload, cfg.codec) {
                            Ok(Msg::Join { client_id, codec }) => {
                                let id = client_id as usize;
                                if id >= cfg.n_clients || slots[id].is_some() {
                                    let _ = ph.stream.set_nonblocking(false);
                                    let _ = send(&mut ph.stream, &Msg::Shutdown, cfg.codec);
                                    Self::shutdown_joined(&mut slots, cfg.codec);
                                    bail!("bad/duplicate client id {id} from {peer}");
                                }
                                if codec != cfg.codec {
                                    let _ = ph.stream.set_nonblocking(false);
                                    let _ = send(&mut ph.stream, &Msg::Shutdown, cfg.codec);
                                    Self::shutdown_joined(&mut slots, cfg.codec);
                                    bail!(
                                        "client {id} from {peer} joined with codec {}, PS runs {}",
                                        codec.name(),
                                        cfg.codec.name()
                                    );
                                }
                                crate::info!("serve: client {id} joined from {peer}");
                                // already nonblocking — exactly what the
                                // round reactor wants
                                slots[id] = Some(ph.stream);
                                joined += 1;
                            }
                            Ok(other) => {
                                let _ = ph.stream.set_nonblocking(false);
                                let _ = send(&mut ph.stream, &Msg::Shutdown, cfg.codec);
                                Self::shutdown_joined(&mut slots, cfg.codec);
                                bail!("expected Join, got {other:?}");
                            }
                            Err(e) => {
                                Self::shutdown_joined(&mut slots, cfg.codec);
                                return Err(e.context(format!("recv Join from {peer}")));
                            }
                        }
                    }
                }
            }
        }
        // the accept loop only exits once `joined == n_clients`, so every
        // slot is filled — but a protocol edge never panics on its own
        // invariant: a hole is a clean error, not an abort
        let mut conns = Vec::with_capacity(slots.len());
        for (id, s) in slots.into_iter().enumerate() {
            match s {
                Some(s) => conns.push(WorkerConn::new(s)),
                None => bail!("internal: accept loop finished with client {id} unjoined"),
            }
        }
        Ok(TcpClientPool {
            conns,
            listener,
            backend: make_backend(cfg)?,
            round: 0,
            d: cfg.d(),
            codec: cfg.codec,
            io_timeout_ms: cfg.io_timeout_ms,
            deadline_factor: cfg.deadline_factor,
            deadline_min_ms: cfg.deadline_min_ms,
            quota: None,
            cancelled: Vec::new(),
            timings: Vec::new(),
            // a handshake still trickling when the fleet completes keeps
            // its slot and deadline across the round loop's rejoin polls
            pending,
            drained_up: 0,
            pollfds,
            pollidx: Vec::new(),
            armed: Vec::new(),
            routed_rejoins: false,
            last_generation: vec![0; cfg.n_clients],
            cmap: CohortMap::new(),
            rotation: FrameRotation::new(),
            plan: None,
            val_scratch: Vec::new(),
            idx_scratch: IndexScratch::default(),
            model_encodes: 0,
            wire_up: 0,
            wire_down: 0,
            rejoins: 0,
        })
    }

    /// Error path of [`Self::accept`]: a bad join must not leave every
    /// already-accepted worker blocked on a model broadcast that will
    /// never come — tell them training is over (best effort; a worker
    /// that died anyway is no reason to skip the rest).
    fn shutdown_joined(slots: &mut [Option<TcpStream>], codec: Codec) {
        for s in slots.iter_mut().flatten() {
            let _ = send(s, &Msg::Shutdown, codec);
        }
    }

    /// Dense `Model` frame serializations so far (exactly one per round
    /// under the dense downlink; zero on a healthy delta-downlink run).
    pub fn model_encodes(&self) -> u64 {
        self.model_encodes
    }

    /// Round-path bytes actually (received, attempted-sent) on the PS
    /// sockets.
    pub fn wire_observed(&self) -> (u64, u64) {
        (self.wire_up, self.wire_down)
    }

    /// Total [`FrameBuf`] capacity-growth events across all streams,
    /// plus broadcast [`FrameRotation`] slot additions.
    pub fn frame_grows(&self) -> u64 {
        self.conns.iter().map(|wc| wc.fb.grows()).sum::<u64>() + self.rotation.grows()
    }

    /// Accepted `Rejoin` re-admissions so far.
    pub fn rejoins(&self) -> u64 {
        self.rejoins
    }

    /// Bytes of stale frames (late reports from speculatively cancelled
    /// rounds) drained off the sockets and discarded — the exact-wire
    /// complement of `wire_up`, which counts committed frames only.
    pub fn drained_up(&self) -> u64 {
        self.drained_up
    }

    /// Tell every worker training is over (best effort — dead streams
    /// are skipped, and a stream failing its goodbye is merely marked
    /// dead), then drain any worker still queued for re-admission so it
    /// is not left blocking on a resync that will never come.
    pub fn shutdown(&mut self) -> Result<()> {
        let codec = self.codec;
        let io_timeout_ms = self.io_timeout_ms;
        for wc in self.conns.iter_mut().filter(|wc| !wc.dead) {
            // the reactor is done with this stream — the goodbye is a
            // plain blocking write again, bounded by the socket deadline
            // (0 = none, like every other deadline in this module)
            let _ = wc.stream.set_nonblocking(false);
            let _ = set_stream_deadline(&wc.stream, io_timeout_ms);
            if send_frame(&mut wc.stream, &Msg::Shutdown, codec, &mut wc.fb).is_err() {
                wc.dead = true;
            }
        }
        while let Ok((mut s, _)) = self.listener.accept() {
            let _ = s.set_nonblocking(false);
            let _ = send(&mut s, &Msg::Shutdown, codec);
        }
        Ok(())
    }

    /// The nonblocking handshake pump (DESIGN.md §11): accept every
    /// queued connect into a [`PendingHandshake`], advance each pending
    /// handshake one readiness step, and move the ones whose first frame
    /// completed into `done`. **Never blocks**: a byte-trickling or
    /// stalled client just stays in `self.pending` across rounds —
    /// dropped with a log line when its `io_timeout_ms` deadline expires
    /// — so a wedged joiner cannot stall the round loop between rounds
    /// the way the old blocking per-stream `recv` could.
    fn pump_handshakes(&mut self, done: &mut Vec<PendingHandshake>) -> Result<()> {
        loop {
            match self.listener.accept() {
                Ok((s, peer)) => {
                    s.set_nonblocking(true)
                        .context("switching a handshake stream to nonblocking mode")?;
                    self.pending.push(PendingHandshake::new(s, peer, self.io_timeout_ms));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(anyhow::Error::new(e).context("polling for rejoins")),
            }
        }
        let mut k = 0;
        while k < self.pending.len() {
            match self.pending[k].step() {
                HandshakeStep::Pending => k += 1,
                HandshakeStep::Dropped(why) => {
                    let ph = self.pending.swap_remove(k);
                    crate::info!("serve: dropped a pending handshake from {}: {why}", ph.peer);
                }
                HandshakeStep::Frame => done.push(self.pending.swap_remove(k)),
            }
        }
        Ok(())
    }

    /// Sharded serving: drain this shard listener's completed `Rejoin`
    /// handshakes into `arrivals` **without admitting them** — the
    /// handshake names a *global* client id, and which shard currently
    /// owns that id is the root's call ([`route_rejoins`]). Only the
    /// codec is validated here; generation checks belong to the owning
    /// pool, whose ledger the stream will land in.
    fn drain_rejoin_handshakes(&mut self, arrivals: &mut Vec<RejoinArrival>) -> Result<()> {
        let mut done = Vec::new();
        self.pump_handshakes(&mut done)?;
        for ph in done {
            let PendingHandshake { mut stream, peer, fb, .. } = ph;
            // the handshake frame is in hand: the answer (resync or
            // refusal) is a plain blocking write again, bounded by the
            // socket deadline (0 = none)
            stream.set_nonblocking(false).context("rejoin stream blocking mode")?;
            set_stream_deadline(&stream, self.io_timeout_ms)?;
            match Msg::decode(&fb.payload, self.codec) {
                Ok(Msg::Rejoin { client_id, generation, held_digest, codec }) => {
                    if codec != self.codec {
                        crate::info!(
                            "serve: refused rejoin from {peer} (client {client_id} \
                             joined with codec {}, PS runs {})",
                            codec.name(),
                            self.codec.name()
                        );
                        let _ = send(&mut stream, &Msg::Shutdown, self.codec);
                        continue;
                    }
                    arrivals.push(RejoinArrival {
                        stream,
                        peer,
                        global_id: client_id as usize,
                        generation,
                        held_digest,
                    });
                }
                Ok(other) => {
                    crate::info!("serve: expected Rejoin from {peer}, got {other:?}");
                    let _ = send(&mut stream, &Msg::Shutdown, self.codec);
                }
                Err(e) => {
                    crate::info!("serve: bad rejoin handshake from {peer}: {e:#}");
                }
            }
        }
        Ok(())
    }

    /// Admit a routed rejoin at this pool's `local` slot (the slot that
    /// currently owns the arrival's global id): same generation fencing,
    /// displacement, and digest-verified resync as the flat path in
    /// [`ClientPool::poll_rejoins`], but the admitted slot is flagged so
    /// the engine's next `poll_rejoins` surfaces it.
    fn admit_routed(&mut self, local: usize, arrival: RejoinArrival, global: &[f32]) -> Result<()> {
        let RejoinArrival { mut stream, peer, global_id, generation, held_digest } = arrival;
        if generation <= self.last_generation[local] {
            crate::info!("serve: refused rejoin from {peer} (client {global_id} gen {generation})");
            let _ = send(&mut stream, &Msg::Shutdown, self.codec);
            return Ok(());
        }
        if !self.conns[local].dead {
            let wc = &mut self.conns[local];
            let _ = wc.stream.set_nonblocking(false);
            let _ = send_frame(&mut wc.stream, &Msg::Shutdown, self.codec, &mut wc.fb);
            crate::info!("serve: rejoin displaces client {global_id}'s stale stream");
        }
        if held_digest != 0 && held_digest == params_digest(global) {
            if let Err(e) = send(&mut stream, &Msg::Sit { round: self.round }, self.codec) {
                crate::info!("serve: rejoin digest ack to client {global_id} failed: {e:#}");
                return Ok(());
            }
            crate::info!(
                "serve: client {global_id} rejoin digest proof accepted — resync skipped"
            );
        } else {
            let frame = encode_model_frame(self.round, global);
            if let Err(e) = stream.write_all(&frame) {
                crate::info!("serve: rejoin resync to client {global_id} failed: {e:#}");
                return Ok(());
            }
        }
        stream.set_nonblocking(true).context("rejoined stream nonblocking mode")?;
        crate::info!(
            "serve: client {global_id} rejoined from {peer} (generation {generation}) \
             -> shard slot {local}"
        );
        let mut wc = WorkerConn::new(stream);
        wc.admitted = true;
        self.conns[local] = wc;
        self.last_generation[local] = generation;
        self.rejoins += 1;
        Ok(())
    }
}

/// One drained, codec-validated `Rejoin` handshake awaiting routing to
/// the shard that currently owns its global client id.
struct RejoinArrival {
    stream: TcpStream,
    peer: std::net::SocketAddr,
    /// the **global** client id the worker rejoins as (the wire carries
    /// global ids so routing survives re-sharding)
    global_id: usize,
    generation: u32,
    held_digest: u64,
}

/// Sharded-TCP rejoin routing (closes the PR 5 addressing gap): a
/// recovered worker knocks on the port it always knew — its *original*
/// shard's listener — but re-sharding may have moved its stream's
/// ownership since. Before each round the topology driver drains every
/// shard's queued handshakes here and admits each one at the slot the
/// **current** assignment gives its global id, wherever that is.
fn route_rejoins(
    pools: &mut [TcpClientPool],
    slices: &[Vec<usize>],
    global: &[f32],
) -> Result<()> {
    let mut arrivals = Vec::new();
    for pool in pools.iter_mut() {
        pool.drain_rejoin_handshakes(&mut arrivals)?;
    }
    for arrival in arrivals {
        match locate_in_slices(slices, arrival.global_id) {
            Some((shard, local)) => pools[shard].admit_routed(local, arrival, global)?,
            None => {
                let RejoinArrival { mut stream, peer, global_id, .. } = arrival;
                crate::info!("serve: refused rejoin from {peer} (unknown client {global_id})");
                let _ = send(&mut stream, &Msg::Shutdown, pools[0].codec);
            }
        }
    }
    Ok(())
}

/// Which (shard, local slot) currently owns `global_id` under the given
/// assignment. Linear scan: slices are small, and nothing here assumes
/// the contiguity the static `locate` arithmetic needs.
fn locate_in_slices(slices: &[Vec<usize>], global_id: usize) -> Option<(usize, usize)> {
    slices.iter().enumerate().find_map(|(shard, slice)| {
        slice.iter().position(|&g| g == global_id).map(|local| (shard, local))
    })
}

/// Apply a blocking-socket deadline, with **`0` = disabled** — the one
/// definition of the knob's zero case on the blocking paths (handshake
/// answers, shutdown goodbyes, the worker's own stream). Zero
/// *explicitly clears* any timeout rather than being skipped or — the
/// trap std itself guards against — passed through as `Duration::ZERO`,
/// which `set_read_timeout` rejects as `InvalidInput` ("instant expiry"
/// is not a thing either end supports). Pinned together with the
/// reactor end by `zero_io_timeout_disables_deadlines_at_both_ends`.
fn set_stream_deadline(s: &TcpStream, io_timeout_ms: u64) -> Result<()> {
    let dl = (io_timeout_ms > 0).then(|| Duration::from_millis(io_timeout_ms));
    s.set_read_timeout(dl).context("set_read_timeout")?;
    s.set_write_timeout(dl).context("set_write_timeout")?;
    Ok(())
}

impl TcpClientPool {
    /// The reactor: drive every armed connection's state machine to
    /// `Done` (or death) in one `poll(2)` readiness loop.
    ///
    /// The loop owns the I/O only: cursor outcomes are classified into
    /// [`ConnEvent`]s, every state change goes through the pure
    /// [`conn_step`] table (exhaustively model-checked in
    /// [`crate::fl::conn_fsm`]), and the returned [`Effect`] tells this
    /// loop which sockets, buffers, and byte counters to touch.
    ///
    /// Each armed connection enters `Writing` with its outgoing frame
    /// queued (a shared rotation `Arc`, or the connection's own
    /// `fb.buf`); the loop polls `POLLOUT` for writers and `POLLIN` for
    /// readers, resumes the half-done frame of every ready socket via
    /// its cursors, and flips `Writing → Reading` (when a reply is
    /// expected) or `→ Done`. A completed reply frame is handed to
    /// `on_frame(conn_index, payload, frame_len)`; an `Err` from it —
    /// bad frame, wrong round, out-of-range indices — kills that
    /// connection only. Per-connection deadlines (armed from
    /// `io_timeout_ms` at phase start; 0 = none) bound the *whole
    /// phase*, so neither a hung worker nor a one-byte-per-second
    /// trickler can hold the round open: expiry marks the connection
    /// dead with a casualty log naming the client, and the survivors
    /// continue. Worker-side EOF/reset/panic surfaces the same way — a
    /// per-client log line, never a PS abort.
    fn run_reactor(
        &mut self,
        quota: Option<usize>,
        desc: &str,
        sit_desc: &str,
        mut on_frame: impl FnMut(usize, &[u8], usize) -> Result<()>,
    ) -> Result<()> {
        let io_timeout_ms = self.io_timeout_ms;
        let deadline_factor = self.deadline_factor;
        let deadline_min_ms = self.deadline_min_ms;
        let round = self.round;
        let codec = self.codec;
        let started = Instant::now();
        for &i in &self.armed {
            let wc = &mut self.conns[i];
            wc.retried = false;
            // adaptive per-client deadline (DESIGN.md §11): a stream
            // with an RTT sample gets clamp(ewma * k, min, io_timeout);
            // no sample (or factor 0) falls back to the flat window
            wc.deadline =
                phase_deadline_ms(io_timeout_ms, deadline_factor, deadline_min_ms, wc.ewma_ms)
                    .map(|ms| started + Duration::from_millis(ms));
        }
        // speculative commit: how many replies have landed, and whether
        // the quota cancellation has already fired
        let mut landed = 0usize;
        let mut cancel_fired = false;
        loop {
            // rebuild the interest set from the still-live state machines
            // (the Vecs keep their capacity across iterations and rounds)
            self.pollfds.clear();
            self.pollidx.clear();
            let mut next_deadline: Option<Instant> = None;
            for &i in &self.armed {
                let wc = &self.conns[i];
                if wc.dead {
                    continue;
                }
                let events = match wc.state {
                    ConnState::Writing { .. } => POLLOUT,
                    ConnState::Reading => POLLIN,
                    ConnState::Idle | ConnState::Done => continue,
                };
                self.pollfds.push(PollFd::new(wc.stream.as_raw_fd(), events));
                self.pollidx.push(i);
                if let Some(dl) = wc.deadline {
                    next_deadline = Some(next_deadline.map_or(dl, |cur| cur.min(dl)));
                }
            }
            if self.pollfds.is_empty() {
                return Ok(());
            }
            let timeout = next_deadline.map(|dl| dl.saturating_duration_since(Instant::now()));
            poll_fds(&mut self.pollfds, timeout)?;
            for k in 0..self.pollidx.len() {
                if !self.pollfds[k].ready() {
                    continue;
                }
                let i = self.pollidx[k];
                let wc = &mut self.conns[i];
                // classify the cursor I/O into a pure FSM event; every
                // transition below is covered by the conn_fsm model check
                let mut io_err: Option<anyhow::Error> = None;
                let mut frame_len = 0usize;
                let event = match wc.state {
                    ConnState::Writing { .. } => {
                        let frame: &[u8] = match &wc.shared {
                            Some(arc) => arc.as_slice(),
                            None => &wc.fb.buf,
                        };
                        ConnEvent::Write(match wc.send.advance(&mut wc.stream, frame) {
                            Ok(IoStep::Done) => WriteOutcome::Complete,
                            Ok(IoStep::Pending) => WriteOutcome::Pending,
                            Err(e) => {
                                io_err = Some(e);
                                WriteOutcome::Failed
                            }
                        })
                    }
                    ConnState::Reading => {
                        ConnEvent::Read(match wc.recv.advance(&mut wc.stream, &mut wc.fb) {
                            Ok(IoStep::Done) => {
                                frame_len = wc.fb.last_recv_frame_len();
                                if wc.drain_frames > 0 {
                                    ReadOutcome::StaleFrame
                                } else {
                                    match on_frame(i, &wc.fb.payload, frame_len) {
                                        Ok(()) => ReadOutcome::FrameAccepted,
                                        Err(e) => {
                                            io_err = Some(e);
                                            ReadOutcome::FrameRejected
                                        }
                                    }
                                }
                            }
                            Ok(IoStep::Pending) => ReadOutcome::Pending,
                            Err(e) => {
                                io_err = Some(e);
                                ReadOutcome::Failed
                            }
                        })
                    }
                    ConnState::Idle | ConnState::Done => continue,
                };
                let was_sit_write =
                    matches!(wc.state, ConnState::Writing { expect_reply: false });
                let t = conn_step(wc.state, event);
                wc.state = t.next;
                match t.effect {
                    Effect::None => {}
                    Effect::ReleaseFrame => {
                        // release the rotation slot now — by the next
                        // checkout its refcount is back to one
                        wc.shared = None;
                    }
                    Effect::Landed => {
                        landed += 1;
                        // feed the adaptive-deadline estimate: one
                        // completed write→reply phase
                        let ms = started.elapsed().as_secs_f32() * 1000.0;
                        wc.ewma_ms = if wc.ewma_ms == 0.0 {
                            ms
                        } else {
                            crate::coordinator::fleet::RTT_EWMA_ALPHA * ms
                                + (1.0 - crate::coordinator::fleet::RTT_EWMA_ALPHA) * wc.ewma_ms
                        };
                        self.timings.push((i, ms));
                    }
                    Effect::DrainedStale => {
                        // a late report from a cancelled round: discard
                        // it (exact wire accounting in drained_up, never
                        // wire_up) and keep reading — the real reply
                        // follows
                        wc.drain_frames -= 1;
                        self.drained_up += frame_len as u64;
                        crate::info!(
                            "serve: client {i} drained a stale frame \
                             ({frame_len} B) from a cancelled round"
                        );
                    }
                    Effect::Casualty(_) => {
                        wc.dead = true;
                        wc.shared = None;
                        let what = if was_sit_write { sit_desc } else { desc };
                        match io_err {
                            Some(e) => crate::info!("serve: client {i} dropped {what}: {e:#}"),
                            None => crate::info!("serve: client {i} dropped {what}"),
                        }
                    }
                    // the I/O events above never produce these (pinned
                    // by the model check's byte_effects_are_single_sourced)
                    Effect::QueueCancelSit | Effect::RearmDeadline => {}
                }
            }
            // speculative commit (DESIGN.md §11): the round is full once
            // `quota` replies landed — everyone still in flight is a
            // straggler. A stream whose broadcast was fully delivered
            // (Reading) gets a clean cancel: a 13-byte Sit tells the
            // worker its round was dropped, its one late report is
            // flagged for draining, and the stream survives untouched —
            // no casualty, no fleet damage. A stream still mid-broadcast
            // (Writing) cannot be cleanly parked — the worker never got
            // the model — so it is dropped as an ordinary casualty.
            if let Some(q) = quota {
                if !cancel_fired && landed >= q {
                    cancel_fired = true;
                    let TcpClientPool { conns, armed, cancelled, wire_down, .. } = self;
                    let now = Instant::now();
                    for &i in armed.iter() {
                        let wc = &mut conns[i];
                        if wc.dead {
                            continue;
                        }
                        let t = conn_step(wc.state, ConnEvent::RoundCommitted);
                        wc.state = t.next;
                        match t.effect {
                            Effect::QueueCancelSit => {
                                encode_frame_into(&Msg::Sit { round }, codec, &mut wc.fb);
                                wc.send.reset();
                                wc.shared = None;
                                wc.drain_frames += 1;
                                *wire_down += SIT_FRAME_BYTES as u64;
                                cancelled.push(i);
                                // the 13-byte Sit write-out gets a fresh
                                // flat window — inheriting the straggler's
                                // nearly-spent reply deadline turned clean
                                // cancels into deadline casualties
                                // (conn_fsm::cancel_window_is_fresh_and_flat)
                                wc.deadline = cancel_deadline_ms(io_timeout_ms)
                                    .map(|ms| now + Duration::from_millis(ms));
                                crate::info!(
                                    "serve: client {i} cancelled (round {round} committed \
                                     with {q} reports) — late report will be drained"
                                );
                            }
                            Effect::Casualty(CasualtyKind::BroadcastUnfinished) => {
                                wc.dead = true;
                                wc.shared = None;
                                crate::info!(
                                    "serve: client {i} dropped {desc}: broadcast \
                                     unfinished when the round committed"
                                );
                            }
                            _ => {}
                        }
                    }
                }
            }
            // deadline pass: whoever is still unfinished past their
            // deadline gets one bounded retry (adaptive deadlines only —
            // the estimate may simply have been too tight) and is then a
            // straggler casualty; the survivors' round continues
            let now = Instant::now();
            for &i in &self.armed {
                let wc = &mut self.conns[i];
                if wc.dead || matches!(wc.state, ConnState::Idle | ConnState::Done) {
                    continue;
                }
                let expired = wc.deadline.is_some_and(|dl| now >= dl);
                if !expired {
                    continue;
                }
                let adaptive = deadline_factor > 0.0 && wc.ewma_ms > 0.0;
                let can_retry = adaptive && !wc.retried;
                let was_sit_write =
                    matches!(wc.state, ConnState::Writing { expect_reply: false });
                let t = conn_step(wc.state, ConnEvent::DeadlineExpired { can_retry });
                wc.state = t.next;
                match t.effect {
                    Effect::RearmDeadline => {
                        // one retry with backoff: re-arm a doubled
                        // adaptive window before giving up
                        wc.retried = true;
                        let ms = phase_deadline_ms(
                            io_timeout_ms,
                            deadline_factor,
                            deadline_min_ms,
                            wc.ewma_ms,
                        )
                        .unwrap_or(1);
                        wc.deadline = Some(now + Duration::from_millis(2 * ms));
                        crate::info!(
                            "serve: client {i} missed its adaptive deadline ({ms} ms) \
                             — one retry ({} ms)",
                            2 * ms
                        );
                    }
                    Effect::Casualty(_) => {
                        wc.dead = true;
                        wc.shared = None;
                        let what = if was_sit_write { sit_desc } else { desc };
                        // name the window that actually expired — the
                        // flat knob's value was misleading for adaptive
                        // (EWMA-derived) windows
                        if adaptive {
                            crate::info!(
                                "serve: client {i} dropped {what}: adaptive phase \
                                 deadline expired (EWMA {:.1} ms)",
                                wc.ewma_ms
                            );
                        } else {
                            crate::info!(
                                "serve: client {i} dropped {what}: phase deadline \
                                 ({io_timeout_ms} ms) expired"
                            );
                        }
                    }
                    _ => {}
                }
            }
        }
    }
}

impl ClientPool for TcpClientPool {
    fn n_clients(&self) -> usize {
        self.conns.len()
    }

    /// Streams that errored (timed out, reset, sent a bad frame) report
    /// unreachable; the engine's fleet degrades them and the age-debt
    /// scheduler stops spending cohort slots on clients whose rounds
    /// cannot complete.
    fn health(&self) -> Vec<bool> {
        self.conns.iter().map(|wc| !wc.dead).collect()
    }

    fn set_commit_quota(&mut self, quota: usize) {
        self.quota = Some(quota);
    }

    fn take_cancelled(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.cancelled)
    }

    fn take_phase_timings(&mut self) -> Vec<(usize, f32)> {
        std::mem::take(&mut self.timings)
    }

    /// Nonblocking accept loop over the kept listener: validate queued
    /// `Rejoin` frames (known id, matching codec, strictly increasing
    /// generation), resync each accepted worker with a `Model` frame
    /// carrying the current global model, and swap the fresh stream into
    /// the slot. The slot is **not** required to be flagged dead: a
    /// restarted worker can reconnect before the PS's next round-path
    /// I/O observes the old stream's death (e.g. a kill between rounds),
    /// and the strictly-greater generation is itself proof the old
    /// stream is stale — it is shut down best-effort and displaced.
    /// Stale/duplicate generations (a flapping worker's leftover
    /// connect) are the refusals.
    fn poll_rejoins(&mut self, global: &[f32]) -> Result<Vec<usize>> {
        if self.routed_rejoins {
            // sharded serving: [`route_rejoins`] already drained every
            // listener and admitted each arrival at its current owning
            // slot (resync included) — here we only surface those
            // freshly-admitted slots to the engine
            let mut admitted = Vec::new();
            for (i, wc) in self.conns.iter_mut().enumerate() {
                if wc.admitted {
                    wc.admitted = false;
                    admitted.push(i);
                }
            }
            return Ok(admitted);
        }
        let mut admitted = Vec::new();
        let mut done = Vec::new();
        self.pump_handshakes(&mut done)?;
        for ph in done {
            let PendingHandshake { mut stream, peer, fb, .. } = ph;
            // the handshake frame is in hand: the resync answer below is
            // a plain blocking write again, bounded by the socket
            // deadline (0 = none)
            stream.set_nonblocking(false).context("rejoin stream blocking mode")?;
            set_stream_deadline(&stream, self.io_timeout_ms)?;
            let mut s = stream;
            let (id, generation, held_digest) = match Msg::decode(&fb.payload, self.codec) {
                Ok(Msg::Rejoin { client_id, generation, held_digest, codec }) => {
                    let id = client_id as usize;
                    if codec != self.codec
                        || id >= self.conns.len()
                        || generation <= self.last_generation[id]
                    {
                        crate::info!(
                            "serve: refused rejoin from {peer} (client {id} gen {generation})"
                        );
                        let _ = send(&mut s, &Msg::Shutdown, self.codec);
                        continue;
                    }
                    if !self.conns[id].dead {
                        // the PS has not yet observed the old stream's
                        // death — the fresh, higher-generation handshake
                        // supersedes it
                        let wc = &mut self.conns[id];
                        let _ = wc.stream.set_nonblocking(false);
                        let _ = send_frame(&mut wc.stream, &Msg::Shutdown, self.codec, &mut wc.fb);
                        crate::info!("serve: rejoin displaces client {id}'s stale stream");
                    }
                    (id, generation, held_digest)
                }
                Ok(other) => {
                    crate::info!("serve: expected Rejoin from {peer}, got {other:?}");
                    let _ = send(&mut s, &Msg::Shutdown, self.codec);
                    continue;
                }
                Err(e) => {
                    crate::info!("serve: bad rejoin handshake from {peer}: {e:#}");
                    continue;
                }
            };
            // resync — digest-verified skip (DESIGN.md §9): a rejoiner
            // whose held-model digest matches the current global model
            // provably already holds it (a warm restart, or a drop after
            // the broadcast landed), so a 13-byte Sit ack replaces the
            // 4d-byte Model resync. Dense-downlink workers always send
            // digest 0 (never a proof); a zero or stale digest falls back
            // to the full resync. Both are control frames, excluded from
            // the round-path wire accounting like Join/Shutdown.
            if held_digest != 0 && held_digest == params_digest(global) {
                if let Err(e) = send(&mut s, &Msg::Sit { round: self.round }, self.codec) {
                    crate::info!("serve: rejoin digest ack to client {id} failed: {e:#}");
                    continue;
                }
                crate::info!("serve: client {id} rejoin digest proof accepted — resync skipped");
            } else {
                let frame = encode_model_frame(self.round, global);
                if let Err(e) = s.write_all(&frame) {
                    crate::info!("serve: rejoin resync to client {id} failed: {e:#}");
                    continue;
                }
            }
            s.set_nonblocking(true).context("rejoined stream nonblocking mode")?;
            crate::info!("serve: client {id} rejoined from {peer} (generation {generation})");
            self.conns[id] = WorkerConn::new(s);
            self.last_generation[id] = generation;
            self.rejoins += 1;
            admitted.push(id);
        }
        Ok(admitted)
    }

    /// The engine's delta-downlink plan for the upcoming broadcast — held
    /// until `train_and_report` consumes it.
    fn set_broadcast_plan(&mut self, plan: &BroadcastPlan) {
        self.plan = Some(plan.clone());
    }

    fn train_and_report(
        &mut self,
        global: &[f32],
        cohort: &[usize],
    ) -> Result<Vec<Option<ClientReport>>> {
        self.round += 1;
        self.d = global.len();
        let round = self.round;
        let codec = self.codec;
        let d = self.d;
        self.cmap.set(self.conns.len(), cohort);
        // arm every reachable stream for one batched reactor pass.
        // Off-cohort workers queue a 13-byte Sit (round-counter sync, no
        // reply) in their own FrameBuf; cohort workers queue the round's
        // zero-copy broadcast: every distinct frame this round needs is
        // encoded once into a FrameRotation buffer and its Arc bytes are
        // shared across the streams assigned to it. Dense downlink: one
        // Model frame for the whole cohort. Delta downlink: the engine's
        // BroadcastPlan maps each reachable cohort member to a sparse
        // Delta frame (shared per distinct base generation) or to the
        // dense fallback frame — so the attempted-frame byte accounting
        // (a frame counts when it is armed, even if the stream dies
        // mid-write) mirrors the engine's per-member arithmetic exactly.
        let plan = self.plan.take();
        debug_assert!(
            match plan.as_ref() {
                Some(p) => p.round == round,
                None => true,
            },
            "broadcast plan round mismatch"
        );
        let mut sit_bytes = 0u64;
        let mut attempted_bytes = 0u64;
        let mut dense_encodes = 0u64;
        {
            let TcpClientPool { conns, cmap, rotation, val_scratch, idx_scratch, armed, .. } =
                self;
            armed.clear();
            let mut dense: Option<Arc<Vec<u8>>> = None;
            let mut delta_frames: Vec<Option<Arc<Vec<u8>>>> =
                vec![None; plan.as_ref().map_or(0, |p| p.deltas.len())];
            for (i, wc) in conns.iter_mut().enumerate() {
                if wc.dead {
                    continue;
                }
                wc.send.reset();
                // a cancelled straggler's stale report may still be
                // (partially) in flight on this stream — resetting the
                // cursor would desync the framing; the drain logic in
                // the reactor finishes the stale frame first
                if wc.drain_frames == 0 {
                    wc.recv.reset();
                }
                if cmap.slot(i) == usize::MAX {
                    sit_bytes += SIT_FRAME_BYTES as u64;
                    encode_frame_into(&Msg::Sit { round }, codec, &mut wc.fb);
                    wc.shared = None;
                    wc.state = conn_step(wc.state, ConnEvent::Armed { expect_reply: false }).next;
                    armed.push(i);
                    continue;
                }
                let slot = plan.as_ref().and_then(|p| p.assign.get(i).copied().flatten());
                // a delta slot assignment implies a plan, so pair them in
                // one match — the impossible (Some, None) corner falls
                // through to the dense fallback instead of panicking
                let frame = match (slot, plan.as_ref()) {
                    (Some(di), Some(p)) => {
                        let entry = &mut delta_frames[di];
                        let arc = entry.get_or_insert_with(|| {
                            let (base, idx) = &p.deltas[di];
                            rotation.checkout(|buf| {
                                encode_delta_frame_into(
                                    codec,
                                    round,
                                    *base,
                                    p.digest,
                                    idx,
                                    global,
                                    buf,
                                    val_scratch,
                                    idx_scratch,
                                )
                            })
                        });
                        Arc::clone(arc)
                    }
                    _ => {
                        let arc = dense.get_or_insert_with(|| {
                            dense_encodes += 1;
                            rotation.checkout(|buf| encode_model_frame_into(round, global, buf))
                        });
                        Arc::clone(arc)
                    }
                };
                attempted_bytes += frame.len() as u64;
                wc.shared = Some(frame);
                wc.state = conn_step(wc.state, ConnEvent::Armed { expect_reply: true }).next;
                armed.push(i);
            }
        }
        self.model_encodes += dense_encodes;
        self.wire_down += sit_bytes + attempted_bytes;
        // one reactor pass interleaves every armed stream: a slow
        // worker's local training overlaps its peers' instead of
        // serializing the round in client order
        let mut results: Vec<Option<(ClientReport, usize)>> =
            (0..self.conns.len()).map(|_| None).collect();
        // the engine's speculative commit quota (overschedule > 0): the
        // reactor commits as soon as that many reports land and cancels
        // the in-flight rest; `None` = wait for everyone (the ε = 0
        // bit-for-bit path)
        let quota = self.quota.take();
        self.run_reactor(
            quota,
            &format!("mid-round {round}"),
            &format!("at Sit (round {round})"),
            |i, payload, frame_len| match Msg::decode(payload, codec)? {
                Msg::Report { report, mean_loss, round: r, .. } if r == round => {
                    // reports are remote input: reject indices outside
                    // the model before they reach selection/aggregation
                    check_indices(&report.idx, d, "report")?;
                    results[i] = Some((ClientReport { report, mean_loss }, frame_len));
                    Ok(())
                }
                other => bail!("round {round}: expected Report, got {other:?}"),
            },
        )?;
        let mut reports = Vec::with_capacity(cohort.len());
        for &c in cohort {
            match results[c].take() {
                Some((rep, up)) => {
                    self.wire_up += up as u64;
                    reports.push(Some(rep));
                }
                None => reports.push(None),
            }
        }
        Ok(reports)
    }

    fn exchange(
        &mut self,
        requests: Option<&[Vec<u32>]>,
        cohort: &[usize],
    ) -> Result<Vec<Option<SparseVec>>> {
        let round = self.round;
        let codec = self.codec;
        let d = self.d;
        self.cmap.set(self.conns.len(), cohort);
        // arm each reachable cohort stream with its Request frame
        // (off-cohort workers already got their Sit): client-side
        // strategies select locally, so the frame may be empty — it
        // still flows to keep the wire flow uniform. Attempted-frame
        // accounting at arm time, as in the broadcast phase.
        let mut request_bytes = 0u64;
        {
            let TcpClientPool { conns, cmap, armed, .. } = self;
            armed.clear();
            for (i, wc) in conns.iter_mut().enumerate() {
                let p = cmap.slot(i);
                if p == usize::MAX || wc.dead {
                    continue;
                }
                wc.send.reset();
                if wc.drain_frames == 0 {
                    wc.recv.reset();
                }
                let indices: &[u32] = requests.map(|r| r[p].as_slice()).unwrap_or(&[]);
                request_bytes += encode_request_into(codec, &mut wc.fb, round, indices) as u64;
                wc.shared = None;
                wc.state = conn_step(wc.state, ConnEvent::Armed { expect_reply: true }).next;
                armed.push(i);
            }
        }
        self.wire_down += request_bytes;
        let mut results: Vec<Option<(SparseVec, usize)>> =
            (0..self.conns.len()).map(|_| None).collect();
        let desc = format!("at exchange (round {round})");
        self.run_reactor(
            None,
            &desc,
            &desc,
            |i, payload, frame_len| match Msg::decode(payload, codec)? {
                Msg::Update { update, round: r, .. } if r == round => {
                    // updates scatter-add into the global model: reject
                    // out-of-range remote indices here, not as a panic
                    // inside aggregation
                    check_indices(&update.idx, d, "update")?;
                    results[i] = Some((update, frame_len));
                    Ok(())
                }
                other => bail!("round {round}: expected Update, got {other:?}"),
            },
        )?;
        let mut updates = Vec::with_capacity(cohort.len());
        for &c in cohort {
            match results[c].take() {
                Some((update, up)) => {
                    self.wire_up += up as u64;
                    updates.push(Some(update));
                }
                None => updates.push(None),
            }
        }
        Ok(updates)
    }

    fn backend(&mut self) -> &mut dyn Backend {
        self.backend.as_mut()
    }
}

impl Reshard for TcpClientPool {
    type Carry = TcpCarry;

    /// Drain the worker streams in local-slot order (dynamic re-shard):
    /// the sockets stay open, only which shard pool pumps their frames
    /// changes.
    fn take_parts(&mut self) -> Vec<TcpCarry> {
        let conns = std::mem::take(&mut self.conns);
        let gens = std::mem::take(&mut self.last_generation);
        conns
            .into_iter()
            .zip(gens)
            .map(|(conn, last_generation)| TcpCarry { conn, last_generation })
            .collect()
    }

    fn install_parts(&mut self, parts: Vec<TcpCarry>) {
        self.conns = Vec::with_capacity(parts.len());
        self.last_generation = Vec::with_capacity(parts.len());
        for part in parts {
            self.conns.push(part.conn);
            self.last_generation.push(part.last_generation);
        }
    }
}

/// Run the parameter server until `cfg.rounds` rounds complete. Under a
/// sharded topology, shard `s`'s listener binds `port + s` and workers
/// connect to their shard's port (they compute their shard from the
/// shared config — see [`run_worker`]).
pub fn run_server(cfg: &ExperimentConfig, port: u16) -> Result<ServeReport> {
    if cfg.topology == crate::coordinator::topology::Topology::Flat {
        let listener =
            TcpListener::bind(("0.0.0.0", port)).with_context(|| format!("binding :{port}"))?;
        return run_server_on(cfg, listener);
    }
    let listeners = (0..cfg.topology.n_shards())
        .map(|s| {
            let p = port
                .checked_add(s as u16)
                .ok_or_else(|| anyhow::anyhow!("shard {s} port {port}+{s} exceeds 65535"))?;
            TcpListener::bind(("0.0.0.0", p)).with_context(|| format!("binding :{p} (shard {s})"))
        })
        .collect::<Result<Vec<_>>>()?;
    run_sharded_server_on(cfg, listeners)
}

/// [`run_server`] over an already-bound listener (lets tests bind an
/// ephemeral port before spawning workers). A mid-round worker failure
/// no longer aborts the run: the round completes with the survivors, the
/// casualty is logged, and a later `Rejoin` brings the worker back.
pub fn run_server_on(cfg: &ExperimentConfig, listener: TcpListener) -> Result<ServeReport> {
    cfg.validate()?;
    let mut pool = TcpClientPool::accept(cfg, listener)?;
    let init = pool.backend.init_params()?;
    let mut engine = RoundEngine::new(cfg, init);
    let (_, test) = load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
    let test_idx: Vec<usize> = (0..test.len()).collect();
    let mut casualties = 0u64;
    let mut cancellations = 0u64;

    for round in 1..=cfg.rounds {
        let out = engine.run_round(&mut pool)?;
        cancellations += out.cancelled.len() as u64;
        if !out.casualties.is_empty() {
            casualties += out.casualties.len() as u64;
            crate::info!(
                "serve: round {round}/{}: finished with {} survivors, lost {:?}",
                cfg.rounds,
                out.cohort.len(),
                out.casualties
            );
        }
        if cfg.eval_every > 0 && round % cfg.eval_every == 0 {
            let (acc, loss) =
                eval_dataset(pool.backend(), engine.global_params(), &test, &test_idx, cfg.batch)?;
            crate::info!(
                "serve: round {round}/{}: acc {:.2}% loss {loss:.4} clusters {}",
                cfg.rounds,
                acc * 100.0,
                engine.ps().clusters().n_clusters()
            );
        }
    }
    pool.shutdown()?;
    let (acc, _) =
        eval_dataset(pool.backend(), engine.global_params(), &test, &test_idx, cfg.batch)?;
    let (wire_up_observed, wire_down_observed) = pool.wire_observed();
    Ok(ServeReport {
        rounds: cfg.rounds,
        final_accuracy: acc,
        cluster_labels: engine.ps().clusters().labels(),
        final_params: engine.global_params().to_vec(),
        uploaded_log: engine.uploaded_log().iter().cloned().collect(),
        comm: engine.comm(),
        model_encodes: pool.model_encodes(),
        wire_up_observed,
        wire_down_observed,
        frame_grows: pool.frame_grows(),
        casualties,
        rejoins: pool.rejoins(),
        cancellations,
        drained_up: pool.drained_up(),
    })
}

/// [`run_server`] for a sharded topology over pre-bound listeners, one
/// per shard in shard order (lets tests bind ephemeral ports before
/// spawning workers). Each shard's [`TcpClientPool`] accepts its slice's
/// workers (joining with **shard-local** ids) and is driven by the shared
/// [`ShardedEngine`]; the root applies one merged server update per round
/// and re-broadcasts through the shards. At recluster boundaries the
/// root re-partitions the fleet with `ClusterManager::shard_slices` and
/// worker streams are handed off between the shard pools (the workers'
/// sockets never notice).
///
/// Shard collect phases run serially here — [`TcpClientPool`] owns a
/// non-`Send` PS backend, so it cannot cross shard threads. Each shard's
/// reactor still overlaps its own workers (one `poll(2)` loop per pool),
/// and every worker of every shard trains concurrently in its own
/// process; only the PS-side frame pumping serializes across shards.
///
/// Rejoins are **routed**: before each round, [`route_rejoins`] drains
/// every shard listener's queued `Rejoin` handshakes and admits each one
/// at the slot the *current* assignment gives its global client id — so
/// a worker that knocks on its original shard's port after a re-shard
/// still lands on the pool that now owns its stream.
///
/// [`ShardedEngine`]: crate::coordinator::topology::ShardedEngine
pub fn run_sharded_server_on(
    cfg: &ExperimentConfig,
    listeners: Vec<TcpListener>,
) -> Result<ServeReport> {
    use crate::coordinator::topology::{client_shards, ShardedEngine};
    cfg.validate()?;
    let shards = cfg.topology.n_shards();
    ensure_listeners(shards, listeners.len())?;
    let slices = client_shards(cfg.n_clients, shards);
    let mut pools: Vec<TcpClientPool> = Vec::with_capacity(shards);
    for ((s, listener), slice) in listeners.into_iter().enumerate().zip(&slices) {
        let mut shard_cfg = cfg.clone();
        shard_cfg.n_clients = slice.len();
        crate::info!("serve: accepting shard {s} ({} clients)", slice.len());
        let mut pool = TcpClientPool::accept(&shard_cfg, listener)?;
        pool.routed_rejoins = true;
        pools.push(pool);
    }
    let init = pools[0].backend.init_params()?;
    let mut engine = ShardedEngine::new(cfg, init)?;
    let (_, test) = load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
    let test_idx: Vec<usize> = (0..test.len()).collect();
    let mut casualties = 0u64;
    let mut cancellations = 0u64;

    for round in 1..=cfg.rounds {
        // admit queued rejoins at their *current* owning shard before the
        // round's collect — a re-shard at the end of round t is reflected
        // in `engine.slices()` by the time round t+1's rejoins route
        route_rejoins(&mut pools, engine.slices(), engine.global_params())?;
        let out = engine.run_round_serial(&mut pools)?;
        casualties += out.casualties.len() as u64;
        cancellations += out.cancelled.len() as u64;
        if cfg.eval_every > 0 && round % cfg.eval_every == 0 {
            let (acc, loss) = eval_dataset(
                pools[0].backend(),
                engine.global_params(),
                &test,
                &test_idx,
                cfg.batch,
            )?;
            crate::info!(
                "serve: round {round}/{}: acc {:.2}% loss {loss:.4} clusters {} ({} shards)",
                cfg.rounds,
                acc * 100.0,
                engine.n_clusters(),
                engine.n_shards()
            );
        }
    }
    for pool in &mut pools {
        pool.shutdown()?;
    }
    let (acc, _) = eval_dataset(
        pools[0].backend(),
        engine.global_params(),
        &test,
        &test_idx,
        cfg.batch,
    )?;
    // roll the per-shard transport observations up next to the engine's
    // rolled-up accounting: the wire pins hold shard-wise, so they hold
    // for the sums
    let mut wire_up_observed = 0;
    let mut wire_down_observed = 0;
    let mut model_encodes = 0;
    let mut frame_grows = 0;
    let mut rejoins = 0;
    let mut drained_up = 0;
    for pool in &pools {
        let (up, down) = pool.wire_observed();
        wire_up_observed += up;
        wire_down_observed += down;
        model_encodes += pool.model_encodes();
        frame_grows += pool.frame_grows();
        rejoins += pool.rejoins();
        drained_up += pool.drained_up();
    }
    Ok(ServeReport {
        rounds: cfg.rounds,
        final_accuracy: acc,
        cluster_labels: engine.cluster_labels(),
        final_params: engine.global_params().to_vec(),
        uploaded_log: engine.uploaded_log().iter().cloned().collect(),
        comm: engine.comm(),
        model_encodes,
        wire_up_observed,
        wire_down_observed,
        frame_grows,
        casualties,
        rejoins,
        cancellations,
        drained_up,
    })
}

fn ensure_listeners(shards: usize, got: usize) -> Result<()> {
    if got != shards {
        bail!("sharded server needs {shards} listeners, got {got}");
    }
    Ok(())
}

/// Run one worker process until the PS sends Shutdown. Under a sharded
/// topology the worker joins its shard's PS with its **shard-local** id
/// (computed from the shared config via
/// [`crate::coordinator::topology::locate`] — nothing crosses the wire);
/// `addr` must already point at that shard's listener (the CLI derives
/// `port + shard` from the base port).
pub fn run_worker(cfg: &ExperimentConfig, addr: &str, id: usize) -> Result<()> {
    run_worker_session(cfg, addr, id, 0)
}

/// [`run_worker`] for a **recovered** worker: instead of a fresh `Join`
/// it sends a `Rejoin` frame carrying its **global** id and `generation`
/// (its restart count, >= 1 and strictly increasing across restarts),
/// waits for the PS's `Model` resync of the current global model, and
/// then runs the normal round loop. Under a sharded topology any shard's
/// port works — the worker naturally knocks on its original (statically
/// derived) shard, and the PS routes the handshake to whichever shard
/// *currently* owns the id ([`route_rejoins`]), so rejoin survives
/// dynamic re-sharding.
pub fn run_worker_rejoin(
    cfg: &ExperimentConfig,
    addr: &str,
    id: usize,
    generation: u32,
) -> Result<()> {
    if generation == 0 {
        bail!("a rejoin needs a generation >= 1 (0 is the original join)");
    }
    run_worker_session(cfg, addr, id, generation)
}

fn run_worker_session(
    cfg: &ExperimentConfig,
    addr: &str,
    id: usize,
    generation: u32,
) -> Result<()> {
    cfg.validate()?;
    if id >= cfg.n_clients {
        bail!("worker id {id} >= n_clients {}", cfg.n_clients);
    }
    let codec = cfg.codec;
    let pc = PhaseCfg::from_config(cfg);
    let mut backend = make_backend(cfg)?;
    // derive this worker's shard exactly like the simulator does: same
    // seed -> same partition, no data on the wire
    let (train, _) = load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
    let shards = partition(&train, cfg.n_clients, &cfg.partition, cfg.seed);
    let init_params = backend.init_params()?;
    let delta_down = cfg.downlink == Downlink::Delta;
    // under the delta downlink the worker must hold a full model copy at
    // all times (sparse frames patch it in place); the dense downlink
    // decodes each broadcast into the (initially empty) reused vector
    let mut params: Vec<f32> = if delta_down { init_params.clone() } else { Vec::new() };
    let shard = crate::data::Shard::from_owned(train.subset(&shards[id]));
    let mut client = Client::new(id, shard, init_params, cfg.seed);
    let delta = cfg.payload == Payload::Delta;
    let mut memory = if delta { vec![0.0f32; cfg.d()] } else { Vec::new() };
    // generation ledger (DESIGN.md §9): which broadcast generation the
    // held params correspond to, plus their running content digest — the
    // proof sent with a Rejoin and checked against every Delta frame
    let mut held_round = 0u32;
    let mut held_digest = if delta_down { params_digest(&params) } else { 0 };

    // under a sharded topology the shard PS indexes streams by
    // shard-local slot; the worker derives its slot from the shared
    // config exactly like the PS does (data/RNG stay keyed by the global
    // id, so training is topology-independent)
    let n_shards = cfg.topology.n_shards();
    let join_id = if n_shards > 1 {
        let (shard, local) = crate::coordinator::topology::locate(cfg.n_clients, n_shards, id);
        crate::info!("worker {id}: shard {shard}, local slot {local}");
        local
    } else {
        id
    };
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;

    // steady-state transport buffers: one FrameBuf for every frame in and
    // out, plus the model broadcast decoded into the reused parameter
    // vector above
    let mut fb = FrameBuf::new();

    if generation == 0 {
        send(&mut stream, &Msg::Join { client_id: join_id as u32, codec }, codec)?;
        crate::info!("worker {id}: joined {addr} (codec {})", codec.name());
    } else {
        // a Rejoin carries the **global** id (unlike Join's shard-local
        // slot): after a dynamic re-shard the stream's owning shard may
        // have moved, and the PS-side router finds the current owner by
        // global id — whichever shard's port this knock lands on
        send(
            &mut stream,
            &Msg::Rejoin { client_id: id as u32, generation, held_digest, codec },
            codec,
        )?;
        // the PS answers an accepted rejoin with the current global model
        // — or, when our held-model digest proved we already hold it, a
        // Sit ack that skips the resync — or Shutdown if it refused us /
        // training already ended
        let payload = recv_payload(&mut stream, &mut fb).context("rejoin resync")?;
        match payload.first().copied() {
            Some(TAG_MODEL) => {
                let r = decode_model_into(payload, &mut params).context("rejoin resync model")?;
                client.state.sync_to(&params);
                if delta_down {
                    // the resync frame is tagged with the PS's completed
                    // round t; the model it carries is generation t + 1
                    // (the upcoming broadcast) — future Delta frames base
                    // against that
                    held_round = r + 1;
                    held_digest = params_digest(&params);
                }
                crate::info!(
                    "worker {id}: rejoined {addr} (generation {generation}), model resynced"
                );
            }
            _ => match Msg::decode(payload, codec)? {
                Msg::Sit { round: t } => {
                    // digest proof accepted: our held params ARE the
                    // current global model (generation t + 1); no bytes
                    // to apply
                    held_round = t + 1;
                    crate::info!(
                        "worker {id}: rejoined {addr} (generation {generation}), \
                         digest proof accepted — resync skipped"
                    );
                }
                Msg::Shutdown => {
                    crate::info!("worker {id}: rejoin refused or training over");
                    return Ok(());
                }
                other => bail!("rejoin: expected Model resync, Sit ack or Shutdown, got {other:?}"),
            },
        }
    }

    loop {
        let payload = recv_payload(&mut stream, &mut fb)?;
        let round = match payload.first().copied() {
            Some(TAG_MODEL) => {
                let r = decode_model_into(payload, &mut params)?;
                if delta_down {
                    // dense fallback / resync frame: re-anchor the ledger
                    held_round = r;
                    held_digest = params_digest(&params);
                }
                r
            }
            // sparse broadcast (DESIGN.md §9): patch the held model in
            // place, then verify the streamed digest. Any mismatch makes
            // this worker bail — the PS records the casualty, forgets our
            // acked generation, and a rejoin resyncs us densely — so a
            // diverged replica can never train on silently wrong params.
            Some(TAG_DELTA) => match Msg::decode(payload, codec)? {
                Msg::Delta { round: r, base_round, digest, delta } => {
                    if !delta_down {
                        bail!("Delta frame under a dense-downlink config");
                    }
                    if base_round != held_round {
                        bail!(
                            "delta base generation {base_round} != held generation \
                             {held_round} — resync needed"
                        );
                    }
                    held_digest = apply_delta_in_place(&mut params, held_digest, &delta)?;
                    if held_digest != digest {
                        bail!(
                            "model digest diverged after delta apply (round {r}): held \
                             {held_digest:#018x} != broadcast {digest:#018x} — resync needed"
                        );
                    }
                    held_round = r;
                    r
                }
                other => bail!("expected Delta, got {other:?}"),
            },
            _ => match Msg::decode(payload, codec)? {
                // off-cohort this round (partial participation): no
                // broadcast, no training, no upload — just wait for the
                // next frame
                Msg::Sit { .. } => continue,
                Msg::Shutdown => break,
                other => bail!("expected Model/Delta/Sit/Shutdown, got {other:?}"),
            },
        };
        // shared phase 1: sync_to (Adam moments persist), H local steps,
        // EF fold, top-r report — the same code the in-process pool runs
        let mem = if delta { Some(&mut memory) } else { None };
        let rep = client_train_phase(&mut client, backend.as_mut(), mem, &params, &pc)?;
        send_report(&mut stream, codec, &mut fb, id as u32, round, &rep.report, rep.mean_loss)?;
        let requested = match recv_frame(&mut stream, codec, &mut fb)? {
            Msg::Request { indices, round: r } if r == round => indices,
            // speculative cancel (DESIGN.md §11): the PS committed the
            // round without us — our report was drained and discarded.
            // Not a failure: the stream stays up, the held model (we
            // applied this round's broadcast) stays valid, and we simply
            // wait for the next broadcast like an off-cohort client.
            Msg::Sit { round: r } if r == round => {
                crate::info!("worker {id}: round {round} cancelled by the PS");
                continue;
            }
            other => bail!("expected Request, got {other:?}"),
        };
        // shared phase 2: answer the PS request, or select locally for
        // client-side strategies (the PS's echo frame is empty then)
        let request = if pc.strategy.needs_report() {
            Some(requested.as_slice())
        } else {
            None
        };
        let mem = if delta { Some(&mut memory) } else { None };
        let update =
            client_update_phase(&mut client, backend.as_mut(), mem, &rep.report, request, &pc)?;
        send_frame(
            &mut stream,
            &Msg::Update { client_id: id as u32, round, update },
            codec,
            &mut fb,
        )?;
    }
    crate::info!("worker {id}: shutdown");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::fl::transport::recv;

    fn smoke_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.payload = Payload::Delta;
        cfg.rounds = 3;
        cfg.n_clients = 2;
        cfg.train_n = 200;
        cfg.test_n = 64;
        cfg.eval_every = 0;
        cfg
    }

    #[test]
    fn distributed_round_trip_localhost() {
        let cfg = smoke_cfg();
        let report = crate::testing::run_distributed_localhost(&cfg).unwrap();
        assert_eq!(report.rounds, 3);
        assert_eq!(report.cluster_labels.len(), 2);
        assert_eq!(report.uploaded_log.len(), 3);
        assert!(report.uploaded_log.iter().all(|r| r.len() == 2));
        assert_eq!(report.casualties, 0);
        assert_eq!(report.rejoins, 0);
        // zero-copy broadcast: one Model serialization per round, shared
        // across both workers
        assert_eq!(report.model_encodes, 3);
        assert_eq!(report.comm.broadcast_down, 3 * 2 * 4 * cfg.d() as u64);
        // the engine's arithmetic wire accounting equals the bytes that
        // actually crossed the PS sockets
        assert_eq!(report.comm.wire_up, report.wire_up_observed);
        assert_eq!(report.comm.wire_down, report.wire_down_observed);
        assert!(report.wire_up_observed > 0 && report.wire_down_observed > 0);
    }

    /// Steady-state buffer-reuse pin: with fixed frame shapes (raw codec
    /// — every frame size is round-independent) the PS-side FrameBufs
    /// hit their high-water capacity in the first rounds and never grow
    /// again, so the growth count is independent of the round count.
    #[test]
    fn steady_state_rounds_reuse_frame_buffers() {
        let grows_of = |rounds: usize| {
            let mut cfg = smoke_cfg();
            cfg.rounds = rounds;
            crate::testing::run_distributed_localhost(&cfg).unwrap().frame_grows
        };
        let short = grows_of(2);
        let long = grows_of(6);
        assert_eq!(short, long, "per-round frame allocations leak into the growth count");
    }

    /// The packed codec shrinks what actually crosses the sockets; the
    /// raw-vs-packed ratio pin (>= 2x uplink) lives in bench_end2end on
    /// the standard scenario.
    #[test]
    fn packed_codec_shrinks_observed_wire_bytes() {
        let cfg = smoke_cfg();
        let raw = crate::testing::run_distributed_localhost(&cfg).unwrap();
        let mut pcfg = cfg.clone();
        pcfg.codec = Codec::Packed;
        let packed = crate::testing::run_distributed_localhost(&pcfg).unwrap();
        assert!(
            packed.wire_up_observed < raw.wire_up_observed,
            "packed uplink {} must undercut raw {}",
            packed.wire_up_observed,
            raw.wire_up_observed
        );
        assert!(packed.wire_down_observed < raw.wire_down_observed);
        // the semantic §6 counters are codec-independent
        assert_eq!(packed.comm.uplink(), raw.comm.uplink());
        assert_eq!(packed.comm.downlink(), raw.comm.downlink());
    }

    /// Delta downlink end to end over real sockets: training is
    /// bit-for-bit the dense run (the sparse frames reconstruct the
    /// exact same models), every broadcast is a `Delta` frame (zero
    /// dense `Model` serializations), the engine's arithmetic wire
    /// accounting still equals the observed socket bytes, and the
    /// downlink shrinks by a large factor.
    #[test]
    fn delta_downlink_tcp_smoke() {
        let dense_cfg = smoke_cfg();
        let dense = crate::testing::run_distributed_localhost(&dense_cfg).unwrap();
        let mut cfg = smoke_cfg();
        cfg.downlink = Downlink::Delta;
        let sparse = crate::testing::run_distributed_localhost(&cfg).unwrap();
        assert_eq!(sparse.casualties, 0);
        assert_eq!(
            sparse.final_params, dense.final_params,
            "the delta downlink must reconstruct the dense run exactly"
        );
        assert_eq!(sparse.uploaded_log, dense.uploaded_log);
        assert_eq!(
            sparse.model_encodes, 0,
            "a healthy delta run never serializes a dense Model frame"
        );
        assert_eq!(sparse.comm.wire_up, sparse.wire_up_observed);
        assert_eq!(
            sparse.comm.wire_down, sparse.wire_down_observed,
            "the engine's per-member delta arithmetic must match the socket bytes"
        );
        assert!(
            sparse.wire_down_observed * 5 < dense.wire_down_observed,
            "delta downlink {} should be well under a fifth of dense {}",
            sparse.wire_down_observed,
            dense.wire_down_observed
        );
        // uplink is untouched by the downlink representation
        assert_eq!(sparse.comm.uplink(), dense.comm.uplink());
    }

    /// The FrameRotation steady-state pin under the delta downlink:
    /// every round re-encodes its (varying-size) sparse frame into a
    /// reclaimed rotation slot, so the growth count — slot additions
    /// plus FrameBuf capacity events — is independent of the round
    /// count.
    #[test]
    fn delta_rounds_reuse_rotated_broadcast_buffers() {
        let grows_of = |rounds: usize| {
            let mut cfg = smoke_cfg();
            cfg.downlink = Downlink::Delta;
            cfg.rounds = rounds;
            crate::testing::run_distributed_localhost(&cfg).unwrap().frame_grows
        };
        let short = grows_of(2);
        let long = grows_of(6);
        assert_eq!(short, long, "per-round broadcast allocations leak into the growth count");
    }

    /// Satellite pin: `io_timeout_ms = 0` means **no deadline** at both
    /// ends of the transport — the blocking-socket end
    /// ([`set_stream_deadline`]) and the reactor/handshake end
    /// ([`phase_deadline_ms`]) — never "instant expiry" (std rejects a
    /// zero socket timeout as `InvalidInput`, and a zero poll deadline
    /// would drop every client on the first pass).
    #[test]
    fn zero_io_timeout_disables_deadlines_at_both_ends() {
        // blocking end: 0 explicitly clears the socket timeouts
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let s = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        set_stream_deadline(&s, 7).unwrap();
        assert_eq!(s.read_timeout().unwrap(), Some(Duration::from_millis(7)));
        assert_eq!(s.write_timeout().unwrap(), Some(Duration::from_millis(7)));
        set_stream_deadline(&s, 0).unwrap();
        assert_eq!(s.read_timeout().unwrap(), None, "0 = disabled, not instant expiry");
        assert_eq!(s.write_timeout().unwrap(), None);
        // reactor/handshake end: the one shared deadline formula
        assert_eq!(phase_deadline_ms(0, 0.0, 0, 0.0), None, "flat window, knob off");
        assert_eq!(phase_deadline_ms(5000, 0.0, 0, 0.0), Some(5000));
        // adaptive window: clamp(ewma * k, min, io_timeout)
        assert_eq!(phase_deadline_ms(5000, 2.0, 50, 100.0), Some(200));
        assert_eq!(phase_deadline_ms(5000, 2.0, 50, 10.0), Some(50), "floor applies");
        assert_eq!(phase_deadline_ms(150, 2.0, 50, 100.0), Some(150), "cap applies");
        assert_eq!(phase_deadline_ms(0, 2.0, 50, 100.0), Some(200), "io_timeout 0 = no cap");
        assert_eq!(phase_deadline_ms(0, 2.0, 50, 0.0), None, "no RTT sample: flat window");
    }

    /// The nonblocking-handshake tentpole: a client that connects first
    /// and then stalls mid-`Join` (three header bytes, then silence) can
    /// no longer wedge accept — the real joiners land immediately, the
    /// staller just occupies a pending-handshake slot until its deadline.
    #[test]
    fn stalled_joiner_cannot_block_accept() {
        let mut cfg = smoke_cfg();
        cfg.io_timeout_ms = 30_000; // staller deadline far beyond the test
        let codec = cfg.codec;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // the staller connects BEFORE any real worker and trickles three
        // bytes of its frame header — under the old blocking accept this
        // held the accept loop hostage for the full io timeout
        let mut staller = TcpStream::connect(addr).unwrap();
        staller.write_all(&[0x5A, 0x5A, 0x5A]).unwrap();
        let hs: Vec<_> = (0..2u32)
            .map(|id| {
                std::thread::spawn(move || {
                    let mut s = TcpStream::connect(addr).unwrap();
                    send(&mut s, &Msg::Join { client_id: id, codec }, codec).unwrap();
                    match recv(&mut s, codec).unwrap() {
                        Msg::Shutdown => {}
                        other => panic!("expected Shutdown, got {other:?}"),
                    }
                })
            })
            .collect();
        let t0 = Instant::now();
        let mut pool = TcpClientPool::accept(&cfg, listener).unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "accept must complete despite the stalled joiner"
        );
        assert_eq!(pool.pending.len(), 1, "the staller sits in a pending-handshake slot");
        pool.shutdown().unwrap();
        drop(staller);
        for h in hs {
            h.join().unwrap();
        }
    }

    /// The speculation tentpole over real sockets: three workers, commit
    /// quota two. The sleeping straggler is cleanly cancelled (a Sit, not
    /// a casualty), its stream survives into the next round, and its one
    /// late report is drained with exact byte accounting — `wire_up`
    /// counts committed frames only.
    #[test]
    fn speculative_tcp_round_commits_without_the_straggler() {
        use crate::fl::transport::{report_frame_bytes, update_frame_bytes};
        let mut cfg = smoke_cfg();
        cfg.n_clients = 3;
        cfg.io_timeout_ms = 30_000;
        let codec = cfg.codec;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker = move |id: u32, slow: bool| {
            let mut s = TcpStream::connect(addr).unwrap();
            send(&mut s, &Msg::Join { client_id: id, codec }, codec).unwrap();
            let mut fb = FrameBuf::new();
            let mut params = Vec::new();
            loop {
                let payload = recv_payload(&mut s, &mut fb).unwrap();
                let round = match payload.first().copied() {
                    Some(TAG_MODEL) => decode_model_into(payload, &mut params).unwrap(),
                    _ => match Msg::decode(payload, codec).unwrap() {
                        Msg::Shutdown => break,
                        other => panic!("expected Model/Shutdown, got {other:?}"),
                    },
                };
                if slow && round == 1 {
                    // still "training" when the PS commits the round
                    std::thread::sleep(Duration::from_millis(400));
                }
                let report = SparseVec::new(vec![id, id + 4], vec![1.0, -1.0]);
                send_report(&mut s, codec, &mut fb, id, round, &report, 0.5).unwrap();
                match recv_frame(&mut s, codec, &mut fb).unwrap() {
                    Msg::Request { round: r, .. } if r == round => {
                        let update = SparseVec::new(vec![id], vec![1.0]);
                        send_frame(
                            &mut s,
                            &Msg::Update { client_id: id, round, update },
                            codec,
                            &mut fb,
                        )
                        .unwrap();
                    }
                    // the speculative cancel: back to awaiting the next
                    // broadcast, exactly like the real worker loop
                    Msg::Sit { round: r } if r == round => continue,
                    other => panic!("expected Request/Sit, got {other:?}"),
                }
            }
        };
        let hs: Vec<_> = (0..3u32)
            .map(|id| std::thread::spawn(move || worker(id, id == 2)))
            .collect();
        let mut pool = TcpClientPool::accept(&cfg, listener).unwrap();
        let global = vec![0.0f32; 32];

        // round 1: speculative — the round commits with 2 of 3 reports
        pool.set_commit_quota(2);
        let reports = pool.train_and_report(&global, &[0, 1, 2]).unwrap();
        assert_eq!(
            reports.iter().map(|r| r.is_some()).collect::<Vec<_>>(),
            vec![true, true, false]
        );
        assert_eq!(pool.take_cancelled(), vec![2]);
        assert!(
            pool.health().iter().all(|&h| h),
            "a cancelled straggler is not a casualty — its stream survives"
        );
        let ups = pool.exchange(None, &[0, 1]).unwrap();
        assert!(ups.iter().all(|u| u.is_some()));

        // round 2: no quota — everyone commits; the straggler's stale
        // round-1 report is drained off the wire first
        let reports = pool.train_and_report(&global, &[0, 1, 2]).unwrap();
        assert!(reports.iter().all(|r| r.is_some()), "the cancelled worker participates again");
        let ups = pool.exchange(None, &[0, 1, 2]).unwrap();
        assert!(ups.iter().all(|u| u.is_some()));
        assert_eq!(
            pool.drained_up(),
            report_frame_bytes(codec, &[2, 6]) as u64,
            "exactly the stale report's bytes, tallied separately"
        );
        // committed-frame accounting never saw the stale report
        let rep_b = |id: u32| report_frame_bytes(codec, &[id, id + 4]) as u64;
        let upd_b = |id: u32| update_frame_bytes(codec, &[id]) as u64;
        let (wire_up, _) = pool.wire_observed();
        let expect = rep_b(0) + rep_b(1) + upd_b(0) + upd_b(1) // round 1: two survivors
            + rep_b(0) + rep_b(1) + rep_b(2) + upd_b(0) + upd_b(1) + upd_b(2); // round 2: all
        assert_eq!(wire_up, expect);
        // the reactor fed per-phase timings for the adaptive deadline
        let timings = pool.take_phase_timings();
        assert!(timings.iter().any(|&(c, _)| c == 0) && timings.iter().any(|&(c, _)| c == 2));
        pool.shutdown().unwrap();
        for h in hs {
            h.join().unwrap();
        }
    }

    /// Off-cohort `Sit` frames ride the reactor's batched write pass and
    /// still cost exactly [`SIT_FRAME_BYTES`] (13 bytes) each in the
    /// attempted `wire_down` accounting — one Model frame to the cohort
    /// member, one 13-byte Sit to the sitter, nothing else.
    #[test]
    fn off_cohort_sit_frames_cost_exactly_13_bytes() {
        use crate::fl::transport::{model_frame_bytes, recv_payload};
        let cfg = smoke_cfg(); // 2 clients, raw codec
        let codec = cfg.codec;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // worker 0: this round's cohort — broadcast in, report out
        let h0 = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            send(&mut s, &Msg::Join { client_id: 0, codec }, codec).unwrap();
            let mut fb = FrameBuf::new();
            let payload = recv_payload(&mut s, &mut fb).unwrap();
            assert_eq!(payload.first().copied(), Some(TAG_MODEL));
            let report = SparseVec::new(vec![1, 3], vec![0.5, -0.5]);
            send_report(&mut s, codec, &mut fb, 0, 1, &report, 0.25).unwrap();
        });
        // worker 1: off-cohort — exactly one 13-byte Sit
        let h1 = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            send(&mut s, &Msg::Join { client_id: 1, codec }, codec).unwrap();
            match recv(&mut s, codec).unwrap() {
                Msg::Sit { round } => assert_eq!(round, 1),
                other => panic!("expected Sit, got {other:?}"),
            }
        });
        let mut pool = TcpClientPool::accept(&cfg, listener).unwrap();
        let global = vec![0.0f32; 64];
        let reports = pool.train_and_report(&global, &[0]).unwrap();
        assert_eq!(reports.len(), 1);
        assert!(reports[0].is_some(), "the cohort member's report must land");
        assert_eq!(SIT_FRAME_BYTES, 13);
        let (_, down) = pool.wire_observed();
        assert_eq!(
            down as usize,
            model_frame_bytes(64) + SIT_FRAME_BYTES,
            "off-cohort downlink must be exactly one 13-byte Sit frame"
        );
        h0.join().unwrap();
        h1.join().unwrap();
    }
}
