//! Multi-process deployment: the PS and each client as separate OS
//! processes speaking the length-prefixed TCP protocol of
//! [`crate::fl::transport`].
//!
//! Both sides are thin adapters over the shared protocol code:
//!
//! * [`run_server`] — binds, waits for `n_clients` joins, then drives the
//!   **same** [`RoundEngine`] the in-process simulator uses, through
//!   [`TcpClientPool`] (the sockets-backed [`ClientPool`]).
//! * [`run_worker`] — owns one client's shard (derived from the shared
//!   seed + its id, so no data ever crosses the wire) and executes the
//!   same [`client_train_phase`] / [`client_update_phase`] as the
//!   in-process pool — local Adam state persists across rounds via
//!   `sync_to`, exactly like the simulator.
//!
//! The two deployments are therefore bit-for-bit identical on the same
//! config + seed (per-round uploaded indices and final global parameters
//! alike) — pinned by `rust/tests/parity.rs`.
//!
//! Both ends use the same `ExperimentConfig`; run e.g.:
//!
//! ```sh
//! ragek serve  --clients 4 --port 7700 --rounds 40 &
//! for i in 0 1 2 3; do ragek worker --connect 127.0.0.1:7700 --id $i & done
//! ```

use crate::backend::{make_backend, Backend};
use crate::config::{ExperimentConfig, Payload};
use crate::coordinator::engine::{
    client_train_phase, client_update_phase, cohort_positions, eval_dataset, ClientPool,
    ClientReport, PhaseCfg, RoundEngine,
};
use crate::data::{load_dataset, partition::partition};
use crate::fl::client::Client;
use crate::fl::metrics::CommStats;
use crate::fl::transport::{encode_model_frame, recv, send, Msg};
use crate::sparse::SparseVec;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// PS-side summary of a distributed run.
#[derive(Debug)]
pub struct ServeReport {
    pub rounds: usize,
    pub final_accuracy: f32,
    pub cluster_labels: Vec<usize>,
    /// final global model (sim/distributed parity checks)
    pub final_params: Vec<f32>,
    /// per round, per client: the uploaded index sets (empty entries for
    /// clients off that round's cohort)
    pub uploaded_log: Vec<Vec<Vec<u32>>>,
    /// the engine's byte-accurate communication accounting
    pub comm: CommStats,
    /// how many times the PS serialized a `Model` frame — the zero-copy
    /// broadcast pin: exactly one per round, however many workers
    pub model_encodes: u64,
}

/// The sockets-backed [`ClientPool`]: one TCP stream per remote worker,
/// indexed by client id. Owns the PS-side backend (server optimizer
/// apply + evaluation).
///
/// Broadcast/collect is **concurrent** — one scoped thread per cohort
/// stream, so a slow worker overlaps with its peers instead of
/// serializing the round in client order — and the model broadcast is
/// **zero-copy**: the
/// `Model` frame is encoded once per round into an `Arc<[u8]>` and the
/// same bytes are written to every cohort stream. Workers outside the
/// round's cohort receive a 13-byte [`Msg::Sit`] frame instead of the
/// d-vector, so downlink scales with the cohort, not with n.
pub struct TcpClientPool {
    streams: Vec<TcpStream>,
    backend: Box<dyn Backend>,
    round: u32,
    /// `Model` frame serializations so far (one per round — pinned by
    /// tests via [`ServeReport::model_encodes`])
    model_encodes: u64,
}

impl TcpClientPool {
    /// Block on an already-bound listener until all `cfg.n_clients`
    /// workers joined. Binding is the caller's job so tests can bind an
    /// ephemeral port *before* any worker spawns (joins then queue in the
    /// accept backlog — no sleeps, no port races).
    pub fn accept(cfg: &ExperimentConfig, listener: TcpListener) -> Result<Self> {
        crate::info!(
            "serve: waiting for {} clients on {:?}",
            cfg.n_clients,
            listener.local_addr()
        );
        let mut slots: Vec<Option<TcpStream>> = (0..cfg.n_clients).map(|_| None).collect();
        let mut joined = 0;
        while joined < cfg.n_clients {
            let (mut s, peer) = listener.accept()?;
            match recv(&mut s) {
                Ok(Msg::Join { client_id }) => {
                    let id = client_id as usize;
                    if id >= cfg.n_clients || slots[id].is_some() {
                        let _ = send(&mut s, &Msg::Shutdown);
                        Self::shutdown_joined(&mut slots);
                        bail!("bad/duplicate client id {id} from {peer}");
                    }
                    crate::info!("serve: client {id} joined from {peer}");
                    slots[id] = Some(s);
                    joined += 1;
                }
                Ok(other) => {
                    let _ = send(&mut s, &Msg::Shutdown);
                    Self::shutdown_joined(&mut slots);
                    bail!("expected Join, got {other:?}");
                }
                Err(e) => {
                    Self::shutdown_joined(&mut slots);
                    return Err(e.context(format!("recv Join from {peer}")));
                }
            }
        }
        Ok(TcpClientPool {
            streams: slots.into_iter().map(|s| s.unwrap()).collect(),
            backend: make_backend(cfg)?,
            round: 0,
            model_encodes: 0,
        })
    }

    /// Error path of [`Self::accept`]: a bad join must not leave every
    /// already-accepted worker blocked on a model broadcast that will
    /// never come — tell them training is over (best effort; a worker
    /// that died anyway is no reason to skip the rest).
    fn shutdown_joined(slots: &mut [Option<TcpStream>]) {
        for s in slots.iter_mut().flatten() {
            let _ = send(s, &Msg::Shutdown);
        }
    }

    /// `Model` frame serializations so far (exactly one per round).
    pub fn model_encodes(&self) -> u64 {
        self.model_encodes
    }

    /// Tell every worker training is over.
    pub fn shutdown(&mut self) -> Result<()> {
        for s in self.streams.iter_mut() {
            send(s, &Msg::Shutdown)?;
        }
        Ok(())
    }
}

impl ClientPool for TcpClientPool {
    fn n_clients(&self) -> usize {
        self.streams.len()
    }

    fn train_and_report(
        &mut self,
        global: &[f32],
        cohort: &[usize],
    ) -> Result<Vec<ClientReport>> {
        self.round += 1;
        let round = self.round;
        let pos = cohort_positions(self.streams.len(), cohort);
        // off-cohort first, inline: a 13-byte Sit per absent worker keeps
        // its round counter in sync without the d-vector — no point
        // spawning a thread for a tiny recv-less write (in the
        // cross-device regime most streams are off-cohort)
        for (i, stream) in self.streams.iter_mut().enumerate() {
            if pos[i] == usize::MAX {
                send(stream, &Msg::Sit { round })?;
            }
        }
        // zero-copy broadcast: serialize the d-vector frame once, write
        // the same bytes to every cohort stream
        let frame: Arc<[u8]> = encode_model_frame(round, global).into();
        self.model_encodes += 1;
        // one thread per cohort stream: a slow worker's local training
        // overlaps its peers' instead of serializing the round in client
        // order
        std::thread::scope(|scope| -> Result<Vec<ClientReport>> {
            let mut handles = Vec::with_capacity(cohort.len());
            for (i, stream) in self.streams.iter_mut().enumerate() {
                if pos[i] == usize::MAX {
                    continue;
                }
                let frame = Arc::clone(&frame);
                handles.push(scope.spawn(move || -> Result<ClientReport> {
                    stream.write_all(&frame).context("send model frame")?;
                    match recv(stream)? {
                        Msg::Report { report, mean_loss, round: r, .. } if r == round => {
                            Ok(ClientReport { report, mean_loss })
                        }
                        other => bail!("round {round}: expected Report, got {other:?}"),
                    }
                }));
            }
            // joining in stream order = ascending client id = cohort order
            handles
                .into_iter()
                .map(|h| h.join().expect("stream thread panicked"))
                .collect()
        })
    }

    fn exchange(
        &mut self,
        requests: Option<&[Vec<u32>]>,
        cohort: &[usize],
    ) -> Result<Vec<SparseVec>> {
        let round = self.round;
        let pos = cohort_positions(self.streams.len(), cohort);
        std::thread::scope(|scope| -> Result<Vec<SparseVec>> {
            let mut handles = Vec::with_capacity(cohort.len());
            for (i, stream) in self.streams.iter_mut().enumerate() {
                if pos[i] == usize::MAX {
                    continue; // off-cohort workers already got their Sit
                }
                // client-side strategies select locally; the Request frame
                // still flows (empty) so the wire flow stays uniform
                let indices = requests.map(|r| r[pos[i]].clone()).unwrap_or_default();
                handles.push(scope.spawn(move || -> Result<SparseVec> {
                    send(stream, &Msg::Request { round, indices })?;
                    match recv(stream)? {
                        Msg::Update { update, round: r, .. } if r == round => Ok(update),
                        other => bail!("round {round}: expected Update, got {other:?}"),
                    }
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("stream thread panicked"))
                .collect()
        })
    }

    fn backend(&mut self) -> &mut dyn Backend {
        self.backend.as_mut()
    }
}

/// Run the parameter server until `cfg.rounds` rounds complete.
pub fn run_server(cfg: &ExperimentConfig, port: u16) -> Result<ServeReport> {
    let listener =
        TcpListener::bind(("0.0.0.0", port)).with_context(|| format!("binding :{port}"))?;
    run_server_on(cfg, listener)
}

/// [`run_server`] over an already-bound listener (lets tests bind an
/// ephemeral port before spawning workers).
pub fn run_server_on(cfg: &ExperimentConfig, listener: TcpListener) -> Result<ServeReport> {
    cfg.validate()?;
    let mut pool = TcpClientPool::accept(cfg, listener)?;
    let init = pool.backend.init_params()?;
    let mut engine = RoundEngine::new(cfg, init);
    let (_, test) = load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
    let test_idx: Vec<usize> = (0..test.len()).collect();

    for round in 1..=cfg.rounds {
        engine.run_round(&mut pool)?;
        if cfg.eval_every > 0 && round % cfg.eval_every == 0 {
            let (acc, loss) =
                eval_dataset(pool.backend(), engine.global_params(), &test, &test_idx, cfg.batch)?;
            crate::info!(
                "serve: round {round}/{}: acc {:.2}% loss {loss:.4} clusters {}",
                cfg.rounds,
                acc * 100.0,
                engine.ps().clusters().n_clusters()
            );
        }
    }
    pool.shutdown()?;
    let (acc, _) =
        eval_dataset(pool.backend(), engine.global_params(), &test, &test_idx, cfg.batch)?;
    Ok(ServeReport {
        rounds: cfg.rounds,
        final_accuracy: acc,
        cluster_labels: engine.ps().clusters().labels(),
        final_params: engine.global_params().to_vec(),
        uploaded_log: engine.uploaded_log().iter().cloned().collect(),
        comm: engine.comm(),
        model_encodes: pool.model_encodes(),
    })
}

/// Run one worker process until the PS sends Shutdown.
pub fn run_worker(cfg: &ExperimentConfig, addr: &str, id: usize) -> Result<()> {
    cfg.validate()?;
    if id >= cfg.n_clients {
        bail!("worker id {id} >= n_clients {}", cfg.n_clients);
    }
    let pc = PhaseCfg::from_config(cfg);
    let mut backend = make_backend(cfg)?;
    // derive this worker's shard exactly like the simulator does: same
    // seed -> same partition, no data on the wire
    let (train, _) = load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
    let shards = partition(&train, cfg.n_clients, &cfg.partition, cfg.seed);
    let mut client = Client::new(id, train.subset(&shards[id]), backend.init_params()?, cfg.seed);
    let delta = cfg.payload == Payload::Delta;
    let mut memory = if delta { vec![0.0f32; cfg.d()] } else { Vec::new() };

    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    send(&mut stream, &Msg::Join { client_id: id as u32 })?;
    crate::info!("worker {id}: joined {addr}");

    loop {
        let (round, params) = match recv(&mut stream)? {
            Msg::Model { round, params } => (round, params),
            // off-cohort this round (partial participation): no broadcast,
            // no training, no upload — just wait for the next frame
            Msg::Sit { .. } => continue,
            Msg::Shutdown => break,
            other => bail!("expected Model/Sit/Shutdown, got {other:?}"),
        };
        // shared phase 1: sync_to (Adam moments persist), H local steps,
        // EF fold, top-r report — the same code the in-process pool runs
        let mem = if delta { Some(&mut memory) } else { None };
        let rep = client_train_phase(&mut client, backend.as_mut(), mem, &params, &pc)?;
        send(
            &mut stream,
            &Msg::Report {
                client_id: id as u32,
                round,
                report: rep.report.clone(),
                mean_loss: rep.mean_loss,
            },
        )?;
        let requested = match recv(&mut stream)? {
            Msg::Request { indices, round: r } if r == round => indices,
            other => bail!("expected Request, got {other:?}"),
        };
        // shared phase 2: answer the PS request, or select locally for
        // client-side strategies (the PS's echo frame is empty then)
        let request = if pc.strategy.needs_report() {
            Some(requested.as_slice())
        } else {
            None
        };
        let mem = if delta { Some(&mut memory) } else { None };
        let update =
            client_update_phase(&mut client, backend.as_mut(), mem, &rep.report, request, &pc)?;
        send(&mut stream, &Msg::Update { client_id: id as u32, round, update })?;
    }
    crate::info!("worker {id}: shutdown");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn distributed_round_trip_localhost() {
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.payload = Payload::Delta;
        cfg.rounds = 3;
        cfg.n_clients = 2;
        cfg.train_n = 200;
        cfg.test_n = 64;
        cfg.eval_every = 0;
        let report = crate::testing::run_distributed_localhost(&cfg).unwrap();
        assert_eq!(report.rounds, 3);
        assert_eq!(report.cluster_labels.len(), 2);
        assert_eq!(report.uploaded_log.len(), 3);
        assert!(report.uploaded_log.iter().all(|r| r.len() == 2));
        // zero-copy broadcast: one Model serialization per round, shared
        // across both workers
        assert_eq!(report.model_encodes, 3);
        assert_eq!(report.comm.broadcast_down, 3 * 2 * 4 * cfg.d() as u64);
    }
}
