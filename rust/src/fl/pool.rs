//! The in-process [`ClientPool`]: simulated clients living in this
//! process, trained **in parallel** on scoped threads.
//!
//! Parallelism follows the backend's replication story
//! ([`crate::backend::BackendLanes`]): the pure-Rust backend is stateless
//! and `Send`, so the pool holds one instance per worker thread and
//! chunks the clients across lanes; the XLA backend keeps a single PJRT
//! runtime per process and is driven serially. Either way the numerics
//! are identical to the sequential simulator — clients are independent
//! given the broadcast model, and results are collected in client order —
//! which `parallel_pool_matches_serial` pins.
//!
//! The per-client protocol itself ([`client_train_phase`] /
//! [`client_update_phase`]) is shared with the TCP worker, so this pool
//! and [`crate::fl::distributed::TcpClientPool`] are two transports for
//! the same code path.

use crate::backend::{make_backend_lanes, Backend, BackendLanes, SendBackend};
use crate::config::{ExperimentConfig, Payload};
use crate::coordinator::engine::{
    client_train_phase, client_update_phase, ClientPool, ClientReport, PhaseCfg,
};
use crate::data::Dataset;
use crate::fl::client::Client;
use crate::sparse::SparseVec;
use anyhow::{ensure, Context, Result};

pub struct InProcessPool {
    clients: Vec<Client>,
    lanes: BackendLanes,
    /// per-client error-feedback memory (Delta payload only; empty
    /// otherwise) — the unsent accumulated drift of Qsparse-local-SGD [7]
    memory: Vec<Vec<f32>>,
    /// phase-1 reports cached for the phase-2 uploads
    reports: Vec<SparseVec>,
    pc: PhaseCfg,
}

impl InProcessPool {
    /// Build the pool from one data shard per client. Returns the pool
    /// and the deterministic initial parameters every client started
    /// from (the engine's initial global model).
    pub fn new(cfg: &ExperimentConfig, shards: Vec<Dataset>) -> Result<(Self, Vec<f32>)> {
        ensure!(
            shards.len() == cfg.n_clients,
            "{} shards for {} clients",
            shards.len(),
            cfg.n_clients
        );
        let want = if cfg.parallel == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.parallel
        };
        let mut lanes = make_backend_lanes(cfg, want.min(cfg.n_clients).max(1))
            .context("creating backend lanes")?;
        let init = lanes.primary().init_params()?;
        let clients: Vec<Client> = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| Client::new(i, shard, init.clone(), cfg.seed))
            .collect();
        let memory = match cfg.payload {
            Payload::Delta => vec![vec![0.0f32; cfg.d()]; cfg.n_clients],
            Payload::Grad => Vec::new(),
        };
        Ok((
            InProcessPool {
                clients,
                lanes,
                memory,
                reports: Vec::new(),
                pc: PhaseCfg::from_config(cfg),
            },
            init,
        ))
    }

    pub fn clients(&self) -> &[Client] {
        &self.clients
    }

    pub fn client_params(&self, i: usize) -> &[f32] {
        &self.clients[i].state.params
    }

    /// Number of clients that train concurrently.
    pub fn n_lanes(&self) -> usize {
        self.lanes.n_lanes()
    }

    /// The PS-side backend (lane 0) — evaluation and server apply —
    /// without needing the [`ClientPool`] trait in scope.
    pub fn backend_mut(&mut self) -> &mut dyn Backend {
        self.lanes.primary()
    }
}

impl ClientPool for InProcessPool {
    fn n_clients(&self) -> usize {
        self.clients.len()
    }

    fn train_and_report(&mut self, global: &[f32]) -> Result<Vec<ClientReport>> {
        let pc = self.pc;
        let delta = pc.payload == Payload::Delta;
        let outs = match &mut self.lanes {
            BackendLanes::Serial(be) => {
                let mut outs = Vec::with_capacity(self.clients.len());
                for (i, c) in self.clients.iter_mut().enumerate() {
                    let mem = if delta { Some(&mut self.memory[i]) } else { None };
                    outs.push(client_train_phase(c, be.as_mut(), mem, global, &pc)?);
                }
                outs
            }
            BackendLanes::Parallel(lanes) => parallel_map(
                &mut self.clients,
                &mut self.memory,
                lanes,
                delta,
                |_, c, be, mem| client_train_phase(c, be, mem, global, &pc),
            )?,
        };
        self.reports = outs.iter().map(|o| o.report.clone()).collect();
        Ok(outs)
    }

    fn exchange(&mut self, requests: Option<&[Vec<u32>]>) -> Result<Vec<SparseVec>> {
        let pc = self.pc;
        let delta = pc.payload == Payload::Delta;
        let reports = std::mem::take(&mut self.reports);
        ensure!(
            reports.len() == self.clients.len(),
            "exchange before train_and_report"
        );
        if let Some(reqs) = requests {
            ensure!(reqs.len() == self.clients.len(), "request count mismatch");
        }
        match &mut self.lanes {
            BackendLanes::Serial(be) => {
                let mut outs = Vec::with_capacity(self.clients.len());
                for (i, c) in self.clients.iter_mut().enumerate() {
                    let mem = if delta { Some(&mut self.memory[i]) } else { None };
                    let req = requests.map(|r| r[i].as_slice());
                    outs.push(client_update_phase(c, be.as_mut(), mem, &reports[i], req, &pc)?);
                }
                Ok(outs)
            }
            BackendLanes::Parallel(lanes) => parallel_map(
                &mut self.clients,
                &mut self.memory,
                lanes,
                delta,
                |i, c, be, mem| {
                    let req = requests.map(|r| r[i].as_slice());
                    client_update_phase(c, be, mem, &reports[i], req, &pc)
                },
            ),
        }
    }

    fn backend(&mut self) -> &mut dyn Backend {
        self.lanes.primary()
    }
}

/// Run `f` over every client, chunked across the backend lanes on scoped
/// threads. Results come back in client order; client i's error-feedback
/// memory rides along when `delta` is set. With a single lane the work
/// runs inline on the calling thread.
fn parallel_map<T, F>(
    clients: &mut [Client],
    memory: &mut [Vec<f32>],
    lanes: &mut [SendBackend],
    delta: bool,
    f: F,
) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize, &mut Client, &mut dyn Backend, Option<&mut Vec<f32>>) -> Result<T> + Sync,
{
    let n = clients.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    // one Option slot per client so the Grad payload (no memory) chunks
    // uniformly with the clients
    let mut slots: Vec<Option<&mut Vec<f32>>> = if delta {
        memory.iter_mut().map(Some).collect()
    } else {
        (0..n).map(|_| None).collect()
    };
    let n_lanes = lanes.len().min(n).max(1);
    if n_lanes == 1 {
        let be = &mut lanes[0];
        let mut out = Vec::with_capacity(n);
        for (i, (c, slot)) in clients.iter_mut().zip(slots.iter_mut()).enumerate() {
            out.push(f(i, c, be.as_mut(), slot.take())?);
        }
        return Ok(out);
    }
    let per = n.div_ceil(n_lanes);
    std::thread::scope(|s| {
        let f = &f;
        let mut handles = Vec::with_capacity(n_lanes);
        for (chunk_no, ((cchunk, schunk), be)) in clients
            .chunks_mut(per)
            .zip(slots.chunks_mut(per))
            .zip(lanes.iter_mut())
            .enumerate()
        {
            let base = chunk_no * per;
            handles.push(s.spawn(move || -> Result<Vec<T>> {
                let mut out = Vec::with_capacity(cchunk.len());
                for (off, (c, slot)) in cchunk.iter_mut().zip(schunk.iter_mut()).enumerate() {
                    out.push(f(base + off, c, be.as_mut(), slot.take())?);
                }
                Ok(out)
            }));
        }
        let mut all = Vec::with_capacity(n);
        for h in handles {
            all.extend(h.join().expect("client worker thread panicked")?);
        }
        Ok(all)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::fl::trainer::Trainer;

    /// Parallel lanes must be bit-for-bit identical to the sequential
    /// simulator: clients are independent given the broadcast model and
    /// the (stateless) Rust backend is replicated per lane.
    #[test]
    fn parallel_pool_matches_serial() {
        let run = |parallel: usize| {
            let mut cfg = ExperimentConfig::mnist_smoke();
            cfg.parallel = parallel;
            cfg.rounds = 5;
            let mut t = Trainer::from_config(&cfg).unwrap();
            for _ in 0..cfg.rounds {
                t.run_round().unwrap();
            }
            (
                t.global_params().to_vec(),
                t.engine().uploaded_log().to_vec(),
            )
        };
        let serial = run(1);
        let parallel = run(4); // mnist_smoke has 4 clients: one lane each
        assert_eq!(serial.1, parallel.1, "uploaded index sets must match");
        assert_eq!(serial.0, parallel.0, "global params must match exactly");
    }

    #[test]
    fn lane_count_respects_config() {
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.parallel = 2;
        let t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.pool().n_lanes(), 2);
        // never more lanes than clients
        cfg.parallel = 64;
        let t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.pool().n_lanes(), cfg.n_clients);
    }
}
