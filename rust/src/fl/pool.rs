//! The in-process [`ClientPool`]: simulated clients living in this
//! process, trained **in parallel** on scoped threads.
//!
//! Parallelism follows the backend's replication story
//! ([`crate::backend::BackendLanes`]): the pure-Rust backend is stateless
//! and `Send`, so the pool holds one instance per worker thread and
//! chunks the clients across lanes; the XLA backend keeps a single PJRT
//! runtime per process and is driven serially. Either way the numerics
//! are identical to the sequential simulator — clients are independent
//! given the broadcast model, and results are collected in client order —
//! which `parallel_pool_matches_serial` pins.
//!
//! The per-client protocol itself ([`client_train_phase`] /
//! [`client_update_phase`]) is shared with the TCP worker, so this pool
//! and [`crate::fl::distributed::TcpClientPool`] are two transports for
//! the same code path. The in-process clients never fail on their own, so
//! every report/update slot comes back `Some`; chaos harnesses (e.g.
//! `testing::FlakyPool`) wrap this pool to simulate drops and rejoins,
//! using [`InProcessPool::resync_client`] to mimic a restarted worker.

use crate::backend::{
    make_backend_lanes, make_send_lanes, Backend, BackendLanes, ClientState, Lanes, SendBackend,
};
use crate::config::{ExperimentConfig, Payload};
use crate::coordinator::engine::{
    client_train_phase, client_update_phase, BroadcastPlan, ClientPool, ClientReport, CohortMap,
    PhaseCfg,
};
use crate::data::Shard;
use crate::fl::client::Client;
use crate::fl::codec::params_digest;
use crate::sparse::SparseVec;
use anyhow::{ensure, Context, Result};

/// An in-process pool whose lanes are all-parallel [`SendBackend`]s: the
/// pool itself is `Send`, so a sharded topology can drive one per shard
/// on scoped threads.
pub type SendPool = InProcessPool<Vec<SendBackend>>;

/// One simulated client's transferable state — what a dynamic re-shard
/// hands between shard pools (the in-process counterpart of moving a TCP
/// stream): the client (data shard, model + optimizer state, RNG) and its
/// error-feedback memory (empty under the Grad payload).
pub struct SimClientCarry {
    pub client: Client,
    pub memory: Vec<f32>,
}

pub struct InProcessPool<L = BackendLanes> {
    clients: Vec<Client>,
    lanes: L,
    /// per-client error-feedback memory (Delta payload only; empty
    /// otherwise) — the unsent accumulated drift of Qsparse-local-SGD [7]
    memory: Vec<Vec<f32>>,
    /// phase-1 reports cached for the phase-2 uploads, with the cohort
    /// they were trained for (the exchange cohort may be a survivor
    /// subset of it)
    reports: Vec<SparseVec>,
    report_cohort: Vec<usize>,
    /// reused client-id -> cohort-position map (stamp-versioned)
    cmap: CohortMap,
    pc: PhaseCfg,
    /// the delta-downlink plan's (round, digest), held between
    /// `set_broadcast_plan` and the broadcast it describes — the sim has
    /// no wire to shrink, but verifying the digest against the model
    /// actually broadcast catches plan/model drift in every sim test
    plan_check: Option<(u32, u64)>,
    /// commit quota for the next `train_and_report` (speculative
    /// over-scheduling, DESIGN.md §11); `None` = commit everyone
    quota: Option<usize>,
    /// members the last quota cancelled, until `take_cancelled` drains
    cancelled: Vec<usize>,
}

/// Requested lane count: config override or auto-detected cores, never
/// exceeding the client count. Under a sharded topology every shard pool
/// trains concurrently on its own scoped thread, so the auto budget is
/// the cores *divided by the shard count* — `parallel = 0` then fills the
/// machine exactly once instead of `shards ×` oversubscribing it (an
/// explicit `parallel` stays per-shard, as documented on the knob).
pub(crate) fn lane_count(cfg: &ExperimentConfig, n_clients: usize) -> usize {
    let want = if cfg.parallel == 0 {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        (cores / cfg.topology.n_shards()).max(1)
    } else {
        cfg.parallel
    };
    want.min(n_clients).max(1)
}

impl InProcessPool {
    /// Build the pool from one data shard per client. Returns the pool
    /// and the deterministic initial parameters every client started
    /// from (the engine's initial global model).
    pub fn new(cfg: &ExperimentConfig, shards: Vec<Shard>) -> Result<(Self, Vec<f32>)> {
        let lanes = make_backend_lanes(cfg, lane_count(cfg, cfg.n_clients))
            .context("creating backend lanes")?;
        let ids: Vec<usize> = (0..cfg.n_clients).collect();
        Self::with_lanes(cfg, shards, &ids, lanes)
    }
}

impl InProcessPool<Vec<SendBackend>> {
    /// Build a `Send` pool over one **shard** of a sharded topology:
    /// `ids[i]` is the *global* client id behind local slot `i` (global
    /// ids seed the per-client RNG streams, so a client's trajectory is
    /// identical whether it trains under a flat or a sharded topology).
    /// `cfg` is the shard-local config (`n_clients` = `ids.len()`).
    pub fn new_send(
        cfg: &ExperimentConfig,
        shards: Vec<Shard>,
        ids: &[usize],
    ) -> Result<(Self, Vec<f32>)> {
        let lanes = make_send_lanes(cfg, lane_count(cfg, cfg.n_clients))
            .context("creating send backend lanes")?;
        InProcessPool::with_lanes(cfg, shards, ids, lanes)
    }
}

impl<L: Lanes> InProcessPool<L> {
    fn with_lanes(
        cfg: &ExperimentConfig,
        shards: Vec<Shard>,
        ids: &[usize],
        mut lanes: L,
    ) -> Result<(Self, Vec<f32>)> {
        ensure!(
            shards.len() == cfg.n_clients && ids.len() == cfg.n_clients,
            "{} shards / {} ids for {} clients",
            shards.len(),
            ids.len(),
            cfg.n_clients
        );
        let init = lanes.primary().init_params()?;
        let clients: Vec<Client> = shards
            .into_iter()
            .zip(ids)
            .map(|(shard, &id)| Client::new(id, shard, init.clone(), cfg.seed))
            .collect();
        let memory = match cfg.payload {
            Payload::Delta => vec![vec![0.0f32; cfg.d()]; cfg.n_clients],
            Payload::Grad => Vec::new(),
        };
        Ok((
            InProcessPool {
                clients,
                lanes,
                memory,
                reports: Vec::new(),
                report_cohort: Vec::new(),
                cmap: CohortMap::new(),
                pc: PhaseCfg::from_config(cfg),
                plan_check: None,
                quota: None,
                cancelled: Vec::new(),
            },
            init,
        ))
    }

    pub fn clients(&self) -> &[Client] {
        &self.clients
    }

    pub fn client_params(&self, i: usize) -> &[f32] {
        &self.clients[i].state.params
    }

    /// Number of clients that train concurrently.
    pub fn n_lanes(&self) -> usize {
        self.lanes.n_lanes()
    }

    /// The PS-side backend (lane 0) — evaluation and server apply —
    /// without needing the [`ClientPool`] trait in scope.
    pub fn backend_mut(&mut self) -> &mut dyn Backend {
        self.lanes.primary()
    }

    /// Mimic a worker-process restart followed by a `Rejoin` resync
    /// (chaos harnesses): the client's model state is replaced by the
    /// current global model with **fresh** optimizer moments, and its
    /// error-feedback memory is cleared — a restarted process remembers
    /// neither.
    pub fn resync_client(&mut self, i: usize, global: &[f32]) {
        self.clients[i].state = ClientState::new(global.to_vec());
        if let Some(mem) = self.memory.get_mut(i) {
            mem.fill(0.0);
        }
    }

}

impl<L: Lanes> crate::coordinator::topology::Reshard for InProcessPool<L> {
    type Carry = SimClientCarry;

    /// Drain every client's transferable state in local-slot order (the
    /// dynamic re-shard hand-off). The pool is unusable until
    /// `install_parts` repopulates it.
    fn take_parts(&mut self) -> Vec<SimClientCarry> {
        let clients = std::mem::take(&mut self.clients);
        let mut memory = std::mem::take(&mut self.memory);
        let delta = self.pc.payload == Payload::Delta;
        clients
            .into_iter()
            .enumerate()
            .map(|(i, client)| SimClientCarry {
                client,
                memory: if delta { std::mem::take(&mut memory[i]) } else { Vec::new() },
            })
            .collect()
    }

    /// Repopulate from carries in (new) local-slot order; the pool's
    /// backend lanes stay put — only the clients move.
    fn install_parts(&mut self, parts: Vec<SimClientCarry>) {
        let delta = self.pc.payload == Payload::Delta;
        self.clients = Vec::with_capacity(parts.len());
        self.memory = if delta { Vec::with_capacity(parts.len()) } else { Vec::new() };
        for part in parts {
            self.clients.push(part.client);
            if delta {
                self.memory.push(part.memory);
            }
        }
        self.reports.clear();
        self.report_cohort.clear();
        self.plan_check = None;
        self.quota = None;
        self.cancelled.clear();
    }
}

impl<L: Lanes> ClientPool for InProcessPool<L> {
    fn n_clients(&self) -> usize {
        self.clients.len()
    }

    /// Simulated clients read `global` directly, so there is nothing to
    /// send sparsely — but the digest tripwire (see `plan_check`) runs in
    /// every delta-downlink sim test.
    fn set_broadcast_plan(&mut self, plan: &BroadcastPlan) {
        self.plan_check = Some((plan.round, plan.digest));
    }

    fn set_commit_quota(&mut self, quota: usize) {
        self.quota = Some(quota);
    }

    fn take_cancelled(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.cancelled)
    }

    fn train_and_report(
        &mut self,
        global: &[f32],
        cohort: &[usize],
    ) -> Result<Vec<Option<ClientReport>>> {
        if let Some((round, digest)) = self.plan_check.take() {
            ensure!(
                params_digest(global) == digest,
                "broadcast plan digest (round {round}) does not match the broadcast model"
            );
        }
        let pc = self.pc;
        let delta = pc.payload == Payload::Delta;
        let outs = cohort_map(
            &mut self.clients,
            &mut self.memory,
            &mut self.lanes,
            &mut self.cmap,
            delta,
            cohort,
            |_, c, be, mem| client_train_phase(c, be, mem, global, &pc),
        )?;
        self.reports = outs.iter().map(|o| o.report.clone()).collect();
        self.report_cohort = cohort.to_vec();
        match self.quota.take() {
            // simulated clients are never slow, so "the first `q`
            // reports land" resolves deterministically to the first `q`
            // in cohort order; the rest are cancelled cleanly — they
            // trained on the broadcast, the round simply committed
            // without their reports (the sim face of the TCP
            // clean-cancel, DESIGN.md §11)
            Some(q) if q < cohort.len() => {
                self.cancelled.extend_from_slice(&cohort[q..]);
                Ok(outs
                    .into_iter()
                    .enumerate()
                    .map(|(p, o)| (p < q).then_some(o))
                    .collect())
            }
            _ => Ok(outs.into_iter().map(Some).collect()),
        }
    }

    fn exchange(
        &mut self,
        requests: Option<&[Vec<u32>]>,
        cohort: &[usize],
    ) -> Result<Vec<Option<SparseVec>>> {
        let pc = self.pc;
        let delta = pc.payload == Payload::Delta;
        let reports = std::mem::take(&mut self.reports);
        let report_cohort = std::mem::take(&mut self.report_cohort);
        ensure!(reports.len() == report_cohort.len(), "exchange before train_and_report");
        if let Some(reqs) = requests {
            ensure!(reqs.len() == cohort.len(), "request count mismatch");
        }
        // the exchange cohort may be a survivor subset of the trained
        // cohort (phase-1 casualties excluded by the engine): map each
        // member back to its cached report
        self.cmap.set(self.clients.len(), &report_cohort);
        let mut report_of = vec![usize::MAX; cohort.len()];
        for (p, &c) in cohort.iter().enumerate() {
            let rp = self.cmap.slot(c);
            ensure!(rp != usize::MAX, "client {c} exchanged without a trained report");
            report_of[p] = rp;
        }
        let outs = cohort_map(
            &mut self.clients,
            &mut self.memory,
            &mut self.lanes,
            &mut self.cmap,
            delta,
            cohort,
            |p, c, be, mem| {
                let req = requests.map(|r| r[p].as_slice());
                client_update_phase(c, be, mem, &reports[report_of[p]], req, &pc)
            },
        )?;
        Ok(outs.into_iter().map(Some).collect())
    }

    fn backend(&mut self) -> &mut dyn Backend {
        self.lanes.primary()
    }
}

/// Run `f` over the cohort's clients, chunked across the backend lanes on
/// scoped threads. `f` receives the client's **cohort position** (its
/// index into the cohort-aligned reports/requests) and results come back
/// in cohort order; a member's error-feedback memory rides along when
/// `delta` is set. Off-cohort clients are untouched — no training, no
/// state change. With a single lane (or the serial backend) the work runs
/// inline on the calling thread; numerics are identical either way.
fn cohort_map<T, F, L>(
    clients: &mut [Client],
    memory: &mut [Vec<f32>],
    lanes: &mut L,
    cmap: &mut CohortMap,
    delta: bool,
    cohort: &[usize],
    f: F,
) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize, &mut Client, &mut dyn Backend, Option<&mut Vec<f32>>) -> Result<T> + Sync,
    L: Lanes,
{
    let n = clients.len();
    let m = cohort.len();
    if m == 0 {
        return Ok(Vec::new());
    }
    debug_assert!(cohort.windows(2).all(|w| w[0] < w[1]) && cohort[m - 1] < n);
    cmap.set(n, cohort);
    // one Option slot per client so the Grad payload (no memory) pairs
    // uniformly with the clients
    let slots: Vec<Option<&mut Vec<f32>>> = if delta {
        memory.iter_mut().map(Some).collect()
    } else {
        (0..n).map(|_| None).collect()
    };
    // cohort members with their cohort position, in cohort order
    let mut work: Vec<(usize, &mut Client, Option<&mut Vec<f32>>)> = clients
        .iter_mut()
        .zip(slots)
        .enumerate()
        .filter(|(i, _)| cmap.slot(*i) != usize::MAX)
        .enumerate()
        .map(|(p, (_i, (c, slot)))| (p, c, slot))
        .collect();
    lane_map(&mut work, lanes, f)
}

/// The lane fan-out itself, shared with [`crate::fl::compact::CompactPool`]
/// (which assembles its work list from materialized slots instead of a
/// dense client array): chunk the work items across the backend lanes on
/// scoped threads, collecting results in work order. With a single lane
/// (or a non-replicable serial backend) the work runs inline on the
/// calling thread; numerics are identical either way.
pub(crate) fn lane_map<T, F, L>(
    work: &mut [(usize, &mut Client, Option<&mut Vec<f32>>)],
    lanes: &mut L,
    f: F,
) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize, &mut Client, &mut dyn Backend, Option<&mut Vec<f32>>) -> Result<T> + Sync,
    L: Lanes,
{
    let m = work.len();
    if m == 0 {
        return Ok(Vec::new());
    }
    if let Some(lanes) = lanes.parallel() {
        let n_lanes = lanes.len().min(m).max(1);
        if n_lanes > 1 {
            let per = m.div_ceil(n_lanes);
            return std::thread::scope(|s| {
                let f = &f;
                let mut handles = Vec::with_capacity(n_lanes);
                for (chunk, be) in work.chunks_mut(per).zip(lanes.iter_mut()) {
                    handles.push(s.spawn(move || -> Result<Vec<T>> {
                        let mut out = Vec::with_capacity(chunk.len());
                        for (p, c, slot) in chunk.iter_mut() {
                            out.push(f(*p, c, be.as_mut(), slot.take())?);
                        }
                        Ok(out)
                    }));
                }
                let mut all = Vec::with_capacity(m);
                for h in handles {
                    all.extend(h.join().expect("client worker thread panicked")?);
                }
                Ok(all)
            });
        }
    }
    // single lane (or a non-replicable serial backend): run inline
    let be = lanes.primary();
    let mut out = Vec::with_capacity(m);
    for (p, c, slot) in work.iter_mut() {
        out.push(f(*p, c, &mut *be, slot.take())?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::fl::trainer::Trainer;

    /// Parallel lanes must be bit-for-bit identical to the sequential
    /// simulator: clients are independent given the broadcast model and
    /// the (stateless) Rust backend is replicated per lane.
    #[test]
    fn parallel_pool_matches_serial() {
        let run = |parallel: usize| {
            let mut cfg = ExperimentConfig::mnist_smoke();
            cfg.parallel = parallel;
            cfg.rounds = 5;
            let mut t = Trainer::from_config(&cfg).unwrap();
            for _ in 0..cfg.rounds {
                t.run_round().unwrap();
            }
            (
                t.global_params().to_vec(),
                t.engine().uploaded_log().iter().cloned().collect::<Vec<_>>(),
            )
        };
        let serial = run(1);
        let parallel = run(4); // mnist_smoke has 4 clients: one lane each
        assert_eq!(serial.1, parallel.1, "uploaded index sets must match");
        assert_eq!(serial.0, parallel.0, "global params must match exactly");
    }

    /// Lane parallelism stays a pure throughput knob under partial
    /// participation: the cohort's members chunk across lanes but train
    /// the same numerics in the same collection order.
    #[test]
    fn partial_participation_parallel_matches_serial() {
        let run = |parallel: usize| {
            let mut cfg = ExperimentConfig::mnist_smoke();
            cfg.parallel = parallel;
            cfg.participation = 0.5; // 4 clients -> cohort of 2
            cfg.rounds = 6;
            let mut t = Trainer::from_config(&cfg).unwrap();
            for _ in 0..cfg.rounds {
                t.run_round().unwrap();
            }
            (
                t.global_params().to_vec(),
                t.engine().uploaded_log().iter().cloned().collect::<Vec<_>>(),
            )
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(serial.1, parallel.1);
        assert_eq!(serial.0, parallel.0);
    }

    /// Off-cohort clients must not train, sync, or otherwise move.
    #[test]
    fn off_cohort_clients_are_untouched() {
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.participation = 0.5; // 4 clients -> round-robin cohort {0, 1}
        let mut t = Trainer::from_config(&cfg).unwrap();
        let before: Vec<Vec<f32>> =
            (0..cfg.n_clients).map(|i| t.pool().client_params(i).to_vec()).collect();
        t.run_round().unwrap();
        assert_ne!(
            before[0],
            t.pool().client_params(0).to_vec(),
            "cohort member 0 must have trained"
        );
        for i in [2, 3] {
            assert_eq!(
                before[i],
                t.pool().client_params(i).to_vec(),
                "client {i} sat the round out"
            );
        }
    }

    #[test]
    fn lane_count_respects_config() {
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.parallel = 2;
        let t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.pool().n_lanes(), 2);
        // never more lanes than clients
        cfg.parallel = 64;
        let t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.pool().n_lanes(), cfg.n_clients);
    }

    /// The exchange cohort may be a survivor subset of the trained
    /// cohort: the pool must answer from the right cached reports.
    #[test]
    fn exchange_accepts_survivor_subset_of_trained_cohort() {
        use crate::data::{load_dataset, partition_shards};
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.participation = 1.0;
        let (train, _) =
            load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
        let train = std::sync::Arc::new(train);
        let shards = partition_shards(&train, cfg.n_clients, &cfg.partition, cfg.seed);
        let (mut pool, init) = InProcessPool::new(&cfg, shards).unwrap();
        let full: Vec<usize> = (0..cfg.n_clients).collect();
        let reports = pool.train_and_report(&init, &full).unwrap();
        assert!(reports.iter().all(Option::is_some));
        // pretend clients 0 and 2 dropped after phase 1
        let survivors = vec![1usize, 3];
        let reqs: Vec<Vec<u32>> = survivors
            .iter()
            .map(|&c| reports[c].as_ref().unwrap().report.idx[..cfg.k].to_vec())
            .collect();
        let ups = pool.exchange(Some(&reqs), &survivors).unwrap();
        assert_eq!(ups.len(), 2);
        for (u, req) in ups.iter().zip(&reqs) {
            assert_eq!(&u.as_ref().unwrap().idx, req, "upload answers the right request");
        }
    }

    /// Speculation in the sim: under a commit quota the first `q`
    /// cohort members report and the rest are cancelled — but the
    /// cancelled members still trained on the broadcast (their local
    /// state moves), and the exchange runs over the winners alone.
    #[test]
    fn commit_quota_cancels_trailing_members_after_they_train() {
        use crate::data::{load_dataset, partition_shards};
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.participation = 1.0;
        let (train, _) =
            load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
        let train = std::sync::Arc::new(train);
        let shards = partition_shards(&train, cfg.n_clients, &cfg.partition, cfg.seed);
        let (mut pool, init) = InProcessPool::new(&cfg, shards).unwrap();
        let before: Vec<Vec<f32>> =
            (0..cfg.n_clients).map(|i| pool.client_params(i).to_vec()).collect();
        let full: Vec<usize> = (0..cfg.n_clients).collect();
        pool.set_commit_quota(2);
        let reports = pool.train_and_report(&init, &full).unwrap();
        assert!(reports[0].is_some() && reports[1].is_some());
        assert!(reports[2].is_none() && reports[3].is_none());
        assert_eq!(pool.take_cancelled(), vec![2, 3]);
        assert!(pool.take_cancelled().is_empty(), "draining transfers ownership");
        for i in 0..cfg.n_clients {
            assert_ne!(
                before[i],
                pool.client_params(i).to_vec(),
                "client {i} trained whether or not its report committed"
            );
        }
        let winners = vec![0usize, 1];
        let reqs: Vec<Vec<u32>> = winners
            .iter()
            .map(|&c| reports[c].as_ref().unwrap().report.idx[..cfg.k].to_vec())
            .collect();
        let ups = pool.exchange(Some(&reqs), &winners).unwrap();
        assert!(ups.iter().all(Option::is_some));
        // the quota applied to that round only
        let reports = pool.train_and_report(&init, &full).unwrap();
        assert!(reports.iter().all(Option::is_some));
        assert!(pool.take_cancelled().is_empty());
    }

    /// take/install round-trips the client state (the re-shard hand-off
    /// primitive): moving every client out and back is a no-op.
    #[test]
    fn take_install_roundtrip_preserves_clients() {
        use crate::coordinator::topology::Reshard;
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.rounds = 2;
        let mut t = Trainer::from_config(&cfg).unwrap();
        t.run_round().unwrap();
        let before: Vec<Vec<f32>> =
            (0..cfg.n_clients).map(|i| t.pool().client_params(i).to_vec()).collect();
        let pool = t.pool_mut();
        let parts = pool.take_parts();
        assert_eq!(parts.len(), cfg.n_clients);
        pool.install_parts(parts);
        for (i, b) in before.iter().enumerate() {
            assert_eq!(&t.pool().client_params(i).to_vec(), b);
        }
        // training continues unperturbed
        t.run_round().unwrap();
    }
}
