//! Pure, enumerable transition functions for the reactor's per-connection
//! state machines (DESIGN.md §13).
//!
//! `fl::distributed`'s reactor drives three interacting machines — the
//! per-connection phase state ([`ConnState`]), the pending-handshake
//! lifecycle, and the per-phase deadline policy. PR 10 splits the
//! *decisions* out of the I/O loop into this module: every transition is
//! a total function from `(state, event)` to `(next state, effect)`,
//! with no I/O, no clocks, and no allocation, so the full state × event
//! product is small enough to walk exhaustively in a model-checking test
//! (`model_check` below). The reactor keeps the I/O — classifying cursor
//! outcomes into [`ConnEvent`]s and applying [`Effect`]s to sockets,
//! buffers, and byte counters — but it can no longer invent a transition
//! the model check has not seen.
//!
//! Invariants pinned by the exhaustive tests:
//!
//! * **totality** — every `(state, event)` pair has a defined transition
//!   (the functions cannot panic; `analyze` additionally denies panic
//!   macros in this module at the source level);
//! * **progress** — from every live state the admissible events reach
//!   `Done` or a casualty; nothing can wedge, because every blocking
//!   state accepts [`ConnEvent::DeadlineExpired`] and a non-retryable
//!   expiry is always a casualty;
//! * **single-count accounting** — each transition carries at most one
//!   [`Effect`] (by construction), the frame-consuming effects
//!   ([`Effect::Landed`], [`Effect::DrainedStale`]) arise only in
//!   `Reading`, and [`Effect::QueueCancelSit`] — the one effect that
//!   adds downlink bytes — is reachable only once per commit, because
//!   its own transition leaves `Reading`;
//! * **deadline coverage** — [`phase_deadline_ms`] returns a window for
//!   every configuration with `io_timeout_ms > 0`, and a cancelled
//!   straggler's `Sit` write-out is re-armed with a *fresh* flat window
//!   ([`cancel_deadline_ms`]) instead of inheriting the nearly-expired
//!   reply deadline that put it in the cancel set in the first place.

/// Where a connection stands in the reactor's current phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnState {
    /// not armed this phase
    Idle,
    /// pushing the queued frame out; `expect_reply` arms the read half
    /// after the last byte (broadcasts and requests await a reply, a
    /// `Sit` does not)
    Writing { expect_reply: bool },
    /// accumulating the worker's reply frame
    Reading,
    /// this connection's work for the phase is complete
    Done,
}

/// Outcome of one [`SendCursor::advance`] call on a ready socket.
///
/// [`SendCursor::advance`]: crate::fl::transport::SendCursor::advance
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriteOutcome {
    /// the last byte of the queued frame reached the socket
    Complete,
    /// the transport would block; stay armed
    Pending,
    /// the stream is done for (reset, EOF mid-frame)
    Failed,
}

/// Outcome of one [`RecvCursor::advance`] call on a ready socket, with
/// the completed frame already classified by the caller (stale-drain
/// check, then the engine's `on_frame` validation).
///
/// [`RecvCursor::advance`]: crate::fl::transport::RecvCursor::advance
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadOutcome {
    /// a complete frame from a cancelled round — discard and keep reading
    StaleFrame,
    /// a complete frame the engine accepted
    FrameAccepted,
    /// a complete frame the engine rejected (bad round, bad indices)
    FrameRejected,
    /// the transport would block; stay armed
    Pending,
    /// the stream is done for (reset, EOF, bad framing)
    Failed,
}

/// Everything that can happen to an armed connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnEvent {
    /// the pool armed this connection for a new phase with a queued
    /// outgoing frame (authoritative: cursors are reset alongside)
    Armed { expect_reply: bool },
    /// `poll(2)` reported the socket writable and the send cursor ran
    Write(WriteOutcome),
    /// `poll(2)` reported the socket readable and the recv cursor ran
    Read(ReadOutcome),
    /// the speculative commit quota filled while this connection was
    /// still in flight (DESIGN.md §11)
    RoundCommitted,
    /// this connection's phase deadline passed; `can_retry` is true for
    /// an adaptive window that has not used its one bounded retry
    DeadlineExpired { can_retry: bool },
}

/// Why a connection became a casualty — the caller maps this to its
/// per-client log line and `dead` flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CasualtyKind {
    /// the queued frame could not be written out
    WriteFailed,
    /// the reply stream failed (reset, EOF, bad framing)
    ReadFailed,
    /// the engine rejected a structurally complete reply
    FrameRejected,
    /// the round committed while this connection's broadcast was still
    /// unfinished — the worker never got the model, so there is nothing
    /// to cancel cleanly
    BroadcastUnfinished,
    /// the phase deadline expired with no retry left
    DeadlineExpired,
}

/// The single side effect a transition instructs the reactor to apply.
/// One effect per transition by construction — the model check leans on
/// this to prove no wire byte is ever counted twice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Effect {
    /// nothing beyond the state change
    None,
    /// the queued frame is fully out: drop the shared rotation slot so
    /// its refcount can fall back to one
    ReleaseFrame,
    /// a committed reply landed: count it toward the quota, feed the
    /// adaptive-deadline EWMA, record the phase timing
    Landed,
    /// a stale frame from a cancelled round completed: tally its bytes
    /// in `drained_up` (never `wire_up`) and keep reading
    DrainedStale,
    /// queue the 13-byte cancel `Sit`, count it in `wire_down`, flag one
    /// stale reply for draining, record the cancellation, and re-arm the
    /// deadline with a fresh flat window ([`cancel_deadline_ms`])
    QueueCancelSit,
    /// grant the one bounded adaptive retry: double the window, mark the
    /// retry spent
    RearmDeadline,
    /// mark the connection dead and log the casualty
    Casualty(CasualtyKind),
}

/// A transition's full instruction to the reactor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transition {
    pub next: ConnState,
    pub effect: Effect,
}

fn stay(state: ConnState) -> Transition {
    Transition { next: state, effect: Effect::None }
}

/// The per-connection transition function — total over the full
/// state × event product, pure, and panic-free. The reactor calls this
/// for every event it observes and applies the returned effect; the
/// model check walks every pair.
pub fn conn_step(state: ConnState, event: ConnEvent) -> Transition {
    match (state, event) {
        // ------------------------------------------------- phase arming
        // Arming is authoritative: the pool queues a fresh outgoing
        // frame and resets the cursors, so it overrides whatever phase
        // state was left behind (normally `Idle` or `Done`).
        (_, ConnEvent::Armed { expect_reply }) => Transition {
            next: ConnState::Writing { expect_reply },
            effect: Effect::None,
        },
        // --------------------------------------------------- write half
        (ConnState::Writing { expect_reply }, ConnEvent::Write(WriteOutcome::Complete)) => {
            Transition {
                next: if expect_reply { ConnState::Reading } else { ConnState::Done },
                effect: Effect::ReleaseFrame,
            }
        }
        (ConnState::Writing { .. }, ConnEvent::Write(WriteOutcome::Pending)) => stay(state),
        (ConnState::Writing { .. }, ConnEvent::Write(WriteOutcome::Failed)) => {
            Transition { next: state, effect: Effect::Casualty(CasualtyKind::WriteFailed) }
        }
        // ---------------------------------------------------- read half
        (ConnState::Reading, ConnEvent::Read(ReadOutcome::StaleFrame)) => {
            Transition { next: ConnState::Reading, effect: Effect::DrainedStale }
        }
        (ConnState::Reading, ConnEvent::Read(ReadOutcome::FrameAccepted)) => {
            Transition { next: ConnState::Done, effect: Effect::Landed }
        }
        (ConnState::Reading, ConnEvent::Read(ReadOutcome::FrameRejected)) => {
            Transition { next: state, effect: Effect::Casualty(CasualtyKind::FrameRejected) }
        }
        (ConnState::Reading, ConnEvent::Read(ReadOutcome::Pending)) => stay(state),
        (ConnState::Reading, ConnEvent::Read(ReadOutcome::Failed)) => {
            Transition { next: state, effect: Effect::Casualty(CasualtyKind::ReadFailed) }
        }
        // ------------------------------------------- speculative commit
        // A stream whose broadcast was fully delivered gets the clean
        // cancel; one still mid-broadcast cannot be parked (the worker
        // never got the model) and is an ordinary casualty. A `Sit`
        // writer is already parked; `Idle`/`Done` have nothing to cancel.
        (ConnState::Reading, ConnEvent::RoundCommitted) => Transition {
            next: ConnState::Writing { expect_reply: false },
            effect: Effect::QueueCancelSit,
        },
        (ConnState::Writing { expect_reply: true }, ConnEvent::RoundCommitted) => {
            Transition { next: state, effect: Effect::Casualty(CasualtyKind::BroadcastUnfinished) }
        }
        // ----------------------------------------------------- deadlines
        (
            ConnState::Writing { .. } | ConnState::Reading,
            ConnEvent::DeadlineExpired { can_retry: true },
        ) => Transition { next: state, effect: Effect::RearmDeadline },
        (
            ConnState::Writing { .. } | ConnState::Reading,
            ConnEvent::DeadlineExpired { can_retry: false },
        ) => Transition { next: state, effect: Effect::Casualty(CasualtyKind::DeadlineExpired) },
        // ------------------------------------------------ inert corners
        // Terminal phase states ignore everything but arming; I/O events
        // cannot reach them because the reactor only polls Writing (OUT)
        // and Reading (IN) connections.
        _ => stay(state),
    }
}

/// One nonblocking pull of a pending handshake, classified by the caller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HandshakeRead {
    /// the handshake frame is complete
    Frame,
    /// frame still incomplete, socket would block
    Pending,
    /// the stream failed (reset, EOF, bad framing)
    Failed,
}

/// What to do with a pending handshake after one pull.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HandshakeDecision {
    /// keep it in the pending list
    Keep,
    /// hand the completed frame to admission
    Complete,
    /// drop it: the deadline expired mid-handshake
    DropExpired,
    /// drop it: the stream failed
    DropFailed,
}

/// The pending-handshake transition function. A frame that completes on
/// the same pull its deadline expires still wins — the bytes are all
/// here, so dropping it would discard a finished handshake for nothing.
pub fn handshake_step(read: HandshakeRead, deadline_expired: bool) -> HandshakeDecision {
    match (read, deadline_expired) {
        (HandshakeRead::Frame, _) => HandshakeDecision::Complete,
        (HandshakeRead::Failed, _) => HandshakeDecision::DropFailed,
        (HandshakeRead::Pending, true) => HandshakeDecision::DropExpired,
        (HandshakeRead::Pending, false) => HandshakeDecision::Keep,
    }
}

/// One phase's deadline window in milliseconds. With an RTT estimate in
/// hand, the window is `clamp(ewma_ms * deadline_factor, deadline_min_ms,
/// io_timeout_ms)` (DESIGN.md §11) — the cap is only applied when
/// `io_timeout_ms > 0`. Otherwise the flat `io_timeout_ms` applies, and
/// `None` (no deadline) only when that is 0.
pub fn phase_deadline_ms(
    io_timeout_ms: u64,
    deadline_factor: f64,
    deadline_min_ms: u64,
    ewma_ms: f32,
) -> Option<u64> {
    if deadline_factor > 0.0 && ewma_ms > 0.0 {
        let mut ms = (ewma_ms as f64 * deadline_factor).max(deadline_min_ms as f64).ceil() as u64;
        if io_timeout_ms > 0 {
            ms = ms.min(io_timeout_ms);
        }
        return Some(ms.max(1));
    }
    (io_timeout_ms > 0).then_some(io_timeout_ms)
}

/// The deadline window for a cancelled straggler's `Sit` write-out: a
/// fresh *flat* window, started at cancel time.
///
/// The connection earned its cancellation by being slow — its adaptive
/// reply deadline is, by definition, nearly (or already) spent when the
/// quota fills. PR 8 let the 13-byte `Sit` inherit that stale window, so
/// a straggler could be cancelled ("no fleet damage", DESIGN.md §11) and
/// then immediately dropped as a deadline casualty anyway, purely
/// because its cancel housekeeping raced a deadline armed for a
/// different, much larger transfer. The model check's deadline invariant
/// surfaced the corner; this window (pinned by `cancel_window_is_fresh_
/// and_flat`) closes it.
pub fn cancel_deadline_ms(io_timeout_ms: u64) -> Option<u64> {
    phase_deadline_ms(io_timeout_ms, 0.0, 0, 0.0)
}

#[cfg(test)]
mod model_check {
    use super::*;

    fn all_states() -> [ConnState; 5] {
        [
            ConnState::Idle,
            ConnState::Writing { expect_reply: true },
            ConnState::Writing { expect_reply: false },
            ConnState::Reading,
            ConnState::Done,
        ]
    }

    fn all_events() -> Vec<ConnEvent> {
        let mut evs = vec![
            ConnEvent::Armed { expect_reply: true },
            ConnEvent::Armed { expect_reply: false },
            ConnEvent::RoundCommitted,
            ConnEvent::DeadlineExpired { can_retry: true },
            ConnEvent::DeadlineExpired { can_retry: false },
        ];
        for w in [WriteOutcome::Complete, WriteOutcome::Pending, WriteOutcome::Failed] {
            evs.push(ConnEvent::Write(w));
        }
        for r in [
            ReadOutcome::StaleFrame,
            ReadOutcome::FrameAccepted,
            ReadOutcome::FrameRejected,
            ReadOutcome::Pending,
            ReadOutcome::Failed,
        ] {
            evs.push(ConnEvent::Read(r));
        }
        evs
    }

    /// The events the reactor can actually generate in each state: write
    /// outcomes only while polling `POLLOUT`, read outcomes only while
    /// polling `POLLIN`, commit/deadline sweeps against any armed state.
    fn admissible(s: ConnState) -> Vec<ConnEvent> {
        let mut evs: Vec<ConnEvent> = Vec::new();
        match s {
            ConnState::Writing { .. } => {
                for w in [WriteOutcome::Complete, WriteOutcome::Pending, WriteOutcome::Failed] {
                    evs.push(ConnEvent::Write(w));
                }
            }
            ConnState::Reading => {
                for r in [
                    ReadOutcome::StaleFrame,
                    ReadOutcome::FrameAccepted,
                    ReadOutcome::FrameRejected,
                    ReadOutcome::Pending,
                    ReadOutcome::Failed,
                ] {
                    evs.push(ConnEvent::Read(r));
                }
            }
            ConnState::Idle | ConnState::Done => return evs,
        }
        evs.push(ConnEvent::RoundCommitted);
        evs.push(ConnEvent::DeadlineExpired { can_retry: true });
        evs.push(ConnEvent::DeadlineExpired { can_retry: false });
        evs
    }

    fn is_blocking(s: ConnState) -> bool {
        matches!(s, ConnState::Writing { .. } | ConnState::Reading)
    }

    /// Totality over the full product, and the terminal phase states are
    /// inert under everything except arming.
    #[test]
    fn full_product_is_total_and_terminals_are_inert() {
        for s in all_states() {
            for e in all_events() {
                let t = conn_step(s, e); // must not panic for any pair
                if matches!(s, ConnState::Idle | ConnState::Done)
                    && !matches!(e, ConnEvent::Armed { .. })
                {
                    assert_eq!(t.next, s, "terminal {s:?} moved on {e:?}");
                    assert_eq!(t.effect, Effect::None, "terminal {s:?} acted on {e:?}");
                }
            }
        }
    }

    /// Arming is authoritative from every state, and nothing else ever
    /// re-arms: `Writing` is entered only by `Armed` or the cancel path.
    #[test]
    fn arming_is_authoritative() {
        for s in all_states() {
            for expect_reply in [true, false] {
                let t = conn_step(s, ConnEvent::Armed { expect_reply });
                assert_eq!(t.next, ConnState::Writing { expect_reply });
                assert_eq!(t.effect, Effect::None);
            }
        }
    }

    /// Every live state reaches `Done` or a casualty under its admissible
    /// events — walked as a reachability fixpoint over the whole graph,
    /// so no reachable state is stuck.
    #[test]
    fn every_live_state_reaches_done_or_casualty() {
        for start in all_states() {
            if !is_blocking(start) {
                continue;
            }
            // BFS over the admissible-event graph from `start`
            let mut frontier = vec![start];
            let mut seen = vec![start];
            let mut done_reachable = false;
            let mut casualty_reachable = false;
            while let Some(s) = frontier.pop() {
                assert!(
                    !admissible(s).is_empty() || !is_blocking(s),
                    "blocking state {s:?} admits no events"
                );
                for e in admissible(s) {
                    let t = conn_step(s, e);
                    if matches!(t.effect, Effect::Casualty(_)) {
                        casualty_reachable = true;
                        continue; // dead is terminal; the walk stops here
                    }
                    if t.next == ConnState::Done {
                        done_reachable = true;
                    }
                    if !seen.contains(&t.next) {
                        seen.push(t.next);
                        frontier.push(t.next);
                    }
                }
            }
            assert!(done_reachable, "{start:?} cannot reach Done");
            assert!(casualty_reachable, "{start:?} cannot reach a casualty");
        }
    }

    /// Every blocking state accepts a deadline event, a non-retryable
    /// expiry is always a casualty (the universal escape — nothing can
    /// wedge the round while a deadline is armed), and the one bounded
    /// retry keeps the state put so the next expiry is final.
    #[test]
    fn deadline_expiry_is_a_universal_escape() {
        for s in all_states() {
            if !is_blocking(s) {
                continue;
            }
            let retry = conn_step(s, ConnEvent::DeadlineExpired { can_retry: true });
            assert_eq!(retry.next, s, "retry must not change phase state");
            assert_eq!(retry.effect, Effect::RearmDeadline);
            let fin = conn_step(s, ConnEvent::DeadlineExpired { can_retry: false });
            assert_eq!(fin.effect, Effect::Casualty(CasualtyKind::DeadlineExpired));
        }
    }

    /// Byte-accounting effects are single-sourced: the frame-consuming
    /// effects only arise in `Reading` from the matching read outcome,
    /// the cancel `Sit` (the one downlink-byte effect) only from
    /// `(Reading, RoundCommitted)`, and a frame release only from a
    /// completed write. With one effect per transition by construction,
    /// no `(state, event)` pair can count a byte twice.
    #[test]
    fn byte_effects_are_single_sourced() {
        for s in all_states() {
            for e in all_events() {
                let t = conn_step(s, e);
                match t.effect {
                    Effect::Landed => {
                        assert_eq!(s, ConnState::Reading);
                        assert_eq!(e, ConnEvent::Read(ReadOutcome::FrameAccepted));
                        assert_eq!(t.next, ConnState::Done);
                    }
                    Effect::DrainedStale => {
                        assert_eq!(s, ConnState::Reading);
                        assert_eq!(e, ConnEvent::Read(ReadOutcome::StaleFrame));
                        assert_eq!(t.next, ConnState::Reading, "the real reply follows");
                    }
                    Effect::QueueCancelSit => {
                        assert_eq!((s, e), (ConnState::Reading, ConnEvent::RoundCommitted));
                        assert_eq!(t.next, ConnState::Writing { expect_reply: false });
                    }
                    Effect::ReleaseFrame => {
                        assert!(matches!(s, ConnState::Writing { .. }));
                        assert_eq!(e, ConnEvent::Write(WriteOutcome::Complete));
                    }
                    Effect::None | Effect::RearmDeadline | Effect::Casualty(_) => {}
                }
            }
        }
    }

    /// A connection is cancelled at most once per commit: the cancel
    /// transition leaves `Reading`, and from the post-cancel state no
    /// admissible event can produce another `QueueCancelSit` or a
    /// `Landed` — the cancelled straggler can neither be double-counted
    /// in `wire_down` nor sneak a late reply into the committed round.
    #[test]
    fn cancel_is_at_most_once_and_final() {
        let cancel = conn_step(ConnState::Reading, ConnEvent::RoundCommitted);
        assert_eq!(cancel.effect, Effect::QueueCancelSit);
        // walk everything reachable from the post-cancel state
        let mut frontier = vec![cancel.next];
        let mut seen = vec![cancel.next];
        while let Some(s) = frontier.pop() {
            for e in admissible(s) {
                let t = conn_step(s, e);
                assert_ne!(t.effect, Effect::QueueCancelSit, "double cancel via {s:?} {e:?}");
                assert_ne!(t.effect, Effect::Landed, "post-cancel landing via {s:?} {e:?}");
                if !matches!(t.effect, Effect::Casualty(_)) && !seen.contains(&t.next) {
                    seen.push(t.next);
                    frontier.push(t.next);
                }
            }
        }
    }

    /// The handshake decision table, exhaustively: a completed frame
    /// always wins, a failure always drops, and only a still-pending
    /// handshake can expire.
    #[test]
    fn handshake_product() {
        for expired in [false, true] {
            assert_eq!(
                handshake_step(HandshakeRead::Frame, expired),
                HandshakeDecision::Complete
            );
            assert_eq!(
                handshake_step(HandshakeRead::Failed, expired),
                HandshakeDecision::DropFailed
            );
        }
        assert_eq!(handshake_step(HandshakeRead::Pending, false), HandshakeDecision::Keep);
        assert_eq!(handshake_step(HandshakeRead::Pending, true), HandshakeDecision::DropExpired);
    }

    /// Deadlines always surface from std's sleep/timeout machinery as
    /// `>= 1 ms` windows — never "instant expiry" (std rejects a zero
    /// timeout), and whenever `io_timeout_ms > 0` **every** blocking
    /// state gets a window: the deadline-coverage half of the model
    /// check, swept over a grid of every regime boundary.
    #[test]
    fn deadline_window_grid() {
        // the PR 8 pins, preserved verbatim
        assert_eq!(phase_deadline_ms(0, 0.0, 0, 0.0), None, "flat window, knob off");
        assert_eq!(phase_deadline_ms(5000, 0.0, 0, 0.0), Some(5000));
        assert_eq!(phase_deadline_ms(5000, 2.0, 50, 100.0), Some(200));
        assert_eq!(phase_deadline_ms(5000, 2.0, 50, 10.0), Some(50), "floor applies");
        assert_eq!(phase_deadline_ms(150, 2.0, 50, 100.0), Some(150), "cap applies");
        assert_eq!(phase_deadline_ms(0, 2.0, 50, 100.0), Some(200), "io_timeout 0 = no cap");
        assert_eq!(phase_deadline_ms(0, 2.0, 50, 0.0), None, "no RTT sample: flat window");
        // the exhaustive grid: every combination of regime boundaries
        for io in [0u64, 1, 150, 5000] {
            for factor in [0.0f64, 0.5, 2.0] {
                for min in [0u64, 50, 9000] {
                    for ewma in [0.0f32, 0.4, 10.0, 100.0, 1.0e6] {
                        let got = phase_deadline_ms(io, factor, min, ewma);
                        if io > 0 {
                            let ms = got.expect("io_timeout > 0 must always arm a deadline");
                            assert!(ms >= 1, "std rejects zero windows");
                            assert!(ms <= io.max(1), "the flat timeout caps every window");
                        } else if factor > 0.0 && ewma > 0.0 {
                            let ms = got.expect("adaptive window with a sample");
                            assert!(ms >= 1, "std rejects zero windows");
                            assert!(ms >= min, "uncapped adaptive windows respect the floor");
                        } else {
                            assert_eq!(got, None, "no knob, no deadline");
                        }
                    }
                }
            }
        }
    }

    /// Regression pin for the cancelled-straggler deadline corner: the
    /// `Sit` write-out window is flat (independent of the straggler's
    /// EWMA — which is exactly what expired on it) and present whenever
    /// the flat timeout is on, so a cancel is never retro-converted into
    /// a deadline casualty by an inherited, already-spent window.
    #[test]
    fn cancel_window_is_fresh_and_flat() {
        assert_eq!(cancel_deadline_ms(0), None);
        assert_eq!(cancel_deadline_ms(3000), Some(3000));
        // the straggler's (spent) adaptive window would have been far
        // tighter; the fresh flat window must not depend on it
        let adaptive = phase_deadline_ms(3000, 2.0, 50, 40.0);
        assert_eq!(adaptive, Some(80), "the reply window the straggler just missed");
        assert_ne!(cancel_deadline_ms(3000), adaptive);
    }
}
