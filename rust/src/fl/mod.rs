//! The federated-learning runtime: clients, the in-process parallel
//! client pool, the end-to-end trainer (a thin adapter over the unified
//! [`crate::coordinator::engine::RoundEngine`]), metrics with
//! byte-accurate communication accounting, the versioned wire
//! [`codec`] (raw v1 | packed v2 delta-varint | packed-f16), and the
//! TCP transport / multi-process deployment driving the very same
//! engine.

pub mod client;
pub mod codec;
pub mod compact;
pub mod conn_fsm;
pub mod distributed;
pub mod metrics;
pub mod pool;
pub mod reactor;
pub mod trainer;
pub mod transport;

pub use client::Client;
pub use codec::Codec;
pub use compact::CompactPool;
pub use metrics::{CommStats, History, RoundRecord};
pub use pool::InProcessPool;
pub use trainer::{Trainer, TrainReport};
