//! The federated-learning runtime: clients, the end-to-end trainer
//! (Algorithm 1), metrics with byte-accurate communication accounting,
//! and the in-process / TCP transports.

pub mod client;
pub mod distributed;
pub mod metrics;
pub mod trainer;
pub mod transport;

pub use client::Client;
pub use metrics::{CommStats, History, RoundRecord};
pub use trainer::{Trainer, TrainReport};
