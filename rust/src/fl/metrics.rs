//! Training metrics: accuracy/loss curves (Fig. 3/5 payloads) and
//! byte-accurate communication accounting (DESIGN.md §6).

use crate::util::json::Json;
use crate::util::plot;

/// Cumulative communication counters (bytes).
///
/// The four protocol counters measure the *information content* of the
/// paper's protocol (DESIGN.md §6 formulas: 4 B per index, 4 B per
/// value) and are deliberately codec-independent, so strategy
/// comparisons stay comparable across wire formats. The `wire_*`
/// counters measure the **exact frame bytes** the negotiated
/// [`crate::fl::codec::Codec`] puts on the sockets (headers, varints,
/// Sit frames included) — pinned equal to the observed socket byte
/// counts by `rust/tests/parity.rs`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    /// client -> PS: top-r index reports (rAge-k only)
    pub report_up: u64,
    /// client -> PS: sparse value uploads
    pub update_up: u64,
    /// PS -> client: index requests (rAge-k only)
    pub request_down: u64,
    /// PS -> client: global model broadcasts
    pub broadcast_down: u64,
    /// exact uplink frame bytes under the active codec (report + update
    /// frames, headers included)
    pub wire_up: u64,
    /// exact downlink frame bytes under the active codec (model +
    /// request + sit frames, headers included)
    pub wire_down: u64,
}

impl CommStats {
    /// Field-wise accumulate `other` into `self` — the sharded topology's
    /// roll-up: the root's counters are the sum of its shard engines'
    /// (DESIGN.md §7; the root <-> shard hop is in-process, zero bytes).
    pub fn absorb(&mut self, other: &CommStats) {
        self.report_up += other.report_up;
        self.update_up += other.update_up;
        self.request_down += other.request_down;
        self.broadcast_down += other.broadcast_down;
        self.wire_up += other.wire_up;
        self.wire_down += other.wire_down;
    }

    pub fn uplink(&self) -> u64 {
        self.report_up + self.update_up
    }

    pub fn downlink(&self) -> u64 {
        self.request_down + self.broadcast_down
    }

    pub fn total(&self) -> u64 {
        self.uplink() + self.downlink()
    }

    /// Exact bytes on the wire in both directions under the active codec.
    pub fn wire_total(&self) -> u64 {
        self.wire_up + self.wire_down
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("report_up", Json::Num(self.report_up as f64)),
            ("update_up", Json::Num(self.update_up as f64)),
            ("request_down", Json::Num(self.request_down as f64)),
            ("broadcast_down", Json::Num(self.broadcast_down as f64)),
            ("uplink", Json::Num(self.uplink() as f64)),
            ("downlink", Json::Num(self.downlink() as f64)),
            ("wire_up", Json::Num(self.wire_up as f64)),
            ("wire_down", Json::Num(self.wire_down as f64)),
        ])
    }
}

/// One global round's record.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    /// mean local training loss across clients this round
    pub train_loss: f32,
    /// global-model test accuracy/loss (None between eval points)
    pub test_acc: Option<f32>,
    pub test_loss: Option<f32>,
    pub n_clusters: usize,
    pub uplink_cum: u64,
}

/// Full training history (one per strategy run).
#[derive(Debug, Clone, Default)]
pub struct History {
    pub name: String,
    pub rounds: Vec<RoundRecord>,
    pub comm: CommStats,
    pub wall_secs: f64,
}

impl History {
    pub fn new(name: &str) -> Self {
        History { name: name.to_string(), ..Default::default() }
    }

    pub fn final_accuracy(&self) -> f32 {
        self.rounds.iter().rev().find_map(|r| r.test_acc).unwrap_or(0.0)
    }

    /// First round at which test accuracy reached `target` (the Fig. 5
    /// "80% by iteration 400" style metric).
    pub fn rounds_to_accuracy(&self, target: f32) -> Option<usize> {
        self.rounds
            .iter()
            .find(|r| r.test_acc.map(|a| a >= target).unwrap_or(false))
            .map(|r| r.round)
    }

    /// Uplink bytes spent when `target` accuracy was first reached.
    pub fn uplink_to_accuracy(&self, target: f32) -> Option<u64> {
        self.rounds
            .iter()
            .find(|r| r.test_acc.map(|a| a >= target).unwrap_or(false))
            .map(|r| r.uplink_cum)
    }

    pub fn acc_series(&self) -> Vec<f64> {
        self.rounds.iter().filter_map(|r| r.test_acc.map(|a| a as f64)).collect()
    }

    pub fn loss_series(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.train_loss as f64).collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("final_accuracy", Json::Num(self.final_accuracy() as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("comm", self.comm.to_json()),
            (
                "rounds",
                Json::Arr(
                    self.rounds
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("round", Json::Num(r.round as f64)),
                                ("train_loss", Json::Num(r.train_loss as f64)),
                                (
                                    "test_acc",
                                    r.test_acc.map(|a| Json::Num(a as f64)).unwrap_or(Json::Null),
                                ),
                                (
                                    "test_loss",
                                    r.test_loss.map(|a| Json::Num(a as f64)).unwrap_or(Json::Null),
                                ),
                                ("n_clusters", Json::Num(r.n_clusters as f64)),
                                ("uplink_cum", Json::Num(r.uplink_cum as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// CSV with one row per round.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("round,train_loss,test_acc,test_loss,n_clusters,uplink_cum\n");
        for r in &self.rounds {
            s.push_str(&format!(
                "{},{},{},{},{},{}\n",
                r.round,
                r.train_loss,
                r.test_acc.map(|a| a.to_string()).unwrap_or_default(),
                r.test_loss.map(|a| a.to_string()).unwrap_or_default(),
                r.n_clusters,
                r.uplink_cum
            ));
        }
        s
    }

    /// Terminal chart of the accuracy curves of several runs.
    pub fn chart_accuracy(histories: &[&History], width: usize, height: usize) -> String {
        let series: Vec<(_, Vec<f64>)> =
            histories.iter().map(|h| (h.name.as_str(), h.acc_series())).collect();
        let refs: Vec<(&str, &[f64])> =
            series.iter().map(|(n, v)| (*n, v.as_slice())).collect();
        plot::line_chart(&refs, width, height)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn history() -> History {
        let mut h = History::new("test");
        for (i, acc) in [(0usize, None), (5, Some(0.4f32)), (10, Some(0.8)), (15, Some(0.9))] {
            h.rounds.push(RoundRecord {
                round: i,
                train_loss: 1.0 / (i + 1) as f32,
                test_acc: acc,
                test_loss: acc.map(|a| 1.0 - a),
                n_clusters: 10 - i / 2,
                uplink_cum: (i as u64 + 1) * 100,
            });
        }
        h
    }

    #[test]
    fn accuracy_queries() {
        let h = history();
        assert_eq!(h.final_accuracy(), 0.9);
        assert_eq!(h.rounds_to_accuracy(0.75), Some(10));
        assert_eq!(h.rounds_to_accuracy(0.99), None);
        assert_eq!(h.uplink_to_accuracy(0.75), Some(1100));
    }

    #[test]
    fn comm_totals() {
        let c = CommStats {
            report_up: 10,
            update_up: 20,
            request_down: 5,
            broadcast_down: 40,
            wire_up: 33,
            wire_down: 50,
        };
        assert_eq!(c.uplink(), 30);
        assert_eq!(c.downlink(), 45);
        assert_eq!(c.total(), 75);
        assert_eq!(c.wire_total(), 83);
    }

    #[test]
    fn csv_and_json_shapes() {
        let h = history();
        let csv = h.to_csv();
        assert_eq!(csv.lines().count(), 5);
        let j = h.to_json();
        assert_eq!(j.at(&["rounds"]).as_arr().unwrap().len(), 4);
        assert_eq!(j.at(&["final_accuracy"]).as_f64(), Some(0.9f32 as f64));
    }
}
