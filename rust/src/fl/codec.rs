//! The v2 packed wire codec: delta+varint sparse index blocks, f16 value
//! mode, bulk f32 (de)serialization, and reusable frame buffers.
//!
//! The raw (v1) frame format spends 8 B per sparse entry (u32 index +
//! f32 value) and encodes dense payloads one element at a time. rAge-k's
//! age-based selection produces index sets drawn from a top-r report —
//! sorted they are clustered and small-gapped, which delta + LEB128
//! coding compresses to ~1–2 B per index. This module holds everything
//! codec-shaped; the frame *layouts* (which field goes where per message)
//! live in [`crate::fl::transport`].
//!
//! Pieces:
//!
//! * [`Codec`] — the negotiated wire format (`raw` | `packed` |
//!   `packed-f16`), carried as a protocol-version byte in the `Join`
//!   frame and checked by the PS at accept time.
//! * LEB128 varints for `u32` with strict overlong/truncation rejection.
//! * [`write_index_block`]/`Dec::index_block` — the order-preserving
//!   sparse index encoding: indices are sorted and delta+varint coded,
//!   then the original order is restored by a varint rank per position
//!   (ranks are a permutation of `0..n`, so their total size is
//!   data-independent; see [`index_block_bytes`]).
//! * IEEE 754 binary16 conversions for the lossy `packed-f16` value mode
//!   (round-to-nearest-even, subnormals and specials handled).
//! * Bulk `f32`/`u32` slice writers and readers — chunked
//!   `to_le_bytes`/`from_le_bytes` over byte windows instead of the old
//!   per-element `Enc::f32` loop with a bounds check per element.
//! * [`FrameBuf`] — per-stream encode scratch + recv payload buffer so
//!   steady-state rounds perform no per-frame transport allocations.

use anyhow::{bail, Result};

// ================================================================= Codec

/// The wire format both ends of a stream agreed on at `Join` time.
///
/// `Raw` is the v1 format (4 B per index, 4 B per value, per-element
/// lists). `Packed` keeps every decoded value bit-identical to `Raw`
/// (lossless; indices delta+varint coded, report values never shipped —
/// the PS protocol does not consume them). `PackedF16` additionally
/// stores sparse *update* values as binary16 (lossy, ~2^-11 relative
/// error; index streams stay lossless).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Codec {
    #[default]
    Raw,
    Packed,
    PackedF16,
}

impl Codec {
    pub fn name(self) -> &'static str {
        match self {
            Codec::Raw => "raw",
            Codec::Packed => "packed",
            Codec::PackedF16 => "packed-f16",
        }
    }

    pub fn parse(s: &str) -> Option<Codec> {
        match s {
            "raw" => Some(Codec::Raw),
            "packed" => Some(Codec::Packed),
            "packed-f16" => Some(Codec::PackedF16),
            _ => None,
        }
    }

    /// The protocol-version byte carried in the `Join` frame.
    pub fn wire_id(self) -> u8 {
        match self {
            Codec::Raw => 0,
            Codec::Packed => 1,
            Codec::PackedF16 => 2,
        }
    }

    pub fn from_wire_id(b: u8) -> Option<Codec> {
        match b {
            0 => Some(Codec::Raw),
            1 => Some(Codec::Packed),
            2 => Some(Codec::PackedF16),
            _ => None,
        }
    }

    /// Sparse index lists are delta+varint coded (not 4 B raw).
    pub fn packs_indices(self) -> bool {
        self != Codec::Raw
    }

    /// Sparse update values ship as binary16.
    pub fn f16_values(self) -> bool {
        self == Codec::PackedF16
    }
}

// ================================================================ varint

/// Encoded size of `x` as a LEB128 varint (1–5 bytes).
pub fn varint_len(x: u32) -> usize {
    if x < 1 << 7 {
        1
    } else if x < 1 << 14 {
        2
    } else if x < 1 << 21 {
        3
    } else if x < 1 << 28 {
        4
    } else {
        5
    }
}

/// Append `x` as a LEB128 varint.
pub fn write_varint(out: &mut Vec<u8>, mut x: u32) {
    loop {
        let b = (x & 0x7f) as u8;
        x >>= 7;
        if x == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

// ============================================================== binary16

/// f32 -> IEEE 754 binary16 bits, round-to-nearest-even. Overflow maps to
/// signed infinity, underflow to signed zero; NaN stays NaN.
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let exp = ((x >> 23) & 0xff) as i32;
    let mut man = x & 0x007f_ffff;
    if exp == 255 {
        // infinity / NaN: keep the top mantissa bits, force NaN to stay NaN
        let m = (man >> 13) as u16;
        return sign | 0x7c00 | if man != 0 && m == 0 { 1 } else { m };
    }
    let e = exp - 127 + 15; // rebias to binary16
    if e >= 0x1f {
        return sign | 0x7c00; // overflow -> +-inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // below half the smallest subnormal -> +-0
        }
        // subnormal half: shift the (implicit-1) mantissa into place
        man |= 0x0080_0000;
        let shift = (14 - e) as u32; // 14..=24
        let half_man = man >> shift;
        let round_bit = 1u32 << (shift - 1);
        let rem = man & ((round_bit << 1) - 1);
        let mut h = half_man;
        if rem > round_bit || (rem == round_bit && half_man & 1 == 1) {
            h += 1;
        }
        return sign | h as u16;
    }
    let half_man = man >> 13;
    let rem = man & 0x1fff;
    let mut h = ((e as u32) << 10) | half_man;
    if rem > 0x1000 || (rem == 0x1000 && half_man & 1 == 1) {
        h += 1; // may carry into the exponent — that rounding to inf is correct
    }
    sign | h as u16
}

/// IEEE 754 binary16 bits -> f32 (exact; every f16 is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13) // inf / NaN
    } else if exp != 0 {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    } else if man == 0 {
        sign // +-0
    } else {
        // subnormal: normalize (value = man * 2^-24)
        let mut e: i32 = 127 - 15 + 1;
        let mut m = man;
        while m & 0x0400 == 0 {
            m <<= 1;
            e -= 1;
        }
        sign | ((e as u32) << 23) | ((m & 0x03ff) << 13)
    };
    f32::from_bits(bits)
}

// ====================================================== bulk primitives

/// Append `x` little-endian.
pub fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Append `x` little-endian.
pub fn put_f32(out: &mut Vec<u8>, x: f32) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Append `xs` as contiguous little-endian f32 words (no length prefix):
/// the buffer is grown once and filled through fixed 4-byte windows, so
/// the per-element capacity/bounds checks of the old `Enc::f32` loop
/// vanish and the loop vectorizes.
pub fn put_f32s_bulk(out: &mut Vec<u8>, xs: &[f32]) {
    let start = out.len();
    out.resize(start + 4 * xs.len(), 0);
    for (w, &x) in out[start..].chunks_exact_mut(4).zip(xs) {
        w.copy_from_slice(&x.to_le_bytes());
    }
}

/// Append `xs` as contiguous little-endian u32 words (no length prefix).
pub fn put_u32s_bulk(out: &mut Vec<u8>, xs: &[u32]) {
    let start = out.len();
    out.resize(start + 4 * xs.len(), 0);
    for (w, &x) in out[start..].chunks_exact_mut(4).zip(xs) {
        w.copy_from_slice(&x.to_le_bytes());
    }
}

/// Append `xs` as contiguous binary16 words (no length prefix).
pub fn put_f16s_bulk(out: &mut Vec<u8>, xs: &[f32]) {
    let start = out.len();
    out.resize(start + 2 * xs.len(), 0);
    for (w, &x) in out[start..].chunks_exact_mut(2).zip(xs) {
        w.copy_from_slice(&f32_to_f16_bits(x).to_le_bytes());
    }
}

// ============================================================== decoding

/// Byte-slice decoder shared by every frame layout: strict bounds checks,
/// varints with overlong rejection, and bulk array reads.
pub struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(b: &'a [u8]) -> Self {
        Dec { b, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("truncated frame ({} bytes left, {n} needed)", self.remaining());
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Like [`Dec::take`], but as a fixed-size array — the length check
    /// rides the fallible conversion, so a decoder word read can never
    /// panic (the protocol edge is a no-panic zone, `cargo run -p
    /// analyze`).
    fn take_word<const N: usize>(&mut self) -> Result<[u8; N]> {
        let s = self.take(N)?;
        s.try_into().map_err(|_| anyhow::anyhow!("internal: take({N}) returned a short slice"))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take_word()?))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take_word()?))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// LEB128 varint. Rejects truncation and overlong encodings (more
    /// than 5 bytes, or 5th-byte bits beyond a u32).
    pub fn varint(&mut self) -> Result<u32> {
        let mut x = 0u32;
        for shift in [0u32, 7, 14, 21, 28] {
            let b = self.u8()?;
            if shift == 28 && b & 0xf0 != 0 {
                bail!("overlong varint");
            }
            x |= ((b & 0x7f) as u32) << shift;
            if b & 0x80 == 0 {
                return Ok(x);
            }
        }
        // the 5th byte either returned or bailed above (0x80 ⊂ 0xf0);
        // kept as a defensive error rather than a panic at the edge
        bail!("overlong varint");
    }

    /// Length-prefixed raw u32 list (the v1 format).
    pub fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let bytes = self.take(n.checked_mul(4).unwrap_or(usize::MAX))?;
        // analyze: allow(panic, chunks_exact(4) yields exact 4-byte windows)
        Ok(bytes.chunks_exact(4).map(|w| u32::from_le_bytes(w.try_into().unwrap())).collect())
    }

    /// Length-prefixed raw f32 list (the v1 format).
    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let mut out = Vec::new();
        self.f32s_bulk_into(n, &mut out)?;
        Ok(out)
    }

    /// `n` contiguous little-endian f32 words into a reused buffer.
    pub fn f32s_bulk_into(&mut self, n: usize, out: &mut Vec<f32>) -> Result<()> {
        let bytes = self.take(n.checked_mul(4).unwrap_or(usize::MAX))?;
        out.clear();
        // analyze: allow(panic, chunks_exact(4) yields exact 4-byte windows)
        out.extend(bytes.chunks_exact(4).map(|w| f32::from_le_bytes(w.try_into().unwrap())));
        Ok(())
    }

    /// `n` contiguous binary16 words, widened to f32.
    pub fn f16s_bulk(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n.checked_mul(2).unwrap_or(usize::MAX))?;
        Ok(bytes
            .chunks_exact(2)
            // analyze: allow(panic, chunks_exact(2) yields exact 2-byte windows)
            .map(|w| f16_bits_to_f32(u16::from_le_bytes(w.try_into().unwrap())))
            .collect())
    }

    /// Decode a packed index block (see [`write_index_block`]): the
    /// original-order index list is reconstructed exactly. Rejects delta
    /// overflow past `u32::MAX` and out-of-range ranks.
    pub fn index_block(&mut self) -> Result<Vec<u32>> {
        let n = self.varint()? as usize;
        // deltas and ranks each need >= 1 byte per entry
        if n > self.remaining() / 2 {
            bail!("index block claims {n} entries with {} bytes left", self.remaining());
        }
        let mut sorted = Vec::with_capacity(n);
        let mut prev = 0u32;
        for j in 0..n {
            let delta = self.varint()?;
            let v = if j == 0 {
                delta
            } else {
                match prev.checked_add(delta) {
                    Some(v) => v,
                    None => bail!("index delta overflows u32"),
                }
            };
            sorted.push(v);
            prev = v;
        }
        let mut idx = Vec::with_capacity(n);
        for _ in 0..n {
            let r = self.varint()? as usize;
            if r >= n {
                bail!("index rank {r} out of range (n = {n})");
            }
            idx.push(sorted[r]);
        }
        Ok(idx)
    }

    /// Every byte consumed?
    pub fn done(&self) -> Result<()> {
        if self.pos != self.b.len() {
            bail!("{} trailing bytes in frame", self.b.len() - self.pos);
        }
        Ok(())
    }
}

// ==================================================== packed index block

/// Sort scratch reused across frames so steady-state encoding never
/// allocates: `perm` holds the sort permutation, `inv` its inverse (the
/// per-position ranks that restore original order on decode).
#[derive(Debug, Default)]
pub struct IndexScratch {
    perm: Vec<u32>,
    inv: Vec<u32>,
}

/// Append the packed encoding of `idx` (order-preserving, lossless):
///
/// ```text
/// varint n | varint idx_sorted[0] | varint gap ... | varint rank[0] ...
/// ```
///
/// where `rank[p]` is the sorted-array position of the index at original
/// position `p`. Sorted top-r/requested index sets are clustered, so the
/// gaps are mostly 1-byte varints; the ranks are a permutation of `0..n`
/// whose encoded size depends only on `n` (1 byte each up to n = 128).
pub fn write_index_block(out: &mut Vec<u8>, idx: &[u32], scratch: &mut IndexScratch) {
    let n = idx.len();
    write_varint(out, n as u32);
    scratch.perm.clear();
    scratch.perm.extend(0..n as u32);
    // stable order for duplicate indices -> exact roundtrip either way
    scratch.perm.sort_unstable_by_key(|&p| (idx[p as usize], p));
    let mut prev = 0u32;
    for (j, &p) in scratch.perm.iter().enumerate() {
        let v = idx[p as usize];
        write_varint(out, if j == 0 { v } else { v - prev });
        prev = v;
    }
    scratch.inv.clear();
    scratch.inv.resize(n, 0);
    for (j, &p) in scratch.perm.iter().enumerate() {
        scratch.inv[p as usize] = j as u32;
    }
    for &r in &scratch.inv {
        write_varint(out, r);
    }
}

/// Exact encoded size of [`write_index_block`] without materializing it.
/// The rank half is data-independent (a permutation of `0..n`), so only
/// the sorted gaps need computing — used by `Msg::wire_bytes` and the
/// engine's exact wire accounting.
pub fn index_block_bytes(idx: &[u32]) -> usize {
    let mut sorted = idx.to_vec();
    sorted.sort_unstable();
    let mut b = varint_len(idx.len() as u32);
    let mut prev = 0u32;
    for (j, &v) in sorted.iter().enumerate() {
        b += varint_len(if j == 0 { v } else { v - prev });
        prev = v;
    }
    for r in 0..idx.len() as u32 {
        b += varint_len(r);
    }
    b
}

// ================================================================ digest

/// One parameter's contribution to [`params_digest`]: a splitmix64-style
/// finalizer over `(position << 32) | value_bits`. Each (index, value)
/// pair scrambles independently, so the whole-vector digest is the
/// wrapping **sum** of the terms — position-dependent (swapping two
/// unequal values changes it) yet order-independent to compute, which is
/// what lets a delta apply update it in O(|delta|): subtract the old
/// term, add the new one.
pub fn digest_term(i: usize, value: f32) -> u64 {
    let mut z = ((i as u64) << 32) ^ (value.to_bits() as u64);
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Content digest of a parameter vector: wrapping sum of
/// [`digest_term`] over every position. Identical vectors (bit-for-bit,
/// including the length implied by the index range) produce identical
/// digests; the delta downlink uses it to prove a worker's applied model
/// equals the PS global without shipping the dense vector
/// (DESIGN.md §9).
pub fn params_digest(params: &[f32]) -> u64 {
    let mut d = (params.len() as u64).wrapping_mul(0x2545_f491_4f6c_dd1d);
    for (i, &v) in params.iter().enumerate() {
        d = d.wrapping_add(digest_term(i, v));
    }
    d
}

// ============================================================== FrameBuf

/// Per-stream transport buffers: the encode scratch (one full outgoing
/// frame), the recv payload buffer, and the index-sort scratch. A stream
/// that sends/receives the same frame shapes every round stops allocating
/// after its first round — [`FrameBuf::grows`] counts capacity-growth
/// events so tests can pin the steady state.
#[derive(Debug, Default)]
pub struct FrameBuf {
    /// outgoing frame bytes (header + payload), reused across sends
    pub(crate) buf: Vec<u8>,
    /// incoming payload bytes (tag + body), reused across recvs
    pub(crate) payload: Vec<u8>,
    pub(crate) scratch: IndexScratch,
    grows: u64,
    last_recv: usize,
}

impl FrameBuf {
    pub fn new() -> Self {
        FrameBuf::default()
    }

    /// Capacity-growth events across both buffers since creation.
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// Wire size (header + payload) of the most recent received frame.
    pub fn last_recv_frame_len(&self) -> usize {
        self.last_recv
    }

    /// The payload (tag + body) of the most recently completed receive —
    /// what [`crate::fl::transport::RecvCursor::advance`] leaves behind
    /// on `Done`, ready for [`crate::fl::transport::Msg::decode`].
    pub fn recv_payload(&self) -> &[u8] {
        &self.payload
    }

    pub(crate) fn note_growth(&mut self, buf_cap_before: usize, payload_cap_before: usize) {
        if self.buf.capacity() > buf_cap_before || self.payload.capacity() > payload_cap_before {
            self.grows += 1;
        }
    }

    pub(crate) fn set_last_recv(&mut self, frame_len: usize) {
        self.last_recv = frame_len;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn varint_roundtrips_at_boundaries() {
        let cases = [
            0u32, 1, 127, 128, 255, 16383, 16384,
            (1 << 21) - 1, 1 << 21, (1 << 28) - 1, 1 << 28, u32::MAX,
        ];
        for x in cases {
            let mut b = Vec::new();
            write_varint(&mut b, x);
            assert_eq!(b.len(), varint_len(x), "len for {x}");
            let mut d = Dec::new(&b);
            assert_eq!(d.varint().unwrap(), x);
            d.done().unwrap();
        }
    }

    #[test]
    fn varint_rejects_truncated_and_overlong() {
        // truncated: continuation bit set, stream ends
        assert!(Dec::new(&[]).varint().is_err());
        assert!(Dec::new(&[0x80]).varint().is_err());
        assert!(Dec::new(&[0xff, 0xff]).varint().is_err());
        // overlong: a 6th byte would be needed
        assert!(Dec::new(&[0x80, 0x80, 0x80, 0x80, 0x80, 0x01]).varint().is_err());
        // 5th byte carries bits beyond a u32 (or a continuation bit)
        assert!(Dec::new(&[0xff, 0xff, 0xff, 0xff, 0x10]).varint().is_err());
        assert!(Dec::new(&[0xff, 0xff, 0xff, 0xff, 0xff]).varint().is_err());
        // the largest valid 5-byte varint is u32::MAX
        let mut d = Dec::new(&[0xff, 0xff, 0xff, 0xff, 0x0f]);
        assert_eq!(d.varint().unwrap(), u32::MAX);
    }

    fn roundtrip_block(idx: &[u32]) {
        let mut out = Vec::new();
        let mut scratch = IndexScratch::default();
        write_index_block(&mut out, idx, &mut scratch);
        assert_eq!(out.len(), index_block_bytes(idx), "size formula for {idx:?}");
        let mut d = Dec::new(&out);
        assert_eq!(d.index_block().unwrap(), idx, "roundtrip for {idx:?}");
        d.done().unwrap();
    }

    #[test]
    fn index_block_roundtrips_edge_cases() {
        roundtrip_block(&[]);
        roundtrip_block(&[0]);
        roundtrip_block(&[u32::MAX]);
        roundtrip_block(&[u32::MAX, 0, u32::MAX - 1]);
        roundtrip_block(&[5, 4, 3, 2, 1, 0]);
        roundtrip_block(&[7, 7, 7]); // duplicates survive exactly
        roundtrip_block(&[1000, 2, 999, 3, 998]);
    }

    #[test]
    fn index_block_roundtrips_randomly() {
        crate::testing::prop_check("index-block-roundtrip", 200, |g| {
            let n = g.usize_in(0, 300);
            let magnitude_order: Vec<u32> = if g.bool() {
                // distinct, out-of-order (report-shaped)
                g.rng.choose_k(40_000, n).into_iter().map(|x| x as u32).collect()
            } else {
                // arbitrary, duplicates allowed, full u32 range
                (0..n).map(|_| (g.rng.below(1 << 16) as u32) << g.rng.below(17) as u32).collect()
            };
            let mut out = Vec::new();
            let mut scratch = IndexScratch::default();
            write_index_block(&mut out, &magnitude_order, &mut scratch);
            if out.len() != index_block_bytes(&magnitude_order) {
                return Err("size formula mismatch".into());
            }
            let mut d = Dec::new(&out);
            let back = d.index_block().map_err(|e| e.to_string())?;
            if back != magnitude_order {
                return Err(format!("roundtrip mismatch: {magnitude_order:?} -> {back:?}"));
            }
            d.done().map_err(|e| e.to_string())
        });
    }

    #[test]
    fn index_block_rejects_adversarial_input() {
        // deltas that overflow u32: [n=2, first=MAX, gap=1]
        let mut b = Vec::new();
        write_varint(&mut b, 2);
        write_varint(&mut b, u32::MAX);
        write_varint(&mut b, 1);
        write_varint(&mut b, 0);
        write_varint(&mut b, 1);
        assert!(Dec::new(&b).index_block().is_err(), "delta overflow must be rejected");

        // rank out of range: [n=1, idx=5, rank=1]
        let mut b = Vec::new();
        write_varint(&mut b, 1);
        write_varint(&mut b, 5);
        write_varint(&mut b, 1);
        assert!(Dec::new(&b).index_block().is_err(), "rank >= n must be rejected");

        // absurd count with a tiny body must fail before allocating
        let mut b = Vec::new();
        write_varint(&mut b, u32::MAX);
        b.push(0);
        assert!(Dec::new(&b).index_block().is_err());

        // truncated mid-block
        let mut b = Vec::new();
        let mut scratch = IndexScratch::default();
        write_index_block(&mut b, &[3, 9, 27], &mut scratch);
        assert!(Dec::new(&b[..b.len() - 1]).index_block().is_err());
    }

    #[test]
    fn f16_handles_specials() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        let nan = f16_bits_to_f32(f32_to_f16_bits(f32::NAN));
        assert!(nan.is_nan());
        // largest finite f16 and first overflow
        assert_eq!(f16_bits_to_f32(0x7bff), 65504.0);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff);
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00, "midpoint rounds to even -> inf");
        assert_eq!(f32_to_f16_bits(1e9), 0x7c00);
        // smallest subnormal and underflow
        assert_eq!(f16_bits_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-24)), 0x0001);
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-26)), 0x0000, "underflow to zero");
        assert_eq!(f32_to_f16_bits(-2.0f32.powi(-26)), 0x8000);
    }

    #[test]
    fn f16_bits_roundtrip_exactly() {
        // every non-NaN f16 must survive f16 -> f32 -> f16 bit-for-bit
        for h in 0..=u16::MAX {
            let is_nan = h & 0x7c00 == 0x7c00 && h & 0x03ff != 0;
            if is_nan {
                assert!(f16_bits_to_f32(h).is_nan());
            } else {
                assert_eq!(f32_to_f16_bits(f16_bits_to_f32(h)), h, "bits {h:#06x}");
            }
        }
    }

    #[test]
    fn f16_tolerance_bound_holds() {
        let mut rng = Rng::new(7);
        for _ in 0..2000 {
            let x = rng.uniform_in(-1e4, 1e4);
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            let tol = x.abs() * 2.0f32.powi(-11) + 2.0f32.powi(-24);
            assert!((x - y).abs() <= tol, "{x} -> {y} (tol {tol})");
        }
    }

    #[test]
    fn bulk_f32_roundtrip() {
        let xs = vec![0.0f32, -1.5, f32::MIN_POSITIVE, 3.25e-9, -2.0e30];
        let mut b = Vec::new();
        put_f32s_bulk(&mut b, &xs);
        assert_eq!(b.len(), 4 * xs.len());
        let mut d = Dec::new(&b);
        let mut out = Vec::new();
        d.f32s_bulk_into(xs.len(), &mut out).unwrap();
        assert_eq!(out, xs);
        d.done().unwrap();
    }

    #[test]
    fn f16_block_roundtrip_within_tolerance() {
        let xs = vec![0.125f32, -0.5, 1.0, -2.0e-3, 3.0e3];
        let mut b = Vec::new();
        put_f16s_bulk(&mut b, &xs);
        assert_eq!(b.len(), 2 * xs.len());
        let back = Dec::new(&b).f16s_bulk(xs.len()).unwrap();
        for (&x, &y) in xs.iter().zip(&back) {
            assert!((x - y).abs() <= x.abs() * 2.0f32.powi(-11));
        }
        // exactly-representable values survive bit-for-bit
        assert_eq!(back[0], 0.125);
        assert_eq!(back[1], -0.5);
        assert_eq!(back[2], 1.0);
    }

    #[test]
    fn digest_is_position_and_value_sensitive() {
        let a = vec![1.0f32, 2.0, 3.0];
        assert_eq!(params_digest(&a), params_digest(&[1.0, 2.0, 3.0]));
        // value change
        assert_ne!(params_digest(&a), params_digest(&[1.0, 2.5, 3.0]));
        // swapping two unequal values must change it (position matters)
        assert_ne!(params_digest(&a), params_digest(&[2.0, 1.0, 3.0]));
        // length matters even when the extra tail is zeros
        assert_ne!(params_digest(&[0.0; 3]), params_digest(&[0.0; 4]));
        // -0.0 and 0.0 differ in bits, so they differ in digest (the
        // digest certifies bit-identity, exactly like the parity pins)
        assert_ne!(params_digest(&[0.0f32]), params_digest(&[-0.0f32]));
    }

    #[test]
    fn digest_updates_incrementally() {
        crate::testing::prop_check("digest-incremental", 50, |g| {
            let d = g.usize_in(1, 200);
            let mut params = g.vec_f32(d, 1.0);
            let mut dig = params_digest(&params);
            for _ in 0..g.usize_in(1, 20) {
                let i = g.usize_in(0, d - 1);
                let new = g.f32_in(-2.0, 2.0);
                dig = dig
                    .wrapping_sub(digest_term(i, params[i]))
                    .wrapping_add(digest_term(i, new));
                params[i] = new;
            }
            if dig != params_digest(&params) {
                return Err("incremental digest diverged from recompute".into());
            }
            Ok(())
        });
    }

    #[test]
    fn codec_parse_and_wire_ids() {
        for c in [Codec::Raw, Codec::Packed, Codec::PackedF16] {
            assert_eq!(Codec::parse(c.name()), Some(c));
            assert_eq!(Codec::from_wire_id(c.wire_id()), Some(c));
        }
        assert_eq!(Codec::parse("zstd"), None);
        assert_eq!(Codec::from_wire_id(9), None);
        assert!(Codec::Packed.packs_indices() && !Codec::Raw.packs_indices());
        assert!(Codec::PackedF16.f16_values() && !Codec::Packed.f16_values());
    }
}
