//! A simulated FL client: local shard, batch schedule, local model state.

use crate::backend::{Backend, ClientState, LocalRoundOut};
use crate::data::{BatchIter, Shard};
use crate::sparse::SparseVec;
use crate::util::rng::{stream_seed, Rng, STREAM_BATCHES, STREAM_CLIENT_RNG};
use anyhow::Result;

/// One client: its data shard and training state. The compute itself goes
/// through the shared [`Backend`] (clients are logically independent; the
/// simulator multiplexes them over one backend instance).
///
/// Both client-local random streams (the batch shuffle and the selection
/// RNG) are seeded through [`stream_seed`], whose full splitmix64 mixing
/// keeps streams pairwise distinct and uncorrelated at 10⁵⁺ clients — the
/// old `seed ^ id * const` folding left low-entropy collisions at fleet
/// scale (`rng::tests::stream_seeds_distinct_at_fleet_scale`).
#[derive(Debug)]
pub struct Client {
    pub id: usize,
    shard: Shard,
    batches: BatchIter,
    pub state: ClientState,
    /// client-local RNG (rTop-k's random k-subset etc.)
    pub rng: Rng,
}

impl Client {
    pub fn new(id: usize, shard: Shard, init_params: Vec<f32>, seed: u64) -> Self {
        let n = shard.len();
        Client {
            id,
            shard,
            batches: BatchIter::new(n, stream_seed(seed, STREAM_BATCHES, id as u64)),
            state: ClientState::new(init_params),
            rng: Rng::new(stream_seed(seed, STREAM_CLIENT_RNG, id as u64)),
        }
    }

    pub fn shard_len(&self) -> usize {
        self.shard.len()
    }

    /// Labels present in this client's shard (diagnostics / ground truth).
    pub fn label_set(&self) -> Vec<u8> {
        self.shard.label_set()
    }

    /// Draw the H batches for one global round as contiguous buffers.
    pub fn draw_round_batches(&mut self, h: usize, b: usize) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(h * b * self.shard.dim());
        let mut ys = Vec::with_capacity(h * b);
        for _ in 0..h {
            let idx = self.batches.next_batch(b);
            let (x, y) = self.shard.gather(&idx);
            xs.extend(x);
            ys.extend(y);
        }
        (xs, ys)
    }

    /// Run the local round (Algorithm 1 lines 4-7).
    pub fn local_round(
        &mut self,
        backend: &mut dyn Backend,
        h: usize,
        b: usize,
    ) -> Result<LocalRoundOut> {
        let (xs, ys) = self.draw_round_batches(h, b);
        backend.local_round(&mut self.state, &xs, &ys, h, b)
    }

    /// Build the sparse upload for a set of requested indices, taking
    /// values from the top-r report (requested ⊆ report for the
    /// report-based strategies).
    pub fn answer_request(report: &SparseVec, requested: &[u32]) -> SparseVec {
        let lookup: std::collections::HashMap<u32, f32> =
            report.idx.iter().cloned().zip(report.val.iter().cloned()).collect();
        let mut idx = Vec::with_capacity(requested.len());
        let mut val = Vec::with_capacity(requested.len());
        for &j in requested {
            if let Some(&v) = lookup.get(&j) {
                idx.push(j);
                val.push(v);
            }
        }
        SparseVec::new(idx, val)
    }

    /// Sparse upload from a dense gradient (rand-k / dense strategies).
    pub fn gather_from_grad(grad: &[f32], requested: &[u32]) -> SparseVec {
        SparseVec::new(
            requested.to_vec(),
            requested.iter().map(|&j| grad[j as usize]).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::synthetic_mnist;

    #[test]
    fn batches_have_expected_shape() {
        let ds = synthetic_mnist(0, 64);
        let mut c = Client::new(0, Shard::from_owned(ds), vec![0.0; 10], 1);
        let (xs, ys) = c.draw_round_batches(3, 8);
        assert_eq!(xs.len(), 3 * 8 * 784);
        assert_eq!(ys.len(), 24);
    }

    #[test]
    fn label_set_sorted_unique() {
        let ds = synthetic_mnist(0, 50);
        let shard = ds.subset(&ds.indices_with_labels(&[3, 7]));
        let c = Client::new(1, Shard::from_owned(shard), vec![], 0);
        assert_eq!(c.label_set(), vec![3, 7]);
    }

    /// An id's two streams come from distinct tagged seeds: the batch
    /// order and the selection RNG must not be lockstep-correlated.
    #[test]
    fn client_streams_are_independent() {
        let ds = synthetic_mnist(0, 64);
        let mut c = Client::new(7, Shard::from_owned(ds), vec![], 42);
        let first_draw = c.rng.next_u64();
        let mut expect = Rng::new(stream_seed(42, STREAM_CLIENT_RNG, 7));
        assert_eq!(first_draw, expect.next_u64());
        let mut batches = BatchIter::new(64, stream_seed(42, STREAM_BATCHES, 7));
        let mut c2 = Client::new(7, Shard::from_owned(synthetic_mnist(0, 64)), vec![], 42);
        let (xs, _) = c2.draw_round_batches(1, 4);
        let idx = batches.next_batch(4);
        let (ex, _) = c2.shard.gather(&idx);
        // c2 already consumed its first batch; re-deriving the same
        // stream from scratch must reproduce it
        assert_eq!(xs, ex);
    }

    #[test]
    fn answer_request_pulls_report_values() {
        let report = SparseVec::new(vec![5, 9, 2], vec![1.5, -2.0, 0.25]);
        let ans = Client::answer_request(&report, &[9, 2]);
        assert_eq!(ans.idx, vec![9, 2]);
        assert_eq!(ans.val, vec![-2.0, 0.25]);
    }

    #[test]
    fn answer_request_skips_unknown() {
        let report = SparseVec::new(vec![5], vec![1.0]);
        let ans = Client::answer_request(&report, &[5, 77]);
        assert_eq!(ans.idx, vec![5]);
    }

    #[test]
    fn gather_from_grad() {
        let grad = vec![0.0f32, 1.0, 2.0, 3.0];
        let s = Client::gather_from_grad(&grad, &[3, 0]);
        assert_eq!(s.val, vec![3.0, 0.0]);
    }
}
