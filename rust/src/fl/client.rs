//! A simulated FL client: local shard, batch schedule, local model state.

use crate::backend::{Backend, ClientState, LocalRoundOut};
use crate::data::{gather_batch, BatchIter, Dataset};
use crate::sparse::SparseVec;
use crate::util::rng::Rng;
use anyhow::Result;

/// One client: its data shard and training state. The compute itself goes
/// through the shared [`Backend`] (clients are logically independent; the
/// simulator multiplexes them over one backend instance).
#[derive(Debug)]
pub struct Client {
    pub id: usize,
    shard: Dataset,
    batches: BatchIter,
    pub state: ClientState,
    /// client-local RNG (rTop-k's random k-subset etc.)
    pub rng: Rng,
}

impl Client {
    pub fn new(id: usize, shard: Dataset, init_params: Vec<f32>, seed: u64) -> Self {
        let n = shard.len();
        Client {
            id,
            shard,
            batches: BatchIter::new(n, seed ^ (id as u64).wrapping_mul(0x9E37)),
            state: ClientState::new(init_params),
            rng: Rng::new(seed ^ 0xC11E47 ^ (id as u64) << 17),
        }
    }

    pub fn shard_len(&self) -> usize {
        self.shard.len()
    }

    /// Labels present in this client's shard (diagnostics / ground truth).
    pub fn label_set(&self) -> Vec<u8> {
        let mut set: Vec<u8> = self.shard.y.to_vec();
        set.sort_unstable();
        set.dedup();
        set
    }

    /// Draw the H batches for one global round as contiguous buffers.
    pub fn draw_round_batches(&mut self, h: usize, b: usize) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(h * b * self.shard.dim);
        let mut ys = Vec::with_capacity(h * b);
        for _ in 0..h {
            let idx = self.batches.next_batch(b);
            let (x, y) = gather_batch(&self.shard, &idx);
            xs.extend(x);
            ys.extend(y);
        }
        (xs, ys)
    }

    /// Run the local round (Algorithm 1 lines 4-7).
    pub fn local_round(
        &mut self,
        backend: &mut dyn Backend,
        h: usize,
        b: usize,
    ) -> Result<LocalRoundOut> {
        let (xs, ys) = self.draw_round_batches(h, b);
        backend.local_round(&mut self.state, &xs, &ys, h, b)
    }

    /// Build the sparse upload for a set of requested indices, taking
    /// values from the top-r report (requested ⊆ report for the
    /// report-based strategies).
    pub fn answer_request(report: &SparseVec, requested: &[u32]) -> SparseVec {
        let lookup: std::collections::HashMap<u32, f32> =
            report.idx.iter().cloned().zip(report.val.iter().cloned()).collect();
        let mut idx = Vec::with_capacity(requested.len());
        let mut val = Vec::with_capacity(requested.len());
        for &j in requested {
            if let Some(&v) = lookup.get(&j) {
                idx.push(j);
                val.push(v);
            }
        }
        SparseVec::new(idx, val)
    }

    /// Sparse upload from a dense gradient (rand-k / dense strategies).
    pub fn gather_from_grad(grad: &[f32], requested: &[u32]) -> SparseVec {
        SparseVec::new(
            requested.to_vec(),
            requested.iter().map(|&j| grad[j as usize]).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::synthetic_mnist;

    #[test]
    fn batches_have_expected_shape() {
        let ds = synthetic_mnist(0, 64);
        let mut c = Client::new(0, ds, vec![0.0; 10], 1);
        let (xs, ys) = c.draw_round_batches(3, 8);
        assert_eq!(xs.len(), 3 * 8 * 784);
        assert_eq!(ys.len(), 24);
    }

    #[test]
    fn label_set_sorted_unique() {
        let ds = synthetic_mnist(0, 50);
        let shard = ds.subset(&ds.indices_with_labels(&[3, 7]));
        let c = Client::new(1, shard, vec![], 0);
        assert_eq!(c.label_set(), vec![3, 7]);
    }

    #[test]
    fn answer_request_pulls_report_values() {
        let report = SparseVec::new(vec![5, 9, 2], vec![1.5, -2.0, 0.25]);
        let ans = Client::answer_request(&report, &[9, 2]);
        assert_eq!(ans.idx, vec![9, 2]);
        assert_eq!(ans.val, vec![-2.0, 0.25]);
    }

    #[test]
    fn answer_request_skips_unknown() {
        let report = SparseVec::new(vec![5], vec![1.0]);
        let ans = Client::answer_request(&report, &[5, 77]);
        assert_eq!(ans.idx, vec![5]);
    }

    #[test]
    fn gather_from_grad() {
        let grad = vec![0.0f32, 1.0, 2.0, 3.0];
        let s = Client::gather_from_grad(&grad, &[3, 0]);
        assert_eq!(s.val, vec![3.0, 0.0]);
    }
}
