//! The end-to-end FL trainer — Algorithm 1 of the paper.
//!
//! Per global round: broadcast the global model, run H local Adam steps
//! on every client, collect top-r reports, select the k requested indices
//! per client (strategy-dependent), upload the sparse updates, aggregate
//! g~ = sum_i g~_i, apply the server optimizer, update ages/frequencies,
//! and every M rounds run the DBSCAN reclustering.

use crate::backend::{make_backend, Backend, GlobalState};
use crate::config::{EvalMode, ExperimentConfig, Payload};
use crate::coordinator::aggregator::Aggregate;
use crate::coordinator::server::{ParameterServer, PsConfig};
use crate::coordinator::strategies::client_select;
use crate::data::{gather_batch, load_dataset, partition::partition, Dataset};
use crate::fl::client::Client;
use crate::fl::metrics::{History, RoundRecord};
use crate::util::timer::Profile;
use anyhow::{Context, Result};

/// Whose parameters an eval pass reads.
#[derive(Debug, Clone, Copy)]
enum ParamsSrc {
    Global,
    Client(usize),
}

/// Everything a finished run reports (the examples/benches render these
/// into the paper's figures).
#[derive(Debug)]
pub struct TrainReport {
    pub history: History,
    /// (round, eq.-3 connectivity matrix) snapshots for Fig. 2 / Fig. 4
    pub heatmaps: Vec<(usize, Vec<Vec<f64>>)>,
    /// final cluster assignment per client
    pub cluster_labels: Vec<usize>,
    /// ground-truth pair labels (when the partition scheme defines them)
    pub truth_labels: Option<Vec<usize>>,
    pub final_accuracy: f32,
    pub profile: Vec<(String, f64, u64)>,
}

pub struct Trainer {
    cfg: ExperimentConfig,
    backend: Box<dyn Backend>,
    ps: ParameterServer,
    clients: Vec<Client>,
    global: GlobalState,
    test: Dataset,
    /// per-client test indices matching the client's label set
    /// (EvalMode::Personal)
    personal_test: Vec<Vec<usize>>,
    /// per-client error-feedback memory (Payload::Delta): unsent
    /// accumulated drift, the mechanism of Qsparse-local-SGD [7] that
    /// makes k << d sparsification converge (DESIGN.md §5)
    memory: Vec<Vec<f32>>,
    /// rounds at which to snapshot the connectivity heatmap
    pub heatmap_rounds: Vec<usize>,
    pub profile: Profile,
    history_comm: crate::fl::metrics::CommStats,
}

impl Trainer {
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let mut backend = make_backend(cfg).context("creating backend")?;
        let (train, test) =
            load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
        let shards = partition(&train, cfg.n_clients, &cfg.partition, cfg.seed);
        let init = backend.init_params()?;
        let clients: Vec<Client> = shards
            .into_iter()
            .enumerate()
            .map(|(i, idx)| Client::new(i, train.subset(&idx), init.clone(), cfg.seed))
            .collect();
        let personal_test: Vec<Vec<usize>> = clients
            .iter()
            .map(|c| test.indices_with_labels(&c.label_set()))
            .collect();
        let ps = ParameterServer::new(PsConfig {
            d: cfg.d(),
            n_clients: cfg.n_clients,
            k: cfg.k,
            strategy: cfg.strategy,
            recluster_every: cfg.recluster_every,
            dbscan: cfg.dbscan,
            merge_rule: cfg.merge_rule,
        });
        let memory = match cfg.payload {
            Payload::Delta => vec![vec![0.0f32; cfg.d()]; cfg.n_clients],
            Payload::Grad => Vec::new(),
        };
        Ok(Trainer {
            cfg: cfg.clone(),
            memory,
            global: GlobalState::new(init),
            backend,
            ps,
            clients,
            test,
            personal_test,
            heatmap_rounds: Vec::new(),
            profile: Profile::new(),
            history_comm: Default::default(),
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn server(&self) -> &ParameterServer {
        &self.ps
    }

    pub fn global_params(&self) -> &[f32] {
        &self.global.params
    }

    /// Evaluate `params` over a test index list, batched (indices cycle
    /// to fill the fixed batch size the XLA artifacts require).
    fn eval_on(&mut self, params_src: ParamsSrc, indices: &[usize]) -> Result<(f32, f32)> {
        anyhow::ensure!(!indices.is_empty(), "empty eval subset");
        let b = self.cfg.batch;
        let n_batches = (indices.len() + b - 1) / b;
        let params: Vec<f32> = match params_src {
            ParamsSrc::Global => self.global.params.clone(),
            ParamsSrc::Client(c) => self.clients[c].state.params.clone(),
        };
        let mut loss_sum = 0.0f32;
        let mut correct = 0usize;
        let mut counted = 0usize;
        for i in 0..n_batches {
            let idx: Vec<usize> =
                (i * b..(i + 1) * b).map(|j| indices[j % indices.len()]).collect();
            let (x, y) = gather_batch(&self.test, &idx);
            let (ls, c) = self.backend.eval(&params, &x, &y)?;
            loss_sum += ls;
            correct += c;
            counted += b;
        }
        Ok((correct as f32 / counted as f32, loss_sum / counted as f32))
    }

    /// Global-model accuracy/loss over the full test set.
    pub fn eval_global(&mut self) -> Result<(f32, f32)> {
        let idx: Vec<usize> = (0..self.test.len()).collect();
        self.eval_on(ParamsSrc::Global, &idx)
    }

    /// The paper's Fig. 3/5 metric: mean over clients of their own model
    /// on test data from their own label distribution.
    pub fn eval_personal(&mut self) -> Result<(f32, f32)> {
        let mut accs = Vec::new();
        let mut losses = Vec::new();
        for c in 0..self.clients.len() {
            let idx = self.personal_test[c].clone();
            let (a, l) = self.eval_on(ParamsSrc::Client(c), &idx)?;
            accs.push(a as f64);
            losses.push(l as f64);
        }
        Ok((crate::util::mean(&accs) as f32, crate::util::mean(&losses) as f32))
    }

    fn eval_configured(&mut self) -> Result<(f32, f32)> {
        match self.cfg.eval_mode {
            EvalMode::Global => self.eval_global(),
            EvalMode::Personal => self.eval_personal(),
        }
    }

    /// One global round (Algorithm 1 lines 3-16). Returns the mean local
    /// training loss.
    pub fn run_round(&mut self) -> Result<f32> {
        let cfg = &self.cfg;
        let (h, b, k, d) = (cfg.h, cfg.batch, cfg.k, cfg.d());
        let n = self.clients.len();

        // ---- local training + reports (lines 4-7)
        let mut reports = Vec::with_capacity(n);
        let mut losses = Vec::with_capacity(n);
        for client in self.clients.iter_mut() {
            client.state.sync_to(&self.global.params);
            let out = self
                .profile
                .time("client.local_round", || client.local_round(self.backend.as_mut(), h, b))?;
            losses.push(out.mean_loss);
            reports.push(out.report);
        }

        // ---- payload: under Delta each client folds this round's drift
        // theta_i - theta into its error-feedback memory and reports the
        // top-r of the *accumulated* unsent update — the Qsparse-local-
        // SGD [7] mechanism the paper's convergence argument relies on
        // (DESIGN.md §5). Values in the report are the accumulated drift,
        // so whatever subset the PS requests carries the full unsent mass
        // on those coordinates.
        if cfg.payload == Payload::Delta {
            for (i, client) in self.clients.iter().enumerate() {
                let mem = &mut self.memory[i];
                for (m, (p, g)) in mem
                    .iter_mut()
                    .zip(client.state.params.iter().zip(&self.global.params))
                {
                    *m += p - g;
                }
                reports[i] = self
                    .profile
                    .time("client.ef_topr", || crate::sparse::topk_abs_sparse(mem, cfg.r));
            }
        }

        // ---- index selection (Algorithm 2 at the PS, or client-side)
        let requested: Vec<Vec<u32>> = if cfg.strategy.needs_report() {
            let idx_reports: Vec<Vec<u32>> = reports.iter().map(|r| r.idx.clone()).collect();
            self.profile.time("ps.select", || self.ps.select_requests(&idx_reports))
        } else {
            let mut out = Vec::with_capacity(n);
            for (client, report) in self.clients.iter_mut().zip(&reports) {
                out.push(client_select(cfg.strategy, &mut client.rng, &report.idx, d, k));
            }
            out
        };

        // ---- sparse uploads (line 8)
        let mut agg = Aggregate::new();
        for i in 0..n {
            let update = if cfg.strategy.needs_dense_grad() {
                // rand-k / dense need coordinates outside the top-r report
                let dense: Vec<f32> = match cfg.payload {
                    Payload::Delta => self.memory[i].clone(),
                    Payload::Grad => {
                        let (xs, ys) = self.clients[i].draw_round_batches(1, b);
                        self.profile.time("client.dense_grad", || {
                            self.backend.dense_grad(&self.clients[i].state.params, &xs, &ys)
                        })?
                        .0
                    }
                };
                Client::gather_from_grad(&dense, &requested[i])
            } else {
                Client::answer_request(&reports[i], &requested[i])
            };
            agg.push(update);
        }

        // ---- error feedback: sent coordinates leave the memory
        if cfg.payload == Payload::Delta {
            for i in 0..n {
                for &j in &requested[i] {
                    self.memory[i][j as usize] = 0.0;
                }
            }
        }

        // ---- communication accounting (DESIGN.md §6)
        {
            let comm = &mut self.history_comm;
            for req in &requested {
                comm.update_up += (req.len() * 8) as u64;
            }
            if cfg.strategy.needs_report() {
                comm.report_up += (n * cfg.r * 4) as u64;
                comm.request_down += (n * k * 4) as u64;
            }
            comm.broadcast_down += (n * d * 4) as u64;
        }

        // ---- aggregate + server update (lines 9-11)
        match cfg.payload {
            Payload::Delta => {
                // FedAvg-style: apply the mean sparse drift directly
                let update = agg.to_dense(d, 1.0 / n as f32);
                self.profile.time("ps.apply", || {
                    for (p, &u) in self.global.params.iter_mut().zip(&update) {
                        *p += u;
                    }
                });
            }
            Payload::Grad if cfg.server_opt == "sgd" => {
                let update = agg.to_dense(d, 1.0);
                let lr = cfg.lr_server;
                self.profile.time("ps.apply", || {
                    for (p, &u) in self.global.params.iter_mut().zip(&update) {
                        *p -= lr * u;
                    }
                });
            }
            Payload::Grad => {
                self.profile.time("ps.apply", || {
                    self.backend.server_apply(&mut self.global, &agg, 1.0, cfg.lr_server)
                })?;
            }
        }

        // ---- age + frequency bookkeeping (Algorithm 2 lines 7-8 / eq. 2)
        self.profile.time("ps.record", || self.ps.record_round(&requested));

        Ok(crate::util::mean(&losses.iter().map(|&x| x as f64).collect::<Vec<_>>()) as f32)
    }

    /// Run the configured number of rounds, producing the full report.
    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let mut history = History::new(self.cfg.strategy.name());
        let mut heatmaps = Vec::new();
        let total = self.cfg.rounds;

        for round in 1..=total {
            let train_loss = self.run_round()?;

            // heatmap snapshots (Fig. 2 / Fig. 4)
            if self.heatmap_rounds.contains(&round) {
                heatmaps.push((round, self.ps.connectivity()));
            }

            // M-periodic clustering (Algorithm 1 lines 13-16)
            self.ps.maybe_recluster();

            let eval_due = self.cfg.eval_every > 0 && round % self.cfg.eval_every == 0;
            let (test_acc, test_loss) = if eval_due || round == total {
                let t_eval = std::time::Instant::now();
                let (a, l) = self.eval_configured()?;
                self.profile.add("ps.eval", t_eval.elapsed().as_secs_f64());
                (Some(a), Some(l))
            } else {
                (None, None)
            };

            history.rounds.push(RoundRecord {
                round,
                train_loss,
                test_acc,
                test_loss,
                n_clusters: self.ps.clusters().n_clusters(),
                uplink_cum: self.history_comm.uplink(),
            });

            if let Some(acc) = test_acc {
                crate::info!(
                    "[{}] round {round}/{total}: loss {train_loss:.4} acc {:.2}% clusters {}",
                    self.cfg.strategy.name(),
                    acc * 100.0,
                    self.ps.clusters().n_clusters()
                );
            }
        }

        history.comm = self.history_comm;
        history.wall_secs = t0.elapsed().as_secs_f64();
        let final_accuracy = history.final_accuracy();
        Ok(TrainReport {
            history,
            heatmaps,
            cluster_labels: self.ps.clusters().labels(),
            truth_labels: match self.cfg.partition {
                crate::data::partition::Scheme::PaperPairs => Some(
                    crate::data::partition::paper_pair_truth(self.cfg.n_clients),
                ),
                _ => None,
            },
            final_accuracy,
            profile: self.profile.snapshot(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn smoke_training_reduces_loss() {
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.rounds = 8;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let report = t.run().unwrap();
        let first = report.history.rounds.first().unwrap().train_loss;
        let last = report.history.rounds.last().unwrap().train_loss;
        assert!(last < first, "loss must decrease: {first} -> {last}");
        assert!(report.history.comm.uplink() > 0);
    }
}
