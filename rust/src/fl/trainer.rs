//! The end-to-end FL trainer — a thin adapter binding the unified round
//! protocol ([`crate::coordinator::engine::RoundEngine`]) to the parallel
//! in-process [`InProcessPool`], plus the evaluation/reporting shell the
//! examples and benches consume.
//!
//! All protocol logic (selection, aggregation, error feedback, server
//! apply, communication accounting, age/frequency bookkeeping, M-periodic
//! DBSCAN) lives in the engine and is shared bit-for-bit with the TCP
//! deployment (`fl::distributed`); see `rust/tests/parity.rs`.

use crate::config::{EvalMode, ExperimentConfig};
use crate::coordinator::engine::{eval_dataset, RoundEngine};
use crate::coordinator::server::ParameterServer;
use crate::data::{load_dataset, partition::partition, Dataset};
use crate::fl::metrics::{History, RoundRecord};
use crate::fl::pool::InProcessPool;
use crate::util::timer::Profile;
use anyhow::{Context, Result};

/// Everything a finished run reports (the examples/benches render these
/// into the paper's figures).
#[derive(Debug)]
pub struct TrainReport {
    pub history: History,
    /// (round, eq.-3 connectivity matrix) snapshots for Fig. 2 / Fig. 4
    pub heatmaps: Vec<(usize, Vec<Vec<f64>>)>,
    /// final cluster assignment per client
    pub cluster_labels: Vec<usize>,
    /// ground-truth pair labels (when the partition scheme defines them)
    pub truth_labels: Option<Vec<usize>>,
    pub final_accuracy: f32,
    pub profile: Vec<(String, f64, u64)>,
}

pub struct Trainer {
    cfg: ExperimentConfig,
    engine: RoundEngine,
    pool: InProcessPool,
    test: Dataset,
    /// per-client test indices matching the client's label set
    /// (EvalMode::Personal)
    personal_test: Vec<Vec<usize>>,
    /// rounds at which to snapshot the connectivity heatmap
    pub heatmap_rounds: Vec<usize>,
}

impl Trainer {
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let (train, test) =
            load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
        let shards: Vec<Dataset> = partition(&train, cfg.n_clients, &cfg.partition, cfg.seed)
            .into_iter()
            .map(|idx| train.subset(&idx))
            .collect();
        let (pool, init) = InProcessPool::new(cfg, shards).context("creating client pool")?;
        let personal_test: Vec<Vec<usize>> = pool
            .clients()
            .iter()
            .map(|c| test.indices_with_labels(&c.label_set()))
            .collect();
        let engine = RoundEngine::new(cfg, init);
        Ok(Trainer {
            cfg: cfg.clone(),
            engine,
            pool,
            test,
            personal_test,
            heatmap_rounds: Vec::new(),
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The shared round protocol this trainer drives.
    pub fn engine(&self) -> &RoundEngine {
        &self.engine
    }

    pub fn pool(&self) -> &InProcessPool {
        &self.pool
    }

    pub fn server(&self) -> &ParameterServer {
        self.engine.ps()
    }

    pub fn global_params(&self) -> &[f32] {
        self.engine.global_params()
    }

    pub fn profile(&self) -> &Profile {
        self.engine.profile()
    }

    /// Global-model accuracy/loss over the full test set.
    pub fn eval_global(&mut self) -> Result<(f32, f32)> {
        let params = self.engine.global_params().to_vec();
        let idx: Vec<usize> = (0..self.test.len()).collect();
        eval_dataset(self.pool.backend_mut(), &params, &self.test, &idx, self.cfg.batch)
    }

    /// The paper's Fig. 3/5 metric: mean over clients of their own model
    /// on test data from their own label distribution.
    pub fn eval_personal(&mut self) -> Result<(f32, f32)> {
        let mut accs = Vec::new();
        let mut losses = Vec::new();
        for c in 0..self.pool.clients().len() {
            let params = self.pool.client_params(c).to_vec();
            let idx = self.personal_test[c].clone();
            let (a, l) =
                eval_dataset(self.pool.backend_mut(), &params, &self.test, &idx, self.cfg.batch)?;
            accs.push(a as f64);
            losses.push(l as f64);
        }
        Ok((crate::util::mean(&accs) as f32, crate::util::mean(&losses) as f32))
    }

    fn eval_configured(&mut self) -> Result<(f32, f32)> {
        match self.cfg.eval_mode {
            EvalMode::Global => self.eval_global(),
            EvalMode::Personal => self.eval_personal(),
        }
    }

    /// One global round (Algorithm 1 lines 3-16). Returns the mean local
    /// training loss.
    pub fn run_round(&mut self) -> Result<f32> {
        Ok(self.engine.run_round(&mut self.pool)?.mean_loss)
    }

    /// Run the configured number of rounds, producing the full report.
    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let mut history = History::new(self.cfg.strategy.name());
        let mut heatmaps = Vec::new();
        let total = self.cfg.rounds;

        for round in 1..=total {
            let train_loss = self.run_round()?;

            // heatmap snapshots (Fig. 2 / Fig. 4)
            if self.heatmap_rounds.contains(&round) {
                heatmaps.push((round, self.engine.ps().connectivity()));
            }

            let eval_due = self.cfg.eval_every > 0 && round % self.cfg.eval_every == 0;
            let (test_acc, test_loss) = if eval_due || round == total {
                let t_eval = std::time::Instant::now();
                let (a, l) = self.eval_configured()?;
                self.engine.profile().add("ps.eval", t_eval.elapsed().as_secs_f64());
                (Some(a), Some(l))
            } else {
                (None, None)
            };

            history.rounds.push(RoundRecord {
                round,
                train_loss,
                test_acc,
                test_loss,
                n_clusters: self.engine.ps().clusters().n_clusters(),
                uplink_cum: self.engine.comm().uplink(),
            });

            if let Some(acc) = test_acc {
                crate::info!(
                    "[{}] round {round}/{total}: loss {train_loss:.4} acc {:.2}% clusters {}",
                    self.cfg.strategy.name(),
                    acc * 100.0,
                    self.engine.ps().clusters().n_clusters()
                );
            }
        }

        history.comm = self.engine.comm();
        history.wall_secs = t0.elapsed().as_secs_f64();
        let final_accuracy = history.final_accuracy();
        Ok(TrainReport {
            history,
            heatmaps,
            cluster_labels: self.engine.ps().clusters().labels(),
            truth_labels: match self.cfg.partition {
                crate::data::partition::Scheme::PaperPairs => Some(
                    crate::data::partition::paper_pair_truth(self.cfg.n_clients),
                ),
                _ => None,
            },
            final_accuracy,
            profile: self.engine.profile().snapshot(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn smoke_training_reduces_loss() {
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.rounds = 8;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let report = t.run().unwrap();
        let first = report.history.rounds.first().unwrap().train_loss;
        let last = report.history.rounds.last().unwrap().train_loss;
        assert!(last < first, "loss must decrease: {first} -> {last}");
        assert!(report.history.comm.uplink() > 0);
    }

    #[test]
    fn eval_is_unbiased_by_batch_padding() {
        // a subset whose size is not a batch multiple must produce the
        // same accuracy as evaluating it at batch sizes that divide it
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.rounds = 2;
        cfg.test_n = 150; // 150 % 32 != 0: the trailing batch is padded
        let mut t = Trainer::from_config(&cfg).unwrap();
        t.run_round().unwrap();
        let (acc_padded, _) = t.eval_global().unwrap();

        // the same model at batch 25 (divides 150) needs no padding at all
        let params = t.global_params().to_vec();
        let idx: Vec<usize> = (0..150).collect();
        let (acc_exact, _) = crate::coordinator::engine::eval_dataset(
            t.pool.backend_mut(),
            &params,
            &t.test,
            &idx,
            25,
        )
        .unwrap();
        assert!(
            (acc_padded - acc_exact).abs() < 1e-6,
            "padded eval {acc_padded} != exact eval {acc_exact}"
        );
    }
}
