//! The end-to-end FL trainer — a thin adapter binding the unified round
//! protocol to the parallel in-process pools, plus the
//! evaluation/reporting shell the examples and benches consume.
//!
//! The `topology` and `client_store` knobs decide the driver: a flat run
//! binds one [`RoundEngine`] to one [`InProcessPool`] (or, under
//! `client_store = compact`, a fleet-scale [`CompactPool`] — DESIGN.md
//! §12); a sharded run builds one `Send` pool per shard ([`SendPool`])
//! and drives them through the [`ShardedEngine`] root aggregator, shard
//! rounds in parallel on scoped threads (DESIGN.md §7). All protocol
//! logic lives in the engines and is shared bit-for-bit with the TCP
//! deployment (`fl::distributed`); see `rust/tests/parity.rs` —
//! including the `Flat ≡ Sharded { shards: 1 }` pin.

use crate::backend::Backend;
use crate::config::{BackendKind, ClientStore, EvalMode, ExperimentConfig};
use crate::coordinator::engine::{eval_dataset, RoundEngine};
use crate::coordinator::server::ParameterServer;
use crate::coordinator::topology::{client_shards, locate, ShardedEngine, Topology};
use crate::data::{load_dataset, partition_shards, Dataset, Shard};
use crate::fl::compact::CompactPool;
use crate::fl::metrics::{CommStats, History, RoundRecord};
use crate::fl::pool::{InProcessPool, SendPool};
use crate::util::timer::Profile;
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::sync::Arc;

/// Everything a finished run reports (the examples/benches render these
/// into the paper's figures).
#[derive(Debug)]
pub struct TrainReport {
    pub history: History,
    /// (round, eq.-3 connectivity matrix) snapshots for Fig. 2 / Fig. 4
    /// (flat topology only — a sharded PS has per-shard matrices)
    pub heatmaps: Vec<(usize, Vec<Vec<f64>>)>,
    /// final cluster assignment per client (fleet-wide unique ids under
    /// a sharded topology)
    pub cluster_labels: Vec<usize>,
    /// ground-truth pair labels (when the partition scheme defines them)
    pub truth_labels: Option<Vec<usize>>,
    pub final_accuracy: f32,
    pub profile: Vec<(String, f64, u64)>,
}

/// Which engine/pool pair drives the rounds.
enum Driver {
    Flat { engine: RoundEngine, pool: InProcessPool },
    Compact { engine: RoundEngine, pool: CompactPool },
    Sharded { engine: ShardedEngine, pools: Vec<SendPool> },
}

/// Build the sharded in-process driver: one `Send` pool per shard over
/// the cluster-aligned client slices, plus the root [`ShardedEngine`].
/// Shared by [`Trainer::from_config`] and the sharding bench (which needs
/// direct engine access to time the serial vs parallel shard drivers).
pub fn build_sharded_inprocess(
    cfg: &ExperimentConfig,
) -> Result<(ShardedEngine, Vec<SendPool>)> {
    cfg.validate()?;
    let (train, _) = load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
    let train = Arc::new(train);
    let shards = partition_shards(&train, cfg.n_clients, &cfg.partition, cfg.seed);
    build_sharded_pools(cfg, shards)
}

fn build_sharded_pools(
    cfg: &ExperimentConfig,
    shards: Vec<Shard>,
) -> Result<(ShardedEngine, Vec<SendPool>)> {
    if cfg.backend != BackendKind::Rust {
        bail!(
            "sharded topologies need per-shard Send backends (rust only — \
             ROADMAP: XLA lane replication); run the xla backend flat"
        );
    }
    let n_shards = cfg.topology.n_shards();
    let slices = client_shards(cfg.n_clients, n_shards);
    let mut by_shard: Vec<Vec<Shard>> = (0..n_shards).map(|_| Vec::new()).collect();
    for (id, ds) in shards.into_iter().enumerate() {
        by_shard[locate(cfg.n_clients, n_shards, id).0].push(ds);
    }
    let mut pools = Vec::with_capacity(n_shards);
    let mut init: Option<Vec<f32>> = None;
    for (slice, data) in slices.iter().zip(by_shard) {
        let mut shard_cfg = cfg.clone();
        shard_cfg.n_clients = slice.len();
        let (pool, pool_init) =
            SendPool::new_send(&shard_cfg, data, slice).context("creating shard client pool")?;
        init.get_or_insert(pool_init);
        pools.push(pool);
    }
    let engine = ShardedEngine::new(cfg, init.expect("at least one shard"))?;
    Ok((engine, pools))
}

pub struct Trainer {
    cfg: ExperimentConfig,
    driver: Driver,
    test: Dataset,
    /// per-client test indices matching the client's label set
    /// (EvalMode::Personal)
    personal_test: Vec<Vec<usize>>,
    /// rounds at which to snapshot the connectivity heatmap (flat only)
    pub heatmap_rounds: Vec<usize>,
}

impl Trainer {
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let (train, test) =
            load_dataset(cfg.corpus, &cfg.data_dir, cfg.seed, cfg.train_n, cfg.test_n);
        let train = Arc::new(train);
        let shards = partition_shards(&train, cfg.n_clients, &cfg.partition, cfg.seed);

        let driver = match (cfg.topology, cfg.client_store) {
            (Topology::Flat, ClientStore::Dense) => {
                let (pool, init) =
                    InProcessPool::new(cfg, shards).context("creating client pool")?;
                Driver::Flat { engine: RoundEngine::new(cfg, init), pool }
            }
            (Topology::Flat, ClientStore::Compact) => {
                let (pool, init) =
                    CompactPool::new(cfg, shards).context("creating compact client pool")?;
                Driver::Compact { engine: RoundEngine::new(cfg, init), pool }
            }
            // validate() rejects compact + sharded
            (Topology::Sharded { .. }, _) => {
                let (engine, pools) = build_sharded_pools(cfg, shards)?;
                Driver::Sharded { engine, pools }
            }
        };

        let mut personal_test = vec![Vec::new(); cfg.n_clients];
        match &driver {
            Driver::Flat { pool, .. } => {
                for c in pool.clients() {
                    personal_test[c.id] = test.indices_with_labels(&c.label_set());
                }
            }
            Driver::Compact { pool, .. } => {
                // answered from the shard views — no client materializes
                for (c, slot) in personal_test.iter_mut().enumerate() {
                    *slot = test.indices_with_labels(&pool.label_set(c));
                }
            }
            Driver::Sharded { pools, .. } => {
                for pool in pools {
                    for c in pool.clients() {
                        personal_test[c.id] = test.indices_with_labels(&c.label_set());
                    }
                }
            }
        }

        Ok(Trainer {
            cfg: cfg.clone(),
            driver,
            test,
            personal_test,
            heatmap_rounds: Vec::new(),
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The flat round engine. Panics under a sharded topology — use
    /// [`Self::sharded`] / the topology-agnostic accessors
    /// ([`Self::comm`], [`Self::uploaded_log`], [`Self::n_clusters`])
    /// there.
    pub fn engine(&self) -> &RoundEngine {
        match &self.driver {
            Driver::Flat { engine, .. } | Driver::Compact { engine, .. } => engine,
            Driver::Sharded { .. } => {
                panic!("Trainer::engine() is flat-topology only; use Trainer::sharded()")
            }
        }
    }

    /// The sharded engine (None under the flat topology).
    pub fn sharded(&self) -> Option<&ShardedEngine> {
        match &self.driver {
            Driver::Flat { .. } | Driver::Compact { .. } => None,
            Driver::Sharded { engine, .. } => Some(engine),
        }
    }

    /// The flat **dense** in-process pool. Panics under a sharded
    /// topology or the compact client store — use
    /// [`Self::client_params`] / [`Self::compact_pool`] there.
    pub fn pool(&self) -> &InProcessPool {
        match &self.driver {
            Driver::Flat { pool, .. } => pool,
            Driver::Compact { .. } | Driver::Sharded { .. } => {
                panic!("Trainer::pool() is dense-flat only; use Trainer::client_params()")
            }
        }
    }

    /// Mutable access to the flat dense pool (chaos harnesses, hand-off
    /// tests). Panics like [`Self::pool`] otherwise.
    pub fn pool_mut(&mut self) -> &mut InProcessPool {
        match &mut self.driver {
            Driver::Flat { pool, .. } => pool,
            Driver::Compact { .. } | Driver::Sharded { .. } => {
                panic!("Trainer::pool_mut() is dense-flat only")
            }
        }
    }

    /// The compact pool when `client_store = compact` (None otherwise) —
    /// memory introspection for the fleet-scale bench.
    pub fn compact_pool(&self) -> Option<&CompactPool> {
        match &self.driver {
            Driver::Compact { pool, .. } => Some(pool),
            _ => None,
        }
    }

    /// The flat parameter server (see [`Self::engine`] for the sharded
    /// contract).
    pub fn server(&self) -> &ParameterServer {
        self.engine().ps()
    }

    pub fn global_params(&self) -> &[f32] {
        match &self.driver {
            Driver::Flat { engine, .. } | Driver::Compact { engine, .. } => {
                engine.global_params()
            }
            Driver::Sharded { engine, .. } => engine.global_params(),
        }
    }

    /// A client's current local parameters, by **global** id under every
    /// topology.
    pub fn client_params(&self, i: usize) -> &[f32] {
        match &self.driver {
            Driver::Flat { pool, .. } => pool.client_params(i),
            Driver::Compact { pool, .. } => pool.client_params(i),
            Driver::Sharded { engine, pools, .. } => {
                let (shard, local) = locate(self.cfg.n_clients, engine.n_shards(), i);
                pools[shard].client_params(local)
            }
        }
    }

    /// Cumulative communication accounting (the shard roll-up under a
    /// sharded topology — DESIGN.md §7).
    pub fn comm(&self) -> CommStats {
        match &self.driver {
            Driver::Flat { engine, .. } | Driver::Compact { engine, .. } => engine.comm(),
            Driver::Sharded { engine, .. } => engine.comm(),
        }
    }

    /// Per-round, per-global-client uploaded index sets under every
    /// topology.
    pub fn uploaded_log(&self) -> &VecDeque<Vec<Vec<u32>>> {
        match &self.driver {
            Driver::Flat { engine, .. } | Driver::Compact { engine, .. } => {
                engine.uploaded_log()
            }
            Driver::Sharded { engine, .. } => engine.uploaded_log(),
        }
    }

    /// Fleet-wide cluster count (sum over shards when sharded).
    pub fn n_clusters(&self) -> usize {
        match &self.driver {
            Driver::Flat { engine, .. } | Driver::Compact { engine, .. } => {
                engine.ps().clusters().n_clusters()
            }
            Driver::Sharded { engine, .. } => engine.n_clusters(),
        }
    }

    fn cluster_labels(&self) -> Vec<usize> {
        match &self.driver {
            Driver::Flat { engine, .. } | Driver::Compact { engine, .. } => {
                engine.ps().clusters().labels()
            }
            Driver::Sharded { engine, .. } => engine.cluster_labels(),
        }
    }

    pub fn profile(&self) -> &Profile {
        match &self.driver {
            Driver::Flat { engine, .. } | Driver::Compact { engine, .. } => engine.profile(),
            Driver::Sharded { engine, .. } => engine.profile(),
        }
    }

    /// The PS-side compute backend (field-disjoint from `test`/`cfg`, so
    /// eval can borrow both).
    fn backend_mut(&mut self) -> &mut dyn Backend {
        Self::driver_backend(&mut self.driver)
    }

    fn driver_backend(driver: &mut Driver) -> &mut dyn Backend {
        match driver {
            Driver::Flat { pool, .. } => pool.backend_mut(),
            Driver::Compact { pool, .. } => pool.backend_mut(),
            Driver::Sharded { pools, .. } => pools[0].backend_mut(),
        }
    }

    /// Global-model accuracy/loss over the full test set.
    pub fn eval_global(&mut self) -> Result<(f32, f32)> {
        let params = self.global_params().to_vec();
        let idx: Vec<usize> = (0..self.test.len()).collect();
        let backend = Self::driver_backend(&mut self.driver);
        eval_dataset(backend, &params, &self.test, &idx, self.cfg.batch)
    }

    /// The paper's Fig. 3/5 metric: mean over clients of their own model
    /// on test data from their own label distribution.
    pub fn eval_personal(&mut self) -> Result<(f32, f32)> {
        let mut accs = Vec::new();
        let mut losses = Vec::new();
        for c in 0..self.cfg.n_clients {
            let params = self.client_params(c).to_vec();
            let idx = self.personal_test[c].clone();
            let backend = Self::driver_backend(&mut self.driver);
            let (a, l) = eval_dataset(backend, &params, &self.test, &idx, self.cfg.batch)?;
            accs.push(a as f64);
            losses.push(l as f64);
        }
        Ok((crate::util::mean(&accs) as f32, crate::util::mean(&losses) as f32))
    }

    fn eval_configured(&mut self) -> Result<(f32, f32)> {
        match self.cfg.eval_mode {
            EvalMode::Global => self.eval_global(),
            EvalMode::Personal => self.eval_personal(),
        }
    }

    /// One global round (Algorithm 1 lines 3-16). Returns the mean local
    /// training loss.
    pub fn run_round(&mut self) -> Result<f32> {
        match &mut self.driver {
            Driver::Flat { engine, pool } => Ok(engine.run_round(pool)?.mean_loss),
            Driver::Compact { engine, pool } => Ok(engine.run_round(pool)?.mean_loss),
            Driver::Sharded { engine, pools } => Ok(engine.run_round(pools)?.mean_loss),
        }
    }

    /// Run the configured number of rounds, producing the full report.
    // Wall-clock totals in the report are a product feature; the
    // clippy.toml clock ban protects round *semantics*, which stay
    // clock-free.
    #[allow(clippy::disallowed_methods)]
    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let mut history = History::new(self.cfg.strategy.name());
        let mut heatmaps = Vec::new();
        let total = self.cfg.rounds;

        for round in 1..=total {
            let train_loss = self.run_round()?;

            // heatmap snapshots (Fig. 2 / Fig. 4) — the fleet-wide eq. (3)
            // matrix only exists on a flat PS
            if self.heatmap_rounds.contains(&round) {
                match &self.driver {
                    Driver::Flat { engine, .. } | Driver::Compact { engine, .. } => {
                        heatmaps.push((round, engine.ps().connectivity()));
                    }
                    Driver::Sharded { .. } => {}
                }
            }

            let eval_due = self.cfg.eval_every > 0 && round % self.cfg.eval_every == 0;
            let (test_acc, test_loss) = if eval_due || round == total {
                let t_eval = std::time::Instant::now();
                let (a, l) = self.eval_configured()?;
                self.profile().add("ps.eval", t_eval.elapsed().as_secs_f64());
                (Some(a), Some(l))
            } else {
                (None, None)
            };

            history.rounds.push(RoundRecord {
                round,
                train_loss,
                test_acc,
                test_loss,
                n_clusters: self.n_clusters(),
                uplink_cum: self.comm().uplink(),
            });

            if let Some(acc) = test_acc {
                crate::info!(
                    "[{}] round {round}/{total}: loss {train_loss:.4} acc {:.2}% clusters {}",
                    self.cfg.strategy.name(),
                    acc * 100.0,
                    self.n_clusters()
                );
            }
        }

        history.comm = self.comm();
        history.wall_secs = t0.elapsed().as_secs_f64();
        let final_accuracy = history.final_accuracy();
        Ok(TrainReport {
            history,
            heatmaps,
            cluster_labels: self.cluster_labels(),
            truth_labels: match self.cfg.partition {
                crate::data::partition::Scheme::PaperPairs => Some(
                    crate::data::partition::paper_pair_truth(self.cfg.n_clients),
                ),
                _ => None,
            },
            final_accuracy,
            profile: self.profile().snapshot(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn smoke_training_reduces_loss() {
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.rounds = 8;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let report = t.run().unwrap();
        let first = report.history.rounds.first().unwrap().train_loss;
        let last = report.history.rounds.last().unwrap().train_loss;
        assert!(last < first, "loss must decrease: {first} -> {last}");
        assert!(report.history.comm.uplink() > 0);
    }

    #[test]
    fn sharded_smoke_training_reduces_loss() {
        use crate::clustering::MergeRule;
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.rounds = 8;
        cfg.topology = Topology::Sharded { shards: 2, root_merge: MergeRule::Min };
        let mut t = Trainer::from_config(&cfg).unwrap();
        assert!(t.sharded().is_some());
        let report = t.run().unwrap();
        let first = report.history.rounds.first().unwrap().train_loss;
        let last = report.history.rounds.last().unwrap().train_loss;
        assert!(last < first, "sharded loss must decrease: {first} -> {last}");
        // two shard engines, clusters counted fleet-wide
        assert_eq!(report.cluster_labels.len(), cfg.n_clients);
        assert!(report.history.comm.uplink() > 0);
    }

    /// The `client_store` knob never changes results: a compact-store
    /// trainer is bit-for-bit a dense-store trainer end to end (losses,
    /// globals, per-client params, comm accounting).
    #[test]
    fn compact_store_matches_dense_trainer() {
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.rounds = 4;
        cfg.participation = 0.5; // leave fresh slots alive
        let mut dense = Trainer::from_config(&cfg).unwrap();
        cfg.client_store = ClientStore::Compact;
        let mut compact = Trainer::from_config(&cfg).unwrap();
        assert!(compact.compact_pool().is_some());

        let rd = dense.run().unwrap();
        let rc = compact.run().unwrap();
        let ld: Vec<f32> = rd.history.rounds.iter().map(|r| r.train_loss).collect();
        let lc: Vec<f32> = rc.history.rounds.iter().map(|r| r.train_loss).collect();
        assert_eq!(ld, lc, "per-round training losses must match exactly");
        assert_eq!(dense.global_params(), compact.global_params());
        assert_eq!(rd.history.comm.uplink(), rc.history.comm.uplink());
        for i in 0..cfg.n_clients {
            assert_eq!(dense.client_params(i), compact.client_params(i), "client {i} params");
        }
    }

    #[test]
    fn eval_is_unbiased_by_batch_padding() {
        // a subset whose size is not a batch multiple must produce the
        // same accuracy as evaluating it at batch sizes that divide it
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.rounds = 2;
        cfg.test_n = 150; // 150 % 32 != 0: the trailing batch is padded
        let mut t = Trainer::from_config(&cfg).unwrap();
        t.run_round().unwrap();
        let (acc_padded, _) = t.eval_global().unwrap();

        // the same model at batch 25 (divides 150) needs no padding at all
        let params = t.global_params().to_vec();
        let idx: Vec<usize> = (0..150).collect();
        let (acc_exact, _) = crate::coordinator::engine::eval_dataset(
            Trainer::driver_backend(&mut t.driver),
            &params,
            &t.test,
            &idx,
            25,
        )
        .unwrap();
        assert!(
            (acc_padded - acc_exact).abs() < 1e-6,
            "padded eval {acc_padded} != exact eval {acc_exact}"
        );
    }
}
