//! Wire transport for the multi-process deployment: a length-prefixed
//! binary protocol over TCP (std::net only; the offline registry has no
//! tokio) plus in-memory encode/decode used by tests.
//!
//! The message set mirrors the paper's protocol exactly — join, model
//! broadcast, top-r report, index request, sparse update — so the byte
//! accounting of DESIGN.md §6 corresponds 1:1 to real frames.
//!
//! Frame layout: `u32 magic | u32 payload_len | u8 tag | payload`,
//! little-endian throughout.

use crate::sparse::SparseVec;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;

/// Protocol magic ("rAgk").
pub const MAGIC: u32 = 0x7241_676b;

#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// client -> PS: hello
    Join { client_id: u32 },
    /// PS -> client: global model broadcast for a round
    Model { round: u32, params: Vec<f32> },
    /// client -> PS: top-r report (indices by |g| desc + signed values)
    Report { client_id: u32, round: u32, report: SparseVec, mean_loss: f32 },
    /// PS -> client: the k requested indices
    Request { round: u32, indices: Vec<u32> },
    /// client -> PS: sparse update for the requested indices
    Update { client_id: u32, round: u32, update: SparseVec },
    /// PS -> client: training finished
    Shutdown,
    /// PS -> client: you are **off-cohort** this round — no model
    /// broadcast, no training, just keep the round counter in sync and
    /// wait for the next frame (partial participation).
    Sit { round: u32 },
}

// ------------------------------------------------------------- encoding

struct Enc(Vec<u8>);

impl Enc {
    fn u32(&mut self, x: u32) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn f32(&mut self, x: f32) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn u32s(&mut self, xs: &[u32]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.u32(x);
        }
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.f32(x);
        }
    }
    fn sparse(&mut self, s: &SparseVec) {
        self.u32s(&s.idx);
        self.f32s(&s.val);
    }
}

struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn u32(&mut self) -> Result<u32> {
        if self.pos + 4 > self.b.len() {
            bail!("truncated frame");
        }
        let v = u32::from_le_bytes(self.b[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        Ok(v)
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        if self.pos + n * 4 > self.b.len() {
            bail!("truncated u32 array (n = {n})");
        }
        (0..n).map(|_| self.u32()).collect()
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        if self.pos + n * 4 > self.b.len() {
            bail!("truncated f32 array (n = {n})");
        }
        (0..n).map(|_| self.f32()).collect()
    }
    fn sparse(&mut self) -> Result<SparseVec> {
        let idx = self.u32s()?;
        let val = self.f32s()?;
        if idx.len() != val.len() {
            bail!("sparse vec length mismatch");
        }
        Ok(SparseVec::new(idx, val))
    }
    fn done(&self) -> Result<()> {
        if self.pos != self.b.len() {
            bail!("{} trailing bytes in frame", self.b.len() - self.pos);
        }
        Ok(())
    }
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Join { .. } => 1,
            Msg::Model { .. } => 2,
            Msg::Report { .. } => 3,
            Msg::Request { .. } => 4,
            Msg::Update { .. } => 5,
            Msg::Shutdown => 6,
            Msg::Sit { .. } => 7,
        }
    }

    /// Serialize to a full frame (incl. magic + length header).
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc(Vec::new());
        match self {
            Msg::Join { client_id } => e.u32(*client_id),
            Msg::Model { round, params } => {
                e.u32(*round);
                e.f32s(params);
            }
            Msg::Report { client_id, round, report, mean_loss } => {
                e.u32(*client_id);
                e.u32(*round);
                e.sparse(report);
                e.f32(*mean_loss);
            }
            Msg::Request { round, indices } => {
                e.u32(*round);
                e.u32s(indices);
            }
            Msg::Update { client_id, round, update } => {
                e.u32(*client_id);
                e.u32(*round);
                e.sparse(update);
            }
            Msg::Shutdown => {}
            Msg::Sit { round } => e.u32(*round),
        }
        let payload = e.0;
        let mut frame = Vec::with_capacity(9 + payload.len());
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.extend_from_slice(&(payload.len() as u32 + 1).to_le_bytes());
        frame.push(self.tag());
        frame.extend_from_slice(&payload);
        frame
    }

    /// Decode a payload (tag + body, no header).
    pub fn decode(tagged: &[u8]) -> Result<Msg> {
        if tagged.is_empty() {
            bail!("empty frame");
        }
        let mut d = Dec { b: &tagged[1..], pos: 0 };
        let msg = match tagged[0] {
            1 => Msg::Join { client_id: d.u32()? },
            2 => Msg::Model { round: d.u32()?, params: d.f32s()? },
            3 => Msg::Report {
                client_id: d.u32()?,
                round: d.u32()?,
                report: d.sparse()?,
                mean_loss: d.f32()?,
            },
            4 => Msg::Request { round: d.u32()?, indices: d.u32s()? },
            5 => Msg::Update { client_id: d.u32()?, round: d.u32()?, update: d.sparse()? },
            6 => Msg::Shutdown,
            7 => Msg::Sit { round: d.u32()? },
            t => bail!("unknown message tag {t}"),
        };
        d.done()?;
        Ok(msg)
    }

    /// Wire size of the encoded frame in bytes, computed arithmetically —
    /// no re-encoding (the old implementation allocated a full frame copy,
    /// a d-vector for `Model`, just to return a length). Pinned equal to
    /// `encode().len()` for every variant by `wire_bytes_never_encodes`.
    pub fn wire_bytes(&self) -> usize {
        // magic(4) + payload_len(4) + tag(1)
        const HEADER: usize = 9;
        // every length-prefixed list costs 4 (count) + 4 per element
        fn list(n: usize) -> usize {
            4 + 4 * n
        }
        fn sparse(s: &SparseVec) -> usize {
            list(s.idx.len()) + list(s.val.len())
        }
        HEADER
            + match self {
                Msg::Join { .. } => 4,
                Msg::Model { params, .. } => 4 + list(params.len()),
                Msg::Report { report, .. } => 4 + 4 + sparse(report) + 4,
                Msg::Request { indices, .. } => 4 + list(indices.len()),
                Msg::Update { update, .. } => 4 + 4 + sparse(update),
                Msg::Shutdown => 0,
                Msg::Sit { .. } => 4,
            }
    }
}

/// Encode a `Model` broadcast frame straight from a parameter slice —
/// byte-identical to `Msg::Model { round, params: params.to_vec() }
/// .encode()` but without materializing the intermediate d-vector copy.
/// The PS encodes **one** such frame per round and writes it to every
/// cohort stream (the zero-copy broadcast); pinned byte-identical by
/// `model_frame_helper_matches_encode`.
pub fn encode_model_frame(round: u32, params: &[f32]) -> Vec<u8> {
    let payload_len = 1 + 4 + 4 + 4 * params.len(); // tag + round + list
    let mut frame = Vec::with_capacity(8 + payload_len);
    frame.extend_from_slice(&MAGIC.to_le_bytes());
    frame.extend_from_slice(&(payload_len as u32).to_le_bytes());
    frame.push(2); // Msg::Model's tag
    frame.extend_from_slice(&round.to_le_bytes());
    frame.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for &x in params {
        frame.extend_from_slice(&x.to_le_bytes());
    }
    frame
}

/// Write one message to a TCP stream.
pub fn send(stream: &mut TcpStream, msg: &Msg) -> Result<()> {
    stream.write_all(&msg.encode()).context("send frame")
}

/// Read one message from a TCP stream (blocking).
pub fn recv(stream: &mut TcpStream) -> Result<Msg> {
    let mut header = [0u8; 8];
    stream.read_exact(&mut header).context("recv header")?;
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != MAGIC {
        bail!("bad magic {magic:#x}");
    }
    let len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    if len == 0 || len > 512 << 20 {
        bail!("implausible frame length {len}");
    }
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload).context("recv payload")?;
    Msg::decode(&payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: Msg) {
        let frame = m.encode();
        assert_eq!(&frame[0..4], &MAGIC.to_le_bytes());
        let len = u32::from_le_bytes(frame[4..8].try_into().unwrap()) as usize;
        assert_eq!(len, frame.len() - 8);
        let back = Msg::decode(&frame[8..]).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Msg::Join { client_id: 3 });
        roundtrip(Msg::Model { round: 7, params: vec![1.0, -2.5, 3.25] });
        roundtrip(Msg::Report {
            client_id: 1,
            round: 2,
            report: SparseVec::new(vec![5, 900, 39000], vec![0.5, -0.25, 1e-9]),
            mean_loss: 2.25,
        });
        roundtrip(Msg::Request { round: 9, indices: vec![1, 2, 3] });
        roundtrip(Msg::Update {
            client_id: 0,
            round: 1,
            update: SparseVec::new(vec![], vec![]),
        });
        roundtrip(Msg::Shutdown);
        roundtrip(Msg::Sit { round: 11 });
    }

    /// One frame of every variant (empty and non-empty payloads where it
    /// matters): the arithmetic size must equal the encoded length.
    fn every_variant() -> Vec<Msg> {
        vec![
            Msg::Join { client_id: 3 },
            Msg::Model { round: 7, params: vec![] },
            Msg::Model { round: 7, params: vec![1.0, -2.5, 3.25] },
            Msg::Report {
                client_id: 1,
                round: 2,
                report: SparseVec::new(vec![5, 900], vec![0.5, -0.25]),
                mean_loss: 2.25,
            },
            Msg::Request { round: 9, indices: vec![1, 2, 3] },
            Msg::Request { round: 9, indices: vec![] },
            Msg::Update {
                client_id: 0,
                round: 1,
                update: SparseVec::new(vec![4, 8, 15], vec![0.1, 0.2, 0.3]),
            },
            Msg::Update { client_id: 0, round: 1, update: SparseVec::new(vec![], vec![]) },
            Msg::Shutdown,
            Msg::Sit { round: 4 },
        ]
    }

    #[test]
    fn wire_bytes_never_encodes() {
        for m in every_variant() {
            assert_eq!(m.wire_bytes(), m.encode().len(), "{m:?}");
        }
    }

    #[test]
    fn model_frame_helper_matches_encode() {
        for params in [vec![], vec![0.5f32], vec![1.0, -2.5, f32::MIN_POSITIVE, 0.0]] {
            let via_msg = Msg::Model { round: 3, params: params.clone() }.encode();
            assert_eq!(encode_model_frame(3, &params), via_msg);
        }
    }

    #[test]
    fn rejects_corrupt_frames() {
        assert!(Msg::decode(&[]).is_err());
        assert!(Msg::decode(&[99]).is_err());
        // truncated body
        let frame = Msg::Request { round: 1, indices: vec![1, 2, 3] }.encode();
        assert!(Msg::decode(&frame[8..frame.len() - 2]).is_err());
        // trailing garbage
        let mut long = frame[8..].to_vec();
        long.push(0);
        assert!(Msg::decode(&long).is_err());
    }

    #[test]
    fn tcp_roundtrip() {
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let m = recv(&mut s).unwrap();
            send(&mut s, &m).unwrap(); // echo
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let msg = Msg::Model { round: 5, params: vec![0.5; 1000] };
        send(&mut stream, &msg).unwrap();
        let back = recv(&mut stream).unwrap();
        assert_eq!(msg, back);
        handle.join().unwrap();
    }

    #[test]
    fn wire_bytes_accounting_matches_design() {
        // sparse update of k entries: 8k payload + 8 list headers
        let k = 10;
        let m = Msg::Update {
            client_id: 0,
            round: 0,
            update: SparseVec::new(vec![0; k], vec![0.0; k]),
        };
        // header(8) + tag(1) + client(4) + round(4) + 2 lens(8) + 8k
        assert_eq!(m.wire_bytes(), 8 + 1 + 4 + 4 + 8 + 8 * k);
        // the Sit control frame is a fixed 13 bytes — cheap enough to keep
        // off-cohort workers in sync every round (DESIGN.md §6)
        assert_eq!(Msg::Sit { round: 1 }.wire_bytes(), 8 + 1 + 4);
    }
}
