//! Wire transport for the multi-process deployment: a length-prefixed
//! binary protocol over TCP (std::net only; the offline registry has no
//! tokio) plus in-memory encode/decode used by tests.
//!
//! The message set mirrors the paper's protocol exactly — join, model
//! broadcast, top-r report, index request, sparse update — so the byte
//! accounting of DESIGN.md §6 corresponds 1:1 to real frames.
//!
//! Frame layout: `u32 magic | u32 payload_len | u8 tag | payload`,
//! little-endian throughout. The *payload* layout is versioned by the
//! [`Codec`] negotiated at `Join` time (the Join frame itself carries a
//! protocol-version byte and is identical under every codec):
//!
//! * [`Codec::Raw`] — the v1 format: 4 B per index, 4 B per value,
//!   length-prefixed lists. `Report` ships its values even though the PS
//!   only consumes the indices.
//! * [`Codec::Packed`] — v2: sparse index lists are sorted and
//!   delta+LEB128 coded with a varint rank per position restoring the
//!   original (magnitude/selection) order exactly; `Report` values are
//!   not transmitted (the PS protocol never reads them — decoded reports
//!   carry zeros); everything else decodes bit-identically to raw.
//! * [`Codec::PackedF16`] — v2 with `Update` values stored as binary16
//!   (lossy; indices stay lossless).
//!
//! Dense `Model` payloads are encoded/decoded with bulk byte-window
//! copies in every codec ([`crate::fl::codec::put_f32s_bulk`]) — the
//! frame bytes are identical across codecs, so the zero-copy broadcast
//! shares one encode per round regardless of the negotiated format.
//!
//! Every frame size is available arithmetically (no encoding) through
//! [`Msg::wire_bytes`] and the `*_frame_bytes` helpers; both are pinned
//! equal to `encode().len()` for every variant in every codec by
//! `wire_bytes_never_encodes`.

use crate::fl::codec::{
    index_block_bytes, put_f16s_bulk, put_f32, put_f32s_bulk, put_u32, put_u32s_bulk,
    write_index_block, Codec, Dec, FrameBuf, IndexScratch,
};
use crate::sparse::SparseVec;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;

/// Protocol magic ("rAgk").
pub const MAGIC: u32 = 0x7241_676b;

/// Handshake protocol version, carried in every `Join`/`Rejoin` frame
/// and checked on decode. v4 added the sparse `Delta` downlink frame and
/// the `Rejoin` held-digest proof (v1 = raw-only wire, v2 = negotiated
/// codecs, v3 = `Rejoin` re-admission + the version byte itself); a PS
/// refuses handshakes from any other version with a clean error instead
/// of mis-parsing newer frames.
pub const PROTOCOL_VERSION: u8 = 4;

/// magic(4) + payload_len(4) + tag(1)
pub const HEADER_BYTES: usize = 9;

/// The `Model` frame's tag byte (the worker hot loop peeks at it to
/// decode the broadcast straight into a reused parameter buffer).
pub const TAG_MODEL: u8 = 2;

/// The `Delta` frame's tag byte (peeked like [`TAG_MODEL`] so the worker
/// routes sparse broadcasts into the in-place apply path).
pub const TAG_DELTA: u8 = 9;

#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// client -> PS: hello + the wire codec this worker is configured
    /// for (codec negotiation; the PS rejects mismatches). Carries
    /// [`PROTOCOL_VERSION`], checked on decode.
    Join { client_id: u32, codec: Codec },
    /// client -> PS: a recovered worker re-admitting itself after its
    /// stream died (DESIGN.md §8). `generation` is the worker's
    /// admission attempt counter (1 for the first rejoin); the PS
    /// refuses stale or duplicate generations and answers an accepted
    /// rejoin with a `Model` frame resyncing the current global model —
    /// unless `held_digest` (the content digest of the model the worker
    /// still holds, 0 = none) matches the PS global, in which case a
    /// 13-byte `Sit` ack replaces the d-sized resync (DESIGN.md §9).
    /// Carries [`PROTOCOL_VERSION`] like `Join`.
    Rejoin { client_id: u32, generation: u32, held_digest: u64, codec: Codec },
    /// PS -> client: global model broadcast for a round
    Model { round: u32, params: Vec<f32> },
    /// PS -> client: sparse model broadcast — only the parameters that
    /// changed between the worker's last-acked generation `base_round`
    /// and this `round`, as absolute new values. `digest` is the content
    /// digest ([`crate::fl::codec::params_digest`]) of the full model at
    /// `round`; the worker updates its running digest incrementally while
    /// applying and bails (forcing a full-model resync via the rejoin
    /// path) on any mismatch. Values are always f32 — model state stays
    /// lossless in every codec, exactly like `Model`.
    Delta { round: u32, base_round: u32, digest: u64, delta: SparseVec },
    /// client -> PS: top-r report (indices by |g| desc + signed values;
    /// packed codecs transmit the indices only — the PS never reads the
    /// values, so they decode as zeros)
    Report { client_id: u32, round: u32, report: SparseVec, mean_loss: f32 },
    /// PS -> client: the k requested indices
    Request { round: u32, indices: Vec<u32> },
    /// client -> PS: sparse update for the requested indices
    Update { client_id: u32, round: u32, update: SparseVec },
    /// PS -> client: training finished
    Shutdown,
    /// PS -> client: you are **off-cohort** this round — no model
    /// broadcast, no training, just keep the round counter in sync and
    /// wait for the next frame (partial participation).
    Sit { round: u32 },
}

// ------------------------------------------------------ frame-size math

fn list4(n: usize) -> usize {
    4 + 4 * n
}

/// Wire size of a `Model` frame (codec-independent: the broadcast is
/// dense f32 in every format).
pub fn model_frame_bytes(d: usize) -> usize {
    HEADER_BYTES + 4 + list4(d)
}

/// Wire size of the fixed `Sit` control frame.
pub const SIT_FRAME_BYTES: usize = HEADER_BYTES + 4;

/// Wire size of a `Delta` frame carrying these changed indices (plus one
/// f32 value per index in every codec — model state stays lossless):
/// round(4) + base_round(4) + digest(8) + indices + values.
pub fn delta_frame_bytes(codec: Codec, idx: &[u32]) -> usize {
    HEADER_BYTES
        + 4
        + 4
        + 8
        + if codec.packs_indices() { index_block_bytes(idx) } else { list4(idx.len()) }
        + 4 * idx.len()
}

/// Wire size of a `Report` frame carrying these indices (raw also ships
/// an equal-length value list; packed ships indices only).
pub fn report_frame_bytes(codec: Codec, idx: &[u32]) -> usize {
    HEADER_BYTES
        + 4
        + 4
        + 4
        + if codec.packs_indices() {
            index_block_bytes(idx)
        } else {
            list4(idx.len()) + list4(idx.len())
        }
}

/// Wire size of a `Request` frame carrying these indices.
pub fn request_frame_bytes(codec: Codec, indices: &[u32]) -> usize {
    HEADER_BYTES
        + 4
        + if codec.packs_indices() { index_block_bytes(indices) } else { list4(indices.len()) }
}

/// Wire size of an `Update` frame carrying these indices plus one value
/// per index (f32 raw/packed, f16 in packed-f16).
pub fn update_frame_bytes(codec: Codec, idx: &[u32]) -> usize {
    HEADER_BYTES
        + 4
        + 4
        + match codec {
            Codec::Raw => list4(idx.len()) + list4(idx.len()),
            Codec::Packed => index_block_bytes(idx) + 4 * idx.len(),
            Codec::PackedF16 => index_block_bytes(idx) + 2 * idx.len(),
        }
}

// ------------------------------------------------------------- encoding

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Join { .. } => 1,
            Msg::Model { .. } => TAG_MODEL,
            Msg::Report { .. } => 3,
            Msg::Request { .. } => 4,
            Msg::Update { .. } => 5,
            Msg::Shutdown => 6,
            Msg::Sit { .. } => 7,
            Msg::Rejoin { .. } => 8,
            Msg::Delta { .. } => TAG_DELTA,
        }
    }

    /// Serialize to a full frame (incl. magic + length header),
    /// allocating fresh buffers — tests and one-off control frames.
    pub fn encode(&self, codec: Codec) -> Vec<u8> {
        let mut out = Vec::new();
        let mut scratch = IndexScratch::default();
        self.encode_into(codec, &mut out, &mut scratch);
        out
    }

    /// Serialize into a reused buffer (cleared first); the index-sort
    /// scratch is reused too, so steady-state encoding allocates nothing.
    pub fn encode_into(&self, codec: Codec, out: &mut Vec<u8>, scratch: &mut IndexScratch) {
        frame_start(out, self.tag());
        match self {
            Msg::Join { client_id, codec: joined } => {
                put_u32(out, *client_id);
                out.push(PROTOCOL_VERSION);
                out.push(joined.wire_id());
            }
            Msg::Rejoin { client_id, generation, held_digest, codec: joined } => {
                put_u32(out, *client_id);
                put_u32(out, *generation);
                out.extend_from_slice(&held_digest.to_le_bytes());
                out.push(PROTOCOL_VERSION);
                out.push(joined.wire_id());
            }
            Msg::Model { round, params } => write_model_payload(out, *round, params),
            Msg::Delta { round, base_round, digest, delta } => write_delta_payload(
                codec, out, scratch, *round, *base_round, *digest, &delta.idx, &delta.val,
            ),
            Msg::Report { client_id, round, report, mean_loss } => write_report_payload(
                codec, out, scratch, *client_id, *round, &report.idx, &report.val, *mean_loss,
            ),
            Msg::Request { round, indices } => {
                write_request_payload(codec, out, scratch, *round, indices)
            }
            Msg::Update { client_id, round, update } => {
                put_u32(out, *client_id);
                put_u32(out, *round);
                if codec.packs_indices() {
                    write_index_block(out, &update.idx, scratch);
                    if codec.f16_values() {
                        put_f16s_bulk(out, &update.val);
                    } else {
                        put_f32s_bulk(out, &update.val);
                    }
                } else {
                    put_u32(out, update.idx.len() as u32);
                    put_u32s_bulk(out, &update.idx);
                    put_u32(out, update.val.len() as u32);
                    put_f32s_bulk(out, &update.val);
                }
            }
            Msg::Shutdown => {}
            Msg::Sit { round } => put_u32(out, *round),
        }
        frame_finish(out);
    }

    /// Decode a payload (tag + body, no header) under the stream's codec.
    /// `Join`, `Shutdown`, and `Sit` are codec-independent.
    pub fn decode(tagged: &[u8], codec: Codec) -> Result<Msg> {
        if tagged.is_empty() {
            bail!("empty frame");
        }
        fn check_version(v: u8, what: &str) -> Result<()> {
            if v != PROTOCOL_VERSION {
                bail!("{what} carries protocol version {v}, this PS speaks {PROTOCOL_VERSION}");
            }
            Ok(())
        }
        let mut d = Dec::new(&tagged[1..]);
        let msg = match tagged[0] {
            1 => {
                let client_id = d.u32()?;
                check_version(d.u8()?, "Join")?;
                let b = d.u8()?;
                let joined = Codec::from_wire_id(b)
                    .with_context(|| format!("unknown codec wire id {b}"))?;
                Msg::Join { client_id, codec: joined }
            }
            8 => {
                let client_id = d.u32()?;
                let generation = d.u32()?;
                let held_digest = d.u64()?;
                check_version(d.u8()?, "Rejoin")?;
                let b = d.u8()?;
                let joined = Codec::from_wire_id(b)
                    .with_context(|| format!("unknown codec wire id {b}"))?;
                Msg::Rejoin { client_id, generation, held_digest, codec: joined }
            }
            TAG_DELTA => {
                let round = d.u32()?;
                let base_round = d.u32()?;
                let digest = d.u64()?;
                let idx = if codec.packs_indices() { d.index_block()? } else { d.u32s()? };
                let mut val = Vec::new();
                d.f32s_bulk_into(idx.len(), &mut val)?;
                Msg::Delta { round, base_round, digest, delta: SparseVec::new(idx, val) }
            }
            TAG_MODEL => {
                let round = d.u32()?;
                let params = d.f32s()?;
                Msg::Model { round, params }
            }
            3 => {
                let client_id = d.u32()?;
                let round = d.u32()?;
                let (report, mean_loss) = if codec.packs_indices() {
                    let mean_loss = d.f32()?;
                    let idx = d.index_block()?;
                    let val = vec![0.0f32; idx.len()];
                    (SparseVec::new(idx, val), mean_loss)
                } else {
                    let idx = d.u32s()?;
                    let val = d.f32s()?;
                    if idx.len() != val.len() {
                        bail!("sparse vec length mismatch");
                    }
                    (SparseVec::new(idx, val), d.f32()?)
                };
                Msg::Report { client_id, round, report, mean_loss }
            }
            4 => {
                let round = d.u32()?;
                let indices =
                    if codec.packs_indices() { d.index_block()? } else { d.u32s()? };
                Msg::Request { round, indices }
            }
            5 => {
                let client_id = d.u32()?;
                let round = d.u32()?;
                let update = if codec.packs_indices() {
                    let idx = d.index_block()?;
                    let val = if codec.f16_values() {
                        d.f16s_bulk(idx.len())?
                    } else {
                        let mut v = Vec::new();
                        d.f32s_bulk_into(idx.len(), &mut v)?;
                        v
                    };
                    SparseVec::new(idx, val)
                } else {
                    let idx = d.u32s()?;
                    let val = d.f32s()?;
                    if idx.len() != val.len() {
                        bail!("sparse vec length mismatch");
                    }
                    SparseVec::new(idx, val)
                };
                Msg::Update { client_id, round, update }
            }
            6 => Msg::Shutdown,
            7 => Msg::Sit { round: d.u32()? },
            t => bail!("unknown message tag {t}"),
        };
        d.done()?;
        Ok(msg)
    }

    /// Wire size of the encoded frame in bytes, computed arithmetically —
    /// no frame is materialized. Pinned equal to `encode(codec).len()` for
    /// every variant in every codec by `wire_bytes_never_encodes`.
    pub fn wire_bytes(&self, codec: Codec) -> usize {
        match self {
            Msg::Join { .. } => HEADER_BYTES + 6,
            Msg::Rejoin { .. } => HEADER_BYTES + 18,
            Msg::Model { params, .. } => model_frame_bytes(params.len()),
            Msg::Delta { delta, .. } => delta_frame_bytes(codec, &delta.idx),
            Msg::Report { report, .. } => report_frame_bytes(codec, &report.idx),
            Msg::Request { indices, .. } => request_frame_bytes(codec, indices),
            Msg::Update { update, .. } => update_frame_bytes(codec, &update.idx),
            Msg::Shutdown => HEADER_BYTES,
            Msg::Sit { .. } => SIT_FRAME_BYTES,
        }
    }
}

/// Open a frame: magic + length placeholder (backpatched by
/// [`frame_finish`]) + tag, into a cleared reused buffer.
fn frame_start(out: &mut Vec<u8>, tag: u8) {
    out.clear();
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&[0u8; 4]);
    out.push(tag);
}

/// Backpatch the payload length written as a placeholder by
/// [`frame_start`].
fn frame_finish(out: &mut Vec<u8>) {
    let len = (out.len() - 8) as u32;
    out[4..8].copy_from_slice(&len.to_le_bytes());
}

/// `Model` payload body (round + length-prefixed bulk f32), shared by
/// `Msg::encode_into` and [`encode_model_frame_into`] so the zero-copy
/// broadcast helper stays byte-identical to the generic encoder.
fn write_model_payload(out: &mut Vec<u8>, round: u32, params: &[f32]) {
    put_u32(out, round);
    put_u32(out, params.len() as u32);
    put_f32s_bulk(out, params);
}

/// `Report` payload body — the single definition of the Report layout,
/// shared by `Msg::encode_into` and the borrowed-parts hot path
/// [`send_report`], so the two encoders cannot drift.
#[allow(clippy::too_many_arguments)]
fn write_report_payload(
    codec: Codec,
    out: &mut Vec<u8>,
    scratch: &mut IndexScratch,
    client_id: u32,
    round: u32,
    idx: &[u32],
    val: &[f32],
    mean_loss: f32,
) {
    put_u32(out, client_id);
    put_u32(out, round);
    if codec.packs_indices() {
        put_f32(out, mean_loss);
        write_index_block(out, idx, scratch);
    } else {
        put_u32(out, idx.len() as u32);
        put_u32s_bulk(out, idx);
        put_u32(out, val.len() as u32);
        put_f32s_bulk(out, val);
        put_f32(out, mean_loss);
    }
}

/// `Request` payload body — the single definition of the Request layout,
/// shared by `Msg::encode_into` and [`send_request`].
fn write_request_payload(
    codec: Codec,
    out: &mut Vec<u8>,
    scratch: &mut IndexScratch,
    round: u32,
    indices: &[u32],
) {
    put_u32(out, round);
    if codec.packs_indices() {
        write_index_block(out, indices, scratch);
    } else {
        put_u32(out, indices.len() as u32);
        put_u32s_bulk(out, indices);
    }
}

/// `Delta` payload body — the single definition of the Delta layout,
/// shared by `Msg::encode_into` and [`encode_delta_frame_into`].
#[allow(clippy::too_many_arguments)]
fn write_delta_payload(
    codec: Codec,
    out: &mut Vec<u8>,
    scratch: &mut IndexScratch,
    round: u32,
    base_round: u32,
    digest: u64,
    idx: &[u32],
    val: &[f32],
) {
    put_u32(out, round);
    put_u32(out, base_round);
    out.extend_from_slice(&digest.to_le_bytes());
    if codec.packs_indices() {
        write_index_block(out, idx, scratch);
    } else {
        put_u32(out, idx.len() as u32);
        put_u32s_bulk(out, idx);
    }
    put_f32s_bulk(out, val);
}

/// Encode a `Delta` broadcast frame straight from the global parameter
/// slice into a reusable buffer, gathering the changed values in index
/// order — byte-identical to `Msg::Delta { .. }.encode(codec)` with
/// `delta.val[j] = global[delta.idx[j]]` (pinned by
/// `delta_frame_helper_matches_encode`). `val_scratch` is the reused
/// gather buffer; `idx` must be in range (it is the PS's own union of
/// updated indices).
#[allow(clippy::too_many_arguments)]
pub fn encode_delta_frame_into(
    codec: Codec,
    round: u32,
    base_round: u32,
    digest: u64,
    idx: &[u32],
    global: &[f32],
    out: &mut Vec<u8>,
    val_scratch: &mut Vec<f32>,
    scratch: &mut IndexScratch,
) {
    val_scratch.clear();
    val_scratch.extend(idx.iter().map(|&i| global[i as usize]));
    out.clear();
    out.reserve(delta_frame_bytes(codec, idx));
    frame_start(out, TAG_DELTA);
    write_delta_payload(codec, out, scratch, round, base_round, digest, idx, val_scratch);
    frame_finish(out);
}

/// Apply a decoded `Delta` in place, updating the running content digest
/// incrementally (O(|delta|), no dense pass). Every index is
/// bounds-checked **before** any parameter mutates, so a malformed or
/// adversarial frame cannot corrupt worker state — it returns an error
/// with the params untouched. Returns the digest after the apply; the
/// caller compares it against the frame's `digest` field and treats a
/// mismatch as divergence (bail -> stream death -> full-model resync via
/// the rejoin path — deterministic fallback, never silent drift).
pub fn apply_delta_in_place(
    params: &mut [f32],
    mut digest: u64,
    delta: &SparseVec,
) -> Result<u64> {
    for &i in &delta.idx {
        if i as usize >= params.len() {
            bail!("delta index {i} out of range (d = {})", params.len());
        }
    }
    for (&i, &v) in delta.idx.iter().zip(&delta.val) {
        let i = i as usize;
        digest = digest
            .wrapping_sub(crate::fl::codec::digest_term(i, params[i]))
            .wrapping_add(crate::fl::codec::digest_term(i, v));
        params[i] = v;
    }
    Ok(digest)
}

/// Encode a `Model` broadcast frame straight from a parameter slice into
/// a reusable buffer — byte-identical to `Msg::Model { round, params }
/// .encode(codec)` for every codec, without materializing the
/// intermediate d-vector copy. The PS encodes **one** such frame per
/// round and writes the same bytes to every cohort stream (the zero-copy
/// broadcast); pinned byte-identical by `model_frame_helper_matches_encode`.
pub fn encode_model_frame_into(round: u32, params: &[f32], out: &mut Vec<u8>) {
    // clear before reserving: `reserve` is relative to the current
    // length, and a buffer still holding last round's frame would
    // otherwise double its capacity on every reuse
    out.clear();
    out.reserve(model_frame_bytes(params.len()));
    frame_start(out, TAG_MODEL);
    write_model_payload(out, round, params);
    frame_finish(out);
}

/// Allocating convenience over [`encode_model_frame_into`].
pub fn encode_model_frame(round: u32, params: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    encode_model_frame_into(round, params, &mut out);
    out
}

/// Decode a `Model` payload (tag + body) straight into a reused parameter
/// buffer, returning the round — the worker hot loop's allocation-free
/// path for the biggest frame of every round.
pub fn decode_model_into(tagged: &[u8], params: &mut Vec<f32>) -> Result<u32> {
    if tagged.first() != Some(&TAG_MODEL) {
        bail!("not a Model frame");
    }
    let mut d = Dec::new(&tagged[1..]);
    let round = d.u32()?;
    let n = d.u32()? as usize;
    d.f32s_bulk_into(n, params)?;
    d.done()?;
    Ok(round)
}

// ------------------------------------------------------------ TCP plumbing

/// Write one message to a TCP stream (allocating; joins, shutdowns,
/// tests — the round hot path uses [`send_frame`]).
pub fn send(stream: &mut TcpStream, msg: &Msg, codec: Codec) -> Result<()> {
    stream.write_all(&msg.encode(codec)).context("send frame")
}

/// Read one message from a TCP stream (allocating; see [`recv_frame`]).
pub fn recv(stream: &mut TcpStream, codec: Codec) -> Result<Msg> {
    let mut fb = FrameBuf::new();
    recv_frame(stream, codec, &mut fb)
}

/// Encode one message into the stream's reused [`FrameBuf`] without
/// writing it — the reactor path queues `fb.buf` behind a [`SendCursor`]
/// instead of blocking on `write_all`. Returns the frame's wire size.
/// Steady-state encodes allocate nothing (capacity growth is tracked by
/// the buffer's growth counter).
pub fn encode_frame_into(msg: &Msg, codec: Codec, fb: &mut FrameBuf) -> usize {
    let (bc, pc) = (fb.buf.capacity(), fb.payload.capacity());
    msg.encode_into(codec, &mut fb.buf, &mut fb.scratch);
    fb.note_growth(bc, pc);
    fb.buf.len()
}

/// Encode a `Request` frame from a borrowed index slice into the
/// stream's [`FrameBuf`] without writing it (the reactor's exchange
/// phase); byte-identical to `Msg::Request { .. }` encoding. Returns the
/// wire size.
pub fn encode_request_into(
    codec: Codec,
    fb: &mut FrameBuf,
    round: u32,
    indices: &[u32],
) -> usize {
    let (bc, pc) = (fb.buf.capacity(), fb.payload.capacity());
    frame_start(&mut fb.buf, 4); // Msg::Request's tag
    write_request_payload(codec, &mut fb.buf, &mut fb.scratch, round, indices);
    frame_finish(&mut fb.buf);
    fb.note_growth(bc, pc);
    fb.buf.len()
}

/// Write one message through the stream's reused [`FrameBuf`]; returns
/// the frame's wire size. Steady-state sends allocate nothing.
pub fn send_frame(
    stream: &mut TcpStream,
    msg: &Msg,
    codec: Codec,
    fb: &mut FrameBuf,
) -> Result<usize> {
    let n = encode_frame_into(msg, codec, fb);
    stream.write_all(&fb.buf).context("send frame")?;
    Ok(n)
}

/// Encode a `Report` frame from borrowed parts through the stream's
/// [`FrameBuf`] — the worker's per-round hot path, avoiding the r-entry
/// report clone a `Msg::Report` would need; returns the wire size.
pub fn send_report(
    stream: &mut TcpStream,
    codec: Codec,
    fb: &mut FrameBuf,
    client_id: u32,
    round: u32,
    report: &SparseVec,
    mean_loss: f32,
) -> Result<usize> {
    let (bc, pc) = (fb.buf.capacity(), fb.payload.capacity());
    frame_start(&mut fb.buf, 3); // Msg::Report's tag
    write_report_payload(
        codec,
        &mut fb.buf,
        &mut fb.scratch,
        client_id,
        round,
        &report.idx,
        &report.val,
        mean_loss,
    );
    frame_finish(&mut fb.buf);
    fb.note_growth(bc, pc);
    stream.write_all(&fb.buf).context("send report frame")?;
    Ok(fb.buf.len())
}

/// Encode a `Request` frame from a borrowed index slice through the
/// stream's [`FrameBuf`] (the PS's per-stream hot path); returns the wire
/// size.
pub fn send_request(
    stream: &mut TcpStream,
    codec: Codec,
    fb: &mut FrameBuf,
    round: u32,
    indices: &[u32],
) -> Result<usize> {
    let n = encode_request_into(codec, fb, round, indices);
    stream.write_all(&fb.buf).context("send request frame")?;
    Ok(n)
}

/// Read one frame's payload (tag + body) into the stream's reused
/// [`FrameBuf`]; steady-state receives allocate nothing. The worker hot
/// loop peeks at the tag to route `Model` frames into
/// [`decode_model_into`] without building a `Msg`.
pub fn recv_payload<'a>(stream: &mut TcpStream, fb: &'a mut FrameBuf) -> Result<&'a [u8]> {
    let mut header = [0u8; 8];
    stream.read_exact(&mut header).context("recv header")?;
    let len = parse_frame_header(&header)?;
    let (bc, pc) = (fb.buf.capacity(), fb.payload.capacity());
    fb.payload.resize(len, 0);
    fb.note_growth(bc, pc);
    stream.read_exact(&mut fb.payload).context("recv payload")?;
    fb.set_last_recv(8 + len);
    Ok(&fb.payload)
}

/// Validate a frame header — magic then length plausibility — and return
/// the payload length. The single definition shared by the blocking
/// ([`recv_payload`]) and resumable ([`RecvCursor`]) receive paths, so
/// both reject exactly the same garbage; fixed-index reads off the
/// `[u8; 8]` keep it free of fallible slice conversions (the protocol
/// edge is a no-panic zone, enforced by `cargo run -p analyze`).
pub fn parse_frame_header(hdr: &[u8; 8]) -> Result<usize> {
    let magic = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]);
    if magic != MAGIC {
        bail!("bad magic {magic:#x}");
    }
    let len = u32::from_le_bytes([hdr[4], hdr[5], hdr[6], hdr[7]]) as usize;
    if len == 0 || len > 512 << 20 {
        bail!("implausible frame length {len}");
    }
    Ok(len)
}

/// Read one message through the stream's reused [`FrameBuf`].
pub fn recv_frame(stream: &mut TcpStream, codec: Codec, fb: &mut FrameBuf) -> Result<Msg> {
    let payload = recv_payload(stream, fb)?;
    Msg::decode(payload, codec)
}

// --------------------------------------------------- resumable framing
//
// The blocking helpers above drive a frame to completion in one call;
// the PS reactor (`fl::distributed`) instead runs its sockets in
// nonblocking mode and resumes each half-done frame whenever `poll(2)`
// reports readiness. The two cursors below hold exactly the state a
// partial transfer needs — the write offset, or the header-so-far plus
// the payload fill level — and produce/consume **byte-identical frames**
// to the blocking path (pinned one byte at a time by the torture tests
// below for every message variant in every codec). They are generic
// over `Read`/`Write` so tests can starve them through 1-byte mock
// sockets; on a nonblocking `TcpStream`, `WouldBlock` maps to
// [`IoStep::Pending`].

/// Outcome of one cursor resumption.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoStep {
    /// The frame completed (the cursor has reset itself for the next
    /// frame).
    Done,
    /// The transport would block; re-arm in the readiness loop and call
    /// `advance` again when the socket is ready.
    Pending,
}

/// Resumable frame write: tracks how many bytes of the queued frame have
/// reached the socket. One cursor per connection, reused across frames.
#[derive(Debug, Default)]
pub struct SendCursor {
    off: usize,
}

impl SendCursor {
    pub fn new() -> Self {
        SendCursor::default()
    }

    /// Forget any partial progress (re-arming a connection for a new
    /// frame after completion does this implicitly — `advance` resets on
    /// [`IoStep::Done`]).
    pub fn reset(&mut self) {
        self.off = 0;
    }

    /// Push more of `frame` into `w`. Returns [`IoStep::Done`] once the
    /// last byte is written (resetting the cursor), [`IoStep::Pending`]
    /// on `WouldBlock`. A peer that closes mid-frame is an error — the
    /// caller logs the casualty and drops the connection.
    pub fn advance(&mut self, w: &mut impl Write, frame: &[u8]) -> Result<IoStep> {
        while self.off < frame.len() {
            match w.write(&frame[self.off..]) {
                Ok(0) => bail!(
                    "connection closed mid-frame ({} of {} bytes written)",
                    self.off,
                    frame.len()
                ),
                Ok(n) => self.off += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return Ok(IoStep::Pending)
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("write frame"),
            }
        }
        self.off = 0;
        Ok(IoStep::Done)
    }
}

/// Resumable frame read: accumulates the 8-byte header, validates it,
/// then fills the frame's payload into the connection's [`FrameBuf`] —
/// across as many `advance` calls as readiness allows. On
/// [`IoStep::Done`] the payload (tag + body) sits in `fb.payload`,
/// exactly as [`recv_payload`] would have left it, and the cursor has
/// reset itself for the next frame.
#[derive(Debug, Default)]
pub struct RecvCursor {
    hdr: [u8; 8],
    hdr_got: usize,
    /// payload length from the validated header; 0 = header not yet
    /// complete (a zero-length payload is rejected as implausible, so 0
    /// is unambiguous as a sentinel)
    need: usize,
    got: usize,
}

impl RecvCursor {
    pub fn new() -> Self {
        RecvCursor::default()
    }

    /// Forget any partial frame (used when a connection is re-armed
    /// after an error; normal completion resets implicitly).
    pub fn reset(&mut self) {
        self.hdr_got = 0;
        self.need = 0;
        self.got = 0;
    }

    /// Pull more of the current frame out of `r`. EOF anywhere — before
    /// the header (a vanished peer) or mid-frame — is an error; a bad
    /// magic or implausible length fails exactly like the blocking
    /// [`recv_payload`] path.
    pub fn advance(&mut self, r: &mut impl Read, fb: &mut FrameBuf) -> Result<IoStep> {
        while self.hdr_got < 8 {
            match r.read(&mut self.hdr[self.hdr_got..]) {
                Ok(0) => {
                    if self.hdr_got == 0 {
                        bail!("connection closed");
                    }
                    bail!("connection closed mid-header ({} of 8 bytes)", self.hdr_got);
                }
                Ok(n) => self.hdr_got += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return Ok(IoStep::Pending)
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("recv header"),
            }
        }
        if self.need == 0 {
            let len = parse_frame_header(&self.hdr)?;
            let (bc, pc) = (fb.buf.capacity(), fb.payload.capacity());
            fb.payload.resize(len, 0);
            fb.note_growth(bc, pc);
            self.need = len;
            self.got = 0;
        }
        while self.got < self.need {
            match r.read(&mut fb.payload[self.got..self.need]) {
                Ok(0) => bail!(
                    "connection closed mid-frame ({} of {} payload bytes)",
                    self.got,
                    self.need
                ),
                Ok(n) => self.got += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return Ok(IoStep::Pending)
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("recv payload"),
            }
        }
        fb.set_last_recv(8 + self.need);
        self.reset();
        Ok(IoStep::Done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [Codec; 3] = [Codec::Raw, Codec::Packed, Codec::PackedF16];

    fn roundtrip(m: Msg, codec: Codec) {
        let frame = m.encode(codec);
        assert_eq!(&frame[0..4], &MAGIC.to_le_bytes());
        let len = u32::from_le_bytes(frame[4..8].try_into().unwrap()) as usize;
        assert_eq!(len, frame.len() - 8);
        let back = Msg::decode(&frame[8..], codec).unwrap();
        assert_eq!(m, back, "codec {codec:?}");
    }

    #[test]
    fn all_messages_roundtrip_raw() {
        roundtrip(Msg::Join { client_id: 3, codec: Codec::Raw }, Codec::Raw);
        roundtrip(
            Msg::Rejoin { client_id: 2, generation: 4, held_digest: 0xDEAD_BEEF, codec: Codec::Raw },
            Codec::Raw,
        );
        roundtrip(Msg::Model { round: 7, params: vec![1.0, -2.5, 3.25] }, Codec::Raw);
        roundtrip(
            Msg::Delta {
                round: 8,
                base_round: 5,
                digest: u64::MAX - 3,
                delta: SparseVec::new(vec![4, 9000, 7], vec![0.5, -1.25, 1e-9]),
            },
            Codec::Raw,
        );
        roundtrip(
            Msg::Report {
                client_id: 1,
                round: 2,
                report: SparseVec::new(vec![5, 900, 39000], vec![0.5, -0.25, 1e-9]),
                mean_loss: 2.25,
            },
            Codec::Raw,
        );
        roundtrip(Msg::Request { round: 9, indices: vec![1, 2, 3] }, Codec::Raw);
        roundtrip(
            Msg::Update { client_id: 0, round: 1, update: SparseVec::new(vec![], vec![]) },
            Codec::Raw,
        );
        roundtrip(Msg::Shutdown, Codec::Raw);
        roundtrip(Msg::Sit { round: 11 }, Codec::Raw);
    }

    #[test]
    fn all_messages_roundtrip_packed() {
        for codec in [Codec::Packed, Codec::PackedF16] {
            // Join carries the *worker's* codec field under any frame codec
            roundtrip(Msg::Join { client_id: 3, codec: Codec::PackedF16 }, codec);
            roundtrip(
                Msg::Rejoin { client_id: 1, generation: 1, held_digest: 7, codec: Codec::Packed },
                codec,
            );
            roundtrip(Msg::Model { round: 7, params: vec![1.0, -2.5, 3.25] }, codec);
            // Delta values stay f32 (lossless) even under packed-f16:
            // model state bit-exactness is what the digest certifies
            roundtrip(
                Msg::Delta {
                    round: 3,
                    base_round: 1,
                    digest: 42,
                    delta: SparseVec::new(vec![39000, 5, 900], vec![1e-9, -2.5, 3.25]),
                },
                codec,
            );
            // report values are not transmitted: they decode as zeros
            let m = Msg::Report {
                client_id: 1,
                round: 2,
                report: SparseVec::new(vec![39000, 5, 900], vec![0.5, -0.25, 1e-9]),
                mean_loss: 2.25,
            };
            let back = Msg::decode(&m.encode(codec)[8..], codec).unwrap();
            match back {
                Msg::Report { client_id: 1, round: 2, report, mean_loss } => {
                    assert_eq!(report.idx, vec![39000, 5, 900], "order must survive");
                    assert_eq!(report.val, vec![0.0; 3]);
                    assert_eq!(mean_loss, 2.25);
                }
                other => panic!("bad decode: {other:?}"),
            }
            // request order survives the sorted encoding
            roundtrip(Msg::Request { round: 9, indices: vec![30, 1, 2000, 2] }, codec);
            roundtrip(Msg::Request { round: 9, indices: vec![] }, codec);
            roundtrip(
                Msg::Update { client_id: 0, round: 1, update: SparseVec::new(vec![], vec![]) },
                codec,
            );
            roundtrip(Msg::Shutdown, codec);
            roundtrip(Msg::Sit { round: 11 }, codec);
        }
        // lossless packed: update values bit-exact
        roundtrip(
            Msg::Update {
                client_id: 4,
                round: 6,
                update: SparseVec::new(vec![80, 4, 15], vec![1e-9, -2.5, 3.25]),
            },
            Codec::Packed,
        );
    }

    #[test]
    fn packed_f16_update_values_round_within_tolerance() {
        let vals = vec![0.5f32, -0.125, 3.0e3, -2.0e-3];
        let m = Msg::Update {
            client_id: 0,
            round: 1,
            update: SparseVec::new(vec![9, 2, 77, 5], vals.clone()),
        };
        let back = Msg::decode(&m.encode(Codec::PackedF16)[8..], Codec::PackedF16).unwrap();
        match back {
            Msg::Update { update, .. } => {
                assert_eq!(update.idx, vec![9, 2, 77, 5], "indices stay lossless");
                for (&x, &y) in vals.iter().zip(&update.val) {
                    assert!((x - y).abs() <= x.abs() * 2.0f32.powi(-11), "{x} -> {y}");
                }
            }
            other => panic!("bad decode: {other:?}"),
        }
    }

    /// One frame of every variant (empty and non-empty payloads where it
    /// matters): the arithmetic size must equal the encoded length, in
    /// every codec.
    fn every_variant() -> Vec<Msg> {
        vec![
            Msg::Join { client_id: 3, codec: Codec::Packed },
            Msg::Rejoin { client_id: 3, generation: 2, held_digest: 1, codec: Codec::Packed },
            Msg::Model { round: 7, params: vec![] },
            Msg::Model { round: 7, params: vec![1.0, -2.5, 3.25] },
            Msg::Delta {
                round: 6,
                base_round: 2,
                digest: 99,
                delta: SparseVec::new(vec![10, 11, 900], vec![0.5, -0.5, 2.0]),
            },
            Msg::Delta { round: 6, base_round: 5, digest: 0, delta: SparseVec::default() },
            Msg::Report {
                client_id: 1,
                round: 2,
                report: SparseVec::new(vec![900, 5], vec![0.5, -0.25]),
                mean_loss: 2.25,
            },
            Msg::Report {
                client_id: 1,
                round: 2,
                report: SparseVec::new(vec![], vec![]),
                mean_loss: 0.5,
            },
            Msg::Request { round: 9, indices: vec![1, 200_000, 3] },
            Msg::Request { round: 9, indices: vec![] },
            Msg::Update {
                client_id: 0,
                round: 1,
                update: SparseVec::new(vec![4, 8, 15], vec![0.1, 0.2, 0.3]),
            },
            Msg::Update { client_id: 0, round: 1, update: SparseVec::new(vec![], vec![]) },
            Msg::Shutdown,
            Msg::Sit { round: 4 },
        ]
    }

    #[test]
    fn wire_bytes_never_encodes() {
        for codec in ALL {
            for m in every_variant() {
                assert_eq!(m.wire_bytes(codec), m.encode(codec).len(), "{codec:?} {m:?}");
            }
        }
    }

    #[test]
    fn frame_size_helpers_match_wire_bytes() {
        let idx = vec![40u32, 4, 400, 44];
        let val = vec![1.0f32; 4];
        for codec in ALL {
            let report = Msg::Report {
                client_id: 0,
                round: 0,
                report: SparseVec::new(idx.clone(), val.clone()),
                mean_loss: 0.0,
            };
            assert_eq!(report.wire_bytes(codec), report_frame_bytes(codec, &idx));
            let req = Msg::Request { round: 0, indices: idx.clone() };
            assert_eq!(req.wire_bytes(codec), request_frame_bytes(codec, &idx));
            let up = Msg::Update {
                client_id: 0,
                round: 0,
                update: SparseVec::new(idx.clone(), val.clone()),
            };
            assert_eq!(up.wire_bytes(codec), update_frame_bytes(codec, &idx));
            let delta = Msg::Delta {
                round: 2,
                base_round: 1,
                digest: 5,
                delta: SparseVec::new(idx.clone(), val.clone()),
            };
            assert_eq!(delta.wire_bytes(codec), delta_frame_bytes(codec, &idx));
        }
        let model = Msg::Model { round: 0, params: vec![0.0; 9] };
        assert_eq!(model.wire_bytes(Codec::Raw), model_frame_bytes(9));
        assert_eq!(Msg::Sit { round: 0 }.wire_bytes(Codec::Packed), SIT_FRAME_BYTES);
    }

    #[test]
    fn packed_shrinks_sparse_frames() {
        // a report-shaped index set: top-75 of d = 39760, arbitrary order
        let idx: Vec<u32> = (0..75u32).map(|i| (i * 523 + 17 * (i % 7)) % 39760).collect();
        let val = vec![1.0f32; idx.len()];
        let m = Msg::Report {
            client_id: 0,
            round: 0,
            report: SparseVec::new(idx.clone(), val),
            mean_loss: 0.0,
        };
        let raw = m.wire_bytes(Codec::Raw);
        let packed = m.wire_bytes(Codec::Packed);
        assert!(
            packed * 2 <= raw,
            "packed report must at least halve the raw frame: {packed} vs {raw}"
        );
        let up = Msg::Update {
            client_id: 0,
            round: 0,
            update: SparseVec::new(idx[..10].to_vec(), vec![1.0; 10]),
        };
        assert!(up.wire_bytes(Codec::Packed) < up.wire_bytes(Codec::Raw));
        assert!(up.wire_bytes(Codec::PackedF16) < up.wire_bytes(Codec::Packed));
    }

    #[test]
    fn delta_shrinks_the_downlink() {
        // the standard-scenario shape: |union| <= n*k = 80 changed
        // indices out of d = 39760
        let idx: Vec<u32> = (0..80u32).map(|i| (i * 523 + 17 * (i % 7)) % 39760).collect();
        let dense = model_frame_bytes(39760);
        for codec in ALL {
            let sparse = delta_frame_bytes(codec, &idx);
            assert!(
                sparse * 100 <= dense,
                "delta must be >= 100x smaller than the dense frame: {sparse} vs {dense}"
            );
        }
    }

    #[test]
    fn delta_frame_helper_matches_encode() {
        let global: Vec<f32> = (0..200).map(|i| (i as f32).sin()).collect();
        for codec in ALL {
            for idx in [vec![], vec![7u32], vec![199, 0, 42, 43]] {
                let val: Vec<f32> = idx.iter().map(|&i| global[i as usize]).collect();
                let via_msg = Msg::Delta {
                    round: 9,
                    base_round: 6,
                    digest: 0x1234_5678_9abc_def0,
                    delta: SparseVec::new(idx.clone(), val),
                }
                .encode(codec);
                let mut out = Vec::new();
                let mut vals = Vec::new();
                let mut scratch = IndexScratch::default();
                encode_delta_frame_into(
                    codec,
                    9,
                    6,
                    0x1234_5678_9abc_def0,
                    &idx,
                    &global,
                    &mut out,
                    &mut vals,
                    &mut scratch,
                );
                assert_eq!(out, via_msg, "{codec:?} {idx:?}");
            }
        }
    }

    #[test]
    fn apply_delta_updates_digest_incrementally() {
        use crate::fl::codec::params_digest;
        let mut params: Vec<f32> = (0..50).map(|i| i as f32 * 0.25).collect();
        let digest = params_digest(&params);
        let delta = SparseVec::new(vec![3, 49, 0], vec![-1.0, 2.5, 0.125]);
        let new_digest = apply_delta_in_place(&mut params, digest, &delta).unwrap();
        assert_eq!(params[3], -1.0);
        assert_eq!(params[49], 2.5);
        assert_eq!(params[0], 0.125);
        assert_eq!(new_digest, params_digest(&params), "incremental == recomputed");
        // an empty delta is the no-op identity
        let same = apply_delta_in_place(&mut params, new_digest, &SparseVec::default()).unwrap();
        assert_eq!(same, new_digest);
    }

    #[test]
    fn apply_delta_rejects_out_of_range_without_mutating() {
        let before: Vec<f32> = vec![1.0, 2.0, 3.0];
        let mut params = before.clone();
        // in-range prefix, out-of-range tail: nothing may be written
        let delta = SparseVec::new(vec![0, 1, 3], vec![9.0, 9.0, 9.0]);
        assert!(apply_delta_in_place(&mut params, 0, &delta).is_err());
        assert_eq!(params, before, "params must be untouched on rejection");
    }

    #[test]
    fn model_frame_helper_matches_encode() {
        for params in [vec![], vec![0.5f32], vec![1.0, -2.5, f32::MIN_POSITIVE, 0.0]] {
            for codec in ALL {
                let via_msg = Msg::Model { round: 3, params: params.clone() }.encode(codec);
                assert_eq!(encode_model_frame(3, &params), via_msg, "{codec:?}");
            }
        }
    }

    #[test]
    fn decode_model_into_reuses_buffer() {
        let params = vec![0.25f32; 100];
        let frame = encode_model_frame(9, &params);
        let mut buf = Vec::new();
        assert_eq!(decode_model_into(&frame[8..], &mut buf).unwrap(), 9);
        assert_eq!(buf, params);
        let cap = buf.capacity();
        // a second same-shape decode must not reallocate
        assert_eq!(decode_model_into(&frame[8..], &mut buf).unwrap(), 9);
        assert_eq!(buf.capacity(), cap);
        // non-model frames are refused
        let sit = Msg::Sit { round: 1 }.encode(Codec::Raw);
        assert!(decode_model_into(&sit[8..], &mut buf).is_err());
    }

    #[test]
    fn rejects_corrupt_frames() {
        for codec in ALL {
            assert!(Msg::decode(&[], codec).is_err());
            assert!(Msg::decode(&[99], codec).is_err());
            // truncated body
            let frame = Msg::Request { round: 1, indices: vec![1, 2, 3] }.encode(codec);
            assert!(Msg::decode(&frame[8..frame.len() - 2], codec).is_err());
            // trailing garbage
            let mut long = frame[8..].to_vec();
            long.push(0);
            assert!(Msg::decode(&long, codec).is_err());
        }
        // unknown codec byte in a Join
        let mut join = Msg::Join { client_id: 0, codec: Codec::Raw }.encode(Codec::Raw);
        let n = join.len();
        join[n - 1] = 77;
        assert!(Msg::decode(&join[8..], Codec::Raw).is_err());
        // wrong protocol version in a Join/Rejoin is refused by name —
        // both a future version and a v3 peer (which predates the Delta
        // downlink and the Rejoin held-digest field)
        for msg in [
            Msg::Join { client_id: 0, codec: Codec::Raw },
            Msg::Rejoin { client_id: 0, generation: 1, held_digest: 0, codec: Codec::Raw },
        ] {
            for wrong in [PROTOCOL_VERSION + 1, PROTOCOL_VERSION - 1] {
                let mut frame = msg.encode(Codec::Raw);
                let n = frame.len();
                frame[n - 2] = wrong; // the version byte
                let err = Msg::decode(&frame[8..], Codec::Raw).unwrap_err();
                assert!(format!("{err:#}").contains("protocol version"), "{err:#}");
            }
        }
        // packed update whose value block is truncated
        let up = Msg::Update {
            client_id: 0,
            round: 1,
            update: SparseVec::new(vec![1, 2], vec![1.0, 2.0]),
        };
        let frame = up.encode(Codec::Packed);
        assert!(Msg::decode(&frame[8..frame.len() - 3], Codec::Packed).is_err());
    }

    #[test]
    fn tcp_roundtrip_all_codecs() {
        use std::net::TcpListener;
        for codec in ALL {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let handle = std::thread::spawn(move || {
                let (mut s, _) = listener.accept().unwrap();
                let mut fb = FrameBuf::new();
                let m = recv_frame(&mut s, codec, &mut fb).unwrap();
                send_frame(&mut s, &m, codec, &mut fb).unwrap(); // echo
            });
            let mut stream = TcpStream::connect(addr).unwrap();
            let msg = Msg::Model { round: 5, params: vec![0.5; 1000] };
            send(&mut stream, &msg, codec).unwrap();
            let back = recv(&mut stream, codec).unwrap();
            assert_eq!(msg, back);
            handle.join().unwrap();
        }
    }

    #[test]
    fn frame_buf_stops_growing_in_steady_state() {
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let codec = Codec::Packed;
        let rounds = 8u32;
        let handle = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut fb = FrameBuf::new();
            let mut grows_after_round = Vec::new();
            for _ in 0..rounds {
                let m = recv_frame(&mut s, codec, &mut fb).unwrap();
                send_frame(&mut s, &m, codec, &mut fb).unwrap();
                grows_after_round.push(fb.grows());
            }
            grows_after_round
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut fb = FrameBuf::new();
        for round in 0..rounds {
            let msg = Msg::Update {
                client_id: 1,
                round,
                update: SparseVec::new(
                    (0..20u32).map(|i| (i * 317 + round * 7) % 39760).collect(),
                    vec![0.5; 20],
                ),
            };
            send_frame(&mut stream, &msg, codec, &mut fb).unwrap();
            let back = recv_frame(&mut stream, codec, &mut fb).unwrap();
            assert_eq!(msg, back);
        }
        let grows = handle.join().unwrap();
        // all buffer growth happens in the first rounds; after the
        // high-water mark every send/recv reuses capacity exactly
        assert_eq!(grows[2], *grows.last().unwrap(), "no growth after round 3: {grows:?}");
    }

    #[test]
    fn send_helpers_match_generic_encoding() {
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let codec = Codec::Packed;
        let report = SparseVec::new(vec![500, 2, 39000], vec![1.5, -0.5, 0.25]);
        let rep2 = report.clone();
        let handle = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut fb = FrameBuf::new();
            let n = send_report(&mut s, codec, &mut fb, 7, 3, &rep2, 1.25).unwrap();
            assert_eq!(n, report_frame_bytes(codec, &rep2.idx));
            let n = send_request(&mut s, codec, &mut fb, 3, &[9, 1, 4]).unwrap();
            assert_eq!(n, request_frame_bytes(codec, &[9, 1, 4]));
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let got = recv(&mut stream, codec).unwrap();
        let want = Msg::Report { client_id: 7, round: 3, report, mean_loss: 1.25 };
        // packed report values are zeroed on decode; compare the rest
        match (got, want) {
            (
                Msg::Report { client_id: a, round: b, report: r1, mean_loss: l1 },
                Msg::Report { client_id: c, round: d, report: r2, mean_loss: l2 },
            ) => {
                assert_eq!((a, b, l1), (c, d, l2));
                assert_eq!(r1.idx, r2.idx);
            }
            other => panic!("bad frames: {other:?}"),
        }
        assert_eq!(
            recv(&mut stream, codec).unwrap(),
            Msg::Request { round: 3, indices: vec![9, 1, 4] }
        );
        handle.join().unwrap();
    }

    // ---------------------------------------- resumable-framing torture
    //
    // The reactor path must produce/consume byte-identical frames to the
    // blocking path under arbitrarily hostile readiness: here every Msg
    // variant crosses a mock socket one byte at a time, with a WouldBlock
    // between every byte, in all three codecs.

    /// Reads at most one byte per call, returning `WouldBlock` before
    /// every byte — the worst-case readiness schedule.
    struct TrickleReader<'a> {
        data: &'a [u8],
        pos: usize,
        starved: bool,
    }

    impl std::io::Read for TrickleReader<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if !self.starved {
                self.starved = true;
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            self.starved = false;
            if self.pos >= self.data.len() {
                return Ok(0); // EOF
            }
            if buf.is_empty() {
                return Ok(0);
            }
            buf[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    /// Accepts at most one byte per call, with a WouldBlock before every
    /// byte — the 1-byte-capacity mock socket of the send torture.
    struct TrickleWriter {
        out: Vec<u8>,
        starved: bool,
    }

    impl std::io::Write for TrickleWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if !self.starved {
                self.starved = true;
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            self.starved = false;
            if buf.is_empty() {
                return Ok(0);
            }
            self.out.push(buf[0]);
            Ok(1)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    /// Drive a cursor until `Done`, re-calling on every `Pending` like
    /// the reactor does when `poll` reports readiness again.
    fn pump(mut step: impl FnMut() -> Result<IoStep>) -> Result<usize> {
        let mut pendings = 0;
        loop {
            match step()? {
                IoStep::Done => return Ok(pendings),
                IoStep::Pending => pendings += 1,
            }
        }
    }

    #[test]
    fn recv_cursor_byte_at_a_time_matches_blocking_decode() {
        for codec in ALL {
            for m in every_variant() {
                let frame = m.encode(codec);
                let mut r = TrickleReader { data: &frame, pos: 0, starved: false };
                let mut fb = FrameBuf::new();
                let mut cur = RecvCursor::new();
                let pendings =
                    pump(|| cur.advance(&mut r, &mut fb)).unwrap();
                // one yield per byte: the cursor resumed across every
                // single split point of the frame
                assert_eq!(pendings, frame.len(), "{codec:?} {m:?}");
                assert_eq!(&fb.payload[..], &frame[8..], "payload must be byte-identical");
                assert_eq!(fb.last_recv_frame_len(), frame.len());
                // and it decodes to exactly what the blocking path sees
                let blocking = Msg::decode(&frame[8..], codec).unwrap();
                let nonblocking = Msg::decode(&fb.payload, codec).unwrap();
                assert_eq!(nonblocking, blocking, "{codec:?}");
            }
        }
    }

    #[test]
    fn recv_cursor_handles_back_to_back_frames_with_one_buffer() {
        // steady-state reuse across frames of different sizes: the cursor
        // self-resets on Done and the FrameBuf stops growing once the
        // high-water mark is set
        let codec = Codec::Packed;
        let frames: Vec<Vec<u8>> = every_variant().iter().map(|m| m.encode(codec)).collect();
        let all: Vec<u8> = frames.iter().flatten().copied().collect();
        let mut r = TrickleReader { data: &all, pos: 0, starved: false };
        let mut fb = FrameBuf::new();
        let mut cur = RecvCursor::new();
        for frame in &frames {
            pump(|| cur.advance(&mut r, &mut fb)).unwrap();
            assert_eq!(&fb.payload[..], &frame[8..]);
        }
        // nothing left: the next advance sees a clean EOF
        let err = pump(|| cur.advance(&mut r, &mut fb)).unwrap_err();
        assert!(format!("{err:#}").contains("connection closed"), "{err:#}");
    }

    #[test]
    fn send_cursor_through_one_byte_socket_is_byte_identical() {
        for codec in ALL {
            for m in every_variant() {
                let frame = m.encode(codec);
                let mut w = TrickleWriter { out: Vec::new(), starved: false };
                let mut cur = SendCursor::new();
                let pendings = pump(|| cur.advance(&mut w, &frame)).unwrap();
                assert_eq!(pendings, frame.len(), "one yield per byte, {codec:?} {m:?}");
                assert_eq!(w.out, frame, "the wire bytes must match the blocking write_all");
            }
        }
    }

    #[test]
    fn send_cursor_reports_peer_close_mid_frame() {
        /// accepts 3 bytes, then behaves like a closed socket
        struct Closing(usize);
        impl std::io::Write for Closing {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                if self.0 == 0 || buf.is_empty() {
                    return Ok(0);
                }
                self.0 -= 1;
                Ok(1)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let frame = Msg::Sit { round: 1 }.encode(Codec::Raw);
        let mut cur = SendCursor::new();
        let err = cur.advance(&mut Closing(3), &frame).unwrap_err();
        assert!(format!("{err:#}").contains("3 of 13 bytes"), "{err:#}");
    }

    #[test]
    fn recv_cursor_rejects_corruption_like_the_blocking_path() {
        // bad magic
        let mut frame = Msg::Sit { round: 1 }.encode(Codec::Raw);
        frame[0] ^= 0xFF;
        let mut r = TrickleReader { data: &frame, pos: 0, starved: false };
        let mut fb = FrameBuf::new();
        let mut cur = RecvCursor::new();
        let err = pump(|| cur.advance(&mut r, &mut fb)).unwrap_err();
        assert!(format!("{err:#}").contains("bad magic"), "{err:#}");
        // implausible length
        let mut frame = Msg::Sit { round: 1 }.encode(Codec::Raw);
        frame[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut r = TrickleReader { data: &frame, pos: 0, starved: false };
        let mut cur = RecvCursor::new();
        let err = pump(|| cur.advance(&mut r, &mut fb)).unwrap_err();
        assert!(format!("{err:#}").contains("implausible frame length"), "{err:#}");
        // EOF mid-payload names the fill level
        let frame = Msg::Request { round: 2, indices: vec![1, 2, 3] }.encode(Codec::Raw);
        let cut = &frame[..frame.len() - 2];
        let mut r = TrickleReader { data: cut, pos: 0, starved: false };
        let mut cur = RecvCursor::new();
        let err = pump(|| cur.advance(&mut r, &mut fb)).unwrap_err();
        assert!(format!("{err:#}").contains("mid-frame"), "{err:#}");
    }

    #[test]
    fn encode_helpers_match_generic_encoding_without_writing() {
        let codec = Codec::Packed;
        let mut fb = FrameBuf::new();
        let msg = Msg::Sit { round: 9 };
        let n = encode_frame_into(&msg, codec, &mut fb);
        assert_eq!(fb.buf, msg.encode(codec));
        assert_eq!(n, msg.wire_bytes(codec));
        let n = encode_request_into(codec, &mut fb, 3, &[9, 1, 4]);
        assert_eq!(fb.buf, Msg::Request { round: 3, indices: vec![9, 1, 4] }.encode(codec));
        assert_eq!(n, request_frame_bytes(codec, &[9, 1, 4]));
    }

    #[test]
    fn wire_bytes_accounting_matches_design() {
        // raw sparse update of k entries: 8k payload + 8 list headers
        let k = 10;
        let m = Msg::Update {
            client_id: 0,
            round: 0,
            update: SparseVec::new(vec![0; k], vec![0.0; k]),
        };
        // header(8) + tag(1) + client(4) + round(4) + 2 lens(8) + 8k
        assert_eq!(m.wire_bytes(Codec::Raw), 8 + 1 + 4 + 4 + 8 + 8 * k);
        // the Sit control frame is a fixed 13 bytes — cheap enough to keep
        // off-cohort workers in sync every round (DESIGN.md §6)
        assert_eq!(Msg::Sit { round: 1 }.wire_bytes(Codec::Raw), 8 + 1 + 4);
        assert_eq!(SIT_FRAME_BYTES, 13);
        // raw delta of k entries: header(9) + round(4) + base(4) +
        // digest(8) + idx list4 + 4k values (DESIGN.md §9)
        let d = Msg::Delta {
            round: 0,
            base_round: 0,
            digest: 0,
            delta: SparseVec::new(vec![0; k], vec![0.0; k]),
        };
        assert_eq!(d.wire_bytes(Codec::Raw), 9 + 4 + 4 + 8 + (4 + 4 * k) + 4 * k);
    }
}
