//! End-to-end global-round latency: the paper's full per-round protocol
//! (local training x N clients -> reports -> selection -> uploads ->
//! aggregation -> server apply -> age/frequency bookkeeping) with the
//! phase breakdown the perf pass optimizes against (EXPERIMENTS.md §Perf),
//! plus the parallel-vs-serial client pool comparison at n_clients = 8.

use ragek::bench::Bench;
use ragek::config::ExperimentConfig;
use ragek::coordinator::strategies::StrategyKind;
use ragek::fl::trainer::Trainer;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("end2end");

    for (tag, strategy) in [
        ("rAge-k ", StrategyKind::RageK),
        ("rTop-k ", StrategyKind::RTopK),
        ("dense  ", StrategyKind::Dense),
    ] {
        let mut cfg = ExperimentConfig::mnist_scaled();
        cfg.rounds = 1;
        cfg.train_n = 2000;
        cfg.test_n = 256;
        cfg.eval_every = 0;
        cfg.strategy = strategy;
        let mut t = Trainer::from_config(&cfg)?;
        b.run(&format!("global round {tag} (10 clients, H=4, b=256)"), || {
            t.run_round().unwrap();
        });
        if strategy == StrategyKind::RageK {
            println!("\nphase breakdown (rAge-k rounds):\n{}", t.profile().report());
        }
    }

    // the parallel in-process pool vs the serial simulator at 8 clients:
    // client rounds are embarrassingly parallel given the broadcast, so
    // wall-clock should approach serial / min(lanes, 8)
    for (tag, parallel) in [("serial (1 lane)   ", 1usize), ("parallel (auto)   ", 0usize)] {
        let mut cfg = ExperimentConfig::mnist_scaled();
        cfg.strategy = StrategyKind::RageK;
        cfg.n_clients = 8;
        cfg.parallel = parallel;
        cfg.rounds = 1;
        cfg.train_n = 2000;
        cfg.test_n = 256;
        cfg.eval_every = 0;
        let mut t = Trainer::from_config(&cfg)?;
        let lanes = t.pool().n_lanes();
        b.run(&format!("global round n=8 {tag} lanes={lanes}"), || {
            t.run_round().unwrap();
        });
    }

    // cohort scaling: wall-clock per round should track the cohort size,
    // not n — only ceil(p * n) clients train/upload per round
    for (tag, participation) in [
        ("p=1.0 (cohort 8)", 1.0f64),
        ("p=0.5 (cohort 4)", 0.5),
        ("p=0.25 (cohort 2)", 0.25),
    ] {
        let mut cfg = ExperimentConfig::mnist_scaled();
        cfg.strategy = StrategyKind::RageK;
        cfg.n_clients = 8;
        cfg.participation = participation;
        cfg.rounds = 1;
        cfg.train_n = 2000;
        cfg.test_n = 256;
        cfg.eval_every = 0;
        let mut t = Trainer::from_config(&cfg)?;
        b.run(&format!("global round n=8 {tag}"), || {
            t.run_round().unwrap();
        });
    }

    // regression check, not a timing: the engine's accounting must scale
    // broadcast_down with the cohort (m), never with n. (The TCP-side
    // zero-copy/Sit pins — model_encodes == rounds, wire broadcast bytes
    // — live in rust/tests/parity.rs, which runs real sockets.)
    {
        let rounds = 4usize;
        let mut cfg = ExperimentConfig::mnist_scaled();
        cfg.strategy = StrategyKind::RageK;
        cfg.n_clients = 8;
        cfg.participation = 0.5;
        cfg.rounds = rounds;
        cfg.train_n = 800;
        cfg.test_n = 128;
        cfg.eval_every = 0;
        let mut t = Trainer::from_config(&cfg)?;
        for _ in 0..rounds {
            t.run_round()?;
        }
        let (m, d) = (cfg.cohort_size() as u64, cfg.d() as u64);
        assert_eq!(m, 4);
        let comm = t.engine().comm();
        assert_eq!(
            comm.broadcast_down,
            rounds as u64 * m * 4 * d,
            "broadcast_down must scale with the cohort, not n"
        );
        println!(
            "cohort regression check OK: broadcast_down {} B over {rounds} rounds = {m}/8 of full",
            comm.broadcast_down
        );
    }

    // wire-codec regression check, not a timing: on the standard rAge-k
    // scenario the packed v2 codec must cut the actual uplink frame
    // bytes at least in half (the §6 protocol counters stay
    // codec-independent, so they must agree exactly across codecs)
    {
        use ragek::fl::codec::Codec;
        let run = |codec: Codec| -> ragek::fl::metrics::CommStats {
            let mut cfg = ExperimentConfig::mnist_scaled();
            cfg.strategy = StrategyKind::RageK;
            cfg.codec = codec;
            cfg.rounds = 2;
            cfg.train_n = 800;
            cfg.test_n = 128;
            cfg.eval_every = 0;
            let mut t = Trainer::from_config(&cfg).unwrap();
            for _ in 0..cfg.rounds {
                t.run_round().unwrap();
            }
            t.engine().comm()
        };
        let raw = run(Codec::Raw);
        let packed = run(Codec::Packed);
        assert_eq!(raw.uplink(), packed.uplink(), "§6 counters are codec-independent");
        assert_eq!(raw.downlink(), packed.downlink());
        let ratio = raw.wire_up as f64 / packed.wire_up as f64;
        assert!(
            ratio >= 2.0,
            "packed codec must at least halve uplink wire bytes (got {ratio:.2}x: {} -> {})",
            raw.wire_up,
            packed.wire_up
        );
        println!(
            "codec regression check OK: uplink {} B (raw) -> {} B (packed), {ratio:.2}x",
            raw.wire_up, packed.wire_up
        );
    }

    // hierarchical topology (DESIGN.md §7): sharded-vs-flat wall-clock.
    // The shared bench::sharding scenario runs one *serial* client lane
    // per shard, so the only parallelism left is the shard level itself —
    // drive_comparison asserts the threaded shard driver beats the serial
    // sum of the shard collects on a multi-core host.
    {
        use ragek::bench::sharding;
        let rounds = 3usize;
        let mut flat = Trainer::from_config(&sharding::scenario(0, rounds))?;
        let flat_wall = b
            .run_once(&format!("{rounds} rounds n=8 flat (serial lanes)"), || {
                for _ in 0..rounds {
                    flat.run_round().unwrap();
                }
            })
            .mean();
        let (serial_sum, parallel_wall, sharded_comm) =
            sharding::drive_comparison(&mut b, rounds)?;

        // bytes/round roll-up is topology-independent (the root <-> shard
        // hop is in-process): identical §6 counters flat vs sharded
        let flat_comm = flat.comm();
        assert_eq!(flat_comm.uplink(), sharded_comm.uplink(), "§7 roll-up: uplink mismatch");
        assert_eq!(flat_comm.downlink(), sharded_comm.downlink());
        assert_eq!(flat_comm.wire_up, sharded_comm.wire_up);
        assert_eq!(flat_comm.wire_down, sharded_comm.wire_down);
        println!(
            "sharding wall-clock: flat {flat_wall:.3}s, sharded x4 serial {serial_sum:.3}s, \
             sharded x4 parallel {parallel_wall:.3}s; bytes/round identical"
        );
    }

    // sharded wire pin over real sockets: the rolled-up wire accounting
    // must equal the bytes observed on the shard PS sockets
    {
        use ragek::clustering::MergeRule;
        use ragek::config::Payload;
        use ragek::coordinator::topology::Topology;
        let mut cfg = ExperimentConfig::mnist_smoke();
        cfg.n_clients = 4;
        cfg.payload = Payload::Delta;
        cfg.rounds = 2;
        cfg.train_n = 200;
        cfg.test_n = 64;
        cfg.eval_every = 0;
        cfg.topology = Topology::Sharded { shards: 2, root_merge: MergeRule::Min };
        let report = ragek::testing::run_distributed_localhost(&cfg)?;
        assert_eq!(
            report.comm.wire_up, report.wire_up_observed,
            "rolled-up uplink accounting must equal observed socket bytes"
        );
        assert_eq!(
            report.comm.wire_down, report.wire_down_observed,
            "rolled-up downlink accounting must equal observed socket bytes"
        );
        println!(
            "sharded wire pin OK: up {} B, down {} B across 2 shard PS pools",
            report.wire_up_observed, report.wire_down_observed
        );
    }

    // PS-only cost at CIFAR scale (no compute backend in the loop):
    // selection + ages + aggregation for 6 clients at d=2.5M
    {
        use ragek::age::AgeVector;
        use ragek::coordinator::aggregator::Aggregate;
        use ragek::coordinator::selection::select_disjoint;
        use ragek::sparse::{topk_abs_sparse, SparseVec};
        use ragek::util::rng::Rng;
        let (d, r, k, n) = (2_515_338usize, 2500usize, 100usize, 6usize);
        let mut rng = Rng::new(1);
        let mut grads = Vec::new();
        for _ in 0..n {
            let mut g = vec![0.0f32; d];
            rng.fill_gaussian(&mut g, 1.0);
            grads.push(g);
        }
        let reports: Vec<SparseVec> =
            grads.iter().map(|g| topk_abs_sparse(g, r)).collect();
        let mut age = AgeVector::new(d);
        b.run(&format!("PS round (no compute) cifar-scale d=2.5M n={n}"), || {
            // selection (3 pairs, disjoint within pair)
            let mut requested: Vec<Vec<u32>> = Vec::new();
            for p in 0..n / 2 {
                let rs: Vec<&[u32]> =
                    vec![&reports[2 * p].idx, &reports[2 * p + 1].idx];
                requested.extend(select_disjoint(&age, &rs, k));
            }
            // uploads + aggregation
            let mut agg = Aggregate::new();
            for (req, rep) in requested.iter().zip(&reports) {
                agg.push(ragek::fl::client::Client::answer_request(rep, req));
            }
            let update = agg.to_dense(d, 1.0 / n as f32);
            std::hint::black_box(&update);
            // eq. (2) — now O(k) lazy instead of the d-dimensional sweep
            let mut union: Vec<u32> = requested.iter().flatten().cloned().collect();
            union.sort_unstable();
            union.dedup();
            age.update(&union);
        });
    }
    b.save();
    Ok(())
}
