//! Clustering-pipeline cost: eq. (3) similarity matrix + DBSCAN over
//! growing client populations (the PS pays this every M rounds).

use ragek::age::FrequencyVector;
use ragek::bench::Bench;
use ragek::clustering::{connectivity_matrix, dbscan, distance_matrix, DbscanParams};
use ragek::util::rng::Rng;

fn freqs(n_clients: usize, rounds: usize, seed: u64) -> Vec<FrequencyVector> {
    let mut rng = Rng::new(seed);
    (0..n_clients)
        .map(|i| {
            let mut f = FrequencyVector::new();
            // pair-structured supports: clients 2p, 2p+1 share a band
            let base = (i / 2) * 500;
            for _ in 0..rounds {
                let idx: Vec<u32> =
                    (0..10).map(|_| (base + rng.below(500)) as u32).collect();
                f.record(&idx);
            }
            f
        })
        .collect()
}

fn main() {
    let mut b = Bench::new("dbscan");
    for n in [10usize, 50, 200] {
        let fv = freqs(n, 100, 7);
        b.run_units(&format!("connectivity (eq.3)  n={n:>3}"), Some((n * n) as f64), || {
            std::hint::black_box(connectivity_matrix(&fv));
        });
        let conn = connectivity_matrix(&fv);
        b.run(&format!("distance+dbscan      n={n:>3}"), || {
            let dist = distance_matrix(&conn);
            std::hint::black_box(dbscan(&dist, DbscanParams::default()));
        });
        b.run(&format!("full recluster pass  n={n:>3}"), || {
            let c = connectivity_matrix(&fv);
            let dist = distance_matrix(&c);
            std::hint::black_box(dbscan(&dist, DbscanParams::default()));
        });
    }
    b.save();
}
