//! PJRT dispatch cost: per-call latency of each MNIST artifact (the
//! request-path budget of the XLA backend) + the local_round
//! amortization that motivates the lax.scan export. Skips without
//! artifacts; needs the `xla-runtime` cargo feature (PJRT bindings).

#[cfg(feature = "xla-runtime")]
use ragek::bench::Bench;
#[cfg(feature = "xla-runtime")]
use ragek::runtime::{lit_f32, lit_i32, lit_scalar, Runtime};
#[cfg(feature = "xla-runtime")]
use ragek::util::rng::Rng;

#[cfg(not(feature = "xla-runtime"))]
fn main() {
    println!("bench_runtime: built without the `xla-runtime` feature; skipping");
}

#[cfg(feature = "xla-runtime")]
fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("bench_runtime: artifacts/ not built (run `make artifacts`); skipping");
        return Ok(());
    }
    let rt = Runtime::load("artifacts", "mnist")?;
    let m = rt.model().clone();
    let (d, bsz, hs, idim) = (m.d, m.batch, m.h_scan, m.input_dim);
    let mut rng = Rng::new(0);

    let params = rt.init_params()?;
    let zeros = vec![0.0f32; d];
    let mut x1 = vec![0.0f32; bsz * idim];
    rng.fill_gaussian(&mut x1, 0.5);
    let y1: Vec<i32> = (0..bsz).map(|i| (i % 10) as i32).collect();
    let mut xh = vec![0.0f32; hs * bsz * idim];
    rng.fill_gaussian(&mut xh, 0.5);
    let yh: Vec<i32> = (0..hs * bsz).map(|i| (i % 10) as i32).collect();

    let mut b = Bench::new("runtime");
    b.run(&format!("eval_batch        (b={bsz})"), || {
        rt.call(
            "eval_batch",
            &[
                lit_f32(&params, &[d as i64]).unwrap(),
                lit_f32(&x1, &[bsz as i64, idim as i64]).unwrap(),
                lit_i32(&y1, &[bsz as i64]).unwrap(),
            ],
        )
        .unwrap();
    });
    b.run(&format!("train_step        (b={bsz})"), || {
        rt.call(
            "train_step",
            &[
                lit_f32(&params, &[d as i64]).unwrap(),
                lit_f32(&zeros, &[d as i64]).unwrap(),
                lit_f32(&zeros, &[d as i64]).unwrap(),
                lit_scalar(0.0),
                lit_f32(&x1, &[bsz as i64, idim as i64]).unwrap(),
                lit_i32(&y1, &[bsz as i64]).unwrap(),
            ],
        )
        .unwrap();
    });
    b.run(&format!("local_round       (H={hs}, 1 dispatch)"), || {
        rt.call(
            "local_round",
            &[
                lit_f32(&params, &[d as i64]).unwrap(),
                lit_f32(&zeros, &[d as i64]).unwrap(),
                lit_f32(&zeros, &[d as i64]).unwrap(),
                lit_scalar(0.0),
                lit_f32(&xh, &[hs as i64, bsz as i64, idim as i64]).unwrap(),
                lit_i32(&yh, &[hs as i64, bsz as i64]).unwrap(),
            ],
        )
        .unwrap();
    });
    let ktot = m.k_total;
    let idx = vec![0i32; ktot];
    let vals = vec![0.0f32; ktot];
    b.run(&format!("apply_sparse      (K={ktot})"), || {
        rt.call(
            "apply_sparse",
            &[
                lit_f32(&params, &[d as i64]).unwrap(),
                lit_f32(&zeros, &[d as i64]).unwrap(),
                lit_f32(&zeros, &[d as i64]).unwrap(),
                lit_scalar(0.0),
                lit_i32(&idx, &[ktot as i64]).unwrap(),
                lit_f32(&vals, &[ktot as i64]).unwrap(),
            ],
        )
        .unwrap();
    });
    let mut grad = vec![0.0f32; d];
    rng.fill_gaussian(&mut grad, 1.0);
    let age = vec![3i32; d];
    b.run("ragek_select      (fused Alg. 2)", || {
        rt.call(
            "ragek_select",
            &[
                lit_f32(&grad, &[d as i64]).unwrap(),
                lit_i32(&age, &[d as i64]).unwrap(),
            ],
        )
        .unwrap();
    });
    b.save();
    println!("\nper-artifact cumulative profile:\n{}", rt.stats.report());
    Ok(())
}
