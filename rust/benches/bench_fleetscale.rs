//! Fleet-scale simulation perf: compact client state machines at
//! n = 10³ / 10⁴ / 10⁵ simulated clients (DESIGN.md §12).
//!
//! Builds a [`ragek::fl::CompactPool`] per scale — every client a
//! zero-float `Fresh` slot viewing an `Arc`-shared corpus — and drives
//! real engine rounds with a fixed 32-member cohort under the age-debt
//! scheduler, so the O(n) paths (scheduling, ages, fleet bookkeeping)
//! and the O(cohort) paths (training, materialization) are both on the
//! clock. Reports construction time, rounds/sec, resident model bytes
//! per client (deterministic, via `resident_client_floats`) and the
//! process RSS peak — the committed `BENCH_fleetscale.json` baseline.
//!
//! Hard gate: at n = 10⁵ the per-client resident footprint must be at
//! least 10x below the dense pool's analytic 3·d·4 bytes/client (in
//! practice it is ~1000x: only ever-scheduled clients hold floats).

use ragek::bench::Bench;
use ragek::config::ExperimentConfig;
use ragek::coordinator::engine::RoundEngine;
use ragek::coordinator::scheduler::SchedulerKind;
use ragek::data::{load_dataset, partition::Scheme, Shard};
use ragek::fl::CompactPool;
use ragek::util::timer::peak_rss_bytes;
use std::sync::Arc;

const ROUNDS: usize = 2;
const COHORT: usize = 32;
/// shared synthetic corpus rows; clients view 2 rows each, modularly
const CORPUS_ROWS: usize = 512;

fn scenario(n: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist_scaled();
    cfg.n_clients = n;
    cfg.participation = COHORT as f64 / n as f64;
    cfg.scheduler = SchedulerKind::AgeDebt;
    cfg.partition = Scheme::Iid; // shards are built directly below
    cfg.parallel = 1;
    cfg.rounds = ROUNDS;
    cfg.recluster_every = ROUNDS; // one recluster lands inside the run
    cfg.h = 1;
    cfg.batch = 16;
    cfg.r = 40;
    cfg.k = 8;
    cfg.eval_every = 0;
    cfg.train_n = CORPUS_ROWS;
    cfg.test_n = 64;
    cfg
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("fleetscale");
    let base = scenario(1000);
    let (corpus, _) =
        load_dataset(base.corpus, &base.data_dir, base.seed, base.train_n, base.test_n);
    let corpus = Arc::new(corpus);
    let d = base.d();
    let dense_bytes_per_client = 3.0 * d as f64 * 4.0;

    println!(
        "\ncompact fleet, {ROUNDS} rounds, cohort {COHORT}, age-debt scheduler \
         (dense analytic: {:.0} KB/client):",
        dense_bytes_per_client / 1024.0
    );
    println!(
        "{:<10} {:>12} {:>10} {:>16} {:>14}",
        "n", "rounds/sec", "live", "bytes/client", "peak RSS MB"
    );

    for n in [1_000usize, 10_000, 100_000] {
        let cfg = scenario(n);
        assert_eq!(cfg.cohort_size(), COHORT, "participation must pin a {COHORT}-cohort");
        let rows = corpus.len() as u32;
        let shards: Vec<Shard> = (0..n as u32)
            .map(|i| Shard::view(corpus.clone(), vec![(2 * i) % rows, (2 * i + 1) % rows]))
            .collect();

        let mut built = None;
        b.run_once(&format!("construct compact pool n={n}"), || {
            built = Some(CompactPool::new(&cfg, shards).unwrap());
        });
        let (mut pool, init) = built.expect("pool constructed");
        assert_eq!(pool.resident_client_floats(), 0, "fresh fleets hold zero model floats");

        let mut engine = RoundEngine::new(&cfg, init);
        let mean = b
            .run_once(&format!("{ROUNDS} rounds n={n}, cohort {COHORT}"), || {
                for _ in 0..ROUNDS {
                    engine.run_round(&mut pool).unwrap();
                }
            })
            .mean();

        assert_eq!(engine.round(), ROUNDS, "every round must commit at n={n}");
        assert!(
            pool.n_live() >= COHORT && pool.n_live() <= ROUNDS * COHORT,
            "only scheduled clients materialize: {} live at n={n}",
            pool.n_live()
        );
        let per_client = pool.resident_client_floats() as f64 * 4.0 / n as f64;
        let rss_mb = peak_rss_bytes().map(|x| x as f64 / (1024.0 * 1024.0));
        println!(
            "{n:<10} {:>12.2} {:>10} {:>16.1} {:>14}",
            ROUNDS as f64 / mean,
            pool.n_live(),
            per_client,
            rss_mb.map(|x| format!("{x:.1}")).unwrap_or_else(|| "n/a".into())
        );
        if n == 100_000 {
            // the acceptance gate: >= 10x below dense per-client state
            assert!(
                per_client * 10.0 <= dense_bytes_per_client,
                "fleet-scale footprint regressed: {per_client:.1} B/client vs \
                 dense {dense_bytes_per_client:.0} B/client"
            );
        }
    }

    b.save();
    Ok(())
}
